#!/usr/bin/env python3
"""Validate an `afd --trace-out` Chrome trace-event JSON file.

Checks the shape Perfetto / chrome://tracing require plus the afd
contract: complete ("X") events carry name/cat/ts/dur/pid/tid, every
track is named by a thread_name metadata event, the core pipeline
stages all appear, and the embedded afd_stats dump is present and
consistent. Stdlib only; exits non-zero with a message on any failure.

Usage: check_trace.py TRACE.json [--require-stage NAME ...]
"""

import json
import sys

REQUIRED_STAGES = {
    "train",
    "codec_encode",
    "codec_decode",
    "frame_encode",
    "frame_parse",
    "shard_aggregate",
}

VALID_PH = {"X", "M", "i"}


def fail(msg):
    print(f"check_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    args = sys.argv[1:]
    if not args:
        fail("usage: check_trace.py TRACE.json [--require-stage NAME ...]")
    path = args[0]
    required = set(REQUIRED_STAGES)
    it = iter(args[1:])
    for a in it:
        if a == "--require-stage":
            required.add(next(it, "") or fail("--require-stage needs a name"))
        else:
            fail(f"unknown argument {a!r}")

    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{path}: {e}")

    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail("traceEvents missing, not a list, or empty")

    named_tracks = set()
    used_tracks = set()
    span_names = set()
    x_events = 0
    for n, ev in enumerate(events):
        if not isinstance(ev, dict):
            fail(f"event {n} is not an object")
        ph = ev.get("ph")
        if ph not in VALID_PH:
            fail(f"event {n}: unexpected ph {ph!r}")
        if ph == "M":
            if ev.get("name") == "thread_name":
                if "tid" not in ev:
                    fail(f"event {n}: thread_name without tid")
                if not ev.get("args", {}).get("name"):
                    fail(f"event {n}: thread_name without args.name")
                named_tracks.add(ev["tid"])
            continue
        if ph == "X":
            x_events += 1
            for k in ("name", "cat", "ts", "dur", "pid", "tid"):
                if k not in ev:
                    fail(f"event {n}: X event missing {k!r}")
            if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
                fail(f"event {n}: bad ts {ev['ts']!r}")
            if not isinstance(ev["dur"], (int, float)) or ev["dur"] < 0:
                fail(f"event {n}: bad dur {ev['dur']!r}")
            span_names.add(ev["name"])
            used_tracks.add(ev["tid"])
        elif ph == "i":
            if "name" not in ev or "ts" not in ev:
                fail(f"event {n}: instant event missing name/ts")
            span_names.add(ev["name"])

    if x_events == 0:
        fail("no complete (ph=X) span events recorded")
    missing = required - span_names
    if missing:
        fail(f"required stages absent from trace: {sorted(missing)}")
    unnamed = used_tracks - named_tracks
    if unnamed:
        fail(f"tracks used by spans but never named: {sorted(unnamed)}")

    stats = doc.get("afd_stats")
    if not isinstance(stats, dict):
        fail("afd_stats missing from trace document")
    for key in ("counters", "frames", "stages", "spans"):
        if key not in stats:
            fail(f"afd_stats missing {key!r}")
    recorded = stats["spans"].get("recorded", 0)
    if recorded <= 0:
        fail("afd_stats.spans.recorded is zero in a traced run")

    print(
        f"check_trace: OK — {x_events} spans over {len(used_tracks)} tracks, "
        f"{len(span_names)} distinct names, stats embedded"
    )


if __name__ == "__main__":
    main()
