#!/usr/bin/env python3
"""Validate an `afd --trace-out` Chrome trace-event JSON file.

Checks the shape Perfetto / chrome://tracing require plus the afd
contract: complete ("X") events carry name/cat/ts/dur/pid/tid, every
track is named by a thread_name metadata event, the core pipeline
stages all appear, and the embedded afd_stats dump is present and
consistent. For merged distributed traces it can additionally require
a minimum number of process tracks (coordinator + remote client
processes), specific instant events (faults, checkpoints, resumes),
and remote counter totals in the embedded stats. Nonzero span-ring
drop counts are reported as warnings. Stdlib only; exits non-zero
with a message on any failure.

Usage: check_trace.py TRACE.json [--require-stage NAME ...]
           [--require-instant NAME ...] [--min-process-tracks N]
           [--min-remote-procs N]
"""

import json
import sys

REQUIRED_STAGES = {
    "train",
    "codec_encode",
    "codec_decode",
    "frame_encode",
    "frame_parse",
    "shard_aggregate",
}

VALID_PH = {"X", "M", "i"}


def fail(msg):
    print(f"check_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def warn(msg):
    print(f"check_trace: WARN: {msg}", file=sys.stderr)


def main():
    args = sys.argv[1:]
    if not args:
        fail(
            "usage: check_trace.py TRACE.json [--require-stage NAME ...] "
            "[--require-instant NAME ...] [--min-process-tracks N] "
            "[--min-remote-procs N]"
        )
    path = args[0]
    required = set(REQUIRED_STAGES)
    required_instants = set()
    min_process_tracks = 0
    min_remote_procs = 0
    it = iter(args[1:])
    for a in it:
        if a == "--require-stage":
            required.add(next(it, "") or fail("--require-stage needs a name"))
        elif a == "--require-instant":
            required_instants.add(
                next(it, "") or fail("--require-instant needs a name")
            )
        elif a == "--min-process-tracks":
            min_process_tracks = int(next(it, "0"))
        elif a == "--min-remote-procs":
            min_remote_procs = int(next(it, "0"))
        else:
            fail(f"unknown argument {a!r}")

    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{path}: {e}")

    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail("traceEvents missing, not a list, or empty")

    named_tracks = set()  # (pid, tid) named by a thread_name event
    used_tracks = set()  # (pid, tid) carrying at least one span
    process_tracks = {}  # pid -> process name
    span_names = set()
    instant_names = set()
    x_events = 0
    for n, ev in enumerate(events):
        if not isinstance(ev, dict):
            fail(f"event {n} is not an object")
        ph = ev.get("ph")
        if ph not in VALID_PH:
            fail(f"event {n}: unexpected ph {ph!r}")
        if ph == "M":
            if ev.get("name") == "thread_name":
                if "tid" not in ev:
                    fail(f"event {n}: thread_name without tid")
                if not ev.get("args", {}).get("name"):
                    fail(f"event {n}: thread_name without args.name")
                named_tracks.add((ev.get("pid"), ev["tid"]))
            elif ev.get("name") == "process_name":
                if "pid" not in ev:
                    fail(f"event {n}: process_name without pid")
                pname = ev.get("args", {}).get("name")
                if not pname:
                    fail(f"event {n}: process_name without args.name")
                process_tracks[ev["pid"]] = pname
            continue
        if ph == "X":
            x_events += 1
            for k in ("name", "cat", "ts", "dur", "pid", "tid"):
                if k not in ev:
                    fail(f"event {n}: X event missing {k!r}")
            if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
                fail(f"event {n}: bad ts {ev['ts']!r}")
            if not isinstance(ev["dur"], (int, float)) or ev["dur"] < 0:
                fail(f"event {n}: bad dur {ev['dur']!r}")
            span_names.add(ev["name"])
            used_tracks.add((ev.get("pid"), ev["tid"]))
        elif ph == "i":
            if "name" not in ev or "ts" not in ev:
                fail(f"event {n}: instant event missing name/ts")
            span_names.add(ev["name"])
            instant_names.add(ev["name"])

    if x_events == 0:
        fail("no complete (ph=X) span events recorded")
    missing = required - span_names
    if missing:
        fail(f"required stages absent from trace: {sorted(missing)}")
    missing_i = required_instants - instant_names
    if missing_i:
        fail(f"required instant events absent from trace: {sorted(missing_i)}")
    unnamed = used_tracks - named_tracks
    if unnamed:
        fail(f"tracks used by spans but never named: {sorted(unnamed)}")
    if len(process_tracks) < min_process_tracks:
        fail(
            f"only {len(process_tracks)} named process track(s) "
            f"({sorted(process_tracks.values())}), need {min_process_tracks}"
        )

    stats = doc.get("afd_stats")
    if not isinstance(stats, dict):
        fail("afd_stats missing from trace document")
    for key in ("counters", "frames", "stages", "spans"):
        if key not in stats:
            fail(f"afd_stats missing {key!r}")
    recorded = stats["spans"].get("recorded", 0)
    if recorded <= 0:
        fail("afd_stats.spans.recorded is zero in a traced run")

    # Span-ring pressure is legal but lossy — surface it.
    dropped = stats["spans"].get("dropped", 0)
    if dropped:
        warn(f"{dropped:.0f} local span record(s) overwritten before export")
    tele_dropped = stats["counters"].get("telemetry_spans_dropped", 0)
    if tele_dropped:
        warn(f"{tele_dropped:.0f} shipped span(s) dropped at the merge cap")

    remote = stats.get("remote", {})
    if min_remote_procs:
        if len(remote) < min_remote_procs:
            fail(
                f"afd_stats.remote has {len(remote)} process(es) "
                f"({sorted(remote)}), need {min_remote_procs}"
            )
        for name, r in remote.items():
            if r.get("frames", 0) <= 0:
                fail(f"remote process {name!r} shipped no telemetry frames")
            if not r.get("counters"):
                fail(f"remote process {name!r} has no counter totals in stats")
        for name, r in remote.items():
            if r.get("ring_dropped", 0):
                warn(
                    f"remote {name!r}: {r['ring_dropped']:.0f} span record(s) "
                    "overwritten before shipping"
                )

    extra = ""
    if process_tracks:
        extra = f", {len(process_tracks)} process track(s)"
    if remote:
        extra += f", {len(remote)} remote proc(s) in stats"
    print(
        f"check_trace: OK — {x_events} spans over {len(used_tracks)} tracks, "
        f"{len(span_names)} distinct names, stats embedded" + extra
    )


if __name__ == "__main__":
    main()
