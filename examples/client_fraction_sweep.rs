//! Client-fraction sweep (paper Fig. 4): Multi-Model AFD vs plain FD as
//! the per-round participation fraction varies, non-IID.
//!
//!   cargo run --release --example client_fraction_sweep -- --rounds 30
//!
//! The paper's observation: with a small fraction each client is
//! selected too rarely for its score map to learn, so AFD degrades to
//! FD; at ~30% the score maps pay off. The *shape* to look for is the
//! AFD-FD accuracy gap growing with the fraction.

use afd::config::{Backend, ExperimentConfig, Preset};
use afd::coordinator::experiment::run_experiment;
use afd::util::cli::ArgSpec;
use afd::util::stats;

fn main() -> anyhow::Result<()> {
    let spec = ArgSpec::new("Fig. 4: accuracy vs client fraction (AFD vs FD)")
        .opt("rounds", "30", "federated rounds per point")
        .opt("clients", "20", "client population")
        .opt("seeds", "2", "seeds per point")
        .opt("fractions", "0.1,0.2,0.3,0.5", "comma-separated fractions")
        .flag("native", "use the artifact-free native backend");
    let args = spec
        .parse("client_fraction_sweep", std::env::args().skip(1))
        .map_err(|e| anyhow::anyhow!(e))?;

    let fractions: Vec<f64> = args
        .get("fractions")
        .unwrap()
        .split(',')
        .map(|s| s.trim().parse().unwrap())
        .collect();
    let seeds = args.usize("seeds").map_err(|e| anyhow::anyhow!(e))?;

    let mut base = ExperimentConfig::preset(Preset::FemnistSmallNonIid);
    if args.bool("native") {
        base = ExperimentConfig::preset(Preset::NativeSmoke);
        base.backend = Backend::Native;
        base.native_dims = (48, 64, 6);
        base.num_clients = 20;
    }
    base.rounds = args.usize("rounds").map_err(|e| anyhow::anyhow!(e))?;
    base.num_clients = args.usize("clients").map_err(|e| anyhow::anyhow!(e))?;
    base.eval_every = base.rounds.div_ceil(10);
    base.data.iid = false;

    println!("== Fig. 4: Top-1 accuracy vs fraction of clients per round ==");
    println!("{:<10} {:>14} {:>14} {:>10}", "fraction", "AFD (multi)", "FD", "gap");
    for &f in &fractions {
        let mut accs = (Vec::new(), Vec::new());
        for s in 0..seeds as u64 {
            for (is_afd, bucket) in [(true, &mut accs.0), (false, &mut accs.1)] {
                let mut cfg = base.clone();
                cfg.client_fraction = f;
                cfg.dropout = if is_afd { "afd_multi" } else { "fd" }.into();
                cfg.seed = s;
                let r = run_experiment(&cfg)?;
                bucket.push(r.best_accuracy());
            }
        }
        let (afd_m, fd_m) = (stats::mean(&accs.0), stats::mean(&accs.1));
        println!(
            "{:<10.2} {:>7.3} ±{:.3} {:>7.3} ±{:.3} {:>+9.3}",
            f,
            afd_m,
            stats::std(&accs.0),
            fd_m,
            stats::std(&accs.1),
            afd_m - fd_m
        );
    }
    println!("\nexpected shape: the AFD−FD gap grows with the fraction (paper Fig. 4).");
    Ok(())
}
