//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Trains the FEMNIST CNN through the full three-layer stack — Rust
//! coordinator → PJRT CPU → AOT-lowered JAX model → Pallas matmul
//! kernels — for a few hundred federated rounds on the synthetic
//! non-IID corpus, logging the loss/accuracy curve and writing the
//! per-round records to `e2e_records.jsonl`.
//!
//!   cargo run --release --example e2e_training -- --rounds 200
//!
//! This is the "prove all layers compose" run: real optimization on a
//! real (synthetic-LEAF) workload, with the paper's full AFD + 8-bit
//! Hadamard + DGC pipeline on the wire.

use afd::config::{ExperimentConfig, Preset};
use afd::coordinator::experiment::Experiment;
use afd::util::cli::ArgSpec;
use afd::util::json::Json;
use afd::util::logging::JsonlSink;

fn main() -> anyhow::Result<()> {
    let spec = ArgSpec::new("AFD end-to-end training driver")
        .opt("rounds", "200", "federated rounds")
        .opt("clients", "20", "client population")
        .opt("seed", "0", "rng seed")
        .opt("out", "e2e_records.jsonl", "records output path");
    let args = spec
        .parse("e2e_training", std::env::args().skip(1))
        .map_err(|e| anyhow::anyhow!(e))?;

    let mut cfg = ExperimentConfig::preset(Preset::FemnistSmallNonIid);
    cfg.rounds = args.usize("rounds").map_err(|e| anyhow::anyhow!(e))?;
    cfg.num_clients = args.usize("clients").map_err(|e| anyhow::anyhow!(e))?;
    cfg.seed = args.u64("seed").map_err(|e| anyhow::anyhow!(e))?;
    cfg.eval_every = 5;
    cfg.eval_batch_limit = Some(20);
    cfg.data.samples_per_client = (60, 140);

    println!("== AFD end-to-end training ==");
    println!(
        "stack: rust coordinator -> PJRT CPU -> JAX train artifact -> Pallas kernels"
    );
    println!(
        "workload: {} | {} clients ({} per round) | {} rounds | AFD fdr={} + quant8 + DGC",
        cfg.variant,
        cfg.num_clients,
        cfg.cohort_size(),
        cfg.rounds,
        cfg.fdr
    );

    let out_path = args.get("out").unwrap().to_string();
    let sink = JsonlSink::create(std::path::Path::new(&out_path))?;

    let wall = std::time::Instant::now();
    let mut exp = Experiment::build(&cfg)?;
    println!("\nround  sim-time    train-loss  test-acc  keep%  down       up");
    let mut curve = Vec::new();
    for round in 1..=cfg.rounds {
        let rec = exp.step(round)?;
        let mut j = rec.to_json();
        j.set("wall_s", Json::Num(wall.elapsed().as_secs_f64()));
        sink.write(&j);
        if let Some(acc) = rec.eval_acc {
            println!(
                "{:>5}  {:>9}  {:>10.4}  {:>8.3}  {:>4.0}%  {:>9}  {:>9}",
                rec.round,
                afd::util::human_duration(rec.cum_s),
                rec.train_loss,
                acc,
                rec.keep_fraction * 100.0,
                afd::util::human_bytes(rec.down_bytes),
                afd::util::human_bytes(rec.up_bytes),
            );
            curve.push((rec.round, rec.cum_s, rec.train_loss, acc));
        }
    }

    // Summary + basic sanity the run actually learned.
    let first_acc = curve.first().map(|c| c.3).unwrap_or(0.0);
    let best_acc = curve.iter().map(|c| c.3).fold(0.0f64, f64::max);
    let last_loss = curve.last().map(|c| c.2).unwrap_or(f64::NAN);
    println!(
        "\nwall-clock {:.1}s | first acc {:.3} -> best {:.3} | final loss {:.4}",
        wall.elapsed().as_secs_f64(),
        first_acc,
        best_acc,
        last_loss
    );
    println!("records written to {out_path}");
    anyhow::ensure!(
        best_acc > first_acc + 0.1,
        "e2e run failed to learn (first {first_acc}, best {best_acc})"
    );
    println!("E2E OK — all three layers compose and the model learns.");
    Ok(())
}
