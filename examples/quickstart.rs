//! Quickstart: the smallest end-to-end AFD run.
//!
//!   make artifacts && cargo run --release --example quickstart
//!
//! Loads the FEMNIST-small artifact, runs 20 federated rounds of
//! Multi-Model AFD with the paper's full compression stack (8-bit
//! Hadamard downlink + DGC uplink) and prints the accuracy curve and
//! simulated wall-clock cost.

use afd::config::{ExperimentConfig, Preset};
use afd::coordinator::experiment::run_experiment;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::preset(Preset::FemnistSmallNonIid);
    cfg.rounds = 20;
    cfg.num_clients = 15;
    cfg.eval_every = 2;
    cfg.seed = 0;

    println!("== AFD quickstart ==");
    println!(
        "variant={} dropout={} fdr={} downlink={} dgc={} clients={} ({}/round)",
        cfg.variant,
        cfg.dropout,
        cfg.fdr,
        cfg.downlink,
        cfg.uplink_dgc,
        cfg.num_clients,
        cfg.cohort_size()
    );

    let report = run_experiment(&cfg)?;
    println!("\nround  sim-time    train-loss  test-acc");
    for r in &report.records {
        if let Some(acc) = r.eval_acc {
            println!(
                "{:>5}  {:>9}  {:>10.4}  {:>8.3}",
                r.round,
                afd::util::human_duration(r.cum_s),
                r.train_loss,
                acc
            );
        }
    }
    println!(
        "\nbest accuracy {:.1}%  |  simulated time {}  |  downlink {}  uplink {}",
        report.best_accuracy() * 100.0,
        afd::util::human_duration(report.total_sim_seconds()),
        afd::util::human_bytes(report.total_down_bytes()),
        afd::util::human_bytes(report.total_up_bytes()),
    );
    Ok(())
}
