//! FDR ablation: the paper's only hyper-parameter.
//!
//! "The FDR parameter should be set empirically between 10% and 50%,
//! taking into consideration the scale of the model. The higher FDR
//! values are often possible with larger models."
//!
//!   cargo run --release --example fdr_ablation -- --rounds 30
//!
//! Sweeps FDR ∈ {10%, 25%, 40%, 50%} for Multi-Model AFD on non-IID
//! FEMNIST and reports accuracy, downlink bytes and simulated
//! convergence time — the three quantities the FDR trades off.

use afd::config::{ExperimentConfig, Preset};
use afd::coordinator::experiment::run_experiment;
use afd::util::cli::ArgSpec;
use afd::util::stats;

fn main() -> anyhow::Result<()> {
    let spec = ArgSpec::new("FDR ablation (paper: set empirically in 10-50%)")
        .opt("rounds", "30", "federated rounds per point")
        .opt("clients", "12", "client population")
        .opt("seeds", "2", "seeds per point")
        .opt("fdrs", "0.1,0.25,0.4,0.5", "comma-separated FDR values");
    let args = spec
        .parse("fdr_ablation", std::env::args().skip(1))
        .map_err(|e| anyhow::anyhow!(e))?;
    let rounds = args.usize("rounds").map_err(|e| anyhow::anyhow!(e))?;
    let clients = args.usize("clients").map_err(|e| anyhow::anyhow!(e))?;
    let seeds = args.usize("seeds").map_err(|e| anyhow::anyhow!(e))?;
    let fdrs: Vec<f64> = args
        .get("fdrs")
        .unwrap()
        .split(',')
        .map(|s| s.trim().parse().unwrap())
        .collect();

    println!("== FDR ablation (Multi-Model AFD, non-IID FEMNIST) ==");
    println!(
        "{:<8} {:>16} {:>14} {:>14} {:>10}",
        "FDR", "best acc", "downlink", "sim time", "keep%"
    );
    for &fdr in &fdrs {
        let mut accs = Vec::new();
        let mut down = Vec::new();
        let mut time = Vec::new();
        let mut keep = Vec::new();
        for s in 0..seeds as u64 {
            let mut cfg = ExperimentConfig::preset(Preset::FemnistSmallNonIid);
            cfg.rounds = rounds;
            cfg.num_clients = clients;
            cfg.fdr = fdr;
            cfg.eval_every = (rounds / 10).max(1);
            cfg.seed = s;
            let r = run_experiment(&cfg)?;
            accs.push(r.best_accuracy());
            down.push(r.total_down_bytes() as f64);
            time.push(r.total_sim_seconds());
            keep.push(
                r.records.iter().map(|x| x.keep_fraction).sum::<f64>()
                    / r.records.len() as f64,
            );
        }
        println!(
            "{:<8.2} {:>9.3} ±{:.3} {:>14} {:>14} {:>9.0}%",
            fdr,
            stats::mean(&accs),
            stats::std(&accs),
            afd::util::human_bytes(stats::mean(&down) as u64),
            afd::util::human_duration(stats::mean(&time)),
            stats::mean(&keep) * 100.0
        );
    }
    println!(
        "\nexpected: downlink bytes fall with FDR; accuracy holds through the\n\
         paper's 10-50% band on this model scale, degrading at the top end."
    );
    Ok(())
}
