//! Sentiment140 IID with Single-Model AFD (Fig. 3 / Table 2 row 3).
//!
//!   cargo run --release --example sentiment140_iid -- --rounds 40
//!
//! The IID setting is where the paper deploys Single-Model AFD: one
//! global score map, one shared sub-model per round, updated from the
//! cohort's average loss. 10% of clients participate per round.

use afd::config::{ExperimentConfig, Preset};
use afd::coordinator::experiment::run_experiment;
use afd::metrics::{render_table, summarize};
use afd::util::cli::ArgSpec;

fn main() -> anyhow::Result<()> {
    let spec = ArgSpec::new("Sentiment140 IID, Single-Model AFD")
        .opt("rounds", "40", "federated rounds")
        .opt("clients", "20", "client population (users)")
        .opt("seeds", "1", "seeds per method")
        .opt("target", "0.75", "target accuracy");
    let args = spec
        .parse("sentiment140_iid", std::env::args().skip(1))
        .map_err(|e| anyhow::anyhow!(e))?;

    let mut base = ExperimentConfig::preset(Preset::Sent140SmallIid);
    base.rounds = args.usize("rounds").map_err(|e| anyhow::anyhow!(e))?;
    base.num_clients = args.usize("clients").map_err(|e| anyhow::anyhow!(e))?;
    base.target_accuracy = Some(args.f64("target").map_err(|e| anyhow::anyhow!(e))?);
    base.eval_every = 2;
    let seeds = args.usize("seeds").map_err(|e| anyhow::anyhow!(e))?;

    println!("== Sentiment140 IID (Single-Model AFD) ==");
    println!(
        "frozen GloVe-like embeddings are NOT transmitted (manifest transmit=false)"
    );

    let grid = ExperimentConfig::paper_method_grid(&base, "afd_single");
    let mut rows = Vec::new();
    for (label, cfg) in &grid {
        let mut reports = Vec::new();
        for s in 0..seeds as u64 {
            let mut c = cfg.clone();
            c.seed = base.seed + s;
            eprintln!("[sent140_iid] {label} seed {s} ...");
            reports.push(run_experiment(&c)?);
        }
        println!("\ncurve [{label}] (sim seconds, accuracy):");
        for (t, a) in reports[0].accuracy_curve() {
            println!("  {t:>10.1}  {a:.3}");
        }
        rows.push(summarize(label, &reports, base.target_accuracy));
    }
    println!(
        "{}",
        render_table("Sentiment140 IID (paper Table 2 row)", &rows)
    );
    Ok(())
}
