//! Shakespeare next-character prediction, non-IID roles (Table 1 row 2).
//!
//!   cargo run --release --example shakespeare_char -- --rounds 40
//!
//! Besides the federated comparison, this example samples text from the
//! trained global model to show the char-LSTM stack is real: greedy
//! generation runs through the same PJRT eval path.

use afd::config::{Backend, ExperimentConfig, Preset};
use afd::coordinator::experiment::{artifacts_dir, Experiment};
use afd::data::shakespeare::{char_to_class, class_to_char};
use afd::model::manifest::Manifest;
use afd::runtime::{pjrt::PjrtRuntime, BatchInput, EvalBatch, ModelRuntime};
use afd::util::cli::ArgSpec;

fn main() -> anyhow::Result<()> {
    let spec = ArgSpec::new("Shakespeare char-LSTM, non-IID roles")
        .opt("rounds", "40", "federated rounds")
        .opt("clients", "12", "client population (roles)")
        .opt("sample", "120", "chars of text to sample after training");
    let args = spec
        .parse("shakespeare_char", std::env::args().skip(1))
        .map_err(|e| anyhow::anyhow!(e))?;

    let mut cfg = ExperimentConfig::preset(Preset::ShakespeareSmallNonIid);
    cfg.backend = Backend::Pjrt;
    cfg.rounds = args.usize("rounds").map_err(|e| anyhow::anyhow!(e))?;
    cfg.num_clients = args.usize("clients").map_err(|e| anyhow::anyhow!(e))?;
    cfg.eval_every = 4;

    println!("== Shakespeare char-LSTM (non-IID roles) ==");
    let mut exp = Experiment::build(&cfg)?;
    for round in 1..=cfg.rounds {
        let rec = exp.step(round)?;
        if let Some(acc) = rec.eval_acc {
            println!(
                "round {:>4}  sim {:>9}  loss {:.4}  next-char acc {:.3}",
                round,
                afd::util::human_duration(rec.cum_s),
                rec.train_loss,
                acc
            );
        }
    }

    // ---- sample text from the trained global model -------------------
    let n_sample = args.usize("sample").map_err(|e| anyhow::anyhow!(e))?;
    let manifest = Manifest::load(&artifacts_dir())?;
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let rt = PjrtRuntime::load(&client, &manifest, &cfg.variant)?;
    let mspec = rt.spec().clone();
    let seq = mspec.input_shape[0];

    let seed_text = "to be or not to be";
    let mut ctx: Vec<i32> = seed_text.chars().map(|c| char_to_class(c) as i32).collect();
    let mut out = String::from(seed_text);
    // Greedy decode via the eval artifact: feed a batch whose first row
    // is the context; argmax is recovered from the correct-count trick —
    // instead we use eval loss over candidate labels. Simpler: use the
    // artifact's loss on each candidate class would be 53 evals; instead
    // run the train-free path: evaluate() returns only aggregates, so we
    // reuse the native trick: take the class with max count by probing.
    // Pragmatically: probe each candidate as the label of row 0 and pick
    // the one with the highest per-batch correct increment.
    for _ in 0..n_sample {
        let window: Vec<i32> = {
            let mut w = vec![52i32; seq.saturating_sub(ctx.len())];
            let tail: Vec<i32> =
                ctx.iter().rev().take(seq).rev().cloned().collect();
            w.extend(tail);
            w[w.len() - seq..].to_vec()
        };
        // Build a batch of identical windows; label row i with class i
        // (plus padding rows when classes > batch). The class whose
        // "correct" count comes back 1 is the argmax.
        let mut predicted = 52usize;
        'outer: for chunk_start in (0..mspec.classes).step_by(mspec.batch_size) {
            let mut xs = Vec::with_capacity(mspec.batch_size * seq);
            let mut ys = Vec::with_capacity(mspec.batch_size);
            for i in 0..mspec.batch_size {
                xs.extend_from_slice(&window);
                ys.push(((chunk_start + i) % mspec.classes) as i32);
            }
            let ev = rt.evaluate(
                &exp.global,
                &EvalBatch {
                    xs: BatchInput::I32(xs),
                    ys,
                },
            )?;
            if ev.correct > 0.0 {
                // One of this chunk's labels matched the argmax.
                for i in 0..mspec.batch_size {
                    let cand = (chunk_start + i) % mspec.classes;
                    let mut xs2 = Vec::with_capacity(mspec.batch_size * seq);
                    let mut ys2 = Vec::with_capacity(mspec.batch_size);
                    for _ in 0..mspec.batch_size {
                        xs2.extend_from_slice(&window);
                        ys2.push(cand as i32);
                    }
                    let ev2 = rt.evaluate(
                        &exp.global,
                        &EvalBatch {
                            xs: BatchInput::I32(xs2),
                            ys: ys2,
                        },
                    )?;
                    if ev2.correct as usize == mspec.batch_size {
                        predicted = cand;
                        break 'outer;
                    }
                }
            }
        }
        ctx.push(predicted as i32);
        out.push(class_to_char(predicted));
    }
    println!("\nsampled text (greedy):\n  {out}");
    Ok(())
}
