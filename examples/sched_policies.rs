//! Scheduler walkthrough: the same federated workload driven by the
//! three scheduling policies, artifact-free on the native backend.
//!
//!   cargo run --release --example sched_policies
//!   cargo run --release --example sched_policies -- --churn 0.7
//!
//! Prints each policy's per-eval accuracy/time curve and a closing
//! summary, optionally with availability churn (clients dropping
//! offline mid-round) enabled.

use afd::config::{ExperimentConfig, Preset};
use afd::coordinator::experiment::run_experiment;
use afd::network::LinkConfig;
use afd::util::cli::ArgSpec;
use afd::util::human_duration;

fn main() -> anyhow::Result<()> {
    let spec = ArgSpec::new("Compare scheduler policies on the native workload")
        .opt("rounds", "40", "federated rounds / aggregations")
        .opt("seed", "0", "rng seed")
        .opt_maybe("churn", "client availability in (0,1]: enables churn");
    let args = spec
        .parse("sched_policies", std::env::args().skip(1))
        .map_err(|e| anyhow::anyhow!(e))?;
    let rounds = args.usize("rounds").map_err(|e| anyhow::anyhow!(e))?;
    let seed = args.u64("seed").map_err(|e| anyhow::anyhow!(e))?;

    println!("== scheduler policies on straggler-heavy links ==\n");
    for policy in ["sync", "overselect", "async_buffered"] {
        let mut cfg = ExperimentConfig::preset(Preset::NativeSmoke);
        cfg.rounds = rounds;
        cfg.eval_every = (rounds / 8).max(1);
        cfg.seed = seed;
        cfg.link = LinkConfig::straggler_heavy();
        cfg.sched.policy = policy.into();
        if let Some(v) = args.get("churn") {
            cfg.sched.enable_churn(v.parse()?)?;
        }

        let r = run_experiment(&cfg)?;
        println!("[{policy}]");
        for rec in &r.records {
            if let Some(acc) = rec.eval_acc {
                println!(
                    "  round {:>3}  t={:>9}  acc {:.3}  arrived {}  cut {}  dropped {}",
                    rec.round,
                    human_duration(rec.cum_s),
                    acc,
                    rec.arrived,
                    rec.cut,
                    rec.dropped
                );
            }
        }
        println!(
            "  => best acc {:.3} in {} simulated\n",
            r.best_accuracy(),
            human_duration(r.total_sim_seconds())
        );
    }
    Ok(())
}
