//! FEMNIST non-IID comparison (the Fig. 2 / Table 1 FEMNIST row).
//!
//!   cargo run --release --example femnist_noniid -- --rounds 60 --seeds 2
//!
//! Runs the paper's four methods — No Compression, DGC, FD+DGC,
//! Multi-Model AFD+DGC — on the synthetic non-IID FEMNIST workload and
//! prints the accuracy curves plus the paper-style summary table.

use afd::config::{ExperimentConfig, Preset};
use afd::coordinator::experiment::run_experiment;
use afd::metrics::{render_table, summarize};
use afd::util::cli::ArgSpec;

fn main() -> anyhow::Result<()> {
    let spec = ArgSpec::new("FEMNIST non-IID: the paper's 4-method comparison")
        .opt("rounds", "50", "federated rounds per run")
        .opt("clients", "15", "client population")
        .opt("seeds", "1", "seeds per method")
        .opt("target", "0.60", "target accuracy for convergence time");
    let args = spec
        .parse("femnist_noniid", std::env::args().skip(1))
        .map_err(|e| anyhow::anyhow!(e))?;

    let mut base = ExperimentConfig::preset(Preset::FemnistSmallNonIid);
    base.rounds = args.usize("rounds").map_err(|e| anyhow::anyhow!(e))?;
    base.num_clients = args.usize("clients").map_err(|e| anyhow::anyhow!(e))?;
    base.target_accuracy = Some(args.f64("target").map_err(|e| anyhow::anyhow!(e))?);
    base.eval_every = 2;
    let seeds = args.usize("seeds").map_err(|e| anyhow::anyhow!(e))?;

    let grid = ExperimentConfig::paper_method_grid(&base, "afd_multi");
    let mut rows = Vec::new();
    for (label, cfg) in &grid {
        let mut reports = Vec::new();
        for s in 0..seeds as u64 {
            let mut c = cfg.clone();
            c.seed = base.seed + s;
            eprintln!("[femnist_noniid] {label} seed {s} ...");
            let r = run_experiment(&c)?;
            eprintln!(
                "  best acc {:.3} | sim {} | down {}",
                r.best_accuracy(),
                afd::util::human_duration(r.total_sim_seconds()),
                afd::util::human_bytes(r.total_down_bytes())
            );
            reports.push(r);
        }
        // Print one accuracy-vs-simulated-time curve per method (Fig. 2).
        println!("\ncurve [{label}] (sim seconds, accuracy):");
        for (t, a) in reports[0].accuracy_curve() {
            println!("  {t:>10.1}  {a:.3}");
        }
        rows.push(summarize(label, &reports, base.target_accuracy));
    }
    println!(
        "{}",
        render_table(
            &format!(
                "FEMNIST non-IID (paper Table 1 row; target {:.0}%)",
                base.target_accuracy.unwrap() * 100.0
            ),
            &rows
        )
    );
    Ok(())
}
