"""Model/dataset variant registry shared by model.py, aot.py and the tests.

A *variant* pins every shape the AOT pipeline needs: the model
architecture (paper-scale or a CPU-budget `small` scale), the federated
round geometry (batch size, batches per local epoch) and the
paper's grid-searched learning rate. The Rust coordinator discovers all
of this through ``artifacts/manifest.json`` — nothing here is duplicated
on the Rust side.

Paper setups (Experimental Setup §):
  * FEMNIST   — CNN: 2×conv5x5 (32, 64) + 2×2 maxpool each, dense 2048,
                softmax 62; lr 0.004.
  * Shakespeare — 8-d embedding → 2×LSTM-256 → dense-53, seq 80; lr 0.08.
  * Sent140   — frozen 300-d GloVe → 2×LSTM-100 → dense-2, seq 25; lr 0.001.
  * batch size 10, one local epoch per round.

`small` variants shrink widths/sequence lengths so that the full
federated simulation (hundreds of rounds × tens of clients) runs in
CPU-PJRT budget; the *structure* (layer types, mask groups, packing
rules) is identical, which is what the reproduction's claims rest on.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class CnnCfg:
    image: int = 28
    channels: int = 1
    conv1: int = 32
    conv2: int = 64
    kernel: int = 5
    dense: int = 2048
    classes: int = 62


@dataclasses.dataclass(frozen=True)
class LstmCfg:
    vocab: int = 53
    embed: int = 8
    hidden: int = 256
    layers: int = 2
    seq: int = 80
    classes: int = 53
    frozen_embed: bool = False


@dataclasses.dataclass(frozen=True)
class Variant:
    name: str
    kind: str                 # "cnn" | "lstm"
    dataset: str              # "femnist" | "shakespeare" | "sent140"
    cfg: object
    lr: float
    batch_size: int = 10      # paper: B = 10
    num_batches: int = 5      # batches per local epoch (fixed per artifact)

    @property
    def samples_per_round(self) -> int:
        return self.batch_size * self.num_batches


VARIANTS: dict[str, Variant] = {}


def _register(v: Variant) -> Variant:
    VARIANTS[v.name] = v
    return v


# ----------------------------------------------------------------- FEMNIST
_register(
    Variant(
        name="femnist_small",
        kind="cnn",
        dataset="femnist",
        cfg=CnnCfg(image=28, conv1=8, conv2=16, dense=128, classes=10),
        lr=0.02,  # smaller model trains best slightly hotter; grid-searched
    )
)
_register(
    Variant(
        name="femnist_paper",
        kind="cnn",
        dataset="femnist",
        cfg=CnnCfg(),  # paper shapes
        lr=0.004,
    )
)

# ------------------------------------------------------------- Shakespeare
_register(
    Variant(
        name="shakespeare_small",
        kind="lstm",
        dataset="shakespeare",
        cfg=LstmCfg(vocab=53, embed=8, hidden=64, layers=2, seq=20, classes=53),
        lr=0.3,  # char-LSTMs at this scale need a hot lr (paper used 0.08 @ 256)
        num_batches=10,  # LEAF shakespeare clients hold 100s of windows
    )
)
_register(
    Variant(
        name="shakespeare_paper",
        kind="lstm",
        dataset="shakespeare",
        cfg=LstmCfg(vocab=53, embed=8, hidden=256, layers=2, seq=80, classes=53),
        lr=0.08,
    )
)

# ---------------------------------------------------------------- Sent140
_register(
    Variant(
        name="sent140_small",
        kind="lstm",
        dataset="sent140",
        cfg=LstmCfg(
            vocab=2000, embed=50, hidden=32, layers=2, seq=25, classes=2,
            frozen_embed=True,
        ),
        lr=0.2,
        num_batches=10,
    )
)
_register(
    Variant(
        name="sent140_paper",
        kind="lstm",
        dataset="sent140",
        cfg=LstmCfg(
            vocab=10000, embed=300, hidden=100, layers=2, seq=25, classes=2,
            frozen_embed=True,
        ),
        lr=0.001,
    )
)

# Variants lowered by default (`make artifacts`); paper-scale ones are
# produced with `python -m compile.aot --paper` and exist to prove the
# full-size models lower + to size the §Perf roofline estimates.
DEFAULT_VARIANTS = ("femnist_small", "shakespeare_small", "sent140_small")
PAPER_VARIANTS = ("femnist_paper", "shakespeare_paper", "sent140_paper")


def get(name: str) -> Variant:
    if name not in VARIANTS:
        raise KeyError(f"unknown variant {name!r}; have {sorted(VARIANTS)}")
    return VARIANTS[name]
