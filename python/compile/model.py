"""L2: the paper's models in JAX, every contraction through the L1 Pallas kernel.

Three models (Experimental Setup §):

* **FEMNIST CNN** — conv5x5(c1) → maxpool2 → conv5x5(c2) → maxpool2 →
  dense(d) → softmax head. Convolutions are lowered to **im2col + the
  Pallas masked matmul**, so AFD's filter masks reach the kernel as the
  matmul's output-unit mask.
* **Shakespeare char-LSTM** — embedding → 2×LSTM → dense head over the
  last hidden state. AFD masks apply to the **non-recurrent**
  connections only (the per-layer outputs flowing upward), preserving
  the recurrent memory path per Zaremba et al. '14 / the paper's RNN
  rule.
* **Sent140 LSTM** — frozen (GloVe-like) embedding → 2×LSTM → 2-class
  head; identical masking rule.

Masking semantics: a sub-model is the full model with 0/1 unit masks.
Dropped units output exactly 0 and every incident weight receives an
exactly-zero gradient (see kernels/matmul.py), so SGD on the masked
model ≡ SGD on the reduced architecture the server logically shipped.
`python/tests/test_mask_gradients.py` asserts this invariant.

The exported functions are *flat-argument* (params..., masks..., data)
so `aot.py` can lower them with a stable argument order recorded in the
manifest the Rust coordinator reads.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import matmul as mk
from .kernels import ref as kref
from .variants import CnnCfg, LstmCfg, Variant

Params = tuple  # tuple of jnp arrays, ordered per ParamSpec list
Masks = tuple   # tuple of jnp arrays, ordered per MaskSpec list


# --------------------------------------------------------------------------
# Specs: the single source of truth for parameter layout / packing metadata.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AxisPack:
    """How one axis of a parameter packs under a mask group.

    ``count`` units of group ``group`` tile this axis ``repeat`` times
    (e.g. the flattened conv features entering the CNN dense layer repeat
    each channel H*W times, channel-fastest). Packed axis length =
    kept(group) * repeat (+ ``fixed`` untouched rows, e.g. the embedding
    part of an LSTM input block).
    """

    group: str
    count: int
    repeat: int = 1
    fixed: int = 0


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple
    trainable: bool = True
    transmit: bool = True            # frozen GloVe embeddings are pre-shipped
    rows: AxisPack | None = None     # packing along axis 0
    cols: AxisPack | None = None     # packing along axis 1 (or 0 for biases)
    flops_per_sample: float = 0.0    # full-model MACs*2 attributed to this param

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))


@dataclasses.dataclass(frozen=True)
class MaskSpec:
    name: str
    size: int
    kind: str  # "conv_filters" | "dense_units" | "lstm_units"


@dataclasses.dataclass(frozen=True)
class ModelDef:
    variant: Variant
    params: tuple
    masks: tuple
    apply_fn: Callable  # (params, masks, x) -> logits
    input_shape: tuple  # one sample, e.g. (28, 28, 1) or (seq,) int32
    input_dtype: str    # "f32" | "i32"

    @property
    def num_params(self) -> int:
        return sum(p.size for p in self.params)


# --------------------------------------------------------------------------
# CNN (FEMNIST)
# --------------------------------------------------------------------------


def _im2col(x: jax.Array, k: int) -> jax.Array:
    """SAME-padded im2col: [B,H,W,C] -> [B*H*W, k*k*C] (dy,dx slow; C fast)."""
    b, h, w, c = x.shape
    p = k // 2
    xp = jnp.pad(x, ((0, 0), (p, p), (p, p), (0, 0)))
    cols = []
    for dy in range(k):
        for dx in range(k):
            cols.append(xp[:, dy : dy + h, dx : dx + w, :])
    patches = jnp.concatenate(cols, axis=-1)  # [B,H,W,k*k*C]
    return patches.reshape(b * h * w, k * k * c)


def _maxpool2(x: jax.Array) -> jax.Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _conv_pallas(x, w, b, mask, use_ref=False):
    """conv2d(SAME) = im2col + Pallas masked matmul; mask = filter mask."""
    bsz, h, ww, _ = x.shape
    k = w.shape[0]
    cout = w.shape[3]
    cols = _im2col(x, k)                       # [B*H*W, k*k*Cin]
    wr = w.reshape(-1, cout)                   # rows: (dy, dx, cin) — matches im2col
    f = kref.matmul_ref if use_ref else mk.matmul
    y = f(cols, wr, b, mask, "relu")
    return y.reshape(bsz, h, ww, cout)


def cnn_specs(cfg: CnnCfg) -> tuple[tuple, tuple]:
    k, c1, c2, d = cfg.kernel, cfg.conv1, cfg.conv2, cfg.dense
    img = cfg.image
    pooled = img // 4
    feat = pooled * pooled * c2
    # MACs*2 per sample (conv: per output pixel per filter k*k*cin*2)
    f_conv1 = 2.0 * img * img * c1 * k * k * cfg.channels
    f_conv2 = 2.0 * (img // 2) ** 2 * c2 * k * k * c1
    f_dense = 2.0 * feat * d
    f_head = 2.0 * d * cfg.classes
    params = (
        ParamSpec("conv1_w", (k, k, cfg.channels, c1),
                  cols=AxisPack("conv1", c1), flops_per_sample=f_conv1),
        ParamSpec("conv1_b", (c1,), cols=AxisPack("conv1", c1)),
        ParamSpec("conv2_w", (k, k, c1, c2),
                  rows=AxisPack("conv1", c1, repeat=k * k),
                  cols=AxisPack("conv2", c2), flops_per_sample=f_conv2),
        ParamSpec("conv2_b", (c2,), cols=AxisPack("conv2", c2)),
        ParamSpec("dense_w", (feat, d),
                  rows=AxisPack("conv2", c2, repeat=pooled * pooled),
                  cols=AxisPack("dense", d), flops_per_sample=f_dense),
        ParamSpec("dense_b", (d,), cols=AxisPack("dense", d)),
        # Output layer always kept intact (paper: input/output layers intact).
        ParamSpec("head_w", (d, cfg.classes),
                  rows=AxisPack("dense", d), flops_per_sample=f_head),
        ParamSpec("head_b", (cfg.classes,)),
    )
    masks = (
        MaskSpec("conv1", c1, "conv_filters"),
        MaskSpec("conv2", c2, "conv_filters"),
        MaskSpec("dense", d, "dense_units"),
    )
    return params, masks


def cnn_apply(cfg: CnnCfg, params: Params, masks: Masks, x: jax.Array,
              use_ref: bool = False) -> jax.Array:
    """x: [B, H, W, C] f32 -> logits [B, classes]."""
    c1w, c1b, c2w, c2b, dw, db, hw, hb = params
    m1, m2, md = masks
    f = kref.matmul_ref if use_ref else mk.matmul
    y = _conv_pallas(x, c1w, c1b, m1, use_ref)        # [B,H,W,c1]
    y = _maxpool2(y)
    y = _conv_pallas(y, c2w, c2b, m2, use_ref)        # [B,H/2,W/2,c2]
    y = _maxpool2(y)
    b = y.shape[0]
    y = y.reshape(b, -1)                              # channel-fastest flatten
    y = f(y, dw, db, md, "relu")                      # [B,d]
    ones = jnp.ones((hw.shape[1],), jnp.float32)
    return f(y, hw, hb, ones, "none")                 # logits


def cnn_init(cfg: CnnCfg, seed: int) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    k, c1, c2, d = cfg.kernel, cfg.conv1, cfg.conv2, cfg.dense
    feat = (cfg.image // 4) ** 2 * c2

    def glorot(shape, fan_in, fan_out):
        lim = np.sqrt(6.0 / (fan_in + fan_out))
        return rng.uniform(-lim, lim, size=shape).astype(np.float32)

    return [
        glorot((k, k, cfg.channels, c1), k * k * cfg.channels, c1),
        np.zeros((c1,), np.float32),
        glorot((k, k, c1, c2), k * k * c1, c2),
        np.zeros((c2,), np.float32),
        glorot((feat, d), feat, d),
        np.zeros((d,), np.float32),
        glorot((d, cfg.classes), d, cfg.classes),
        np.zeros((cfg.classes,), np.float32),
    ]


# --------------------------------------------------------------------------
# LSTM (Shakespeare / Sent140)
# --------------------------------------------------------------------------


def _lstm_layer(xs, w, b, hidden: int, use_ref: bool = False):
    """xs: [T, B, D] -> hs: [T, B, H]. Gates via the Pallas kernel.

    Gate order: i, f, g, o. Forget-gate bias +1 at init time (see
    lstm_init), not in the graph.
    """
    t, bsz, _ = xs.shape
    ones = jnp.ones((4 * hidden,), jnp.float32)
    f = kref.matmul_ref if use_ref else mk.matmul

    def step(carry, x_t):
        c, h = carry
        z = f(jnp.concatenate([x_t, h], axis=1), w, b, ones, "none")
        i, fg, g, o = jnp.split(z, 4, axis=1)
        c = jax.nn.sigmoid(i) * jnp.tanh(g) + jax.nn.sigmoid(fg) * c
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (c, h), h

    init = (
        jnp.zeros((bsz, hidden), jnp.float32),
        jnp.zeros((bsz, hidden), jnp.float32),
    )
    _, hs = jax.lax.scan(step, init, xs)
    return hs


def lstm_specs(cfg: LstmCfg) -> tuple[tuple, tuple]:
    h, e = cfg.hidden, cfg.embed
    # flops per sample: seq * (2*(D+H)*4H) per layer + head
    f_l1 = 2.0 * cfg.seq * (e + h) * 4 * h
    f_l2 = 2.0 * cfg.seq * (h + h) * 4 * h
    f_head = 2.0 * h * cfg.classes
    params = (
        ParamSpec("embed", (cfg.vocab, e),
                  trainable=not cfg.frozen_embed,
                  transmit=not cfg.frozen_embed),
        # Input block rows [0:D] = upward connections (maskable by the
        # *previous* layer's mask); rows [D:D+H] = recurrent, never masked.
        ParamSpec("lstm1_w", (e + h, 4 * h), flops_per_sample=f_l1),
        ParamSpec("lstm1_b", (4 * h,)),
        ParamSpec("lstm2_w", (h + h, 4 * h),
                  rows=AxisPack("lstm1", h, fixed=h), flops_per_sample=f_l2),
        ParamSpec("lstm2_b", (4 * h,)),
        ParamSpec("head_w", (h, cfg.classes),
                  rows=AxisPack("lstm2", h), flops_per_sample=f_head),
        ParamSpec("head_b", (cfg.classes,)),
    )
    masks = (
        MaskSpec("lstm1", h, "lstm_units"),
        MaskSpec("lstm2", h, "lstm_units"),
    )
    return params, masks


def lstm_apply(cfg: LstmCfg, params: Params, masks: Masks, x: jax.Array,
               use_ref: bool = False) -> jax.Array:
    """x: [B, T] int32 token ids -> logits [B, classes].

    Masks multiply each layer's *upward* output (non-recurrent
    connections only): the in-layer recurrence sees the unmasked h.
    """
    embed, w1, b1, w2, b2, hw, hb = params
    m1, m2 = masks
    f = kref.matmul_ref if use_ref else mk.matmul
    emb = jnp.take(embed, x, axis=0)           # [B,T,E]
    xs = jnp.transpose(emb, (1, 0, 2))         # [T,B,E]
    h1 = _lstm_layer(xs, w1, b1, cfg.hidden, use_ref)
    h1_up = h1 * m1[None, None, :]             # mask non-recurrent path
    h2 = _lstm_layer(h1_up, w2, b2, cfg.hidden, use_ref)
    last = h2[-1] * m2[None, :]
    ones = jnp.ones((hw.shape[1],), jnp.float32)
    return f(last, hw, hb, ones, "none")


def lstm_init(cfg: LstmCfg, seed: int) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    h, e = cfg.hidden, cfg.embed

    def glorot(shape, fan_in, fan_out):
        lim = np.sqrt(6.0 / (fan_in + fan_out))
        return rng.uniform(-lim, lim, size=shape).astype(np.float32)

    def gate_bias():
        b = np.zeros((4 * h,), np.float32)
        b[h : 2 * h] = 1.0  # forget-gate bias
        return b

    if cfg.frozen_embed:
        # Deterministic "pretrained GloVe-like" table: unit-norm gaussian
        # rows seeded independently of model init (ships with the app).
        #
        # Real GloVe vectors carry sentiment structure — that latent
        # signal is what makes the paper's frozen-embedding Sent140 model
        # trainable at all. We emulate it: token ids 1..20 (the positive
        # lexicon, by convention shared with the Rust data generator) get
        # a +µ component along a fixed latent axis, ids 21..40 (negative
        # lexicon) get −µ; everything else is unstructured. See
        # DESIGN.md §2 (Sent140 substitution).
        erng = np.random.default_rng(0x610E)  # "GlOvE"
        embed = erng.normal(size=(cfg.vocab, e)).astype(np.float32)
        axis = erng.normal(size=(e,)).astype(np.float32)
        axis /= np.linalg.norm(axis)
        mu = 2.0
        embed[1:21] += mu * axis
        embed[21:41] -= mu * axis
        embed /= np.maximum(np.linalg.norm(embed, axis=1, keepdims=True), 1e-6)
    else:
        embed = (rng.normal(size=(cfg.vocab, e)) * 0.1).astype(np.float32)
    return [
        embed,
        glorot((e + h, 4 * h), e + h, 4 * h),
        gate_bias(),
        glorot((h + h, 4 * h), 2 * h, 4 * h),
        gate_bias(),
        glorot((h, cfg.classes), h, cfg.classes),
        np.zeros((cfg.classes,), np.float32),
    ]


# --------------------------------------------------------------------------
# Model registry + train/eval step builders
# --------------------------------------------------------------------------


def build(variant: Variant, use_ref: bool = False) -> ModelDef:
    if variant.kind == "cnn":
        cfg = variant.cfg
        params, masks = cnn_specs(cfg)
        apply_fn = functools.partial(cnn_apply, cfg, use_ref=use_ref)
        input_shape = (cfg.image, cfg.image, cfg.channels)
        input_dtype = "f32"
    elif variant.kind == "lstm":
        cfg = variant.cfg
        params, masks = lstm_specs(cfg)
        apply_fn = functools.partial(lstm_apply, cfg, use_ref=use_ref)
        input_shape = (cfg.seq,)
        input_dtype = "i32"
    else:
        raise ValueError(variant.kind)
    return ModelDef(variant, params, masks, apply_fn, input_shape, input_dtype)


def init_params(model: ModelDef, seed: int = 0) -> list[np.ndarray]:
    if model.variant.kind == "cnn":
        return cnn_init(model.variant.cfg, seed)
    return lstm_init(model.variant.cfg, seed)


def xent_loss(logits: jax.Array, y: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy; y int32 labels."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - picked)


def make_train_step(model: ModelDef):
    """One local epoch: lax.scan of SGD steps over the round's batches.

    Flat signature (AOT argument order, mirrored in the manifest):
      (*params, *masks, xs, ys, lr) ->
      (*updated_params, mean_loss)

    xs: [num_batches, B, *input_shape]; ys: [num_batches, B] i32;
    lr: scalar f32.
    """
    np_, ng = len(model.params), len(model.masks)
    trainable = tuple(p.trainable for p in model.params)
    apply_fn = model.apply_fn

    def train_step(*args):
        params = args[:np_]
        masks = args[np_ : np_ + ng]
        xs, ys, lr = args[np_ + ng :]

        def loss_fn(ps, x, y):
            return xent_loss(apply_fn(ps, masks, x), y)

        def body(ps, batch):
            x, y = batch
            loss, grads = jax.value_and_grad(loss_fn)(ps, x, y)
            new = tuple(
                p - lr * g if tr else p
                for p, g, tr in zip(ps, grads, trainable)
            )
            return new, loss

        out, losses = jax.lax.scan(body, tuple(params), (xs, ys))
        return (*out, jnp.mean(losses))

    return train_step


def make_eval_step(model: ModelDef):
    """Full-model evaluation over one batch.

    (*params, x, y) -> (loss_sum, correct_count)  both f32 scalars.
    """
    np_ = len(model.params)
    ones = tuple(jnp.ones((m.size,), jnp.float32) for m in model.masks)
    apply_fn = model.apply_fn

    def eval_step(*args):
        params = args[:np_]
        x, y = args[np_:]
        logits = apply_fn(params, ones, x)
        logz = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        loss_sum = jnp.sum(logz - picked)
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        return loss_sum, correct

    return eval_step


def example_args_train(model: ModelDef, seed: int = 0):
    """ShapeDtypeStructs for lowering the train step."""
    v = model.variant
    sds = []
    for p in model.params:
        sds.append(jax.ShapeDtypeStruct(p.shape, jnp.float32))
    for m in model.masks:
        sds.append(jax.ShapeDtypeStruct((m.size,), jnp.float32))
    xdt = jnp.float32 if model.input_dtype == "f32" else jnp.int32
    sds.append(
        jax.ShapeDtypeStruct((v.num_batches, v.batch_size) + model.input_shape, xdt)
    )
    sds.append(jax.ShapeDtypeStruct((v.num_batches, v.batch_size), jnp.int32))
    sds.append(jax.ShapeDtypeStruct((), jnp.float32))
    return sds


def example_args_eval(model: ModelDef):
    v = model.variant
    sds = [jax.ShapeDtypeStruct(p.shape, jnp.float32) for p in model.params]
    xdt = jnp.float32 if model.input_dtype == "f32" else jnp.int32
    sds.append(jax.ShapeDtypeStruct((v.batch_size,) + model.input_shape, xdt))
    sds.append(jax.ShapeDtypeStruct((v.batch_size,), jnp.int32))
    return sds
