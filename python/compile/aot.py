"""AOT pipeline: lower every model variant to HLO text + write the manifest.

Python runs ONCE (``make artifacts``); the Rust coordinator is
self-contained afterwards. Interchange is **HLO text** — the image's
xla_extension 0.5.1 rejects jax≥0.5 serialized HloModuleProtos (64-bit
instruction ids); the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Outputs (artifacts/):
  train_<variant>.hlo.txt   — one local epoch (scan of SGD steps)
  eval_<variant>.hlo.txt    — loss-sum + correct-count over one batch
  <variant>.init.bin        — little-endian f32 initial parameters (concat)
  kernel_masked_dense.hlo.txt     — L1 matmul kernel artifact (runtime tests)
  kernel_hadamard_roundtrip.hlo.txt — L1 quant kernel artifact (bench/race)
  manifest.json             — everything the Rust side needs: argument
      order, parameter segments (+ packing metadata for sub-model byte
      accounting), mask groups, data shapes, FLOPs attribution, lr.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import variants as V
from .kernels import hadamard_quant as hq
from .kernels import matmul as mk

INIT_SEED = 0


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _axis_pack_json(ap: M.AxisPack | None):
    if ap is None:
        return None
    return {
        "group": ap.group,
        "count": ap.count,
        "repeat": ap.repeat,
        "fixed": ap.fixed,
    }


def variant_manifest(v: V.Variant, model: M.ModelDef) -> dict:
    params = []
    offset = 0
    for p in model.params:
        params.append(
            {
                "name": p.name,
                "shape": list(p.shape),
                "size": p.size,
                "offset": offset,
                "trainable": p.trainable,
                "transmit": p.transmit,
                "rows": _axis_pack_json(p.rows),
                "cols": _axis_pack_json(p.cols),
                "flops_per_sample": p.flops_per_sample,
            }
        )
        offset += p.size
    masks = [
        {"name": m.name, "size": m.size, "kind": m.kind} for m in model.masks
    ]
    cfg = dataclasses.asdict(v.cfg)
    return {
        "name": v.name,
        "kind": v.kind,
        "dataset": v.dataset,
        "cfg": cfg,
        "lr": v.lr,
        "batch_size": v.batch_size,
        "num_batches": v.num_batches,
        "classes": v.cfg.classes,
        "input_shape": list(model.input_shape),
        "input_dtype": model.input_dtype,
        "num_params": model.num_params,
        "params": params,
        "mask_groups": masks,
        "train_hlo": f"train_{v.name}.hlo.txt",
        "eval_hlo": f"eval_{v.name}.hlo.txt",
        "init_params": f"{v.name}.init.bin",
        # Argument orders, explicit so the Rust side never guesses:
        "train_args": (
            [p.name for p in model.params]
            + [f"mask:{m.name}" for m in model.masks]
            + ["xs", "ys", "lr"]
        ),
        "train_outputs": [p.name for p in model.params] + ["mean_loss"],
        "eval_args": [p.name for p in model.params] + ["x", "y"],
        "eval_outputs": ["loss_sum", "correct"],
    }


def lower_variant(v: V.Variant, outdir: str, verbose: bool = True) -> dict:
    model = M.build(v)
    if verbose:
        print(f"[aot] lowering {v.name} ({model.num_params} params) ...", flush=True)

    train = M.make_train_step(model)
    lowered = jax.jit(train).lower(*M.example_args_train(model))
    train_txt = to_hlo_text(lowered)
    with open(os.path.join(outdir, f"train_{v.name}.hlo.txt"), "w") as f:
        f.write(train_txt)

    ev = M.make_eval_step(model)
    lowered_e = jax.jit(ev).lower(*M.example_args_eval(model))
    with open(os.path.join(outdir, f"eval_{v.name}.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered_e))

    init = M.init_params(model, INIT_SEED)
    flat = np.concatenate([p.reshape(-1) for p in init]).astype("<f4")
    flat.tofile(os.path.join(outdir, f"{v.name}.init.bin"))

    if verbose:
        print(
            f"[aot]   train hlo {len(train_txt)/1e6:.2f} MB, "
            f"init {flat.nbytes/1e6:.2f} MB",
            flush=True,
        )
    return variant_manifest(v, model)


def lower_kernel_artifacts(outdir: str) -> dict:
    """Standalone L1 kernel artifacts for Rust runtime tests + benches."""
    m, k, n = 64, 96, 32

    def masked_dense(x, w, b, mask):
        return (mk.matmul(x, w, b, mask, "relu"),)

    sds = [
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
    ]
    with open(os.path.join(outdir, "kernel_masked_dense.hlo.txt"), "w") as f:
        f.write(to_hlo_text(jax.jit(masked_dense).lower(*sds)))

    length, block = 4096, 256

    def had_roundtrip(x, signs):
        return (hq.roundtrip(x, signs, block),)

    sds = [
        jax.ShapeDtypeStruct((length,), jnp.float32),
        jax.ShapeDtypeStruct((length,), jnp.float32),
    ]
    with open(os.path.join(outdir, "kernel_hadamard_roundtrip.hlo.txt"), "w") as f:
        f.write(to_hlo_text(jax.jit(had_roundtrip).lower(*sds)))

    return {
        "masked_dense": {
            "hlo": "kernel_masked_dense.hlo.txt",
            "m": m, "k": k, "n": n,
        },
        "hadamard_roundtrip": {
            "hlo": "kernel_hadamard_roundtrip.hlo.txt",
            "length": length, "block": block,
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--paper", action="store_true",
        help="also lower paper-scale variants (slow; large artifacts)",
    )
    ap.add_argument("--variants", nargs="*", default=None)
    args = ap.parse_args()

    outdir = args.out
    os.makedirs(outdir, exist_ok=True)

    names = list(args.variants or V.DEFAULT_VARIANTS)
    if args.paper:
        names += [n for n in V.PAPER_VARIANTS if n not in names]

    manifest = {
        "format_version": 1,
        "init_seed": INIT_SEED,
        "variants": {},
        "kernels": lower_kernel_artifacts(outdir),
    }
    for name in names:
        v = V.get(name)
        manifest["variants"][name] = lower_variant(v, outdir)

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote manifest with {len(names)} variants to {outdir}/manifest.json")


if __name__ == "__main__":
    main()
