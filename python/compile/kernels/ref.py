"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel must agree
with its oracle to float tolerance across the shape/dtype sweep in
``python/tests/``. They are also used by ``model.py --ref`` to build a
kernel-free copy of each model for end-to-end numerical comparison.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def activation_ref(z: jax.Array, activation: str) -> jax.Array:
    if activation == "none":
        return z
    if activation == "relu":
        return jnp.maximum(z, 0.0)
    if activation == "sigmoid":
        return jax.nn.sigmoid(z)
    if activation == "tanh":
        return jnp.tanh(z)
    raise ValueError(f"unknown activation {activation!r}")


def matmul_ref(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array,
    mask: jax.Array,
    activation: str = "none",
) -> jax.Array:
    """Oracle for kernels.matmul.matmul: mask * act(x @ w + bias)."""
    z = (
        jnp.dot(
            x.astype(jnp.float32),
            w.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        + bias.astype(jnp.float32)[None, :]
    )
    a = activation_ref(z, activation)
    return (a * mask.astype(jnp.float32)[None, :]).astype(x.dtype)


def dense_ref(x, w, b, mask=None, activation="none"):
    if mask is None:
        mask = jnp.ones((w.shape[1],), x.dtype)
    lead = x.shape[:-1]
    y = matmul_ref(x.reshape((-1, x.shape[-1])), w, b, mask, activation)
    return y.reshape(lead + (w.shape[1],))


def hadamard_matrix(h: int) -> jax.Array:
    """Explicit normalized Walsh–Hadamard matrix (Sylvester construction)."""
    assert h & (h - 1) == 0 and h > 0, f"H must be a power of two, got {h}"
    m = jnp.ones((1, 1), jnp.float32)
    while m.shape[0] < h:
        m = jnp.block([[m, m], [m, -m]])
    return m / jnp.sqrt(jnp.asarray(h, jnp.float32))


def hadamard_quantize_ref(x: jax.Array, signs: jax.Array, block: int = 256):
    """Oracle for kernels.hadamard_quant.hadamard_quantize."""
    (l,) = x.shape
    pad = (-l) % block
    xp = jnp.pad(x, (0, pad)).reshape((-1, block))
    sg = signs.reshape((-1, block))
    hm = hadamard_matrix(block)
    y = (xp * sg) @ hm.T  # rows transformed
    s = jnp.max(jnp.abs(y), axis=-1)
    safe = jnp.where(s > 0.0, s, 1.0)
    q = jnp.clip(jnp.round(y / safe[:, None] * 127.0), -127.0, 127.0).astype(jnp.int8)
    return q, s


def hadamard_dequantize_ref(q: jax.Array, scales: jax.Array, signs: jax.Array, length: int):
    nb, block = q.shape
    hm = hadamard_matrix(block)
    y = q.astype(jnp.float32) / 127.0 * scales[:, None]
    x = (y @ hm.T) * signs.reshape((nb, block))
    return x.reshape((-1,))[:length]
