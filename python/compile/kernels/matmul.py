"""L1 Pallas kernel: tiled matmul with fused bias + activation + unit mask.

This single kernel backs every dense contraction in the AFD models:

* fully-connected layers (FEMNIST CNN head, LSTM output heads),
* convolutions (lowered to im2col + matmul in ``model.py``),
* LSTM gate pre-activations (``x @ Wx + h @ Wh + b``).

The *unit mask* is how Adaptive Federated Dropout's sub-models reach the
compute layer: a 0/1 vector over output units multiplies the activated
output, so dropped units produce exactly zero and (through autodiff /
the custom VJP below) receive exactly-zero gradients for every incident
weight — numerically identical to training the reduced architecture the
server logically shipped.

TPU idiom (see DESIGN.md §Hardware-Adaptation): the kernel tiles
M×N×K into VMEM-sized blocks (default 128×128×128 — MXU-aligned), loops
K on the innermost grid axis accumulating into the revisited output
block, and fuses bias/activation/mask into the final-K epilogue so the
output makes a single HBM round-trip. On this image it must be lowered
with ``interpret=True`` (CPU PJRT cannot execute Mosaic custom-calls);
the structure is nevertheless what a real TPU lowering would want.

Correctness oracle: ``ref.matmul_ref`` (pure jnp), swept by
``python/tests/test_kernel_matmul.py`` (hypothesis over shapes/dtypes).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Activation codes shared with ref.py and the AOT manifest.
ACTIVATIONS = ("none", "relu", "sigmoid", "tanh")

# Tile defaults, tuned in the §Perf pass (EXPERIMENTS.md): on CPU-PJRT
# interpret-mode the grid loop dominates, so larger M/K tiles (fewer
# grid steps over the im2col'd conv rows) beat the MXU-shaped 128³
# starting point by ~14% end-to-end on the FEMNIST train step. On a real
# TPU these would be VMEM-budgeted (see DESIGN.md §Hardware-Adaptation).
DEFAULT_BLOCK_M = 512
DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_K = 512


def _apply_activation(z: jax.Array, activation: str) -> jax.Array:
    if activation == "none":
        return z
    if activation == "relu":
        return jnp.maximum(z, 0.0)
    if activation == "sigmoid":
        return jax.nn.sigmoid(z)
    if activation == "tanh":
        return jnp.tanh(z)
    raise ValueError(f"unknown activation {activation!r}")


def _activation_grad_from_output(a: jax.Array, activation: str) -> jax.Array:
    """d act(z) / dz expressed through the *output* a = act(z).

    Using the output avoids stashing the pre-activation as a residual
    (one fewer M×N tensor on the backward HBM path).
    """
    if activation == "none":
        return jnp.ones_like(a)
    if activation == "relu":
        return (a > 0.0).astype(a.dtype)
    if activation == "sigmoid":
        return a * (1.0 - a)
    if activation == "tanh":
        return 1.0 - a * a
    raise ValueError(f"unknown activation {activation!r}")


def _matmul_kernel(x_ref, w_ref, b_ref, m_ref, o_ref, *, nk: int, activation: str):
    """Grid = (M/bm, N/bn, K/bk); o block revisited along k (accumulator)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        z = o_ref[...] + b_ref[...].astype(jnp.float32)[None, :]
        a = _apply_activation(z, activation)
        o_ref[...] = a * m_ref[...].astype(jnp.float32)[None, :]


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _matmul_fwd_raw(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array,
    mask: jax.Array,
    activation: str,
    block_m: int,
    block_n: int,
    block_k: int,
) -> jax.Array:
    """Pallas forward on padded operands; returns f32 [M, N]."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)

    bm = min(block_m, max(8, m))
    bn = min(block_n, max(8, n))
    bk = min(block_k, max(8, k))

    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bk), 1, bn)
    bp = _pad_to(bias, 0, bn)
    mp = _pad_to(mask, 0, bn)

    mp_, kp_ = xp.shape
    _, np_ = wp.shape
    nk = kp_ // bk
    grid = (mp_ // bm, np_ // bn, nk)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp_, np_), jnp.float32),
        interpret=True,  # CPU PJRT: Mosaic custom-calls are not executable
    )(xp, wp, bp, mp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def matmul(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array,
    mask: jax.Array,
    activation: str = "none",
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:
    """``mask * act(x @ w + bias)`` via the Pallas kernel.

    Args:
      x:    [M, K] input (f32 or bf16).
      w:    [K, N] weights.
      bias: [N].
      mask: [N] 0/1 unit mask (AFD sub-model selection); not differentiated.
      activation: one of ``ACTIVATIONS``.

    Returns [M, N] in x.dtype.
    """
    out = _matmul_fwd_raw(x, w, bias, mask, activation, block_m, block_n, block_k)
    return out.astype(x.dtype)


def _matmul_vjp_fwd(x, w, bias, mask, activation, block_m, block_n, block_k):
    a = _matmul_fwd_raw(x, w, bias, mask, activation, block_m, block_n, block_k)
    # Residuals: inputs + the *masked activated output* a (mask is 0/1 so the
    # activation-derivative-from-output trick still works on masked units:
    # their cotangent is zeroed by the mask factor anyway).
    return a.astype(x.dtype), (x, w, mask, a)


def _matmul_vjp_bwd(activation, block_m, block_n, block_k, residuals, g):
    x, w, mask, a = residuals
    gf = g.astype(jnp.float32) * mask.astype(jnp.float32)[None, :]
    # For masked units a == 0; relu'(0) = 0, sigmoid'(0-output) etc. are
    # scaled by gf == 0, so dz is exact.
    dz = gf * _activation_grad_from_output(a, activation)
    ones = jnp.ones((), jnp.float32)
    # dx = dz @ w.T  — reuse the Pallas kernel (no bias/act/mask).
    dx = _matmul_fwd_raw(
        dz,
        w.astype(jnp.float32).T,
        jnp.zeros((w.shape[0],), jnp.float32),
        jnp.broadcast_to(ones, (w.shape[0],)),
        "none",
        block_m,
        block_n,
        block_k,
    )
    # dw = x.T @ dz
    dw = _matmul_fwd_raw(
        x.astype(jnp.float32).T,
        dz,
        jnp.zeros((dz.shape[1],), jnp.float32),
        jnp.broadcast_to(ones, (dz.shape[1],)),
        "none",
        block_m,
        block_n,
        block_k,
    )
    db = jnp.sum(dz, axis=0)
    return (
        dx.astype(x.dtype),
        dw.astype(w.dtype),
        db.astype(x.dtype),
        None,  # mask: not differentiated
    )


matmul.defvjp(_matmul_vjp_fwd, _matmul_vjp_bwd)


def dense(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    mask: Optional[jax.Array] = None,
    activation: str = "none",
) -> jax.Array:
    """Convenience wrapper: dense layer over the Pallas kernel.

    Accepts inputs of rank >= 2; contracts the last axis.
    """
    if mask is None:
        mask = jnp.ones((w.shape[1],), x.dtype)
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    y = matmul(x2, w, b, mask, activation)
    return y.reshape(lead + (w.shape[1],))
