"""L1 Pallas kernel: blockwise randomized Hadamard transform + 8-bit quantization.

This is the paper's **downlink compression** operator ("8-bit Gradient
Quantization after applying Hadamard transformation as a basis function
to spread the information on the compressed weights", Konečný et al.
2016 / Lyubarskii & Vershynin 2010): for each length-``H`` block ``x`` of
the flattened sub-model,

    y = (1/sqrt(H)) · H_H · (d ⊙ x)          (randomized Hadamard rotation)
    q = round(clip(y / s, -1, 1) · 127)      (8-bit uniform quantization)
    s = max|y|                               (per-block scale)

and the inverse recovers ``x ≈ d ⊙ (1/sqrt(H)) · H_H · (q/127 · s)``
(the Walsh–Hadamard matrix is symmetric and H·H = H·I, so the same
butterfly inverts the rotation).

TPU idiom: the butterfly runs log2(H) stages fully in-register on a
(block, H) tile — a reshape/concat network rather than strided memory
access — and the quantization epilogue is fused so each block makes one
HBM round-trip. ``interpret=True`` for CPU PJRT (see matmul.py).

The Rust coordinator has an equivalent native implementation
(`compression::quant`); `aot.py` exports this kernel as its own artifact
so the two can be cross-checked and raced (bench_micro_hotpath).

Oracle: ``ref.hadamard_quantize_ref`` / ``ref.hadamard_dequantize_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 256  # elements per Hadamard block (power of two)


def _wht_inplace(v: jax.Array) -> jax.Array:
    """Normalized fast Walsh–Hadamard transform along the last axis.

    v: [..., H] with H a power of two. log2(H) butterfly stages expressed
    as reshape + stack (in-register on TPU; no strided loads).
    """
    h = v.shape[-1]
    lead = v.shape[:-1]
    n = 1
    while n < h:
        v = v.reshape(lead + (h // (2 * n), 2, n))
        a = v[..., 0, :]
        b = v[..., 1, :]
        v = jnp.stack((a + b, a - b), axis=-2)
        n *= 2
    v = v.reshape(lead + (h,))
    return v / jnp.sqrt(jnp.asarray(h, jnp.float32))


def _quant_kernel(x_ref, sign_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32) * sign_ref[...].astype(jnp.float32)
    y = _wht_inplace(x)
    s = jnp.max(jnp.abs(y), axis=-1, keepdims=True)
    safe = jnp.where(s > 0.0, s, 1.0)
    q = jnp.clip(jnp.round(y / safe * 127.0), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = s[..., 0]


def _dequant_kernel(q_ref, scale_ref, sign_ref, x_ref):
    y = q_ref[...].astype(jnp.float32) / 127.0 * scale_ref[...][..., None]
    x = _wht_inplace(y)  # H is symmetric + orthogonal (normalized): self-inverse
    x_ref[...] = x * sign_ref[...].astype(jnp.float32)


def _block_specs(nblocks_tile: int, block: int):
    return [
        pl.BlockSpec((nblocks_tile, block), lambda i: (i, 0)),
    ]


def hadamard_quantize(
    x: jax.Array, signs: jax.Array, block: int = DEFAULT_BLOCK
) -> tuple[jax.Array, jax.Array]:
    """Quantize a flat f32 vector.

    Args:
      x:     [L] flat parameters; L is padded to a multiple of ``block``.
      signs: [L_padded] ±1 Rademacher diagonal (shared with the decoder;
             the Rust side derives it from the round seed).

    Returns (q [nblocks, block] int8, scales [nblocks] f32).
    """
    (l,) = x.shape
    pad = (-l) % block
    xp = jnp.pad(x, (0, pad)).reshape((-1, block))
    nb = xp.shape[0]
    sg = signs.reshape((-1, block))
    assert sg.shape[0] == nb, (sg.shape, nb)

    q, scales = pl.pallas_call(
        _quant_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block), jnp.int8),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=True,
    )(xp, sg)
    return q, scales


def hadamard_dequantize(
    q: jax.Array, scales: jax.Array, signs: jax.Array, length: int
) -> jax.Array:
    """Inverse of :func:`hadamard_quantize`; returns [length] f32."""
    nb, block = q.shape
    sg = signs.reshape((nb, block))
    x = pl.pallas_call(
        _dequant_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), jnp.float32),
        interpret=True,
    )(q, scales, sg)
    return x.reshape((-1,))[:length]


def roundtrip(x: jax.Array, signs: jax.Array, block: int = DEFAULT_BLOCK) -> jax.Array:
    """quantize → dequantize (the fused artifact exported by aot.py)."""
    q, s = hadamard_quantize(x, signs, block)
    return hadamard_dequantize(q, s, signs, x.shape[0])
