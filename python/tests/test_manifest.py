"""Manifest consistency: the contract between aot.py and the Rust side."""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile import variants as V

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest_for(name):
    v = V.get(name)
    md = M.build(v)
    return aot.variant_manifest(v, md), md, v


@pytest.mark.parametrize("name", V.DEFAULT_VARIANTS)
def test_offsets_are_contiguous(name):
    man, md, _ = _manifest_for(name)
    off = 0
    for p in man["params"]:
        assert p["offset"] == off
        assert p["size"] == int(np.prod(p["shape"]))
        off += p["size"]
    assert off == man["num_params"]


@pytest.mark.parametrize("name", V.DEFAULT_VARIANTS)
def test_packing_metadata_refers_to_real_groups(name):
    man, _, _ = _manifest_for(name)
    groups = {g["name"]: g["size"] for g in man["mask_groups"]}
    for p in man["params"]:
        for axis in ("rows", "cols"):
            ap = p[axis]
            if ap is None:
                continue
            assert ap["group"] in groups, (p["name"], ap)
            assert ap["count"] == groups[ap["group"]]
            # "rows" is the flattened leading extent (conv weights are 4-D:
            # im2col flattens (kh, kw, cin) into matmul rows).
            extent = (
                int(np.prod(p["shape"][:-1])) if axis == "rows" else p["shape"][-1]
            )
            assert ap["count"] * ap["repeat"] + ap["fixed"] == extent, p["name"]


@pytest.mark.parametrize("name", V.DEFAULT_VARIANTS)
def test_arg_orders(name):
    man, md, v = _manifest_for(name)
    assert man["train_args"][: len(md.params)] == [p.name for p in md.params]
    g = len(md.masks)
    assert man["train_args"][len(md.params) : len(md.params) + g] == [
        f"mask:{m.name}" for m in md.masks
    ]
    assert man["train_args"][-3:] == ["xs", "ys", "lr"]
    assert man["train_outputs"][-1] == "mean_loss"
    assert man["eval_outputs"] == ["loss_sum", "correct"]


@pytest.mark.parametrize("name", V.DEFAULT_VARIANTS)
def test_flops_attribution_positive(name):
    man, _, _ = _manifest_for(name)
    total = sum(p["flops_per_sample"] for p in man["params"])
    assert total > 0
    # Matmul-ish layers carry flops; biases don't.
    for p in man["params"]:
        if p["name"].endswith("_b"):
            assert p["flops_per_sample"] == 0


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_written_manifest_matches_fresh():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        written = json.load(f)
    for name in written["variants"]:
        fresh, _, _ = _manifest_for(name)
        got = written["variants"][name]
        assert got["params"] == fresh["params"], name
        assert got["mask_groups"] == fresh["mask_groups"], name
        assert got["train_args"] == fresh["train_args"], name


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_init_bin_sizes():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        written = json.load(f)
    for name, man in written["variants"].items():
        path = os.path.join(ARTIFACTS, man["init_params"])
        assert os.path.getsize(path) == 4 * man["num_params"], name


def test_frozen_embed_flagged_not_transmitted():
    man, _, _ = _manifest_for("sent140_small")
    embed = next(p for p in man["params"] if p["name"] == "embed")
    assert embed["trainable"] is False
    assert embed["transmit"] is False
