"""The masking invariant AFD rests on: masked full model ≡ reduced sub-model.

1. Every parameter incident to a dropped unit receives an exactly-zero
   gradient — SGD leaves it bit-identical.
2. Training the masked full model step-by-step matches training the
   physically-reduced architecture (columns/rows deleted) for the CNN
   dense layer.
3. LSTM masks only affect the *non-recurrent* path: the recurrent
   weights of a layer keep receiving gradients even when the layer's
   upward mask drops units.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile import variants as V

jax.config.update("jax_platform_name", "cpu")


def _data(v, md, seed=0):
    rng = np.random.default_rng(seed)
    if md.input_dtype == "f32":
        x = rng.normal(size=(v.batch_size,) + md.input_shape).astype(np.float32)
    else:
        x = rng.integers(0, v.cfg.vocab, size=(v.batch_size,) + md.input_shape).astype(
            np.int32
        )
    y = rng.integers(0, v.cfg.classes, size=(v.batch_size,)).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


def _grads(md, params, masks, x, y):
    def loss_fn(ps):
        return M.xent_loss(md.apply_fn(tuple(ps), tuple(masks), x), y)

    return jax.grad(loss_fn)(list(params))


def _masks_with_drop(md, group_idx, dropped_idx):
    masks = [np.ones((m.size,), np.float32) for m in md.masks]
    masks[group_idx][dropped_idx] = 0.0
    return [jnp.asarray(m) for m in masks]


def test_cnn_dropped_units_zero_grads():
    v = V.get("femnist_small")
    md = M.build(v)
    params = [jnp.asarray(p) for p in M.init_params(md, 0)]
    x, y = _data(v, md)

    # Drop dense units 3, 7, 11.
    dropped = np.array([3, 7, 11])
    masks = _masks_with_drop(md, 2, dropped)
    g = _grads(md, params, masks, x, y)
    names = [p.name for p in md.params]
    dw = np.asarray(g[names.index("dense_w")])
    db = np.asarray(g[names.index("dense_b")])
    hw = np.asarray(g[names.index("head_w")])
    assert np.all(dw[:, dropped] == 0.0), "cols into dropped dense units"
    assert np.all(db[dropped] == 0.0)
    assert np.all(hw[dropped, :] == 0.0), "rows out of dropped dense units"
    # Kept units still learn.
    kept = np.setdiff1d(np.arange(dw.shape[1]), dropped)
    assert np.any(dw[:, kept] != 0.0)


def test_cnn_dropped_filters_zero_grads():
    v = V.get("femnist_small")
    md = M.build(v)
    params = [jnp.asarray(p) for p in M.init_params(md, 0)]
    x, y = _data(v, md)
    dropped = np.array([1, 5])
    masks = _masks_with_drop(md, 0, dropped)  # conv1 filters
    g = _grads(md, params, masks, x, y)
    names = [p.name for p in md.params]
    c1w = np.asarray(g[names.index("conv1_w")])
    c2w = np.asarray(g[names.index("conv2_w")])
    assert np.all(c1w[..., dropped] == 0.0)
    assert np.all(c2w[:, :, dropped, :] == 0.0), "conv2 weights reading dropped ch."


def test_masked_training_equals_reduced_architecture():
    """Delete two dense units physically; compare an SGD step."""
    v = V.get("femnist_small")
    md = M.build(v)
    params = [jnp.asarray(p) for p in M.init_params(md, 0)]
    x, y = _data(v, md)
    names = [p.name for p in md.params]
    dropped = np.array([0, 13])
    kept = np.setdiff1d(np.arange(v.cfg.dense), dropped)
    masks = _masks_with_drop(md, 2, dropped)

    lr = 0.1
    g = _grads(md, params, masks, x, y)
    stepped = [p - lr * gg for p, gg in zip(params, g)]

    # Reduced architecture: slice dense cols + head rows, retrain one step.
    cfg2 = V.CnnCfg(
        image=v.cfg.image, conv1=v.cfg.conv1, conv2=v.cfg.conv2,
        dense=len(kept), classes=v.cfg.classes,
    )
    v2 = V.Variant(name="tmp", kind="cnn", dataset="femnist", cfg=cfg2, lr=v.lr)
    md2 = M.build(v2)
    p2 = list(params)
    p2[names.index("dense_w")] = params[names.index("dense_w")][:, kept]
    p2[names.index("dense_b")] = params[names.index("dense_b")][kept]
    p2[names.index("head_w")] = params[names.index("head_w")][kept, :]
    ones2 = [jnp.ones((m.size,), jnp.float32) for m in md2.masks]
    g2 = _grads(md2, p2, ones2, x, y)
    stepped2 = [p - lr * gg for p, gg in zip(p2, g2)]

    # Compare the kept coordinates of every parameter.
    np.testing.assert_allclose(
        np.asarray(stepped[names.index("dense_w")])[:, kept],
        np.asarray(stepped2[names.index("dense_w")]),
        rtol=1e-5, atol=1e-6,
    )
    np.testing.assert_allclose(
        np.asarray(stepped[names.index("head_w")])[kept, :],
        np.asarray(stepped2[names.index("head_w")]),
        rtol=1e-5, atol=1e-6,
    )
    # Dropped coordinates unchanged in the masked model.
    np.testing.assert_array_equal(
        np.asarray(stepped[names.index("dense_w")])[:, dropped],
        np.asarray(params[names.index("dense_w")])[:, dropped],
    )


def test_lstm_recurrent_path_survives_masking():
    v = V.get("shakespeare_small")
    md = M.build(v)
    params = [jnp.asarray(p) for p in M.init_params(md, 0)]
    x, y = _data(v, md)
    names = [p.name for p in md.params]
    h = v.cfg.hidden

    dropped = np.arange(h // 2)  # drop half of layer-1's upward units
    masks = _masks_with_drop(md, 0, dropped)
    g = _grads(md, params, masks, x, y)

    # lstm2_w rows [0:h] read layer-1's (masked) upward output: dropped rows zero.
    w2 = np.asarray(g[names.index("lstm2_w")])
    assert np.all(w2[dropped, :] == 0.0)
    assert np.any(w2[h:, :] != 0.0), "recurrent rows of layer 2 still learn"
    # Layer-1's own recurrent rows keep nonzero gradient: memory preserved.
    w1 = np.asarray(g[names.index("lstm1_w")])
    emb = v.cfg.embed
    rec_rows = w1[emb:, :]
    assert np.any(rec_rows != 0.0), "layer-1 recurrence must keep learning"


def test_full_mask_equals_no_mask():
    for name in ("femnist_small", "shakespeare_small", "sent140_small"):
        v = V.get(name)
        md = M.build(v)
        params = [jnp.asarray(p) for p in M.init_params(md, 1)]
        ones = [jnp.ones((m.size,), jnp.float32) for m in md.masks]
        x, _ = _data(v, md, seed=2)
        a = md.apply_fn(tuple(params), tuple(ones), x)
        md_ref = M.build(v, use_ref=True)
        b = md_ref.apply_fn(tuple(params), tuple(ones), x)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)
