"""L1 correctness: Pallas masked matmul vs the pure-jnp oracle.

Hypothesis sweeps shapes, dtypes, activations and mask densities; every
case must agree with ``ref.matmul_ref`` to float tolerance, forward and
backward (custom VJP).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as mk
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _mk_case(seed, m, k, n, dtype, mask_density):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, k)).astype(np.float32)
    w = rng.normal(size=(k, n)).astype(np.float32)
    b = rng.normal(size=(n,)).astype(np.float32)
    mask = (rng.random(n) < mask_density).astype(np.float32)
    return (
        jnp.asarray(x, dtype),
        jnp.asarray(w, dtype),
        jnp.asarray(b, dtype),
        jnp.asarray(mask, dtype),
    )


shapes = st.tuples(
    st.integers(1, 70), st.integers(1, 70), st.integers(1, 70)
)


@settings(max_examples=25, deadline=None)
@given(
    shape=shapes,
    seed=st.integers(0, 2**31 - 1),
    act=st.sampled_from(mk.ACTIVATIONS),
    density=st.sampled_from([0.0, 0.3, 0.7, 1.0]),
)
def test_forward_matches_ref_f32(shape, seed, act, density):
    m, k, n = shape
    x, w, b, mask = _mk_case(seed, m, k, n, jnp.float32, density)
    got = mk.matmul(x, w, b, mask, act)
    want = ref.matmul_ref(x, w, b, mask, act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    shape=shapes,
    seed=st.integers(0, 2**31 - 1),
    act=st.sampled_from(mk.ACTIVATIONS),
)
def test_forward_matches_ref_bf16(shape, seed, act):
    m, k, n = shape
    x, w, b, mask = _mk_case(seed, m, k, n, jnp.bfloat16, 0.5)
    got = mk.matmul(x, w, b, mask, act).astype(jnp.float32)
    want = ref.matmul_ref(x, w, b, mask, act).astype(jnp.float32)
    # bf16 storage, f32 accumulation in both paths.
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@settings(max_examples=12, deadline=None)
@given(
    shape=shapes,
    seed=st.integers(0, 2**31 - 1),
    act=st.sampled_from(mk.ACTIVATIONS),
    density=st.sampled_from([0.0, 0.5, 1.0]),
)
def test_gradients_match_ref(shape, seed, act, density):
    m, k, n = shape
    x, w, b, mask = _mk_case(seed, m, k, n, jnp.float32, density)

    def loss_k(x, w, b):
        return jnp.sum(mk.matmul(x, w, b, mask, act) ** 2)

    def loss_r(x, w, b):
        return jnp.sum(ref.matmul_ref(x, w, b, mask, act) ** 2)

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(x, w, b)
    for a, bb in zip(gk, gr):
        np.testing.assert_allclose(a, bb, rtol=5e-4, atol=5e-4)


def test_masked_columns_get_zero_weight_grads():
    """AFD invariant: weights into a dropped unit receive exactly-zero grad."""
    x, w, b, _ = _mk_case(7, 16, 12, 9, jnp.float32, 1.0)
    mask = jnp.asarray([1, 0, 1, 0, 0, 1, 1, 0, 1], jnp.float32)

    def loss(w, b):
        return jnp.sum(mk.matmul(x, w, b, mask, "relu"))

    dw, db = jax.grad(loss, argnums=(0, 1))(w, b)
    dropped = np.where(np.asarray(mask) == 0.0)[0]
    assert np.all(np.asarray(dw)[:, dropped] == 0.0)
    assert np.all(np.asarray(db)[dropped] == 0.0)


def test_blocking_invariance():
    """Result must not depend on the tile decomposition."""
    x, w, b, mask = _mk_case(11, 100, 90, 80, jnp.float32, 0.6)
    base = mk.matmul(x, w, b, mask, "tanh", 128, 128, 128)
    for bm, bn, bk in [(32, 32, 32), (16, 64, 32), (128, 16, 8)]:
        got = mk.matmul(x, w, b, mask, "tanh", bm, bn, bk)
        np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)


def test_dense_wrapper_rank3():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(4, 7, 10)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(10, 6)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(6,)).astype(np.float32))
    got = mk.dense(x, w, b, activation="relu")
    want = ref.dense_ref(x, w, b, activation="relu")
    assert got.shape == (4, 7, 6)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_bad_activation_raises():
    x, w, b, mask = _mk_case(0, 4, 4, 4, jnp.float32, 1.0)
    with pytest.raises(ValueError):
        mk.matmul(x, w, b, mask, "gelu")
