"""L2 model behaviour: shapes, training signal, eval semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import variants as V

jax.config.update("jax_platform_name", "cpu")

SMALL = ("femnist_small", "shakespeare_small", "sent140_small")


def _batch(v, md, seed=0, nb=None):
    rng = np.random.default_rng(seed)
    nb = nb or v.num_batches
    if md.input_dtype == "f32":
        xs = rng.normal(size=(nb, v.batch_size) + md.input_shape).astype(np.float32)
    else:
        xs = rng.integers(
            0, v.cfg.vocab, size=(nb, v.batch_size) + md.input_shape
        ).astype(np.int32)
    ys = rng.integers(0, v.cfg.classes, size=(nb, v.batch_size)).astype(np.int32)
    return jnp.asarray(xs), jnp.asarray(ys)


@pytest.mark.parametrize("name", SMALL)
def test_logit_shapes(name):
    v = V.get(name)
    md = M.build(v)
    params = [jnp.asarray(p) for p in M.init_params(md, 0)]
    masks = [jnp.ones((m.size,), jnp.float32) for m in md.masks]
    xs, _ = _batch(v, md, nb=1)
    logits = md.apply_fn(tuple(params), tuple(masks), xs[0])
    assert logits.shape == (v.batch_size, v.cfg.classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", SMALL)
def test_train_step_reduces_loss(name):
    v = V.get(name)
    md = M.build(v)
    params = [jnp.asarray(p) for p in M.init_params(md, 0)]
    masks = [jnp.ones((m.size,), jnp.float32) for m in md.masks]
    xs, ys = _batch(v, md)
    step = jax.jit(M.make_train_step(md))
    out = step(*params, *masks, xs, ys, jnp.float32(v.lr))
    l0 = float(out[-1])
    out2 = step(*out[:-1], *masks, xs, ys, jnp.float32(v.lr))
    out3 = step(*out2[:-1], *masks, xs, ys, jnp.float32(v.lr))
    assert float(out3[-1]) < l0, f"{name}: {l0} -> {float(out3[-1])}"


def test_frozen_embedding_not_updated():
    v = V.get("sent140_small")
    md = M.build(v)
    params = [jnp.asarray(p) for p in M.init_params(md, 0)]
    masks = [jnp.ones((m.size,), jnp.float32) for m in md.masks]
    xs, ys = _batch(v, md)
    step = jax.jit(M.make_train_step(md))
    out = step(*params, *masks, xs, ys, jnp.float32(v.lr))
    names = [p.name for p in md.params]
    i = names.index("embed")
    np.testing.assert_array_equal(np.asarray(out[i]), np.asarray(params[i]))
    # ...but everything trainable moved.
    for j, p in enumerate(md.params):
        if p.trainable:
            assert np.any(np.asarray(out[j]) != np.asarray(params[j])), p.name


def test_trainable_embedding_updates():
    v = V.get("shakespeare_small")
    md = M.build(v)
    params = [jnp.asarray(p) for p in M.init_params(md, 0)]
    masks = [jnp.ones((m.size,), jnp.float32) for m in md.masks]
    xs, ys = _batch(v, md)
    step = jax.jit(M.make_train_step(md))
    out = step(*params, *masks, xs, ys, jnp.float32(v.lr))
    names = [p.name for p in md.params]
    i = names.index("embed")
    assert np.any(np.asarray(out[i]) != np.asarray(params[i]))


@pytest.mark.parametrize("name", SMALL)
def test_eval_step_counts(name):
    v = V.get(name)
    md = M.build(v)
    params = [jnp.asarray(p) for p in M.init_params(md, 0)]
    xs, ys = _batch(v, md, nb=1)
    ev = jax.jit(M.make_eval_step(md))
    loss_sum, correct = ev(*params, xs[0], ys[0])
    assert float(loss_sum) > 0.0
    assert 0.0 <= float(correct) <= v.batch_size
    # Cross-check correct-count against a manual argmax.
    masks = [jnp.ones((m.size,), jnp.float32) for m in md.masks]
    logits = md.apply_fn(tuple(params), tuple(masks), xs[0])
    want = int(np.sum(np.argmax(np.asarray(logits), axis=-1) == np.asarray(ys[0])))
    assert int(correct) == want


def test_train_step_with_masks_only_updates_submodel():
    v = V.get("femnist_small")
    md = M.build(v)
    params = [jnp.asarray(p) for p in M.init_params(md, 0)]
    names = [p.name for p in md.params]
    masks_np = [np.ones((m.size,), np.float32) for m in md.masks]
    dropped = np.array([2, 4, 6, 8])
    masks_np[2][dropped] = 0.0
    masks = [jnp.asarray(m) for m in masks_np]
    xs, ys = _batch(v, md)
    step = jax.jit(M.make_train_step(md))
    out = step(*params, *masks, xs, ys, jnp.float32(v.lr))
    dw0 = np.asarray(params[names.index("dense_w")])
    dw1 = np.asarray(out[names.index("dense_w")])
    np.testing.assert_array_equal(dw1[:, dropped], dw0[:, dropped])
    kept = np.setdiff1d(np.arange(dw0.shape[1]), dropped)
    assert np.any(dw1[:, kept] != dw0[:, kept])


def test_xent_loss_matches_manual():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(8, 5)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 5, size=(8,)).astype(np.int32))
    got = float(M.xent_loss(logits, y))
    p = np.exp(np.asarray(logits))
    p /= p.sum(axis=1, keepdims=True)
    want = float(np.mean(-np.log(p[np.arange(8), np.asarray(y)])))
    assert abs(got - want) < 1e-5
