"""L1 correctness: Pallas Hadamard+8-bit quantization vs the jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import hadamard_quant as hq
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _case(seed, length, block, scale=1.0):
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(length,)) * scale).astype(np.float32)
    padded = length + ((-length) % block)
    signs = rng.choice([-1.0, 1.0], size=(padded,)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(signs)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    length=st.integers(1, 3000),
    block=st.sampled_from([16, 64, 256]),
)
def test_quantize_matches_ref(seed, length, block):
    x, signs = _case(seed, length, block)
    q, s = hq.hadamard_quantize(x, signs, block)
    qr, sr = ref.hadamard_quantize_ref(x, signs, block)
    np.testing.assert_allclose(s, sr, rtol=1e-5, atol=1e-6)
    # Round-to-nearest ties may fall either way across implementations:
    # allow off-by-one on the int8 grid.
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32) - qr.astype(jnp.int32)))) <= 1


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    length=st.integers(1, 3000),
    block=st.sampled_from([16, 64, 256]),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_roundtrip_error_bound(seed, length, block, scale):
    """Quantization error per coordinate is bounded by the grid step.

    After the rotation each block's values are bounded by its scale s;
    the int8 grid step is s/127, and the inverse rotation is orthogonal
    (preserves l-inf up to sqrt(block) in the worst case). Empirically
    (and what matters for FL convergence) the max error is ~s·sqrt(b)/254;
    we assert a conservative bound.
    """
    x, signs = _case(seed, length, block, scale)
    y = hq.roundtrip(x, signs, block)
    q, s = ref.hadamard_quantize_ref(x, signs, block)
    bound = float(jnp.max(s)) / 254.0 * np.sqrt(block) * 1.5 + 1e-7
    assert float(jnp.max(jnp.abs(y - x))) <= bound


def test_roundtrip_zero_vector():
    x = jnp.zeros((512,), jnp.float32)
    signs = jnp.ones((512,), jnp.float32)
    y = hq.roundtrip(x, signs, 256)
    np.testing.assert_array_equal(np.asarray(y), np.zeros(512, np.float32))


def test_wht_is_orthonormal_involution():
    """The normalized WHT used in-kernel must be its own inverse."""
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.normal(size=(4, 128)).astype(np.float32))
    w = hq._wht_inplace(hq._wht_inplace(v))
    np.testing.assert_allclose(w, v, rtol=1e-5, atol=1e-5)
    # And matches the explicit Sylvester matrix.
    hm = ref.hadamard_matrix(128)
    np.testing.assert_allclose(hq._wht_inplace(v), v @ hm.T, rtol=1e-5, atol=1e-5)


def test_signs_change_rotation_but_not_recovery():
    x, signs = _case(5, 1024, 256)
    signs2 = -signs
    y1 = hq.roundtrip(x, signs, 256)
    y2 = hq.roundtrip(x, signs2, 256)
    # Different rotations, both must recover x to quantization tolerance.
    assert float(jnp.max(jnp.abs(y1 - x))) < 0.1
    assert float(jnp.max(jnp.abs(y2 - x))) < 0.1
