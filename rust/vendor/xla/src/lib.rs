//! In-tree stub of the `xla` (xla-rs) API surface used by `afd`.
//!
//! The PJRT backend needs the XLA C++ toolchain, which is not present
//! in offline or CI environments. This stub keeps the whole workspace
//! compiling and testable without it:
//!
//! * [`Literal`] is fully functional (create from bytes, read back as
//!   typed vectors) — the `runtime::literal` helpers and their tests
//!   work against it unchanged;
//! * [`PjRtClient::cpu`] returns a descriptive error, so every PJRT
//!   call site gates cleanly at runtime ("backend unavailable") — the
//!   same way PJRT tests already gate on `rust/artifacts/` being
//!   present.
//!
//! To run the real PJRT backend, repoint the `xla` path dependency in
//! `rust/Cargo.toml` at an environment that provides xla-rs and run
//! `make artifacts`; no source changes are needed.

use std::fmt;
use std::path::Path;

/// Stub error. Every fallible entry point returns this with a message
/// explaining that the stub is active.
#[derive(Clone, Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT unavailable (built against the in-tree xla stub; \
         point rust/Cargo.toml's `xla` dependency at xla-rs to enable it)"
    ))
}

/// Element types used by the afd artifacts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    fn byte_width(self) -> usize {
        4
    }
}

/// Rust scalar types storable in a [`Literal`].
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_le(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le(b: &[u8]) -> f32 {
        f32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le(b: &[u8]) -> i32 {
        i32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

/// A host tensor literal: element type + dims + raw little-endian data.
/// Fully functional in the stub (tuples only come out of executions,
/// which the stub cannot perform).
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let numel: usize = dims.iter().product();
        if numel * ty.byte_width() != data.len() {
            return Err(Error(format!(
                "literal shape {dims:?} ({numel} elements) does not match {} data bytes",
                data.len()
            )));
        }
        Ok(Literal {
            ty,
            dims: dims.to_vec(),
            bytes: data.to_vec(),
        })
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error(format!(
                "literal holds {:?}, asked for {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(self.bytes.chunks_exact(4).map(T::from_le).collect())
    }

    /// Decompose a tuple literal. Tuples are only produced by PJRT
    /// executions, which the stub cannot run.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    /// One-element tuple convenience used by kernel tests.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }
}

/// Parsed HLO module. The stub cannot parse HLO text.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(unavailable(&format!(
            "parsing HLO text {}",
            path.as_ref().display()
        )))
    }
}

/// An XLA computation handle.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer returned by executions.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable. Unconstructible through the stub (compile
/// always fails), but the type and methods keep call sites compiling.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT device client.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes)
                .unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert_eq!(lit.dims(), &[3]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[2], &[0u8; 4])
                .is_err()
        );
    }

    #[test]
    fn client_is_gated() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.0.contains("stub"));
    }
}
