//! Minimal in-tree replacement for the `anyhow` crate.
//!
//! The build environment for this repository is fully offline (see
//! `afd::util` module docs), so the usual ecosystem crates are replaced
//! by small, tested implementations. This crate provides exactly the
//! `anyhow` API subset the workspace uses:
//!
//! * [`Error`] — an opaque, `Send + Sync` error value built from a
//!   message or any `std::error::Error`;
//! * [`Result`] — `Result<T, Error>` with the usual default parameter;
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the construction macros;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`
//!   and `Option`.
//!
//! Error chains are flattened eagerly into the message
//! (`"context: cause"`), which matches how the workspace formats errors
//! (`{e}` / `{e:#}`); downcasting and backtraces are intentionally out
//! of scope. Swap this path dependency for the registry `anyhow` in
//! `rust/Cargo.toml` if the full feature set is ever needed.

use std::fmt;

/// Opaque error: a flattened message chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`,
// exactly like the real `anyhow::Error` — that is what makes the
// blanket `From` below coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(&e)
    }
}

/// `anyhow::Result<T>` — `E` defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a message, a format string, or any
/// displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: `", stringify!($cond), "`"));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

/// Attach context to errors (and to `None`).
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T, ()> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let v: i32 = s.parse()?; // From<ParseIntError>
        ensure!(v > 0, "want positive, got {v}");
        Ok(v)
    }

    #[test]
    fn macros_and_from() {
        assert_eq!(parse("3").unwrap(), 3);
        assert!(parse("x").is_err());
        let e = parse("-1").unwrap_err();
        assert_eq!(e.to_string(), "want positive, got -1");
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let owned: Error = anyhow!(String::from("owned"));
        assert_eq!(owned.to_string(), "owned");
        let fmt = anyhow!("x={} y={:?}", 1, "z");
        assert_eq!(fmt.to_string(), "x=1 y=\"z\"");
    }

    #[test]
    fn context_chains() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("outer").unwrap_err();
        assert!(e.to_string().starts_with("outer: "));
        let n: Option<i32> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
        let w: std::result::Result<(), String> = Err("inner".into());
        let e = w.with_context(|| format!("step {}", 2)).unwrap_err();
        assert_eq!(e.to_string(), "step 2: inner");
    }

    #[test]
    fn bail_and_bare_ensure() {
        fn f(flag: bool) -> Result<()> {
            ensure!(flag);
            bail!("always");
        }
        assert!(f(false).unwrap_err().to_string().contains("flag"));
        assert_eq!(f(true).unwrap_err().to_string(), "always");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_bounds<T: Send + Sync + 'static>() {}
        assert_bounds::<Error>();
    }
}
