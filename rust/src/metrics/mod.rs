//! Metrics: per-round records, convergence detection, report rendering.

use crate::util::json::Json;
use crate::util::stats;

/// One federated round's record.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// Simulated wall-clock duration of this round (network + compute).
    pub round_s: f64,
    /// Cumulative simulated time at the end of this round.
    pub cum_s: f64,
    /// Mean local training loss over the cohort.
    pub train_loss: f64,
    /// Global-model test accuracy (if this round evaluated).
    pub eval_acc: Option<f64>,
    pub eval_loss: Option<f64>,
    /// Measured downlink wire bytes: framed lengths as a socket would
    /// carry them (offer + model + round-close control frames).
    pub down_bytes: u64,
    /// Measured uplink wire bytes (the framed update).
    pub up_bytes: u64,
    /// Codec payload alone on the downlink; `down_bytes -
    /// down_payload_bytes` is the protocol's framing overhead.
    pub down_payload_bytes: u64,
    /// Update body alone on the uplink.
    pub up_payload_bytes: u64,
    /// Mean keep fraction of the round's sub-models.
    pub keep_fraction: f64,
    /// Clients whose updates were aggregated this round.
    pub arrived: usize,
    /// Stragglers cut by the scheduler (quorum/deadline).
    pub cut: usize,
    /// Clients lost to availability churn before arrival.
    pub dropped: usize,
    /// Clients lost in transit by the transport (dead or timed-out
    /// connection); the engine converts them into cuts instead of
    /// failing the run.
    pub lost: usize,
    /// Running total of clients excluded from selection after
    /// repeatedly faulting (see `rust/src/fault/README.md`).
    pub quarantined: usize,
}

impl RoundRecord {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("round", Json::Num(self.round as f64));
        j.set("round_s", Json::Num(self.round_s));
        j.set("cum_s", Json::Num(self.cum_s));
        j.set("train_loss", Json::Num(self.train_loss));
        j.set(
            "eval_acc",
            self.eval_acc.map(Json::Num).unwrap_or(Json::Null),
        );
        j.set(
            "eval_loss",
            self.eval_loss.map(Json::Num).unwrap_or(Json::Null),
        );
        j.set("down_bytes", Json::Num(self.down_bytes as f64));
        j.set("up_bytes", Json::Num(self.up_bytes as f64));
        j.set("down_payload_bytes", Json::Num(self.down_payload_bytes as f64));
        j.set("up_payload_bytes", Json::Num(self.up_payload_bytes as f64));
        j.set("keep_fraction", Json::Num(self.keep_fraction));
        j.set("arrived", Json::Num(self.arrived as f64));
        j.set("cut", Json::Num(self.cut as f64));
        j.set("dropped", Json::Num(self.dropped as f64));
        j.set("lost", Json::Num(self.lost as f64));
        j.set("quarantined", Json::Num(self.quarantined as f64));
        j
    }
}

/// Full experiment output.
#[derive(Clone, Debug)]
pub struct ExperimentReport {
    pub method: String,
    pub variant: String,
    pub seed: u64,
    pub records: Vec<RoundRecord>,
    /// (round, simulated seconds) at which the target accuracy was first
    /// reached (smoothed), if a target was configured and reached.
    pub converged: Option<(usize, f64)>,
}

impl ExperimentReport {
    pub fn final_accuracy(&self) -> f64 {
        self.records
            .iter()
            .rev()
            .find_map(|r| r.eval_acc)
            .unwrap_or(0.0)
    }

    /// Best (peak) evaluated accuracy.
    pub fn best_accuracy(&self) -> f64 {
        self.records
            .iter()
            .filter_map(|r| r.eval_acc)
            .fold(0.0, f64::max)
    }

    pub fn total_sim_seconds(&self) -> f64 {
        self.records.last().map(|r| r.cum_s).unwrap_or(0.0)
    }

    pub fn total_down_bytes(&self) -> u64 {
        self.records.iter().map(|r| r.down_bytes).sum()
    }

    pub fn total_up_bytes(&self) -> u64 {
        self.records.iter().map(|r| r.up_bytes).sum()
    }

    pub fn total_down_payload_bytes(&self) -> u64 {
        self.records.iter().map(|r| r.down_payload_bytes).sum()
    }

    pub fn total_up_payload_bytes(&self) -> u64 {
        self.records.iter().map(|r| r.up_payload_bytes).sum()
    }

    /// Fraction of all wire bytes that is protocol overhead rather
    /// than codec payload: `1 − payload/wire` over both directions.
    pub fn framing_overhead_fraction(&self) -> f64 {
        let wire = (self.total_down_bytes() + self.total_up_bytes()) as f64;
        if wire == 0.0 {
            return 0.0;
        }
        let payload = (self.total_down_payload_bytes() + self.total_up_payload_bytes()) as f64;
        1.0 - payload / wire
    }

    /// Accuracy curve as (cum simulated seconds, accuracy) points.
    pub fn accuracy_curve(&self) -> Vec<(f64, f64)> {
        self.records
            .iter()
            .filter_map(|r| r.eval_acc.map(|a| (r.cum_s, a)))
            .collect()
    }

    /// First simulated time at which the (moving-average smoothed)
    /// accuracy reaches `target` — the paper's "convergence time".
    pub fn time_to_accuracy(&self, target: f64, window: usize) -> Option<(usize, f64)> {
        let pts: Vec<(usize, f64, f64)> = self
            .records
            .iter()
            .filter_map(|r| r.eval_acc.map(|a| (r.round, r.cum_s, a)))
            .collect();
        if pts.is_empty() {
            return None;
        }
        let accs: Vec<f64> = pts.iter().map(|p| p.2).collect();
        let smooth = stats::moving_average(&accs, window);
        for (i, &s) in smooth.iter().enumerate() {
            if s >= target {
                return Some((pts[i].0, pts[i].1));
            }
        }
        None
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("method", Json::Str(self.method.clone()));
        j.set("variant", Json::Str(self.variant.clone()));
        j.set("seed", Json::Num(self.seed as f64));
        j.set(
            "records",
            Json::Arr(self.records.iter().map(|r| r.to_json()).collect()),
        );
        j.set(
            "converged",
            self.converged
                .map(|(r, s)| {
                    let mut o = Json::obj();
                    o.set("round", Json::Num(r as f64));
                    o.set("sim_s", Json::Num(s));
                    o
                })
                .unwrap_or(Json::Null),
        );
        j
    }
}

/// Aggregate several seeds of the same method into mean ± std, the way
/// the paper reports ("we repeat each experiment 5 times ... report the
/// mean").
pub struct MethodSummary {
    pub method: String,
    pub accuracy_mean: f64,
    pub accuracy_std: f64,
    pub time_mean_s: f64,
    /// Mean fraction of wire bytes that is protocol overhead (framing
    /// + control frames + sub-model bitmaps) rather than codec payload
    /// — the table's Framing column, so protocol cost sits next to
    /// codec savings.
    pub overhead_frac: f64,
    pub reached: usize,
    pub total: usize,
}

pub fn summarize(
    method: &str,
    reports: &[ExperimentReport],
    target: Option<f64>,
) -> MethodSummary {
    let accs: Vec<f64> = reports.iter().map(|r| r.best_accuracy()).collect();
    let times: Vec<f64> = match target {
        Some(t) => reports
            .iter()
            .filter_map(|r| r.time_to_accuracy(t, 3).map(|(_, s)| s))
            .collect(),
        None => reports.iter().map(|r| r.total_sim_seconds()).collect(),
    };
    let overheads: Vec<f64> = reports
        .iter()
        .map(|r| r.framing_overhead_fraction())
        .collect();
    MethodSummary {
        method: method.to_string(),
        accuracy_mean: stats::mean(&accs),
        accuracy_std: stats::std(&accs),
        time_mean_s: stats::mean(&times),
        overhead_frac: stats::mean(&overheads),
        reached: times.len(),
        total: reports.len(),
    }
}

/// Render a paper-style table (method / accuracy / convergence time /
/// speedup vs the first row / framing overhead as a share of wire
/// bytes — the protocol's cost next to the codec's savings).
pub fn render_table(title: &str, rows: &[MethodSummary]) -> String {
    let mut s = format!("\n== {title} ==\n");
    s.push_str(&format!(
        "{:<18} {:>18} {:>22} {:>10} {:>9}\n",
        "Method", "Accuracy", "Convergence Time", "Speedup", "Framing"
    ));
    let base = rows.first().map(|r| r.time_mean_s).unwrap_or(0.0);
    for r in rows {
        let acc = format!(
            "{:.1}% ± {:.2}%",
            r.accuracy_mean * 100.0,
            r.accuracy_std * 100.0
        );
        let time = if r.reached == 0 {
            "not reached".to_string()
        } else {
            format!(
                "{} ({}/{})",
                crate::util::human_duration(r.time_mean_s),
                r.reached,
                r.total
            )
        };
        let speedup = if r.time_mean_s > 0.0 && base > 0.0 && r.reached > 0 {
            format!("{:.0}x", base / r.time_mean_s)
        } else {
            "-".to_string()
        };
        let framing = format!("{:.2}%", r.overhead_frac * 100.0);
        s.push_str(&format!(
            "{:<18} {:>18} {:>22} {:>10} {:>9}\n",
            r.method, acc, time, speedup, framing
        ));
    }
    s
}

/// Render the observability layer's per-stage time breakdown (host
/// wall-clock, from the span recorder's histograms) in the same
/// paper-table style as [`render_table`]. Returns `None` when nothing
/// was recorded — tracing off, or the `trace` feature compiled out —
/// so callers can print it only when it says something.
pub fn render_stage_table() -> Option<String> {
    let rows = crate::obs::export::stage_rows();
    if rows.iter().all(|r| r.1 == 0) {
        return None;
    }
    let mut s = String::from("\n== Stage time breakdown (host wall-clock) ==\n");
    s.push_str(&format!(
        "{:<18} {:>10} {:>14} {:>12} {:>12} {:>12}\n",
        "Stage", "Count", "Total", "Mean", "p50", "p99"
    ));
    for (name, count, total_ns, mean_ns, p50_ns, p99_ns) in rows {
        if count == 0 {
            continue;
        }
        s.push_str(&format!(
            "{:<18} {:>10} {:>14} {:>12} {:>12} {:>12}\n",
            name,
            count,
            crate::util::human_duration(total_ns as f64 * 1e-9),
            format!("{:.1}us", mean_ns * 1e-3),
            format!("{:.1}us", p50_ns as f64 * 1e-3),
            format!("{:.1}us", p99_ns as f64 * 1e-3),
        ));
    }
    // Residual-store traffic, when a population actually paged state.
    let m = &crate::obs::metrics::RESIDUAL_STORE_MISSES;
    let h = &crate::obs::metrics::RESIDUAL_STORE_HITS;
    if m.get() + h.get() > 0 {
        s.push_str(&format!(
            "residual store: {} hits, {} misses, {} evictions, {} spilled, \
             resident peak {}\n",
            h.get(),
            m.get(),
            crate::obs::metrics::RESIDUAL_STORE_EVICTIONS.get(),
            crate::util::human_bytes(
                crate::obs::metrics::RESIDUAL_STORE_SPILLED_BYTES.get()
            ),
            crate::util::human_bytes(crate::obs::metrics::RESIDENT_BYTES_PEAK.get()),
        ));
    }
    // Fault-injection accounting, when a plan actually fired.
    let faults: u64 = crate::fault::ALL_SITES
        .iter()
        .map(|&site| crate::obs::metrics::FAULTS_INJECTED[site as usize].get())
        .sum();
    if faults > 0 {
        s.push_str(&format!(
            "faults: {} injected, {} clients quarantined\n",
            faults,
            crate::obs::metrics::CLIENTS_QUARANTINED.get(),
        ));
    }
    // Checkpoint traffic, when the coordinator wrote or restored any.
    let ckpts = crate::obs::metrics::CHECKPOINTS_WRITTEN.get();
    let restores = crate::obs::metrics::RESTORES.get();
    if ckpts + restores > 0 {
        s.push_str(&format!(
            "checkpoints: {} written ({}), {} restored\n",
            ckpts,
            crate::util::human_bytes(crate::obs::metrics::CHECKPOINT_BYTES.get()),
            restores,
        ));
    }
    // Span-ring pressure: overwritten records mean the trace (and any
    // shipped telemetry) is missing the oldest spans of a busy thread.
    let (_, ring_dropped) = crate::obs::span::ring_totals();
    if ring_dropped > 0 {
        s.push_str(&format!(
            "span rings: {ring_dropped} record(s) overwritten before export \
             (raise RING_CAPACITY or trace a shorter run)\n"
        ));
    }
    // Telemetry side-channel traffic, when remote processes shipped any.
    let tf = crate::obs::metrics::TELEMETRY_FRAMES.get();
    if tf > 0 {
        s.push_str(&format!(
            "telemetry: {} frame(s), {} on the wire, {} remote span(s) dropped\n",
            tf,
            crate::util::human_bytes(crate::obs::metrics::TELEMETRY_BYTES.get()),
            crate::obs::metrics::TELEMETRY_SPANS_DROPPED.get(),
        ));
    }
    Some(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_report(acc_per_round: &[f64], secs_per_round: f64) -> ExperimentReport {
        let mut cum = 0.0;
        let records = acc_per_round
            .iter()
            .enumerate()
            .map(|(i, &a)| {
                cum += secs_per_round;
                RoundRecord {
                    round: i + 1,
                    round_s: secs_per_round,
                    cum_s: cum,
                    train_loss: 1.0 / (i + 1) as f64,
                    eval_acc: Some(a),
                    eval_loss: Some(1.0 - a),
                    down_bytes: 1000,
                    up_bytes: 500,
                    down_payload_bytes: 900,
                    up_payload_bytes: 450,
                    keep_fraction: 0.75,
                    arrived: 5,
                    cut: 0,
                    dropped: 0,
                    lost: 0,
                    quarantined: 0,
                }
            })
            .collect();
        ExperimentReport {
            method: "test".into(),
            variant: "v".into(),
            seed: 0,
            records,
            converged: None,
        }
    }

    #[test]
    fn convergence_detection_uses_smoothing() {
        // A single noisy spike must not count as convergence (window 3).
        let r = fake_report(&[0.1, 0.9, 0.1, 0.5, 0.8, 0.85, 0.9], 10.0);
        let hit = r.time_to_accuracy(0.8, 3).unwrap();
        assert_eq!(hit.0, 7, "spike at round 2 must not trigger");
        assert!(r.time_to_accuracy(0.99, 3).is_none());
        // Window of 1 takes the spike.
        assert_eq!(r.time_to_accuracy(0.8, 1).unwrap().0, 2);
    }

    #[test]
    fn report_accessors() {
        let r = fake_report(&[0.2, 0.6, 0.4], 5.0);
        assert_eq!(r.final_accuracy(), 0.4);
        assert_eq!(r.best_accuracy(), 0.6);
        assert_eq!(r.total_sim_seconds(), 15.0);
        assert_eq!(r.total_down_bytes(), 3000);
        assert_eq!(r.accuracy_curve().len(), 3);
    }

    #[test]
    fn summary_and_table_render() {
        let reports = vec![
            fake_report(&[0.5, 0.8, 0.9], 10.0),
            fake_report(&[0.4, 0.7, 0.9], 10.0),
        ];
        let s = summarize("AFD + DGC", &reports, Some(0.85));
        assert_eq!(s.total, 2);
        assert!(s.accuracy_mean > 0.8);
        let slow = MethodSummary {
            method: "No Compression".into(),
            accuracy_mean: 0.9,
            accuracy_std: 0.01,
            time_mean_s: 300.0,
            overhead_frac: 0.003,
            reached: 2,
            total: 2,
        };
        let table = render_table("Table 1 (tiny)", &[slow, s]);
        assert!(table.contains("No Compression"));
        assert!(table.contains("AFD + DGC"));
        assert!(table.contains('x'), "speedup column should render: {table}");
        assert!(table.contains("Framing"), "overhead column: {table}");
        // fake_report: payload 1350 of 1500 wire per round ⇒ 10%.
        assert!(table.contains("10.00%"), "overhead value: {table}");
    }

    #[test]
    fn framing_overhead_fraction_reads_wire_vs_payload() {
        let r = fake_report(&[0.5], 1.0);
        assert_eq!(r.total_down_payload_bytes(), 900);
        assert_eq!(r.total_up_payload_bytes(), 450);
        let f = r.framing_overhead_fraction();
        assert!((f - 0.1).abs() < 1e-12, "fraction {f}");
        // An empty report divides nothing.
        let empty = ExperimentReport {
            method: "m".into(),
            variant: "v".into(),
            seed: 0,
            records: Vec::new(),
            converged: None,
        };
        assert_eq!(empty.framing_overhead_fraction(), 0.0);
    }

    #[test]
    fn json_serialization() {
        let r = fake_report(&[0.3], 1.0);
        let j = r.to_json();
        let text = j.to_string_compact();
        let parsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(
            parsed.get("records").unwrap().as_arr().unwrap().len(),
            1
        );
    }
}
