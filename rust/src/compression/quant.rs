//! 8-bit uniform quantization with randomized Hadamard rotation — the
//! paper's downlink codec (native Rust twin of the Pallas kernel
//! `python/compile/kernels/hadamard_quant.py`; the two are cross-checked
//! in `rust/tests/compression_roundtrip.rs` and raced in
//! `bench_micro_hotpath`).
//!
//! Pipeline per length-`B` block (B a power of two):
//!   y = (1/√B) · H_B · (d ⊙ x)   — spread information across the block
//!   s = max|y|,  q_i = round(y_i/s · 127) ∈ i8
//! Wire format: `u32 length ‖ per block (f32 scale ‖ B × i8)`.
//! The Rademacher diagonal `d` is derived from the shared seed, so it
//! costs zero wire bytes — and it is **streamed** block by block from
//! the seed's PRNG stream into workspace scratch, so neither encode
//! nor decode materializes (or caches) a payload-sized sign vector:
//! the codec is stateless and allocation-free against a warm
//! [`Workspace`] (the sign *values* are identical to generating the
//! whole padded vector up front, because the stream is consumed in
//! block order — `Pcg64::rademacher_fill` draws **64 signs per
//! `next_u64`**, so per-block streaming chains exactly like one long
//! draw as long as the block size is a multiple of 64, which every
//! supported block is). Encode and decode each stream the diagonal
//! once; at one PRNG step per 64 coordinates the doubled generation
//! the deleted coordinator-side sign cache used to avoid is now noise
//! rather than a hot-path cost.
//!
//! Rounding is ties-to-even via [`simd::quantize_unit`] (the
//! magic-constant trick), computed identically by the scalar and AVX2
//! paths — encodings are byte-identical between the two (enforced by
//! `rust/tests/simd_conformance.rs`); ties-to-even also matches the
//! Pallas twin (`jnp.round`).

use crate::compression::{DenseCodec, Encoded};
use crate::tensor::kernels::Workspace;
use crate::tensor::simd;
use crate::util::rng::Pcg64;

pub const DEFAULT_BLOCK: usize = 256;

/// Stream tag keeping the sign sequence independent of other per-seed
/// randomness (cohort sampling etc.).
const SIGN_STREAM: u64 = 0x5167;

/// The seed's sign stream; consumed in block order by encode/decode
/// (public so reference implementations — the conformance suite's
/// scalar-primitive encoder — derive identical signs).
pub fn sign_stream(seed: u64) -> Pcg64 {
    Pcg64::with_stream(seed, SIGN_STREAM)
}

/// Stateless Hadamard + int8 codec (see module docs).
pub struct HadamardQuant8 {
    pub block: usize,
}

impl HadamardQuant8 {
    pub fn new(block: usize) -> HadamardQuant8 {
        // Power of two for the FWHT; ≥ 64 so the batched Rademacher
        // draw (64 signs per PRNG word) streams block-by-block exactly
        // like one whole-vector draw (module docs).
        assert!(
            block.is_power_of_two() && block >= 64,
            "quant8 block must be a power of two ≥ 64, got {block}"
        );
        HadamardQuant8 { block }
    }
}

impl Default for HadamardQuant8 {
    fn default() -> Self {
        HadamardQuant8::new(DEFAULT_BLOCK)
    }
}

/// In-place fast Walsh–Hadamard transform (unnormalized butterflies);
/// caller applies the 1/√B normalization. Dispatches through the SIMD
/// layer (bit-identical to the scalar butterflies for every length).
pub fn fwht(v: &mut [f32]) {
    simd::fwht(v);
}

impl DenseCodec for HadamardQuant8 {
    fn name(&self) -> &'static str {
        "quant8"
    }

    fn encode_into(&self, values: &[f32], seed: u64, ws: &mut Workspace, out: &mut Encoded) {
        let _sp = crate::obs::span_ab(crate::obs::Stage::CodecEncode, values.len() as u64, 0);
        let b = self.block;
        let n = values.len();
        let nblocks = n.div_ceil(b);
        let inv_sqrt = 1.0 / (b as f32).sqrt();
        let mut signs_rng = sign_stream(seed);

        let bytes = &mut out.bytes;
        bytes.clear();
        bytes.reserve(4 + nblocks * (4 + b));
        bytes.extend_from_slice(&(n as u32).to_le_bytes());
        let mut buf = ws.take_uncleared(b);
        let mut signs = ws.take_uncleared(b);
        for blk in 0..nblocks {
            let start = blk * b;
            let take = (n - start).min(b);
            buf[..take].copy_from_slice(&values[start..start + take]);
            buf[take..].fill(0.0);
            signs_rng.rademacher_fill(&mut signs);
            simd::mul_inplace(&mut buf, &signs);
            simd::fwht(&mut buf);
            // max|buf| without the per-element normalization multiply
            // (pulled out of the loop; §Perf).
            let m = simd::absmax(&buf);
            let scale = m * inv_sqrt;
            bytes.extend_from_slice(&scale.to_le_bytes());
            // Quantize straight into the wire buffer (no staging copy).
            let qs = if scale > 0.0 { 127.0 / m } else { 0.0 };
            let base = bytes.len();
            bytes.resize(base + b, 0);
            simd::quantize_block(&buf, qs, &mut bytes[base..]);
        }
        ws.give(buf);
        ws.give(signs);
    }

    fn decode_slice_into(&self, bytes: &[u8], seed: u64, ws: &mut Workspace, out: &mut Vec<f32>) {
        let _sp = crate::obs::span_ab(crate::obs::Stage::CodecDecode, bytes.len() as u64, 0);
        let b = self.block;
        let n = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
        let nblocks = n.div_ceil(b);
        let inv_sqrt = 1.0 / (b as f32).sqrt();
        let mut signs_rng = sign_stream(seed);

        out.clear();
        out.reserve(n);
        let mut buf = ws.take_uncleared(b);
        let mut signs = ws.take_uncleared(b);
        let mut off = 4;
        for blk in 0..nblocks {
            let scale = f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
            off += 4;
            simd::dequantize_block(&bytes[off..off + b], scale, &mut buf);
            off += b;
            // H is self-inverse under the 1/√B normalization: applying the
            // unnormalized FWHT then multiplying by 1/√B inverts encode.
            simd::fwht(&mut buf);
            signs_rng.rademacher_fill(&mut signs);
            let start = blk * b;
            let take = (n - start).min(b);
            let base = out.len();
            out.resize(base + take, 0.0);
            simd::scaled_signed_mul(&buf[..take], &signs[..take], inv_sqrt, &mut out[base..]);
        }
        ws.give(buf);
        ws.give(signs);
    }

    fn wire_len(&self, n: usize) -> u64 {
        4 + (n.div_ceil(self.block) as u64) * (4 + self.block as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gauss(n: usize, seed: u64, sigma: f32) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| rng.normal_f32(0.0, sigma)).collect()
    }

    #[test]
    fn fwht_is_self_inverse_up_to_n() {
        let mut v = gauss(64, 0, 1.0);
        let orig = v.clone();
        fwht(&mut v);
        fwht(&mut v);
        for (a, b) in v.iter().zip(&orig) {
            assert!((a / 64.0 - b).abs() < 1e-4);
        }
    }

    #[test]
    fn roundtrip_error_is_small_and_nonzero() {
        let c = HadamardQuant8::default();
        for (n, sigma) in [(1000usize, 1.0f32), (256, 0.01), (5000, 100.0), (3, 1.0)] {
            let xs = gauss(n, 42, sigma);
            let enc = c.encode(&xs, 7);
            let dec = c.decode(&enc, 7);
            assert_eq!(dec.len(), n);
            let linf = xs
                .iter()
                .zip(&dec)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            // Per-coordinate error bounded by ~ s·√B/127 with s ≈ the
            // post-rotation max ≈ few·σ for gaussian blocks.
            assert!(linf <= sigma * 0.6 + 1e-6, "n={n} σ={sigma} err={linf}");
            if n >= 256 {
                assert!(linf > 0.0, "8-bit quantization cannot be lossless");
            }
        }
    }

    #[test]
    fn wire_size_is_about_one_byte_per_element() {
        let c = HadamardQuant8::default();
        let xs = gauss(4096, 1, 1.0);
        let enc = c.encode(&xs, 3);
        let raw = 4 * 4096u64;
        assert_eq!(enc.wire_bytes(), 4 + 16 * (4 + 256));
        assert_eq!(c.wire_len(4096), enc.wire_bytes());
        assert_eq!(c.wire_len(1), 4 + 4 + 256);
        assert!(enc.wire_bytes() * 3 < raw, "must be ≳ 3.9× smaller than f32");
    }

    #[test]
    fn wrong_seed_fails_to_recover() {
        let c = HadamardQuant8::default();
        let xs = gauss(512, 5, 1.0);
        let enc = c.encode(&xs, 10);
        let good = c.decode(&enc, 10);
        let bad = c.decode(&enc, 11);
        let err_good = crate::tensor::rel_l2_error(&good, &xs);
        let err_bad = crate::tensor::rel_l2_error(&bad, &xs);
        assert!(err_good < 0.02);
        assert!(err_bad > 0.5, "decoding with the wrong signs must garble");
    }

    #[test]
    fn streamed_signs_match_whole_vector_generation() {
        // The per-block sign stream must equal generating the whole
        // padded diagonal up front — the invariant that lets encode
        // and decode stream independently.
        let padded = 3 * DEFAULT_BLOCK;
        let whole = sign_stream(9).rademacher(padded);
        let mut streamed = vec![0.0f32; padded];
        let mut rng = sign_stream(9);
        for blk in 0..3 {
            rng.rademacher_fill(&mut streamed[blk * DEFAULT_BLOCK..(blk + 1) * DEFAULT_BLOCK]);
        }
        assert_eq!(whole, streamed);
    }

    #[test]
    fn into_api_is_byte_identical_to_allocating_api() {
        let c = HadamardQuant8::default();
        let mut ws = Workspace::new();
        for n in [1usize, 255, 256, 257, 1000] {
            let xs = gauss(n, n as u64, 1.0);
            let mut enc = Encoded::default();
            c.encode_into(&xs, 7, &mut ws, &mut enc);
            assert_eq!(enc.bytes, c.encode(&xs, 7).bytes, "n={n}");
            let mut dec = Vec::new();
            c.decode_into(&enc, 7, &mut ws, &mut dec);
            let dec2 = c.decode(&enc, 7);
            assert_eq!(dec, dec2, "n={n}");
            assert_eq!(dec.len(), n);
        }
    }

    #[test]
    fn zeros_roundtrip_exactly() {
        let c = HadamardQuant8::default();
        let xs = vec![0.0f32; 300];
        let dec = c.decode(&c.encode(&xs, 0), 0);
        assert_eq!(dec, xs);
    }

    #[test]
    fn rotation_spreads_outliers() {
        // A single huge coordinate would dominate naive quantization;
        // the Hadamard rotation spreads it so other coords survive.
        let c = HadamardQuant8::default();
        let mut xs = vec![0.01f32; 256];
        xs[17] = 50.0;
        let dec = c.decode(&c.encode(&xs, 2), 2);
        // The small coordinates should still be recovered with error
        // much smaller than the outlier magnitude.
        let small_err: f32 = (0..256)
            .filter(|&i| i != 17)
            .map(|i| (dec[i] - xs[i]).abs())
            .fold(0.0, f32::max);
        assert!((dec[17] - 50.0).abs() < 2.0);
        assert!(small_err < 0.1, "small coords err {small_err}");
    }
}
