//! 8-bit uniform quantization with randomized Hadamard rotation — the
//! paper's downlink codec (native Rust twin of the Pallas kernel
//! `python/compile/kernels/hadamard_quant.py`; the two are cross-checked
//! in `rust/tests/compression_roundtrip.rs` and raced in
//! `bench_micro_hotpath`).
//!
//! Pipeline per length-`B` block (B a power of two):
//!   y = (1/√B) · H_B · (d ⊙ x)   — spread information across the block
//!   s = max|y|,  q_i = round(y_i/s · 127) ∈ i8
//! Wire format: `u32 length ‖ per block (f32 scale ‖ B × i8)`.
//! The Rademacher diagonal `d` is derived from the shared seed, so it
//! costs zero wire bytes.

use std::sync::{Arc, Mutex};

use crate::compression::{DenseCodec, Encoded};
use crate::util::rng::Pcg64;

pub const DEFAULT_BLOCK: usize = 256;

/// Cached sign vectors the encoder state holds. Seeds are unique per
/// (round, client), so the realistic hit is the decode immediately
/// following an encode of the same payload — the cap only needs to
/// cover the worker threads' concurrently in-flight encode/decode
/// pairs, and a small cap bounds retained memory (each entry is a
/// model-sized f32 vector).
const SIGN_CACHE_CAP: usize = 8;

/// One cached Rademacher diagonal: `(seed, padded_len, signs)`.
type SignEntry = (u64, usize, Arc<Vec<f32>>);

pub struct HadamardQuant8 {
    pub block: usize,
    /// Rademacher sign cache keyed by `(seed, padded_len)` — encode and
    /// decode of the same payload derive identical signs, so caching
    /// halves the sign generation per client round (and a stable seed
    /// reuses them outright). Entries are invalidated by key: a new
    /// seed or length simply misses and regenerates; LRU order evicts.
    signs: Mutex<Vec<SignEntry>>,
}

impl HadamardQuant8 {
    pub fn new(block: usize) -> HadamardQuant8 {
        HadamardQuant8 {
            block,
            signs: Mutex::new(Vec::new()),
        }
    }

    fn signs_for(&self, seed: u64, len: usize) -> Arc<Vec<f32>> {
        {
            let mut g = self.signs.lock().unwrap();
            if let Some(pos) = g.iter().position(|e| e.0 == seed && e.1 == len) {
                let e = g.remove(pos); // move to back = most recent
                let s = e.2.clone();
                g.push(e);
                return s;
            }
        }
        // Generate outside the lock (the expensive part).
        let fresh = Arc::new(signs_for(seed, len));
        let mut g = self.signs.lock().unwrap();
        if g.len() >= SIGN_CACHE_CAP {
            g.remove(0);
        }
        g.push((seed, len, fresh.clone()));
        fresh
    }
}

impl Default for HadamardQuant8 {
    fn default() -> Self {
        HadamardQuant8::new(DEFAULT_BLOCK)
    }
}

/// In-place fast Walsh–Hadamard transform (unnormalized butterflies);
/// caller applies the 1/√B normalization.
pub fn fwht(v: &mut [f32]) {
    let n = v.len();
    debug_assert!(n.is_power_of_two());
    let mut h = 1;
    while h < n {
        let stride = h * 2;
        let mut base = 0;
        while base < n {
            for i in base..base + h {
                let a = v[i];
                let b = v[i + h];
                v[i] = a + b;
                v[i + h] = a - b;
            }
            base += stride;
        }
        h = stride;
    }
}

fn signs_for(seed: u64, len: usize) -> Vec<f32> {
    // Stream tag keeps the sign sequence independent of other per-seed
    // randomness (cohort sampling etc.).
    Pcg64::with_stream(seed, 0x5167).rademacher(len)
}

impl DenseCodec for HadamardQuant8 {
    fn name(&self) -> &'static str {
        "quant8"
    }

    fn encode(&self, values: &[f32], seed: u64) -> Encoded {
        let b = self.block;
        let n = values.len();
        let nblocks = n.div_ceil(b);
        let padded = nblocks * b;
        let signs = self.signs_for(seed, padded);
        let inv_sqrt = 1.0 / (b as f32).sqrt();

        let mut bytes = Vec::with_capacity(4 + nblocks * (4 + b));
        bytes.extend_from_slice(&(n as u32).to_le_bytes());
        let mut buf = vec![0.0f32; b];
        let mut qbuf = vec![0u8; b];
        for blk in 0..nblocks {
            let start = blk * b;
            let take = (n - start).min(b);
            buf[..take].copy_from_slice(&values[start..start + take]);
            buf[take..].fill(0.0);
            for (v, s) in buf.iter_mut().zip(&signs[start..start + b]) {
                *v *= s;
            }
            fwht(&mut buf);
            // max|buf| without the per-element normalization multiply
            // (pulled out of the loop; §Perf).
            let mut m = 0.0f32;
            for v in &buf {
                m = m.max(v.abs());
            }
            let scale = m * inv_sqrt;
            bytes.extend_from_slice(&scale.to_le_bytes());
            // Quantize into a stack buffer, then one memcpy — avoids the
            // bounds-checked byte-at-a-time push (§Perf).
            let qs = if scale > 0.0 { 127.0 / m } else { 0.0 };
            for (dst, v) in qbuf.iter_mut().zip(&buf) {
                *dst = (v * qs).round().clamp(-127.0, 127.0) as i8 as u8;
            }
            bytes.extend_from_slice(&qbuf);
        }
        Encoded { bytes }
    }

    fn decode(&self, enc: &Encoded, seed: u64) -> Vec<f32> {
        let b = self.block;
        let n = u32::from_le_bytes(enc.bytes[0..4].try_into().unwrap()) as usize;
        let nblocks = n.div_ceil(b);
        let padded = nblocks * b;
        let signs = self.signs_for(seed, padded);
        let inv_sqrt = 1.0 / (b as f32).sqrt();

        let mut out = Vec::with_capacity(n);
        let mut buf = vec![0.0f32; b];
        let mut off = 4;
        for blk in 0..nblocks {
            let scale =
                f32::from_le_bytes(enc.bytes[off..off + 4].try_into().unwrap());
            off += 4;
            for (v, &q) in buf.iter_mut().zip(&enc.bytes[off..off + b]) {
                *v = (q as i8) as f32 / 127.0 * scale;
            }
            off += b;
            // H is self-inverse under the 1/√B normalization: applying the
            // unnormalized FWHT then multiplying by 1/√B inverts encode.
            fwht(&mut buf);
            let start = blk * b;
            let take = (n - start).min(b);
            for i in 0..take {
                out.push(buf[i] * inv_sqrt * signs[start + i]);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gauss(n: usize, seed: u64, sigma: f32) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| rng.normal_f32(0.0, sigma)).collect()
    }

    #[test]
    fn fwht_is_self_inverse_up_to_n() {
        let mut v = gauss(64, 0, 1.0);
        let orig = v.clone();
        fwht(&mut v);
        fwht(&mut v);
        for (a, b) in v.iter().zip(&orig) {
            assert!((a / 64.0 - b).abs() < 1e-4);
        }
    }

    #[test]
    fn roundtrip_error_is_small_and_nonzero() {
        let c = HadamardQuant8::default();
        for (n, sigma) in [(1000usize, 1.0f32), (256, 0.01), (5000, 100.0), (3, 1.0)] {
            let xs = gauss(n, 42, sigma);
            let enc = c.encode(&xs, 7);
            let dec = c.decode(&enc, 7);
            assert_eq!(dec.len(), n);
            let linf = xs
                .iter()
                .zip(&dec)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            // Per-coordinate error bounded by ~ s·√B/127 with s ≈ the
            // post-rotation max ≈ few·σ for gaussian blocks.
            assert!(linf <= sigma * 0.6 + 1e-6, "n={n} σ={sigma} err={linf}");
            if n >= 256 {
                assert!(linf > 0.0, "8-bit quantization cannot be lossless");
            }
        }
    }

    #[test]
    fn wire_size_is_about_one_byte_per_element() {
        let c = HadamardQuant8::default();
        let xs = gauss(4096, 1, 1.0);
        let enc = c.encode(&xs, 3);
        let raw = 4 * 4096u64;
        assert_eq!(enc.wire_bytes(), 4 + 16 * (4 + 256));
        assert!(enc.wire_bytes() * 3 < raw, "must be ≳ 3.9× smaller than f32");
    }

    #[test]
    fn wrong_seed_fails_to_recover() {
        let c = HadamardQuant8::default();
        let xs = gauss(512, 5, 1.0);
        let enc = c.encode(&xs, 10);
        let good = c.decode(&enc, 10);
        let bad = c.decode(&enc, 11);
        let err_good = crate::tensor::rel_l2_error(&good, &xs);
        let err_bad = crate::tensor::rel_l2_error(&bad, &xs);
        assert!(err_good < 0.02);
        assert!(err_bad > 0.5, "decoding with the wrong signs must garble");
    }

    #[test]
    fn sign_cache_hits_and_invalidates() {
        let c = HadamardQuant8::default();
        let a = c.signs_for(7, 512);
        let b = c.signs_for(7, 512);
        assert!(std::sync::Arc::ptr_eq(&a, &b), "same (seed, len) must hit");
        let d = c.signs_for(8, 512); // seed change → regenerate
        assert!(!std::sync::Arc::ptr_eq(&a, &d));
        let e = c.signs_for(7, 256); // length change → regenerate
        assert_eq!(e.len(), 256);
        assert!(!std::sync::Arc::ptr_eq(&a, &e));
        // Cached signs are exactly the seed-derived sequence.
        assert_eq!(*a, signs_for(7, 512));
        // Encode/decode agree through the cache (and with fresh state).
        let xs = gauss(512, 1, 1.0);
        let enc = c.encode(&xs, 7);
        let fresh = HadamardQuant8::default();
        let enc2 = fresh.encode(&xs, 7);
        assert_eq!(enc.bytes, enc2.bytes);
    }

    #[test]
    fn zeros_roundtrip_exactly() {
        let c = HadamardQuant8::default();
        let xs = vec![0.0f32; 300];
        let dec = c.decode(&c.encode(&xs, 0), 0);
        assert_eq!(dec, xs);
    }

    #[test]
    fn rotation_spreads_outliers() {
        // A single huge coordinate would dominate naive quantization;
        // the Hadamard rotation spreads it so other coords survive.
        let c = HadamardQuant8::default();
        let mut xs = vec![0.01f32; 256];
        xs[17] = 50.0;
        let dec = c.decode(&c.encode(&xs, 2), 2);
        // The small coordinates should still be recovered with error
        // much smaller than the outlier magnitude.
        let small_err: f32 = (0..256)
            .filter(|&i| i != 17)
            .map(|i| (dec[i] - xs[i]).abs())
            .fold(0.0, f32::max);
        assert!((dec[17] - 50.0).abs() < 2.0);
        assert!(small_err < 0.1, "small coords err {small_err}");
    }
}
