//! Sparse index codecs for the DGC uplink wire format.
//!
//! A sparsified delta is a set of (index, value) pairs over a vector of
//! known length. Three index encodings are implemented and the encoder
//! picks the smallest per message:
//!
//! * `Bitmap`  — n/8 bytes regardless of k (wins when k/n ≳ 1/40).
//! * `U32`     — 4 bytes per index (wins for very sparse messages over
//!               short vectors).
//! * `Varint`  — delta-gap LEB128 (usually wins: sorted indices have
//!               small gaps at DGC sparsities).
//!
//! The `*_into` entry points are the hot path: they write into
//! caller-provided sinks (wire output, varint staging, decoded
//! index/value buffers), so a warm client round encodes and decodes
//! sparse messages with zero heap allocations; the allocating
//! wrappers delegate to them byte-for-byte.

/// LEB128 unsigned varint.
pub fn write_varint(mut v: u64, out: &mut Vec<u8>) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

pub fn read_varint(bytes: &[u8], pos: &mut usize) -> u64 {
    let mut v = 0u64;
    let mut shift = 0;
    loop {
        let b = bytes[*pos];
        *pos += 1;
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return v;
        }
        shift += 7;
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum IndexScheme {
    Bitmap = 0,
    U32 = 1,
    Varint = 2,
}

/// Encode sorted indices with the smallest applicable scheme, staging
/// the varint candidate in `varint_scratch` (cleared first; capacity
/// reused). Format: `u8 scheme ‖ u32 k ‖ payload`.
pub fn encode_indices_into(
    indices: &[u32],
    n: usize,
    varint_scratch: &mut Vec<u8>,
    out: &mut Vec<u8>,
) {
    debug_assert!(indices.windows(2).all(|w| w[0] < w[1]), "sorted+unique");
    let k = indices.len();
    let bitmap_sz = n.div_ceil(8);
    let u32_sz = 4 * k;
    varint_scratch.clear();
    let mut prev = 0u32;
    for (i, &idx) in indices.iter().enumerate() {
        let gap = if i == 0 { idx } else { idx - prev - 1 };
        write_varint(gap as u64, varint_scratch);
        prev = idx;
    }
    let (scheme, _) = [
        (IndexScheme::Bitmap, bitmap_sz),
        (IndexScheme::U32, u32_sz),
        (IndexScheme::Varint, varint_scratch.len()),
    ]
    .into_iter()
    .min_by_key(|(_, sz)| *sz)
    .unwrap();

    out.push(scheme as u8);
    out.extend_from_slice(&(k as u32).to_le_bytes());
    match scheme {
        IndexScheme::Bitmap => {
            // Build the bitmap in place on the output sink (zeroed
            // range, then set bits) — no staging buffer.
            let base = out.len();
            out.resize(base + bitmap_sz, 0);
            for &i in indices {
                out[base + (i as usize) / 8] |= 1 << (i % 8);
            }
        }
        IndexScheme::U32 => {
            for &i in indices {
                out.extend_from_slice(&i.to_le_bytes());
            }
        }
        IndexScheme::Varint => out.extend_from_slice(varint_scratch),
    }
}

/// Allocating wrapper around [`encode_indices_into`].
pub fn encode_indices(indices: &[u32], n: usize, out: &mut Vec<u8>) {
    let mut scratch = Vec::with_capacity(2 * indices.len());
    encode_indices_into(indices, n, &mut scratch, out);
}

/// Decode indices into `out` (cleared first; capacity reused); returns
/// bytes consumed.
pub fn decode_indices_into(bytes: &[u8], n: usize, out: &mut Vec<u32>) -> usize {
    let scheme = bytes[0];
    let k = u32::from_le_bytes(bytes[1..5].try_into().unwrap()) as usize;
    let mut pos = 5;
    out.clear();
    out.reserve(k);
    match scheme {
        0 => {
            let bitmap_sz = n.div_ceil(8);
            let bm = &bytes[pos..pos + bitmap_sz];
            for i in 0..n {
                if bm[i / 8] & (1 << (i % 8)) != 0 {
                    out.push(i as u32);
                }
            }
            pos += bitmap_sz;
        }
        1 => {
            for _ in 0..k {
                out.push(u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()));
                pos += 4;
            }
        }
        2 => {
            let mut prev = 0u32;
            for i in 0..k {
                let gap = read_varint(bytes, &mut pos) as u32;
                let idx = if i == 0 { gap } else { prev + 1 + gap };
                out.push(idx);
                prev = idx;
            }
        }
        s => panic!("unknown index scheme {s}"),
    }
    debug_assert_eq!(out.len(), k);
    pos
}

/// Allocating wrapper: decode indices; returns (indices, bytes consumed).
pub fn decode_indices(bytes: &[u8], n: usize) -> (Vec<u32>, usize) {
    let mut out = Vec::new();
    let used = decode_indices_into(bytes, n, &mut out);
    (out, used)
}

/// Full sparse-vector message into `out` (appended; callers clear).
/// Format: `u32 n ‖ indices ‖ k × f32`. `varint_scratch` stages the
/// varint index candidate (capacity reused).
pub fn encode_sparse_into(
    indices: &[u32],
    values: &[f32],
    n: usize,
    varint_scratch: &mut Vec<u8>,
    out: &mut Vec<u8>,
) {
    assert_eq!(indices.len(), values.len());
    out.reserve(9 + indices.len() * 6);
    out.extend_from_slice(&(n as u32).to_le_bytes());
    encode_indices_into(indices, n, varint_scratch, out);
    for v in values {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Allocating wrapper around [`encode_sparse_into`].
pub fn encode_sparse(indices: &[u32], values: &[f32], n: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(9 + indices.len() * 6);
    let mut scratch = Vec::with_capacity(2 * indices.len());
    encode_sparse_into(indices, values, n, &mut scratch, &mut out);
    out
}

/// Fully-checked decode of a sparse message into caller-provided
/// index/value sinks (cleared first; capacity reused); returns the
/// dense length `n`, or a diagnosable error on any malformed input —
/// truncated headers/payloads, oversized counts, overflowing varints —
/// without panicking and without reserving more memory than the
/// message's own length can justify (every `reserve` is preceded by a
/// remaining-bytes check, so a hostile count cannot force a huge
/// allocation). The transport layer decodes remote `UpdateUp` bodies
/// through this.
pub fn try_decode_sparse_into(
    bytes: &[u8],
    indices: &mut Vec<u32>,
    values: &mut Vec<f32>,
) -> Result<usize, &'static str> {
    if bytes.len() < 4 {
        return Err("message shorter than its length header");
    }
    let n = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
    let rest = &bytes[4..];
    if rest.len() < 5 {
        return Err("truncated index header");
    }
    let scheme = rest[0];
    let k = u32::from_le_bytes(rest[1..5].try_into().unwrap()) as usize;
    if k > n {
        return Err("more indices than coordinates");
    }
    let mut pos = 5usize;
    indices.clear();
    match scheme {
        0 => {
            let bm = n.div_ceil(8);
            if rest.len() - pos < bm {
                return Err("truncated index bitmap");
            }
            indices.reserve(k);
            for i in 0..n {
                if rest[pos + i / 8] & (1 << (i % 8)) != 0 {
                    indices.push(i as u32);
                }
            }
            pos += bm;
        }
        1 => {
            if rest.len() - pos < 4 * k {
                return Err("truncated u32 indices");
            }
            indices.reserve(k);
            for _ in 0..k {
                let idx = u32::from_le_bytes(rest[pos..pos + 4].try_into().unwrap());
                indices.push(idx);
                pos += 4;
            }
        }
        2 => {
            // Each varint is at least one byte, so k is bounded by the
            // remaining message length before anything is reserved.
            if rest.len() - pos < k {
                return Err("truncated varint indices");
            }
            indices.reserve(k);
            let mut prev = 0u32;
            for i in 0..k {
                let mut v = 0u64;
                let mut shift = 0u32;
                loop {
                    if pos >= rest.len() {
                        return Err("truncated varint index");
                    }
                    let b = rest[pos];
                    pos += 1;
                    v |= ((b & 0x7f) as u64) << shift;
                    if b & 0x80 == 0 {
                        break;
                    }
                    shift += 7;
                    if shift > 63 {
                        return Err("varint index overflows 64 bits");
                    }
                }
                let gap = u32::try_from(v).map_err(|_| "index gap overflows u32")?;
                let idx = if i == 0 {
                    gap
                } else {
                    prev
                        .checked_add(1)
                        .and_then(|p| p.checked_add(gap))
                        .ok_or("index overflows u32")?
                };
                indices.push(idx);
                prev = idx;
            }
        }
        _ => return Err("unknown index scheme"),
    }
    if indices.len() != k {
        return Err("index count disagrees with header");
    }
    if rest.len() - pos < 4 * k {
        return Err("truncated values");
    }
    values.clear();
    values.reserve(k);
    for c in rest[pos..pos + 4 * k].chunks_exact(4) {
        values.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
    Ok(n)
}

/// Decode a sparse message into caller-provided index/value sinks
/// (cleared first; capacity reused); returns the dense length `n`.
/// Panics with the defect name on malformed input (trusted-input
/// callers — the server decodes untrusted remote bodies through
/// [`try_decode_sparse_into`] instead).
pub fn decode_sparse_into(bytes: &[u8], indices: &mut Vec<u32>, values: &mut Vec<f32>) -> usize {
    try_decode_sparse_into(bytes, indices, values)
        .unwrap_or_else(|e| panic!("sparse decode: {e}"))
}

/// Allocating wrapper around [`decode_sparse_into`].
pub fn decode_sparse(bytes: &[u8]) -> (Vec<u32>, Vec<f32>, usize) {
    let mut indices = Vec::new();
    let mut values = Vec::new();
    let n = decode_sparse_into(bytes, &mut indices, &mut values);
    (indices, values, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn varint_roundtrip() {
        let vals = [0u64, 1, 127, 128, 300, 1 << 20, u64::MAX];
        let mut buf = Vec::new();
        for &v in &vals {
            write_varint(v, &mut buf);
        }
        let mut pos = 0;
        for &v in &vals {
            assert_eq!(read_varint(&buf, &mut pos), v);
        }
        assert_eq!(pos, buf.len());
    }

    fn random_indices(n: usize, k: usize, seed: u64) -> Vec<u32> {
        let mut rng = Pcg64::new(seed);
        let mut idx = rng.sample_indices(n, k);
        idx.sort_unstable();
        idx.into_iter().map(|i| i as u32).collect()
    }

    #[test]
    fn all_schemes_roundtrip() {
        for (n, k) in [(1000usize, 5usize), (1000, 400), (64, 64), (10_000, 100), (8, 0)] {
            let idx = random_indices(n, k, (n + k) as u64);
            let mut buf = Vec::new();
            encode_indices(&idx, n, &mut buf);
            let (got, used) = decode_indices(&buf, n);
            assert_eq!(got, idx, "n={n} k={k}");
            assert_eq!(used, buf.len());
        }
    }

    #[test]
    fn into_api_reuses_sinks_and_matches_allocating_api() {
        let mut scratch = Vec::new();
        let mut wire = Vec::new();
        let mut idx_out = Vec::new();
        let mut val_out = Vec::new();
        for (n, k) in [(1000usize, 5usize), (800, 400), (10_000, 100), (8, 0)] {
            let idx = random_indices(n, k, 7 * (n + k) as u64);
            let vals: Vec<f32> = idx.iter().map(|&i| i as f32 * 0.5).collect();
            wire.clear();
            encode_sparse_into(&idx, &vals, n, &mut scratch, &mut wire);
            assert_eq!(wire, encode_sparse(&idx, &vals, n), "n={n} k={k}");
            let got_n = decode_sparse_into(&wire, &mut idx_out, &mut val_out);
            assert_eq!(got_n, n);
            assert_eq!(idx_out, idx);
            assert_eq!(val_out, vals);
        }
    }

    #[test]
    fn dense_selection_picks_bitmap() {
        let n = 800;
        let idx = random_indices(n, 400, 1);
        let mut buf = Vec::new();
        encode_indices(&idx, n, &mut buf);
        assert_eq!(buf[0], 0, "bitmap should win at 50% density");
        assert_eq!(buf.len(), 5 + 100);
    }

    #[test]
    fn sparse_selection_picks_varint() {
        let n = 1_000_000;
        let idx = random_indices(n, 500, 2);
        let mut buf = Vec::new();
        encode_indices(&idx, n, &mut buf);
        assert_eq!(buf[0], 2, "varint should win at 0.05% density");
        assert!(buf.len() < 5 + 4 * 500, "varint must beat u32 here");
    }

    #[test]
    fn try_decode_rejects_malformed_without_panicking() {
        let n = 5000;
        let idx = random_indices(n, 50, 4);
        let vals: Vec<f32> = idx.iter().map(|&i| i as f32).collect();
        let msg = encode_sparse(&idx, &vals, n);
        let mut gi = Vec::new();
        let mut gv = Vec::new();
        // Well-formed round-trips through the checked path.
        assert_eq!(try_decode_sparse_into(&msg, &mut gi, &mut gv), Ok(n));
        assert_eq!(gi, idx);
        assert_eq!(gv, vals);
        // Truncation at every byte is an Err, never a panic.
        for cut in 0..msg.len() {
            assert!(
                try_decode_sparse_into(&msg[..cut], &mut gi, &mut gv).is_err(),
                "prefix {cut}"
            );
        }
        // A hostile count cannot force a huge reserve: claim u32::MAX
        // indices in a tiny message.
        let mut hostile = msg.clone();
        hostile[5..9].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(try_decode_sparse_into(&hostile, &mut gi, &mut gv).is_err());
        // Unknown scheme byte.
        let mut bad = msg.clone();
        bad[4] = 9;
        assert_eq!(
            try_decode_sparse_into(&bad, &mut gi, &mut gv),
            Err("unknown index scheme")
        );
    }

    #[test]
    fn sparse_message_roundtrip() {
        let n = 5000;
        let idx = random_indices(n, 50, 3);
        let vals: Vec<f32> = idx.iter().map(|&i| i as f32 * 0.25).collect();
        let msg = encode_sparse(&idx, &vals, n);
        let (gi, gv, gn) = decode_sparse(&msg);
        assert_eq!(gn, n);
        assert_eq!(gi, idx);
        assert_eq!(gv, vals);
    }
}
