//! Compression substrate: the paper's downlink and uplink codecs.
//!
//! * [`quant`] — 8-bit uniform quantization after a randomized Hadamard
//!   rotation (Konečný et al. '16; Lyubarskii & Vershynin '10). Applied
//!   to **server→client** sub-model payloads ("we compress all
//!   server-to-clients exchanges using 8-bit Gradient Quantization after
//!   applying Hadamard transformation").
//! * [`dgc`] — Deep Gradient Compression (Lin et al. '18): top-k
//!   sparsification with momentum correction, local gradient
//!   accumulation and gradient clipping. Applied to **client→server**
//!   model deltas ("DGC only operates on client-to-server communications
//!   because it is ingrained in the local training process").
//! * [`sparse`] — index codecs (bitmap vs u32 vs varint) used by DGC's
//!   wire format; picked per message by size.
//!
//! Codecs are *real*: they serialize to bytes and decode back, so the
//! byte counts fed to the network simulator are the actual encoded
//! sizes and the distortion the training loop sees is the actual
//! quantization/sparsification error.
//!
//! ## Allocation-free entry points
//!
//! The hot path runs through the `*_into` methods: every codec writes
//! its wire bytes into a caller-provided [`Encoded`] and decodes into
//! a caller-provided `Vec<f32>`, drawing internal scratch (block
//! buffers, streamed Hadamard signs, varint staging) from the
//! [`Workspace`] arena — so a warmed client round encodes and decodes
//! with **zero heap allocations** (`rust/tests/zero_alloc.rs`). The
//! allocating `encode`/`decode` wrappers delegate to `*_into` and are
//! byte-identical to them; inner loops dispatch through
//! [`crate::tensor::simd`] and are byte-identical between the SIMD
//! and scalar paths (`rust/tests/simd_conformance.rs`). See
//! `rust/src/compression/README.md` for the scratch contract.

pub mod dgc;
pub mod quant;
pub mod sparse;

use crate::tensor::kernels::Workspace;

/// A wire message with its true encoded size.
#[derive(Clone, Debug, Default)]
pub struct Encoded {
    pub bytes: Vec<u8>,
}

impl Encoded {
    pub fn wire_bytes(&self) -> u64 {
        self.bytes.len() as u64
    }
}

/// Downlink codec interface (dense f32 payloads). `Sync` because the
/// scheduler shares one codec across the worker pool (codecs are
/// stateless; shared randomness is derived from the per-call seed).
///
/// Implementations provide the allocation-free `*_into` methods;
/// `encode`/`decode` are convenience wrappers that allocate and must
/// stay byte-identical (they delegate by default).
pub trait DenseCodec: Send + Sync {
    fn name(&self) -> &'static str;

    /// Encode into `out` (cleared first; capacity reused). `seed` lets
    /// encoder+decoder derive shared randomness (Hadamard signs)
    /// without shipping it; `ws` supplies internal scratch.
    fn encode_into(&self, values: &[f32], seed: u64, ws: &mut Workspace, out: &mut Encoded);

    /// Decode a raw wire-byte slice into `out` (cleared first; capacity
    /// reused). The slice form is the primitive so transports can
    /// decode borrowed frame payloads zero-copy.
    fn decode_slice_into(&self, bytes: &[u8], seed: u64, ws: &mut Workspace, out: &mut Vec<f32>);

    /// Decode into `out` (cleared first; capacity reused).
    fn decode_into(&self, enc: &Encoded, seed: u64, ws: &mut Workspace, out: &mut Vec<f32>) {
        self.decode_slice_into(&enc.bytes, seed, ws, out);
    }

    /// Exact wire length (bytes) of an encoding of `n` values — lets a
    /// receiver validate a payload's length *before* decoding it, so a
    /// mismatched stream errors diagnosably instead of panicking in
    /// the decoder.
    fn wire_len(&self, n: usize) -> u64;

    /// Allocating wrapper around [`DenseCodec::encode_into`].
    fn encode(&self, values: &[f32], seed: u64) -> Encoded {
        let mut ws = Workspace::new();
        let mut out = Encoded::default();
        self.encode_into(values, seed, &mut ws, &mut out);
        out
    }

    /// Allocating wrapper around [`DenseCodec::decode_into`].
    fn decode(&self, enc: &Encoded, seed: u64) -> Vec<f32> {
        let mut ws = Workspace::new();
        let mut out = Vec::new();
        self.decode_into(enc, seed, &mut ws, &mut out);
        out
    }
}

/// Identity codec: raw little-endian f32 (the No-Compression baseline).
pub struct RawF32;

impl DenseCodec for RawF32 {
    fn name(&self) -> &'static str {
        "raw_f32"
    }

    fn encode_into(&self, values: &[f32], _seed: u64, _ws: &mut Workspace, out: &mut Encoded) {
        let _sp = crate::obs::span_ab(crate::obs::Stage::CodecEncode, values.len() as u64, 0);
        let bytes = &mut out.bytes;
        bytes.clear();
        bytes.reserve(4 + values.len() * 4);
        bytes.extend_from_slice(&(values.len() as u32).to_le_bytes());
        for v in values {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn decode_slice_into(&self, bytes: &[u8], _seed: u64, _ws: &mut Workspace, out: &mut Vec<f32>) {
        let _sp = crate::obs::span_ab(crate::obs::Stage::CodecDecode, bytes.len() as u64, 0);
        let n = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
        assert!(
            bytes.len() >= 4 + 4 * n,
            "raw_f32 decode: encoded buffer holds {} bytes but its header claims \
             {n} f32 values ({} bytes) — truncated or corrupt message",
            bytes.len(),
            4 + 4 * n
        );
        out.clear();
        out.reserve(n);
        for c in bytes[4..4 + 4 * n].chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
    }

    fn wire_len(&self, n: usize) -> u64 {
        4 + 4 * n as u64
    }
}

/// Build a downlink codec by name.
pub fn make_dense_codec(kind: &str) -> anyhow::Result<Box<dyn DenseCodec>> {
    Ok(match kind {
        "raw" => Box::new(RawF32),
        "quant8" => Box::new(quant::HadamardQuant8::default()),
        other => anyhow::bail!("unknown dense codec {other:?} (raw|quant8)"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_roundtrip_exact() {
        let xs: Vec<f32> = (0..37).map(|i| i as f32 * 0.5 - 7.0).collect();
        let c = RawF32;
        let enc = c.encode(&xs, 1);
        assert_eq!(enc.wire_bytes(), 4 + 37 * 4);
        assert_eq!(c.wire_len(37), enc.wire_bytes());
        assert_eq!(c.decode(&enc, 1), xs);
    }

    #[test]
    fn raw_into_reuses_buffers_and_matches_allocating_api() {
        let xs: Vec<f32> = (0..100).map(|i| i as f32 * 0.25).collect();
        let c = RawF32;
        let mut ws = Workspace::new();
        let mut enc = Encoded::default();
        let mut dec = Vec::new();
        for run in 0..3 {
            c.encode_into(&xs, 1, &mut ws, &mut enc);
            assert_eq!(enc.bytes, c.encode(&xs, 1).bytes, "run {run}");
            c.decode_into(&enc, 1, &mut ws, &mut dec);
            assert_eq!(dec, xs, "run {run}");
        }
    }

    #[test]
    #[should_panic(expected = "raw_f32 decode")]
    fn raw_decode_names_the_buffer_on_truncation() {
        let c = RawF32;
        let mut enc = c.encode(&[1.0, 2.0, 3.0], 0);
        enc.bytes.truncate(8); // header claims 3 values, payload cut
        let _ = c.decode(&enc, 0);
    }

    #[test]
    fn factory() {
        assert!(make_dense_codec("raw").is_ok());
        assert!(make_dense_codec("quant8").is_ok());
        assert!(make_dense_codec("zstd99").is_err());
    }
}
