//! Compression substrate: the paper's downlink and uplink codecs.
//!
//! * [`quant`] — 8-bit uniform quantization after a randomized Hadamard
//!   rotation (Konečný et al. '16; Lyubarskii & Vershynin '10). Applied
//!   to **server→client** sub-model payloads ("we compress all
//!   server-to-clients exchanges using 8-bit Gradient Quantization after
//!   applying Hadamard transformation").
//! * [`dgc`] — Deep Gradient Compression (Lin et al. '18): top-k
//!   sparsification with momentum correction, local gradient
//!   accumulation and gradient clipping. Applied to **client→server**
//!   model deltas ("DGC only operates on client-to-server communications
//!   because it is ingrained in the local training process").
//! * [`sparse`] — index codecs (bitmap vs u32 vs varint) used by DGC's
//!   wire format; picked per message by size.
//!
//! Codecs are *real*: they serialize to bytes and decode back, so the
//! byte counts fed to the network simulator are the actual encoded
//! sizes and the distortion the training loop sees is the actual
//! quantization/sparsification error.

pub mod dgc;
pub mod quant;
pub mod sparse;

/// A wire message with its true encoded size.
#[derive(Clone, Debug)]
pub struct Encoded {
    pub bytes: Vec<u8>,
}

impl Encoded {
    pub fn wire_bytes(&self) -> u64 {
        self.bytes.len() as u64
    }
}

/// Downlink codec interface (dense f32 payloads). `Sync` because the
/// scheduler shares one codec across the worker pool (codecs are
/// stateless; shared randomness is derived from the per-call seed).
pub trait DenseCodec: Send + Sync {
    fn name(&self) -> &'static str;
    /// Encode; `seed` lets encoder+decoder derive shared randomness
    /// (Hadamard signs) without shipping it.
    fn encode(&self, values: &[f32], seed: u64) -> Encoded;
    fn decode(&self, enc: &Encoded, seed: u64) -> Vec<f32>;
}

/// Identity codec: raw little-endian f32 (the No-Compression baseline).
pub struct RawF32;

impl DenseCodec for RawF32 {
    fn name(&self) -> &'static str {
        "raw_f32"
    }

    fn encode(&self, values: &[f32], _seed: u64) -> Encoded {
        let mut bytes = Vec::with_capacity(4 + values.len() * 4);
        bytes.extend_from_slice(&(values.len() as u32).to_le_bytes());
        for v in values {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        Encoded { bytes }
    }

    fn decode(&self, enc: &Encoded, _seed: u64) -> Vec<f32> {
        let n = u32::from_le_bytes(enc.bytes[0..4].try_into().unwrap()) as usize;
        enc.bytes[4..4 + 4 * n]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }
}

/// Build a downlink codec by name.
pub fn make_dense_codec(kind: &str) -> anyhow::Result<Box<dyn DenseCodec>> {
    Ok(match kind {
        "raw" => Box::new(RawF32),
        "quant8" => Box::new(quant::HadamardQuant8::default()),
        other => anyhow::bail!("unknown dense codec {other:?} (raw|quant8)"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_roundtrip_exact() {
        let xs: Vec<f32> = (0..37).map(|i| i as f32 * 0.5 - 7.0).collect();
        let c = RawF32;
        let enc = c.encode(&xs, 1);
        assert_eq!(enc.wire_bytes(), 4 + 37 * 4);
        assert_eq!(c.decode(&enc, 1), xs);
    }

    #[test]
    fn factory() {
        assert!(make_dense_codec("raw").is_ok());
        assert!(make_dense_codec("quant8").is_ok());
        assert!(make_dense_codec("zstd99").is_err());
    }
}
