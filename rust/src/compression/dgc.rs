//! Deep Gradient Compression (Lin et al., ICLR'18) — the uplink codec
//! and the paper's state-of-the-art comparison point.
//!
//! DGC ships only the top-k largest-magnitude coordinates of each
//! update and keeps the rest as *local accumulation* so no information
//! is lost, only delayed. The four accuracy-preserving ingredients from
//! the paper are implemented on the FedAvg model delta
//! (`ΔW = W_local − W_received`, the pseudo-gradient of a round):
//!
//! 1. **Momentum correction** — accumulate `u = m·u + Δ` and sparsify
//!    the velocity accumulation `v += u` rather than raw deltas.
//! 2. **Local gradient accumulation** — unsent coordinates of `v` (and
//!    `u`) carry over to later rounds.
//! 3. **Gradient clipping** — `Δ` is L2-clipped before accumulation.
//! 4. **Masked momentum** (momentum-factor masking) — sent coordinates
//!    reset both `v` and `u`, preventing stale momentum.
//!
//! Each FL client owns one [`DgcState`]; the server decodes with
//! [`decode`] (shared wire format from [`super::sparse`]).
//!
//! The hot path is [`DgcState::compress_into`]: the momentum scan
//! dispatches through [`crate::tensor::simd`] (bit-identical scalar /
//! AVX2), the top-k value gather vectorizes, and the wire message plus
//! varint staging go into caller-provided sinks — zero heap
//! allocations once the accumulators and sinks are warm. The
//! allocating [`DgcState::compress`] wrapper delegates byte-for-byte.

use crate::compression::sparse;
use crate::tensor::simd;

#[derive(Clone, Debug)]
pub struct DgcConfig {
    /// Fraction of coordinates sent per round (e.g. 0.03 ⇒ 97% sparse).
    pub sparsity: f64,
    /// Momentum-correction factor `m` (0 disables).
    pub momentum: f32,
    /// L2 clipping threshold; `None` disables.
    pub clip_norm: Option<f32>,
}

impl Default for DgcConfig {
    fn default() -> Self {
        DgcConfig {
            sparsity: 0.03,
            momentum: 0.9,
            clip_norm: Some(5.0),
        }
    }
}

/// Per-client DGC accumulation state (survives across rounds).
#[derive(Debug)]
pub struct DgcState {
    cfg: DgcConfig,
    /// Momentum buffer `u` (lazily sized on first use).
    u: Vec<f32>,
    /// Velocity accumulation `v`.
    v: Vec<f32>,
    /// Reusable top-k candidate indices (refilled with `0..n` per
    /// round; keeping the buffer avoids a fresh `(0..n).collect()`
    /// allocation every compress).
    idx_scratch: Vec<u32>,
    /// Reusable gathered-values buffer for the wire encoder.
    val_scratch: Vec<f32>,
}

/// Manual `Clone`: the scheduler snapshots DGC state to roll back
/// cut/churn-dropped clients — the scratch buffers carry no round
/// state, so clones start them empty instead of copying.
impl Clone for DgcState {
    fn clone(&self) -> DgcState {
        DgcState {
            cfg: self.cfg.clone(),
            u: self.u.clone(),
            v: self.v.clone(),
            idx_scratch: Vec::new(),
            val_scratch: Vec::new(),
        }
    }
}

impl DgcState {
    pub fn new(cfg: DgcConfig) -> DgcState {
        DgcState {
            cfg,
            u: Vec::new(),
            v: Vec::new(),
            idx_scratch: Vec::new(),
            val_scratch: Vec::new(),
        }
    }

    pub fn config(&self) -> &DgcConfig {
        &self.cfg
    }

    /// Residual buffers `(u, v)` — momentum and velocity accumulation.
    /// Both are empty until the first compress. The residual store's
    /// spill path persists exactly these two vectors (plus the RNG and
    /// participation count); the scratch buffers carry no round state.
    pub fn residuals(&self) -> (&[f32], &[f32]) {
        (&self.u, &self.v)
    }

    /// Restore residual buffers from a spill record, reusing existing
    /// capacity (no allocation when the shell previously held buffers
    /// of at least this length). `u` and `v` must be the same length.
    pub fn restore_residuals(&mut self, u: &[f32], v: &[f32]) {
        assert_eq!(u.len(), v.len(), "restore_residuals: u/v length mismatch");
        self.u.clear();
        self.u.extend_from_slice(u);
        self.v.clear();
        self.v.extend_from_slice(v);
    }

    /// Heap bytes currently held by this state (residuals + scratch
    /// capacity) — the residual store's budget accounting.
    pub fn resident_bytes(&self) -> usize {
        self.u.capacity() * 4
            + self.v.capacity() * 4
            + self.idx_scratch.capacity() * 4
            + self.val_scratch.capacity() * 4
    }

    /// Residual mass currently held back (diagnostics).
    pub fn residual_l2(&self) -> f32 {
        crate::tensor::l2_norm(&self.v)
    }

    /// Compress one round's delta into `out` (cleared first; capacity
    /// reused), staging the varint index candidate in
    /// `varint_scratch`. Internal accumulators keep everything that
    /// was not sent. Allocation-free once the accumulators (first call
    /// per model size) and sinks are warm.
    pub fn compress_into(
        &mut self,
        delta: &[f32],
        varint_scratch: &mut Vec<u8>,
        out: &mut Vec<u8>,
    ) {
        let _sp = crate::obs::span_ab(crate::obs::Stage::DgcCompress, delta.len() as u64, 0);
        let n = delta.len();
        if n == 0 {
            out.clear();
            sparse::encode_sparse_into(&[], &[], 0, varint_scratch, out);
            return;
        }
        if self.u.len() != n {
            // Resize-in-place keeps capacity when a pooled shell is
            // reused for the same model size (the residual store's
            // zero-alloc rehydration path).
            self.u.clear();
            self.u.resize(n, 0.0);
            self.v.clear();
            self.v.resize(n, 0.0);
        }

        // (3) gradient clipping on the incoming delta.
        let mut scale = 1.0f32;
        if let Some(c) = self.cfg.clip_norm {
            let norm = crate::tensor::l2_norm(delta);
            if norm > c {
                scale = c / norm;
            }
        }

        // (1) momentum correction + (2) accumulation (elementwise,
        // SIMD-dispatched, bit-identical to the scalar scan).
        let m = self.cfg.momentum;
        simd::dgc_scan(&mut self.u, &mut self.v, delta, m, scale);

        // Top-k selection on |v|.
        let k = ((n as f64) * self.cfg.sparsity).ceil() as usize;
        let k = k.clamp(1, n);
        let Self {
            v,
            u,
            idx_scratch,
            val_scratch,
            ..
        } = self;
        idx_scratch.clear();
        idx_scratch.extend(0..n as u32);
        // Partial selection: O(n) average via select_nth. `total_cmp`
        // (not `partial_cmp(..).unwrap()`) keeps NaN deltas from
        // panicking: NaN magnitudes sort as largest, deterministically.
        idx_scratch.select_nth_unstable_by(k - 1, |&a, &b| {
            let va = v[a as usize].abs();
            let vb = v[b as usize].abs();
            vb.total_cmp(&va)
        });
        idx_scratch.truncate(k);
        idx_scratch.sort_unstable();

        val_scratch.clear();
        simd::gather_extend(val_scratch, v, idx_scratch);
        // (4) masked momentum: clear sent coordinates in both buffers.
        for &i in idx_scratch.iter() {
            v[i as usize] = 0.0;
            u[i as usize] = 0.0;
        }
        out.clear();
        sparse::encode_sparse_into(idx_scratch, val_scratch, n, varint_scratch, out);
    }

    /// Allocating wrapper around [`DgcState::compress_into`].
    pub fn compress(&mut self, delta: &[f32]) -> Vec<u8> {
        let mut out = Vec::new();
        let mut scratch = Vec::new();
        self.compress_into(delta, &mut scratch, &mut out);
        out
    }
}

/// Server side: decode a DGC message into a dense delta.
pub fn decode(bytes: &[u8]) -> Vec<f32> {
    let (idx, vals, n) = sparse::decode_sparse(bytes);
    let mut out = vec![0.0f32; n];
    for (i, v) in idx.into_iter().zip(vals) {
        out[i as usize] = v;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn gauss(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn sends_only_k_coordinates() {
        let mut st = DgcState::new(DgcConfig {
            sparsity: 0.01,
            momentum: 0.0,
            clip_norm: None,
        });
        let delta = gauss(10_000, 0);
        let msg = st.compress(&delta);
        let dec = decode(&msg);
        let nz = dec.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nz, 100);
        // Sent coordinates are the largest-magnitude ones.
        let mut mags: Vec<f32> = delta.iter().map(|v| v.abs()).collect();
        mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let threshold = mags[99];
        for (i, &v) in dec.iter().enumerate() {
            if v != 0.0 {
                assert!(delta[i].abs() >= threshold * 0.999, "coord {i}");
            }
        }
    }

    #[test]
    fn accumulation_preserves_mass_without_momentum() {
        // With m=0 and no clipping, sum of everything decoded over many
        // rounds equals the sum of all deltas (nothing is lost).
        let n = 512;
        let mut st = DgcState::new(DgcConfig {
            sparsity: 0.05,
            momentum: 0.0,
            clip_norm: None,
        });
        let mut total_in = vec![0.0f32; n];
        let mut total_out = vec![0.0f32; n];
        for r in 0..60 {
            let d = gauss(n, r);
            crate::tensor::add_assign(&mut total_in, &d);
            let out = decode(&st.compress(&d));
            crate::tensor::add_assign(&mut total_out, &out);
        }
        // Outstanding residual accounts for the whole difference.
        for i in 0..n {
            let diff = total_in[i] - total_out[i];
            assert!((diff - st.v[i]).abs() < 1e-3, "coord {i}");
        }
    }

    #[test]
    fn momentum_amplifies_persistent_directions() {
        let n = 256;
        let mut st = DgcState::new(DgcConfig {
            sparsity: 0.02,
            momentum: 0.9,
            clip_norm: None,
        });
        // A constant direction on coord 7, noise elsewhere.
        let mut sent7 = 0.0f32;
        for r in 0..30 {
            let mut d = gauss(n, 100 + r);
            for v in d.iter_mut() {
                *v *= 0.05;
            }
            d[7] += 1.0;
            let out = decode(&st.compress(&d));
            sent7 += out[7];
        }
        // With momentum the persistent coordinate must dominate what was
        // shipped: total ≈ Σ_t (1+m+…) ≥ the raw sum of 30.
        assert!(sent7 > 30.0, "sent7={sent7}");
    }

    #[test]
    fn clipping_bounds_update_norm() {
        let mut st = DgcState::new(DgcConfig {
            sparsity: 1.0, // send everything → decode == accumulated
            momentum: 0.0,
            clip_norm: Some(1.0),
        });
        let mut d = gauss(64, 5);
        crate::tensor::scale(100.0, &mut d); // huge delta
        let out = decode(&st.compress(&d));
        let norm = crate::tensor::l2_norm(&out);
        assert!((norm - 1.0).abs() < 1e-3, "norm={norm}");
    }

    #[test]
    fn wire_size_much_smaller_than_dense() {
        let mut st = DgcState::new(DgcConfig::default());
        let d = gauss(100_000, 9);
        let msg = st.compress(&d);
        let dense = 4 * 100_000;
        assert!(
            msg.len() * 15 < dense,
            "expected ≥15× reduction, got {}x",
            dense / msg.len()
        );
    }

    #[test]
    fn nan_delta_does_not_panic() {
        // Regression: top-k used `partial_cmp(..).unwrap()`, which
        // panics the moment a NaN reaches the comparator. `total_cmp`
        // sorts NaN magnitudes first instead — deterministic, no panic.
        let mut st = DgcState::new(DgcConfig {
            sparsity: 0.05,
            momentum: 0.9,
            clip_norm: None, // clipping would smear NaN everywhere
        });
        let mut d = gauss(256, 3);
        d[17] = f32::NAN;
        d[201] = f32::NAN;
        let msg = st.compress(&d);
        let dec = decode(&msg);
        assert_eq!(dec.len(), 256);
        // The NaN coordinates were the "largest" and got shipped.
        assert!(dec[17].is_nan());
        assert!(dec[201].is_nan());
        // Later clean rounds keep working on the same state.
        let msg2 = st.compress(&gauss(256, 4));
        assert_eq!(decode(&msg2).len(), 256);
    }

    #[test]
    fn clone_resets_scratch_but_keeps_accumulators() {
        let mut st = DgcState::new(DgcConfig::default());
        let _ = st.compress(&gauss(512, 8));
        let cl = st.clone();
        assert_eq!(cl.v, st.v);
        assert_eq!(cl.u, st.u);
        assert!(cl.idx_scratch.is_empty());
        assert!(cl.val_scratch.is_empty());
    }

    #[test]
    fn compress_into_matches_allocating_api_and_reuses_sinks() {
        let mut a = DgcState::new(DgcConfig::default());
        let mut b = DgcState::new(DgcConfig::default());
        let mut scratch = Vec::new();
        let mut out = Vec::new();
        for r in 0..5 {
            let d = gauss(512, 40 + r);
            let want = a.compress(&d);
            b.compress_into(&d, &mut scratch, &mut out);
            assert_eq!(out, want, "round {r}");
        }
        assert_eq!(a.v, b.v);
        assert_eq!(a.u, b.u);
    }

    #[test]
    fn residual_export_restore_roundtrips_exactly() {
        let mut st = DgcState::new(DgcConfig::default());
        let _ = st.compress(&gauss(300, 21));
        let (u, v) = st.residuals();
        let (u, v) = (u.to_vec(), v.to_vec());
        let mut shell = DgcState::new(DgcConfig::default());
        let _ = shell.compress(&gauss(300, 22)); // warm the shell's buffers
        shell.restore_residuals(&u, &v);
        // The restored state continues bit-identically to the original.
        let d = gauss(300, 23);
        assert_eq!(st.compress(&d), shell.compress(&d));
        assert_eq!(st.u, shell.u);
        assert_eq!(st.v, shell.v);
    }

    #[test]
    fn state_resizes_on_model_change() {
        let mut st = DgcState::new(DgcConfig::default());
        let _ = st.compress(&gauss(100, 1));
        let msg = st.compress(&gauss(200, 2)); // different length: reset
        let dec = decode(&msg);
        assert_eq!(dec.len(), 200);
    }
}
