//! Deterministic, seedable RNG substrate (no `rand` crate offline).
//!
//! `Pcg64` implements PCG-XSL-RR 128/64 (O'Neill 2014): a 128-bit LCG with
//! an xor-shift + random-rotation output function. It is fast, passes
//! BigCrush, and — critically for the reproduction — every experiment in
//! this repo is exactly reproducible from its seed (the paper reports
//! means over 5 seeds; our benches do the same).

/// PCG-XSL-RR 128/64.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed with an arbitrary u64; stream constant fixed.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Independent stream: distinct `stream` values give statistically
    /// independent sequences for the same seed (used per-client).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64) | stream as u128) | 1;
        let mut rng = Self { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive a child RNG (e.g. per client / per round) without
    /// correlating streams.
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        let a = self.next_u64();
        Pcg64::with_stream(a ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15), tag | 1)
    }

    /// Export the raw `(state, inc)` pair. Together with
    /// [`Pcg64::from_raw`] this gives an exact serialization of the
    /// generator position — the residual store's spill file persists
    /// evicted clients' RNGs this way so rehydration resumes the
    /// stream bit-for-bit.
    pub fn to_raw(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from a [`Pcg64::to_raw`] export.
    pub fn from_raw(state: u128, inc: u128) -> Pcg64 {
        Pcg64 { state, inc }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) (Lemire's rejection-free-ish method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // Widening multiply; rejection loop removes modulo bias.
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (cached second value omitted for
    /// simplicity; callers are not throughput-bound on normals).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) uniformly (partial
    /// Fisher–Yates over an index table; O(n) setup, used for cohorts).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Weighted sampling of `k` distinct indices ∝ weights (Efraimidis &
    /// Spirakis exponential-jump keys: key_i = u_i^(1/w_i); top-k keys).
    ///
    /// Zero/negative weights are treated as a tiny epsilon so every unit
    /// retains a nonzero chance — the paper's score maps start at 0 and
    /// must still explore (weighted *random* selection, Alg. 1 line 9).
    pub fn weighted_sample_distinct(&mut self, weights: &[f64], k: usize) -> Vec<usize> {
        assert!(k <= weights.len());
        let mut keyed: Vec<(f64, usize)> = weights
            .iter()
            .enumerate()
            .map(|(i, &w)| {
                let w = if w > 0.0 { w } else { 1e-9 };
                let u = self.next_f64().max(1e-300);
                (u.ln() / w, i) // log-space key; larger is better
            })
            .collect();
        // Partial selection of the k largest keys: O(n) average via
        // select_nth instead of a full O(n log n) sort (§Perf: 81µs →
        // ~26µs on a 2048-unit group).
        if k > 0 && k < keyed.len() {
            keyed.select_nth_unstable_by(k - 1, |a, b| {
                b.0.partial_cmp(&a.0).unwrap()
            });
        }
        keyed.truncate(k);
        keyed.into_iter().map(|(_, i)| i).collect()
    }

    /// Rademacher ±1 signs (Hadamard diagonal), deterministic per seed —
    /// the downlink encoder and client decoder derive the same signs from
    /// the round seed instead of shipping them.
    pub fn rademacher(&mut self, n: usize) -> Vec<f32> {
        let mut out = vec![0.0; n];
        self.rademacher_fill(&mut out);
        out
    }

    /// Fill `out` with Rademacher signs — the same draw sequence as
    /// [`Pcg64::rademacher`], into a caller-provided buffer (the
    /// allocation-free codec paths stream signs block by block).
    ///
    /// The draw is **batched**: one `next_u64` yields up to 64 signs
    /// (bit `i` of the word is sign `i` of the chunk, `1` ⇒ `-1.0`),
    /// so a quant8 block costs `B/64` PRNG steps instead of `B`. A
    /// partial tail chunk still consumes one whole word and discards
    /// the unused bits — therefore two fills chain identically to one
    /// longer fill exactly when every fill length is a multiple of 64
    /// (the quant8 block sizes are), which is the invariant that lets
    /// encode and decode stream the diagonal independently.
    pub fn rademacher_fill(&mut self, out: &mut [f32]) {
        for chunk in out.chunks_mut(64) {
            let mut word = self.next_u64();
            for v in chunk.iter_mut() {
                *v = if word & 1 == 0 { 1.0 } else { -1.0 };
                word >>= 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::new(1);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut rng = Pcg64::new(2);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(3);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::new(4);
        for _ in 0..50 {
            let s = rng.sample_indices(20, 7);
            let mut t = s.clone();
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), 7);
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn weighted_sampling_prefers_heavy_indices() {
        let mut rng = Pcg64::new(5);
        let mut weights = vec![1.0; 20];
        weights[3] = 200.0;
        weights[11] = 200.0;
        let mut hits3 = 0;
        let mut hits11 = 0;
        let trials = 400;
        for _ in 0..trials {
            let s = rng.weighted_sample_distinct(&weights, 5);
            assert_eq!(s.len(), 5);
            if s.contains(&3) {
                hits3 += 1;
            }
            if s.contains(&11) {
                hits11 += 1;
            }
        }
        assert!(hits3 > trials * 9 / 10, "hits3={hits3}");
        assert!(hits11 > trials * 9 / 10, "hits11={hits11}");
    }

    #[test]
    fn weighted_sampling_zero_weights_still_selectable() {
        let mut rng = Pcg64::new(6);
        let weights = vec![0.0; 10];
        let s = rng.weighted_sample_distinct(&weights, 10);
        assert_eq!(s.len(), 10); // must fill k even with all-zero scores
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(7);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut s = xs.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn raw_roundtrip_resumes_the_stream_exactly() {
        let mut rng = Pcg64::with_stream(99, 7);
        for _ in 0..13 {
            rng.next_u64();
        }
        let (state, inc) = rng.to_raw();
        let mut resumed = Pcg64::from_raw(state, inc);
        for _ in 0..64 {
            assert_eq!(rng.next_u64(), resumed.next_u64());
        }
    }

    #[test]
    fn forks_are_decorrelated() {
        let mut root = Pcg64::new(8);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let eq = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(eq, 0);
    }

    #[test]
    fn rademacher_balanced() {
        let mut rng = Pcg64::new(9);
        let signs = rng.rademacher(10_000);
        let pos = signs.iter().filter(|&&s| s > 0.0).count();
        assert!((pos as i64 - 5000).abs() < 300, "pos={pos}");
        assert!(signs.iter().all(|&s| s == 1.0 || s == -1.0));
    }

    #[test]
    fn rademacher_batches_64_signs_per_word() {
        // The batched draw is pinned to the PRNG word stream: sign i of
        // a 64-chunk is bit i of one `next_u64` (1 ⇒ -1.0), and a
        // partial tail chunk consumes exactly one word.
        let mut words = Pcg64::new(11);
        let (w0, w1) = (words.next_u64(), words.next_u64());
        let mut rng = Pcg64::new(11);
        let signs = rng.rademacher(64 + 7);
        for i in 0..64 {
            let want = if (w0 >> i) & 1 == 0 { 1.0 } else { -1.0 };
            assert_eq!(signs[i], want, "bit {i}");
        }
        for i in 0..7 {
            let want = if (w1 >> i) & 1 == 0 { 1.0 } else { -1.0 };
            assert_eq!(signs[64 + i], want, "tail bit {i}");
        }
        // The tail discarded the rest of w1: the next draw starts on a
        // fresh word.
        let mut cont = Pcg64::new(11);
        let _ = cont.rademacher(64 + 7);
        assert_eq!(cont.next_u64(), words.next_u64());
    }

    #[test]
    fn rademacher_fills_chain_at_multiples_of_64() {
        // Per-block streaming == one whole-vector draw when every block
        // length is a multiple of 64 (the quant8 invariant).
        let whole = Pcg64::new(12).rademacher(4 * 128);
        let mut rng = Pcg64::new(12);
        let mut streamed = vec![0.0f32; 4 * 128];
        for blk in streamed.chunks_mut(128) {
            rng.rademacher_fill(blk);
        }
        assert_eq!(whole, streamed);
    }
}
