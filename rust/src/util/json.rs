//! Minimal JSON substrate (no `serde` offline): parser + writer.
//!
//! Used for `artifacts/manifest.json`, experiment configs and metrics
//! export. Supports the full JSON grammar (objects, arrays, strings with
//! escapes, numbers, bools, null); numbers are held as f64 (adequate for
//! every value we exchange — sizes, offsets, rates).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------------ access
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object member lookup that errors with the path (manifest parsing
    /// wants loud failures, not silent defaults).
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            msg: format!("missing key {key:?}"),
            pos: 0,
        })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ------------------------------------------------------- construction
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Json {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn from_f64(v: f64) -> Json {
        Json::Num(v)
    }

    // ----------------------------------------------------------- writing
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(0));
        s
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(ind + 1));
                        v.write(out, Some(ind + 1));
                    } else {
                        v.write(out, None);
                    }
                }
                if let Some(ind) = indent {
                    if !a.is_empty() {
                        out.push('\n');
                        out.push_str(&" ".repeat(ind));
                    }
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(ind + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent.map(|i| i + 1));
                }
                if let Some(ind) = indent {
                    if !m.is_empty() {
                        out.push('\n');
                        out.push_str(&" ".repeat(ind));
                    }
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------- parsing

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

pub fn parse_file(path: &std::path::Path) -> Result<Json, anyhow::Error> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {lit}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            let combined =
                                0x10000 + (((cp - 0xD800) << 10) | (lo - 0xDC00));
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-decode multi-byte UTF-8 in place.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert!(a[2].get("b").unwrap().is_null());
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let src = r#"{"n": 1.5, "s": "a\"b", "arr": [true, false, null], "o": {}}"#;
        let v = parse(src).unwrap();
        for text in [v.to_string_pretty(), v.to_string_compact()] {
            assert_eq!(parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
        // Raw UTF-8 passthrough too.
        let v = parse("\"héllo\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo"));
    }

    #[test]
    fn errors_are_positioned() {
        let e = parse("{\"a\": }").unwrap_err();
        assert!(e.pos > 0);
        assert!(parse("[1, 2").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn integer_rendering_has_no_fraction() {
        let v = Json::Num(42.0);
        assert_eq!(v.to_string_compact(), "42");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::obj());
        assert_eq!(parse("  [ ]  ").unwrap(), Json::Arr(vec![]));
    }
}
