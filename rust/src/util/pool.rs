//! Worker thread-pool substrate (no `tokio`/`rayon` offline).
//!
//! The scheduler ([`crate::sched::Engine`]) fans a dispatch batch's
//! local training out across this pool whenever the model runtime is
//! thread-safe (`RuntimeHost::Parallel`, the native backend); the PJRT
//! backend executes serially on the coordinator thread because its
//! wrapper types are not `Send` (XLA parallelizes internally). `Pool`
//! is a fixed-size worker pool with a parallel map that preserves
//! input order — all the structure the engine needs, none of the
//! generality we'd get (and pay for) from an async runtime. Python is
//! never on this path.
//!
//! Error-vs-panic contract of [`Pool::map`]: fallible jobs return
//! their `Result`s as ordinary *values*, collected in input order
//! (the scheduler's jobs return `anyhow::Result` and the caller
//! decides what an `Err` means); *panics* in jobs are caught, all
//! remaining jobs still run, and one captured panic is re-raised on
//! the caller thread afterwards.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size worker pool.
pub struct Pool {
    tx: mpsc::Sender<Msg>,
    rx_shared: Arc<Mutex<mpsc::Receiver<Msg>>>,
    workers: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl Pool {
    pub fn new(size: usize) -> Pool {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx_shared = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx_shared);
                thread::Builder::new()
                    .name(format!("afd-worker-{i}"))
                    .spawn(move || {
                        // Pre-register this worker's span ring so the
                        // first traced job records allocation-free.
                        crate::obs::register_thread();
                        loop {
                            let msg = { rx.lock().unwrap().recv() };
                            match msg {
                                Ok(Msg::Run(job)) => job(),
                                Ok(Msg::Shutdown) | Err(_) => break,
                            }
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Pool {
            tx,
            rx_shared,
            workers,
            size,
        }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Worker count [`Pool::default_for_machine`] would choose —
    /// computable without spawning anything (used to size shard
    /// layouts before any thread exists).
    pub fn default_machine_width() -> usize {
        let n = thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        n.saturating_sub(1).max(1)
    }

    /// Default pool sized to the machine (leaving a core for the
    /// coordinator thread).
    pub fn default_for_machine() -> Pool {
        Pool::new(Pool::default_machine_width())
    }

    /// Parallel map preserving input order. Panics in tasks are captured
    /// and re-raised on the caller thread (after all tasks finish).
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let f = Arc::new(f);
        let (done_tx, done_rx) = mpsc::channel::<(usize, thread::Result<R>)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let done = done_tx.clone();
            let job: Job = Box::new(move || {
                let out = catch_unwind(AssertUnwindSafe(|| f(item)));
                let _ = done.send((i, out));
            });
            self.tx.send(Msg::Run(job)).expect("pool closed");
        }
        drop(done_tx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
        for _ in 0..n {
            let (i, res) = done_rx.recv().expect("worker vanished");
            match res {
                Ok(r) => slots[i] = Some(r),
                Err(p) => panic = Some(p),
            }
        }
        if let Some(p) = panic {
            std::panic::resume_unwind(p);
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }

    /// Fire-and-wait execution of heterogeneous closures.
    pub fn run_all(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'static>>) {
        let n = jobs.len();
        let (done_tx, done_rx) = mpsc::channel::<thread::Result<()>>();
        for job in jobs {
            let done = done_tx.clone();
            let wrapped: Job = Box::new(move || {
                let out = catch_unwind(AssertUnwindSafe(job));
                let _ = done.send(out);
            });
            self.tx.send(Msg::Run(wrapped)).expect("pool closed");
        }
        drop(done_tx);
        let mut panic = None;
        for _ in 0..n {
            if let Err(p) = done_rx.recv().expect("worker vanished") {
                panic = Some(p);
            }
        }
        if let Some(p) = panic {
            std::panic::resume_unwind(p);
        }
    }
}

/// A [`Pool`] whose worker threads spawn on first fan-out. Consumers
/// that share one pool (the scheduler's parallel training path and the
/// sharded aggregator) hold an `Arc<LazyPool>`; runs that never fan
/// out — the serial bit-exactness reference, single-shard aggregation
/// on small models, the PJRT backend — never pay for the threads. The
/// width is fixed at construction so shard layouts can be sized before
/// any thread exists.
pub struct LazyPool {
    inner: std::sync::OnceLock<Pool>,
    size: usize,
}

impl LazyPool {
    /// Lazy pool with a fixed worker count (spawned on first [`get`]).
    ///
    /// [`get`]: LazyPool::get
    pub fn new(size: usize) -> LazyPool {
        LazyPool {
            inner: std::sync::OnceLock::new(),
            size: size.max(1),
        }
    }

    /// Machine-default width (same sizing as
    /// [`Pool::default_for_machine`]), threads not yet spawned.
    pub fn default_for_machine() -> LazyPool {
        LazyPool::new(Pool::default_machine_width())
    }

    /// Worker count the pool has (or will have) — no spawning.
    pub fn size(&self) -> usize {
        self.size
    }

    /// The underlying pool, spawning its workers on first call.
    pub fn get(&self) -> &Pool {
        self.inner.get_or_init(|| Pool::new(self.size))
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        // Wake any worker blocked on an empty queue after the channel is
        // drained: dropping the sender disconnects recv().
        let _ = &self.rx_shared;
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = Pool::new(4);
        let out = pool.map((0..100).collect(), |i: usize| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn map_runs_in_parallel() {
        let pool = Pool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&counter);
        let start = std::time::Instant::now();
        pool.map((0..8).collect(), move |_: usize| {
            c.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(50));
        });
        // 8 × 50ms on 4 workers ≈ 100ms; serial would be 400ms.
        assert!(start.elapsed().as_millis() < 350);
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn panics_propagate() {
        let pool = Pool::new(2);
        pool.map(vec![0, 1, 2], |i: i32| {
            if i == 1 {
                panic!("boom");
            }
            i
        });
    }

    #[test]
    fn map_returns_result_values_without_panicking() {
        // Errors are values: every job completes, Errs come back in
        // input order, and nothing unwinds (contrast `panics_propagate`).
        let pool = Pool::new(3);
        let out: Vec<Result<usize, String>> =
            pool.map((0..10).collect(), |i: usize| {
                if i % 3 == 0 {
                    Err(format!("job {i} failed"))
                } else {
                    Ok(i * 2)
                }
            });
        assert_eq!(out.len(), 10);
        for (i, r) in out.iter().enumerate() {
            if i % 3 == 0 {
                assert_eq!(r.as_ref().unwrap_err(), &format!("job {i} failed"));
            } else {
                assert_eq!(*r.as_ref().unwrap(), i * 2);
            }
        }
        // The pool is still healthy after a batch with errors.
        let ok = pool.map(vec![1, 2, 3], |i: i32| i + 1);
        assert_eq!(ok, vec![2, 3, 4]);
    }

    #[test]
    fn empty_map() {
        let pool = Pool::new(2);
        let out: Vec<i32> = pool.map(Vec::<i32>::new(), |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn lazy_pool_reports_width_without_spawning_and_maps_after() {
        let lazy = LazyPool::new(3);
        // Width is known before any thread exists.
        assert_eq!(lazy.size(), 3);
        // First fan-out spawns; repeated gets reuse the same pool.
        let out = lazy.get().map((0..10).collect(), |i: usize| i * 2);
        assert_eq!(out, (0..10).map(|i| i * 2).collect::<Vec<_>>());
        assert_eq!(lazy.get().size(), 3);
        assert_eq!(LazyPool::default_for_machine().size(), Pool::default_machine_width());
        assert_eq!(LazyPool::new(0).size(), 1, "width clamps to 1 like Pool::new");
    }

    #[test]
    fn pool_survives_many_rounds() {
        let pool = Pool::new(3);
        for round in 0..20 {
            let out = pool.map((0..10).collect(), move |i: usize| i + round);
            assert_eq!(out.len(), 10);
        }
    }
}
