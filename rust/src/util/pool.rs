//! Worker thread-pool substrate (no `tokio`/`rayon` offline).
//!
//! The coordinator trains a round's cohort in parallel: each selected
//! client's local epoch is an independent PJRT execution. `Pool` is a
//! fixed-size worker pool with a `scope`d parallel-map that preserves
//! input order and propagates panics — all the structure the round loop
//! needs, none of the generality we'd get (and pay for) from an async
//! runtime. Python is never on this path.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size worker pool.
pub struct Pool {
    tx: mpsc::Sender<Msg>,
    rx_shared: Arc<Mutex<mpsc::Receiver<Msg>>>,
    workers: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl Pool {
    pub fn new(size: usize) -> Pool {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx_shared = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx_shared);
                thread::Builder::new()
                    .name(format!("afd-worker-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Pool {
            tx,
            rx_shared,
            workers,
            size,
        }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Default pool sized to the machine (leaving a core for the
    /// coordinator thread).
    pub fn default_for_machine() -> Pool {
        let n = thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        Pool::new(n.saturating_sub(1).max(1))
    }

    /// Parallel map preserving input order. Panics in tasks are captured
    /// and re-raised on the caller thread (after all tasks finish).
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let f = Arc::new(f);
        let (done_tx, done_rx) = mpsc::channel::<(usize, thread::Result<R>)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let done = done_tx.clone();
            let job: Job = Box::new(move || {
                let out = catch_unwind(AssertUnwindSafe(|| f(item)));
                let _ = done.send((i, out));
            });
            self.tx.send(Msg::Run(job)).expect("pool closed");
        }
        drop(done_tx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
        for _ in 0..n {
            let (i, res) = done_rx.recv().expect("worker vanished");
            match res {
                Ok(r) => slots[i] = Some(r),
                Err(p) => panic = Some(p),
            }
        }
        if let Some(p) = panic {
            std::panic::resume_unwind(p);
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }

    /// Fire-and-wait execution of heterogeneous closures.
    pub fn run_all(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'static>>) {
        let n = jobs.len();
        let (done_tx, done_rx) = mpsc::channel::<thread::Result<()>>();
        for job in jobs {
            let done = done_tx.clone();
            let wrapped: Job = Box::new(move || {
                let out = catch_unwind(AssertUnwindSafe(job));
                let _ = done.send(out);
            });
            self.tx.send(Msg::Run(wrapped)).expect("pool closed");
        }
        drop(done_tx);
        let mut panic = None;
        for _ in 0..n {
            if let Err(p) = done_rx.recv().expect("worker vanished") {
                panic = Some(p);
            }
        }
        if let Some(p) = panic {
            std::panic::resume_unwind(p);
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        // Wake any worker blocked on an empty queue after the channel is
        // drained: dropping the sender disconnects recv().
        let _ = &self.rx_shared;
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = Pool::new(4);
        let out = pool.map((0..100).collect(), |i: usize| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn map_runs_in_parallel() {
        let pool = Pool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&counter);
        let start = std::time::Instant::now();
        pool.map((0..8).collect(), move |_: usize| {
            c.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(50));
        });
        // 8 × 50ms on 4 workers ≈ 100ms; serial would be 400ms.
        assert!(start.elapsed().as_millis() < 350);
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn panics_propagate() {
        let pool = Pool::new(2);
        pool.map(vec![0, 1, 2], |i: i32| {
            if i == 1 {
                panic!("boom");
            }
            i
        });
    }

    #[test]
    fn empty_map() {
        let pool = Pool::new(2);
        let out: Vec<i32> = pool.map(Vec::<i32>::new(), |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn pool_survives_many_rounds() {
        let pool = Pool::new(3);
        for round in 0..20 {
            let out = pool.map((0..10).collect(), move |i: usize| i + round);
            assert_eq!(out.len(), 10);
        }
    }
}
