//! Declarative CLI flag parser substrate (no `clap` offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, repeated
//! flags, positional arguments and auto-generated `--help`. Each binary
//! (the `afd` launcher, every example and bench) builds an `ArgSpec` and
//! gets consistent parsing + usage text.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct FlagDef {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<String>,
}

#[derive(Default)]
pub struct ArgSpec {
    pub about: &'static str,
    flags: Vec<FlagDef>,
    positional: Vec<(&'static str, &'static str)>,
}

#[derive(Debug)]
pub struct Args {
    values: BTreeMap<String, Vec<String>>,
    positional: Vec<String>,
}

impl ArgSpec {
    pub fn new(about: &'static str) -> Self {
        ArgSpec {
            about,
            ..Default::default()
        }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagDef {
            name,
            help,
            takes_value: false,
            default: None,
        });
        self
    }

    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.flags.push(FlagDef {
            name,
            help,
            takes_value: true,
            default: Some(default.to_string()),
        });
        self
    }

    /// Option with no default (optional value).
    pub fn opt_maybe(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagDef {
            name,
            help,
            takes_value: true,
            default: None,
        });
        self
    }

    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positional.push((name, help));
        self
    }

    pub fn usage(&self, prog: &str) -> String {
        let mut s = format!("{}\n\nUsage: {prog}", self.about);
        for (p, _) in &self.positional {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [flags]\n\nFlags:\n");
        for f in &self.flags {
            let val = if f.takes_value { " <value>" } else { "" };
            let def = match &f.default {
                Some(d) => format!(" (default: {d})"),
                None => String::new(),
            };
            s.push_str(&format!("  --{}{val}\n      {}{def}\n", f.name, f.help));
        }
        s.push_str("  --help\n      print this message\n");
        for (p, h) in &self.positional {
            s.push_str(&format!("\n<{p}>: {h}"));
        }
        s
    }

    /// Parse `std::env::args().skip(1)`-style iterators. On `--help`
    /// prints usage and exits 0; on errors returns Err with message.
    pub fn parse<I: IntoIterator<Item = String>>(
        &self,
        prog: &str,
        argv: I,
    ) -> Result<Args, String> {
        let mut values: BTreeMap<String, Vec<String>> = BTreeMap::new();
        for f in &self.flags {
            if let Some(d) = &f.default {
                values.insert(f.name.to_string(), vec![d.clone()]);
            }
        }
        let mut positional = Vec::new();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                println!("{}", self.usage(prog));
                std::process::exit(0);
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let def = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}"))?;
                let value = if def.takes_value {
                    match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{name} requires a value"))?,
                    }
                } else {
                    if inline.is_some() {
                        return Err(format!("--{name} does not take a value"));
                    }
                    "true".to_string()
                };
                let entry = values.entry(name).or_default();
                if def.default.is_some() && entry.len() == 1 && entry[0] == *def.default.as_ref().unwrap() {
                    entry.clear(); // replace default on first explicit use
                }
                entry.push(value);
            } else {
                positional.push(arg);
            }
        }
        if positional.len() > self.positional.len() {
            return Err(format!(
                "unexpected positional argument {:?}",
                positional[self.positional.len()]
            ));
        }
        Ok(Args { values, positional })
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.values
            .get(name)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn has(&self, name: &str) -> bool {
        self.values.get(name).map(|v| !v.is_empty()).unwrap_or(false)
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    pub fn usize(&self, name: &str) -> Result<usize, String> {
        self.parse_as(name)
    }

    pub fn u64(&self, name: &str) -> Result<u64, String> {
        self.parse_as(name)
    }

    pub fn f64(&self, name: &str) -> Result<f64, String> {
        self.parse_as(name)
    }

    pub fn bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }

    fn parse_as<T: std::str::FromStr>(&self, name: &str) -> Result<T, String> {
        let raw = self
            .get(name)
            .ok_or_else(|| format!("missing --{name}"))?;
        raw.parse::<T>()
            .map_err(|_| format!("--{name}: cannot parse {raw:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("test tool")
            .opt("rounds", "100", "number of rounds")
            .opt_maybe("preset", "preset name")
            .flag("verbose", "chatty output")
            .positional("target", "what to run")
    }

    fn parse(args: &[&str]) -> Result<Args, String> {
        spec().parse("t", args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]).unwrap();
        assert_eq!(a.usize("rounds").unwrap(), 100);
        assert!(!a.bool("verbose"));
        assert!(a.get("preset").is_none());
    }

    #[test]
    fn explicit_values_override() {
        let a = parse(&["--rounds", "7", "--verbose", "--preset=x", "tgt"]).unwrap();
        assert_eq!(a.usize("rounds").unwrap(), 7);
        assert!(a.bool("verbose"));
        assert_eq!(a.get("preset"), Some("x"));
        assert_eq!(a.positional(0), Some("tgt"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse(&["--rounds=55"]).unwrap();
        assert_eq!(a.usize("rounds").unwrap(), 55);
    }

    #[test]
    fn repeated_flags_accumulate() {
        let a = parse(&["--preset", "a", "--preset", "b"]).unwrap();
        assert_eq!(a.get_all("preset"), vec!["a", "b"]);
        assert_eq!(a.get("preset"), Some("b")); // last wins for scalar get
    }

    #[test]
    fn errors() {
        assert!(parse(&["--nope"]).is_err());
        assert!(parse(&["--rounds"]).is_err());
        assert!(parse(&["--verbose=x"]).is_err());
        assert!(parse(&["a", "b"]).is_err());
        let a = parse(&["--rounds", "abc"]).unwrap();
        assert!(a.usize("rounds").is_err());
    }
}
