//! Small statistics toolkit for metrics + the bench harness.

/// Running mean/variance (Welford) — numerically stable accumulation for
/// loss curves and bench timings.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1); 0 for fewer than two points.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Quantile with linear interpolation (q in [0,1]); xs need not be sorted.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
    }
}

/// Simple moving average used to smooth accuracy curves before
/// convergence detection (matches how the paper eyeballs time-to-target
/// on noisy curves).
pub fn moving_average(xs: &[f64], window: usize) -> Vec<f64> {
    let w = window.max(1);
    let mut out = Vec::with_capacity(xs.len());
    let mut sum = 0.0;
    for i in 0..xs.len() {
        sum += xs[i];
        if i >= w {
            sum -= xs[i - w];
        }
        out.push(sum / (i.min(w - 1) + 1) as f64);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn quantiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 2.5);
    }

    #[test]
    fn moving_average_ramps() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ma = moving_average(&xs, 2);
        assert_eq!(ma, vec![0.0, 0.5, 1.5, 2.5]);
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[1.0]), 0.0);
        let w = Welford::new();
        assert_eq!(w.sem(), 0.0);
    }
}
