//! Leveled logging substrate with per-round structured records.
//!
//! `AFD_LOG=debug|info|warn|error` controls verbosity (default info).
//! The coordinator also appends machine-readable JSON-lines round records
//! through `JsonlSink` for post-hoc analysis (EXPERIMENTS.md plots).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);
static START: Mutex<Option<Instant>> = Mutex::new(None);

pub fn init_from_env() {
    let lvl = match std::env::var("AFD_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Info,
    };
    set_level(lvl);
    *START.lock().unwrap() = Some(Instant::now());
}

pub fn set_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    lvl as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(lvl: Level, msg: &str) {
    if !enabled(lvl) {
        return;
    }
    let t = START
        .lock()
        .unwrap()
        .map(|s| s.elapsed().as_secs_f64())
        .unwrap_or(0.0);
    let tag = match lvl {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    eprintln!("[{t:9.3}s {tag}] {msg}");
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, &format!($($arg)*)) };
}

/// Append-only JSON-lines sink (metrics export).
pub struct JsonlSink {
    file: Mutex<std::fs::File>,
}

impl JsonlSink {
    pub fn create(path: &std::path::Path) -> std::io::Result<JsonlSink> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(JsonlSink {
            file: Mutex::new(std::fs::File::create(path)?),
        })
    }

    pub fn write(&self, record: &crate::util::json::Json) {
        let line = record.to_string_compact();
        let mut f = self.file.lock().unwrap();
        let _ = writeln!(f, "{line}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }

    #[test]
    fn jsonl_sink_writes_lines() {
        let dir = std::env::temp_dir().join("afd_log_test");
        let path = dir.join("out.jsonl");
        let sink = JsonlSink::create(&path).unwrap();
        let mut rec = crate::util::json::Json::obj();
        rec.set("round", crate::util::json::Json::Num(3.0));
        sink.write(&rec);
        sink.write(&rec);
        drop(sink);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.contains("\"round\":3"));
    }
}
