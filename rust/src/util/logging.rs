//! Leveled logging substrate with per-round structured records.
//!
//! `AFD_LOG=debug|info|warn|error` controls verbosity (default info).
//! The coordinator also appends machine-readable JSON-lines round records
//! through `JsonlSink` for post-hoc analysis (EXPERIMENTS.md plots).
//!
//! Two reliability properties, both pinned by tests:
//!
//! * **Timestamps never start at zero.** The epoch is a lazy
//!   [`OnceLock`]: the first `log()` call pins it if `init_from_env`
//!   has not run yet, so early messages measure from first use instead
//!   of printing `0.000s` forever.
//! * **Lines are never torn.** Every record — human log line or JSONL
//!   record — is formatted into a buffer first and written through one
//!   locked writer, so concurrent threads cannot interleave fragments.
//!   JSONL write *failures* are not silently swallowed either: they
//!   are counted in an atomic ([`dropped_lines`]) and surfaced in the
//!   end-of-run observability stats dump.

use std::io::Write;
use std::sync::atomic::{AtomicU8, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);
/// Lazily pinned epoch: first use wins, whether that is
/// `init_from_env` or an early `log()` call.
static START: OnceLock<Instant> = OnceLock::new();
/// Serializes whole log lines across threads (stderr's own lock is
/// per-`write` call, which is not enough once a line is assembled from
/// several pieces).
static LOG_WRITER: Mutex<()> = Mutex::new(());
/// JSONL records whose write failed (disk full, closed pipe, …).
static DROPPED_JSONL: AtomicU64 = AtomicU64::new(0);

fn start() -> Instant {
    *START.get_or_init(Instant::now)
}

pub fn init_from_env() {
    let lvl = match std::env::var("AFD_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Info,
    };
    set_level(lvl);
    start();
}

pub fn set_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    lvl as u8 >= LEVEL.load(Ordering::Relaxed)
}

/// JSONL lines dropped because their write failed (see
/// [`JsonlSink::write`]). Exposed in the observability stats dump.
pub fn dropped_lines() -> u64 {
    DROPPED_JSONL.load(Ordering::Relaxed)
}

pub fn log(lvl: Level, msg: &str) {
    if !enabled(lvl) {
        return;
    }
    let t = start().elapsed().as_secs_f64();
    let tag = match lvl {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    let line = format!("[{t:9.3}s {tag}] {msg}\n");
    // One locked write of the whole line: concurrent loggers cannot
    // interleave fragments. Poisoning is harmless here (the guard
    // protects no data), so a panicking logger does not mute the rest
    // of the process.
    let _guard = LOG_WRITER.lock().unwrap_or_else(|e| e.into_inner());
    let _ = std::io::stderr().write_all(line.as_bytes());
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, &format!($($arg)*)) };
}

/// Append-only JSON-lines sink (metrics export).
pub struct JsonlSink {
    file: Mutex<std::fs::File>,
}

impl JsonlSink {
    pub fn create(path: &std::path::Path) -> std::io::Result<JsonlSink> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(JsonlSink {
            file: Mutex::new(std::fs::File::create(path)?),
        })
    }

    /// Append one record as a single line. A failed write cannot abort
    /// an experiment mid-run, but it is not silent either: the drop is
    /// counted and reported at the end of the run.
    pub fn write(&self, record: &crate::util::json::Json) {
        let line = record.to_string_compact();
        let mut f = self.file.lock().unwrap_or_else(|e| e.into_inner());
        if writeln!(f, "{line}").is_err() {
            DROPPED_JSONL.fetch_add(1, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }

    #[test]
    fn jsonl_sink_writes_lines() {
        let dir = std::env::temp_dir().join("afd_log_test");
        let path = dir.join("out.jsonl");
        let sink = JsonlSink::create(&path).unwrap();
        let mut rec = crate::util::json::Json::obj();
        rec.set("round", crate::util::json::Json::Num(3.0));
        sink.write(&rec);
        sink.write(&rec);
        drop(sink);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.contains("\"round\":3"));
    }

    #[test]
    fn epoch_pins_lazily_before_init() {
        // Any `start()` path — here via `log` gating — must yield a
        // usable epoch without `init_from_env` having run.
        let t0 = start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t0.elapsed().as_secs_f64() > 0.0);
        // The epoch is pinned once: later calls return the same instant
        // (`init_from_env` goes through the same `start()`).
        assert_eq!(start(), t0);
    }
}
