//! Counting global allocator for zero-allocation proofs.
//!
//! The hot-path contract of this repo — `train_epoch` and plan-based
//! pack/unpack allocate nothing after warm-up — is enforced by tests
//! and reported by benches. Both need the same instrument: a
//! `GlobalAlloc` wrapper around [`System`] that counts allocation
//! events while armed.
//!
//! The library itself never installs an allocator; binaries that want
//! counting opt in:
//!
//! ```ignore
//! use afd::util::alloc_count::{self, CountingAllocator};
//!
//! #[global_allocator]
//! static ALLOC: CountingAllocator = CountingAllocator;
//!
//! // ... warm up ...
//! alloc_count::arm();
//! hot_path();
//! assert_eq!(alloc_count::disarm(), 0);
//! ```
//!
//! Counting is process-global (any thread's allocations count while
//! armed), so measure with concurrent work quiesced — the zero-alloc
//! test lives alone in its own integration-test binary for exactly
//! this reason.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

static ARMED: AtomicBool = AtomicBool::new(false);
static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

/// System allocator that counts `alloc`/`realloc` events while armed.
pub struct CountingAllocator;

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// Reset the counter and start counting allocation events.
pub fn arm() {
    ALLOCATIONS.store(0, Ordering::SeqCst);
    ARMED.store(true, Ordering::SeqCst);
}

/// Stop counting; returns the number of events since [`arm`].
pub fn disarm() -> u64 {
    ARMED.store(false, Ordering::SeqCst);
    ALLOCATIONS.load(Ordering::SeqCst)
}

/// Current count (armed or not).
pub fn allocations() -> u64 {
    ALLOCATIONS.load(Ordering::SeqCst)
}
