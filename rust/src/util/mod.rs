//! Foundation substrates built in-tree (offline environment: the cargo
//! registry only carries the xla-crate closure, so the usual ecosystem
//! crates — rand, serde, clap, tokio, rayon, criterion, proptest — are
//! replaced by the minimal, tested implementations in this module).

pub mod alloc_count;
pub mod cli;
pub mod json;
pub mod logging;
pub mod pool;
pub mod rng;
pub mod stats;

/// Format a byte count human-readably (tables in benches/examples).
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format seconds as h/m/s for convergence-time tables.
pub fn human_duration(secs: f64) -> String {
    if secs < 60.0 {
        format!("{secs:.1}s")
    } else if secs < 3600.0 {
        format!("{:.1}min", secs / 60.0)
    } else {
        format!("{:.2}h", secs / 3600.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(human_duration(5.0), "5.0s");
        assert_eq!(human_duration(90.0), "1.5min");
        assert_eq!(human_duration(7200.0), "2.00h");
    }
}
