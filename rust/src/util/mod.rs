//! Foundation substrates built in-tree (offline environment: the cargo
//! registry only carries the xla-crate closure, so the usual ecosystem
//! crates — rand, serde, clap, tokio, rayon, criterion, proptest — are
//! replaced by the minimal, tested implementations in this module).

pub mod alloc_count;
pub mod cli;
pub mod json;
pub mod logging;
pub mod pool;
pub mod rng;
pub mod stats;

/// Format a byte count human-readably (tables in benches/examples).
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// FNV-1a 64 over the little-endian bytes of a `u64` stream — the one
/// fold shared by [`model_hash`] and the manifest layout fingerprint,
/// so the two can never quietly diverge in hashing behavior.
pub fn fnv1a_u64s(vals: impl IntoIterator<Item = u64>) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in vals {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Hash of a parameter vector's exact bit patterns — the cheap
/// fingerprint `afd serve` prints so a TCP run and a loopback run can
/// be compared for bit-identity from their logs (and the CI socket
/// smoke does exactly that).
pub fn model_hash(params: &[f32]) -> u64 {
    fnv1a_u64s(params.iter().map(|v| v.to_bits() as u64))
}

/// Format seconds as h/m/s for convergence-time tables.
pub fn human_duration(secs: f64) -> String {
    if secs < 60.0 {
        format!("{secs:.1}s")
    } else if secs < 3600.0 {
        format!("{:.1}min", secs / 60.0)
    } else {
        format!("{:.2}h", secs / 3600.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn model_hash_is_bit_sensitive() {
        let a = vec![0.5f32, -1.25, 3.0];
        let mut b = a.clone();
        assert_eq!(model_hash(&a), model_hash(&b));
        b[1] = f32::from_bits(b[1].to_bits() ^ 1); // one ULP
        assert_ne!(model_hash(&a), model_hash(&b));
        // Signed zero differs from zero at the bit level — the hash
        // must see it (bit-identity, not numeric equality).
        assert_ne!(model_hash(&[0.0]), model_hash(&[-0.0]));
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(human_duration(5.0), "5.0s");
        assert_eq!(human_duration(90.0), "1.5min");
        assert_eq!(human_duration(7200.0), "2.00h");
    }
}
