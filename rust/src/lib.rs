//! # AFD — Adaptive Federated Dropout
//!
//! Production-oriented reproduction of *"Adaptive Federated Dropout:
//! Improving Communication Efficiency and Generalization for Federated
//! Learning"* (Bouacida et al., 2020) as a three-layer Rust + JAX +
//! Pallas system:
//!
//! * **Layer 3 (this crate)** — the federated-learning coordinator:
//!   client selection, activation score maps, sub-model construction
//!   ([`dropout`]), downlink/uplink compression ([`compression`]),
//!   FedAvg aggregation — sharded across the worker pool, optionally
//!   through a hierarchical edge-aggregation tree, with a retained
//!   single-threaded reference both must match bit-for-bit
//!   ([`aggregation`], see `rust/src/aggregation/README.md`) —
//!   wireless link simulation + availability churn ([`network`]), the
//!   event-driven round scheduler with sync/overselect/async-buffered
//!   policies ([`sched`]) and convergence accounting ([`metrics`]).
//! * **Layer 2** — the paper's models (FEMNIST CNN, Shakespeare and
//!   Sent140 LSTMs) written in JAX and AOT-lowered to HLO text
//!   (`python/compile/`), executed from Rust through [`runtime`].
//! * **Layer 1** — Pallas kernels for every dense contraction and the
//!   Hadamard/8-bit quantizer (`python/compile/kernels/`).
//!
//! Python runs only at build time (`make artifacts`); the request path
//! is pure Rust + PJRT.
//!
//! Module map (coordinator side): [`config`] assembles an experiment;
//! [`coordinator`] owns the round loop and drives it through
//! [`sched`]'s virtual-clock engine; [`clients`] holds the fleet as a
//! lazily-materialized `Population` — per-client state derived purely
//! from `(seed, id)` at sampling time, mutable remainders (DGC
//! residuals, RNG position) paged through a byte-budgeted
//! `ResidualStore` with an exact-round-trip spill file, so a
//! million-client run holds only cohort-proportional resident state
//! (see `rust/src/clients/README.md`); per-client work flows through
//! [`dropout`] → [`compression`] → [`transport`] → [`runtime`] →
//! [`aggregation`] (client training and the sharded server-side
//! average share one worker pool; whole rounds aggregate in a single
//! batched dispatch), with [`network`] charging simulated time on
//! measured wire bytes and [`metrics`] keeping the books.
//! [`transport`] frames the whole conversation (versioned,
//! CRC32-checked, length-prefixed — `RoundOffer`/`ModelDown`/
//! `UpdateUp`/`Ack`/`Cut`/`StateSync`, keep masks RLE-compressed when
//! that wins) and runs it over an in-process loopback or real TCP
//! sockets (`afd serve` / `afd client`): one event-loop thread
//! multiplexes all client sockets with non-blocking I/O, rounds
//! pipeline per connection, crashed clients reconnect and resume via
//! exact state replay, and connections that stay dead degrade into
//! policy-visible losses — bit-identical to loopback either way, churn
//! included (see `rust/src/transport/README.md`). [`tensor`] holds the flat-array ops, the blocked
//! training kernels, the runtime-dispatched SIMD layer
//! (`tensor::simd`, cargo feature `simd`: AVX2 with a scalar
//! reference that is bit-identical either way) and the zero-allocation
//! workspace arena — f32 training scratch plus the codec byte/u32/bool
//! pools that make a whole warm client round allocation-free (see
//! `rust/src/tensor/README.md` and `rust/src/compression/README.md`).
//! [`util`] holds the offline substrates (RNG, JSON, CLI, thread
//! pool, stats, counting allocator). [`obs`] is the observability
//! layer threaded through all of the above: an allocation-free span
//! recorder (per-thread ring buffers), a static counter/histogram
//! registry, and Chrome-trace / stats exporters (`--trace-out`,
//! `--stats-out`; cargo feature `trace`, on by default), plus the
//! distributed telemetry plane (`obs::remote`): remote client
//! processes ship span/counter snapshots home in `Telemetry` wire
//! frames, the coordinator merges them — clock-aligned, one trace
//! process group per federation member — and `--metrics-addr` serves
//! live Prometheus/JSON stats mid-run. Recording never changes
//! results (traced runs are bit-identical to untraced,
//! `rust/tests/obs_conformance.rs`; telemetry-armed runs too,
//! `rust/tests/obs_distributed.rs`) and a warm client round stays
//! allocation-free with tracing on (`rust/tests/zero_alloc.rs`). See
//! `rust/src/obs/README.md`. [`fault`] is the robustness mirror of
//! [`obs`]: a deterministic fault-injection engine (`--fault-plan` /
//! `--fault-seed`) whose fire decisions are a pure function of
//! `(fault_seed, site, round, client)`, gated behind the same
//! one-atomic-load pattern — every injected fault class is either
//! fully masked (bit-identical to fault-free) or converted to a typed
//! loss / diagnosable error, never a panic; repeatedly-faulting
//! clients are quarantined, and `afd serve` checkpoints coordinator
//! state at round boundaries so `--restore` resumes a killed run
//! bit-identically (see `rust/src/fault/README.md`).

// The offline substrates favor explicit indexed loops over iterator
// adapters in hot paths; keep clippy's style-only lints from failing
// `-D warnings` CI on that idiom.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::field_reassign_with_default)]
#![allow(clippy::new_without_default)]
#![allow(clippy::manual_memcpy)]

pub mod aggregation;
pub mod bench;
pub mod clients;
pub mod compression;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dropout;
pub mod fault;
pub mod metrics;
pub mod model;
pub mod network;
pub mod obs;
pub mod prop;
pub mod runtime;
pub mod sched;
pub mod tensor;
pub mod transport;
pub mod util;
