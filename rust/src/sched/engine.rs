//! The event-driven round engine: virtual clock, client lifecycle,
//! parallel local training, policy-driven round closing.
//!
//! One [`Engine`] owns the scheduling state of an experiment: the
//! policy, the availability model, a handle to the shared worker pool
//! (also used by the coordinator's sharded aggregator), and — for
//! continuous policies — the in-flight min-heap and the virtual clock.
//! Each [`Engine::step`] produces one aggregation's [`RoundSummary`];
//! the coordinator wraps it into a `RoundRecord` (evaluation stays
//! coordinator-side, costing no simulated time).
//!
//! ## Client lifecycle
//!
//! dispatch (cohort sampled, sub-model selected, epoch drawn)
//!   → compute (local training, executed *eagerly* on the host — the
//!     virtual clock charges `down + compute + up` from the sampled
//!     [`ClientLink`], so simulation order is free to differ from
//!     virtual-time order)
//!   → arrival event (min-heap keyed on virtual arrival time)
//!   → banked by the policy, cut at a deadline, or dropped by churn.
//!
//! Local training runs through `util::pool::Pool` when the runtime is
//! thread-safe ([`RuntimeHost::Parallel`], the native backend); the
//! PJRT backend executes serially on the coordinator thread (its
//! wrapper types are not `Send` — XLA parallelizes internally).
//! Results are identical either way: each client's round is a pure
//! function of its job, and `Pool::map` preserves input order.
//!
//! ## Determinism
//!
//! All RNG draws (cohort sampling, sub-model selection, epoch
//! shuffles) happen on the coordinator thread in dispatch order;
//! worker threads only run the pure per-client function. Arrival ties
//! break on dispatch sequence numbers. With the `Sync` policy and
//! churn disabled, the engine performs exactly the RNG call sequence
//! of the pre-scheduler serial loop and reproduces its `RoundRecord`s
//! bit-for-bit (see `rust/tests/sched_policies.rs`).

use std::collections::BinaryHeap;
use std::sync::Arc;

use anyhow::Result;

use crate::aggregation::{AddOp, Aggregator};
use crate::clients::Population;
use crate::compression::dgc::DgcState;
use crate::compression::DenseCodec;
use crate::config::ExperimentConfig;
use crate::coordinator::{run_client_round, ClientRoundOutcome};
use crate::dropout::SubmodelStrategy;
use crate::model::manifest::VariantSpec;
use crate::model::packing::{PackPlan, PlanCache};
use crate::model::submodel::SubModel;
use crate::network::{Availability, NetworkSim};
use crate::runtime::{EpochData, RuntimeHost};
use crate::sched::policy::SchedulerPolicy;
use crate::tensor::kernels::WorkspacePool;
use crate::transport::{LossReason, StateSyncSnapshot, Transport};
use crate::util::pool::LazyPool;
use crate::util::rng::Pcg64;

/// Everything the engine borrows from the experiment for one step.
/// Field-level borrows keep the engine separable from the coordinator
/// struct (the serial `&mut self.fleet[c]` pattern the engine replaces).
pub struct RoundCtx<'a> {
    pub cfg: &'a ExperimentConfig,
    pub spec: &'a VariantSpec,
    pub runtime: &'a RuntimeHost,
    pub strategy: &'a mut dyn SubmodelStrategy,
    pub downlink: &'a Arc<dyn DenseCodec>,
    /// The client population: datasets, RNG streams and mutable
    /// per-client state, lazily materialized and paged through the
    /// bounded residual store (see [`crate::clients::Population`]).
    /// Replaces the old eager `Vec<ClientState>` fleet + shared
    /// dataset pair.
    pub fleet: &'a mut Population,
    pub net: &'a NetworkSim,
    /// The aggregation path (flat sharded or hierarchical tree — both
    /// bit-identical to the retained `FedAvg` reference; they share
    /// the engine's worker pool).
    pub agg: &'a mut Aggregator,
    pub rng: &'a mut Pcg64,
    pub global: &'a mut Vec<f32>,
    pub lr: f32,
    /// Cumulative simulated seconds before this step (availability
    /// time base for round-scoped policies).
    pub cum_s: f64,
    /// Coordinator-side pack-plan cache (keyed by kept-unit bitmap);
    /// plans are resolved at dispatch so workers never touch the lock.
    pub plans: &'a PlanCache,
    /// Shared scratch workspaces; jobs check one out only while they
    /// execute, so peak scratch scales with worker-pool width, not
    /// cohort size.
    pub workspaces: &'a Arc<WorkspacePool>,
    /// The transport every client round's frames travel through —
    /// in-process loopback by default, real TCP under `afd serve`.
    /// Round-trips run inside the per-client jobs (parallel across the
    /// pool); the round-closing `Ack`/`Cut` control frames go out on
    /// the coordinator thread once inclusion is decided.
    pub transport: &'a Arc<dyn Transport>,
}

/// One aggregation's accounting, produced by [`Engine::step`].
#[derive(Clone, Debug, Default)]
pub struct RoundSummary {
    /// Simulated duration of this round / aggregation window.
    pub round_s: f64,
    /// Measured wire bytes (framed lengths, control frames included).
    pub down_bytes: u64,
    pub up_bytes: u64,
    /// Codec payload bytes alone (wire − payload = framing overhead).
    pub down_payload_bytes: u64,
    pub up_payload_bytes: u64,
    /// Mean local training loss over aggregated clients.
    pub train_loss: f64,
    /// Mean keep fraction over aggregated clients' sub-models.
    pub keep_fraction: f64,
    /// Clients whose updates were aggregated.
    pub arrived: usize,
    /// Stragglers cut by quorum/deadline (work discarded, no bytes
    /// charged).
    pub cut: usize,
    /// Clients lost to availability churn before arrival.
    pub dropped: usize,
    /// Clients lost by the transport mid-exchange (connection death or
    /// I/O timeout) — the graceful-degradation path: their DGC state
    /// is rolled back like a cut and no bytes are charged, but the
    /// record says exactly what the network took.
    pub lost: usize,
    /// Running total of clients excluded from future cohorts after
    /// repeatedly faulting (see `rust/src/fault/README.md`). Always 0
    /// in fault-free runs — genuine churn losses never quarantine.
    pub quarantined: usize,
}

/// A prepared per-client job: everything the (possibly worker-thread)
/// training closure needs, moved out of coordinator state.
struct ClientJob {
    client: usize,
    submodel: SubModel,
    /// Pack plan resolved from the coordinator's cache at dispatch.
    plan: Arc<PackPlan>,
    data: EpochData,
    dgc: Option<DgcState>,
    /// FedAvg weight, reported on the client's uplink frame.
    num_samples: usize,
    /// Pre-round client state captured for session resume (only when
    /// the transport asks; see [`Transport::wants_state_sync`]).
    sync: Option<StateSyncSnapshot>,
}

struct JobResult {
    outcome: ClientRoundOutcome,
    dgc: Option<DgcState>,
    /// The job's epoch buffer, handed back to the client's
    /// [`ClientState`] for reuse next round (allocation-free epoch
    /// assembly after each client's warm-up).
    data: Option<EpochData>,
}

/// An in-flight client's completion event (continuous policies carry
/// these across aggregations).
struct InFlight {
    arrival: f64,
    seq: u64,
    version: u64,
    /// Round id this client was dispatched in (`Ack`/`Cut` frames echo
    /// it back to the device).
    round: u32,
    outcome: ClientRoundOutcome,
    /// Pre-round DGC snapshot, restored if this client is dropped
    /// before its upload lands (see [`Engine::prepare_jobs`]).
    dgc_backup: Option<DgcState>,
}

impl PartialEq for InFlight {
    fn eq(&self, other: &InFlight) -> bool {
        self.seq == other.seq
    }
}

impl Eq for InFlight {}

impl Ord for InFlight {
    // Reversed (earliest arrival first) so BinaryHeap pops in virtual
    // time order; ties break on dispatch sequence for determinism.
    fn cmp(&self, other: &InFlight) -> std::cmp::Ordering {
        other
            .arrival
            .total_cmp(&self.arrival)
            .then(other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for InFlight {
    fn partial_cmp(&self, other: &InFlight) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

fn round_seed(seed: u64, round: usize) -> u64 {
    // Matches the pre-scheduler serial loop's expression bit-for-bit.
    seed ^ ((round as u64) << 20)
}

/// Run one client job under the fault gate. With no fault plan
/// installed this is a direct call (one relaxed atomic load). With a
/// plan active the job runs inside `catch_unwind`, so a panicking
/// worker job — injected or genuine — degrades into the same zeroed
/// lost outcome a transport loss produces instead of tearing down the
/// run; an injected clock stall converts a delivered outcome into a
/// deadline loss after the fact (uniform across policies: the arrival
/// simply never counts, no bytes are charged).
fn run_guarded(
    round: usize,
    client: usize,
    submodel: &SubModel,
    f: impl FnOnce() -> Result<ClientRoundOutcome>,
) -> Result<ClientRoundOutcome> {
    use crate::fault::{self, Site};
    if !fault::enabled() {
        return f();
    }
    let (r, c) = (round as u64, client as u64);
    let panicking = fault::should(Site::WorkerPanic, r, c);
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        if panicking {
            panic!("injected fault: worker panic (round {round}, client {client})");
        }
        f()
    }));
    let mut outcome = match caught {
        Ok(result) => result?,
        // A panic may leave the job's borrowed scratch (workspace
        // buffers, DGC accumulators) half-written; the workspace pool
        // re-allocates lost buffers and the caller rolls DGC back to
        // its pre-round snapshot (the snapshot condition includes
        // `fault::enabled()`), so nothing half-written survives.
        Err(_) => ClientRoundOutcome {
            client,
            submodel: submodel.clone(),
            train_loss: 0.0,
            down_bytes: 0,
            up_bytes: 0,
            down_payload_bytes: 0,
            up_payload_bytes: 0,
            epoch_flops: 0.0,
            reconstructed: Vec::new(),
            coord_mask: Vec::new(),
            agg_plan: None,
            lost: Some(LossReason::Disconnected),
        },
    };
    if outcome.lost.is_none() && fault::should(Site::ClockStall, r, c) {
        // The device finished but its clock stalled past the deadline:
        // the update never arrives and no bytes count. Buffers stay
        // attached — lost outcomes pass through `recycle_outcomes`.
        outcome.down_bytes = 0;
        outcome.up_bytes = 0;
        outcome.down_payload_bytes = 0;
        outcome.up_payload_bytes = 0;
        outcome.train_loss = 0.0;
        outcome.epoch_flops = 0.0;
        outcome.lost = Some(LossReason::Timeout);
    }
    Ok(outcome)
}

/// The event-driven federation scheduler.
pub struct Engine {
    policy: Box<dyn SchedulerPolicy>,
    avail: Availability,
    /// Worker pool for parallel local training; shared (same `Arc`)
    /// with the coordinator's sharded aggregator so training and
    /// aggregation fan out over one set of threads — they never run
    /// concurrently (aggregation starts after the batch's jobs join).
    /// Lazy: workers spawn on the first actual fan-out, so serial
    /// paths (PJRT, the bit-exactness reference) never pay for them.
    pool: Arc<LazyPool>,
    /// Virtual clock (continuous policies only; round-scoped policies
    /// work in per-round offsets to stay bit-compatible with the
    /// serial reference).
    now: f64,
    /// Global model version (incremented per aggregation).
    version: u64,
    /// Dispatch sequence counter (arrival tie-break).
    seq: u64,
    heap: BinaryHeap<InFlight>,
    in_flight: Vec<bool>,
    /// Downlink bytes charged at dispatch, reported at the next
    /// aggregation (continuous policies).
    pending_down: u64,
    /// Codec-payload share of `pending_down` (framing-overhead
    /// accounting).
    pending_down_payload: u64,
    /// Transport losses accumulated since the last summary (continuous
    /// policies lose clients at refill time, between aggregations).
    pending_lost: usize,
    /// Reused output buffer for the batched aggregation: the new
    /// global is built here in one pool dispatch, then swapped with
    /// `ctx.global` (last round's vector becomes next round's
    /// scratch — no per-round model-sized allocation).
    global_scratch: Vec<f32>,
    /// Reused index scratch for epoch assembly (shuffle order).
    epoch_order: Vec<u32>,
    /// Per-client fault tallies (lazily sized; empty in fault-free
    /// runs, so the warm path never touches it).
    fault_counts: Vec<u32>,
    /// Clients excluded from selection after reaching the quarantine
    /// threshold ([`crate::fault::quarantine_after`]).
    quarantined: Vec<bool>,
    quarantined_total: usize,
}

impl Engine {
    pub fn new(
        policy: Box<dyn SchedulerPolicy>,
        avail: Availability,
        pool: Arc<LazyPool>,
    ) -> Engine {
        Engine {
            policy,
            avail,
            pool,
            now: 0.0,
            version: 0,
            seq: 0,
            heap: BinaryHeap::new(),
            in_flight: Vec::new(),
            pending_down: 0,
            pending_down_payload: 0,
            pending_lost: 0,
            global_scratch: Vec::new(),
            epoch_order: Vec::new(),
            fault_counts: Vec::new(),
            quarantined: Vec::new(),
            quarantined_total: 0,
        }
    }

    /// Record one fault attributed to `client`; on the
    /// [`crate::fault::quarantine_after`]-th the client is excluded
    /// from future cohorts (policy-visible via
    /// [`RoundSummary::quarantined`]). Transport and worker losses
    /// only reach here while a fault plan is active — genuine churn
    /// losses in fault-free runs must not perturb selection (the
    /// bit-compatibility contract). Spill-record corruption counts
    /// unconditionally: it only fires on actual data damage.
    fn note_fault(&mut self, client: usize, n: usize) {
        if self.fault_counts.len() < n {
            self.fault_counts.resize(n, 0);
            self.quarantined.resize(n, false);
        }
        self.fault_counts[client] += 1;
        if !self.quarantined[client]
            && self.fault_counts[client] >= crate::fault::quarantine_after()
        {
            self.quarantined[client] = true;
            self.quarantined_total += 1;
            crate::obs::metrics::CLIENTS_QUARANTINED.incr();
            crate::obs::span::mark(
                crate::obs::Stage::QuarantineMark,
                client as u64,
                self.fault_counts[client] as u64,
            );
        }
    }

    fn is_quarantined(&self, c: usize) -> bool {
        self.quarantined.get(c).copied().unwrap_or(false)
    }

    /// Serialize the scheduler's round-boundary state for a
    /// coordinator checkpoint. Only round-scoped policies can
    /// checkpoint: a continuous policy's in-flight heap spans
    /// aggregation boundaries, so a round edge is not a quiescent
    /// point for it.
    pub fn save_state(&self, out: &mut Vec<u8>) -> Result<()> {
        use crate::dropout::statebytes as sb;
        if self.policy.continuous() || !self.heap.is_empty() {
            anyhow::bail!(
                "checkpoint: policy {} is continuous (in-flight work crosses round \
                 boundaries); checkpointing supports round-scoped policies only",
                self.policy.name()
            );
        }
        sb::push_f64(out, self.now);
        sb::push_u64(out, self.version);
        sb::push_u64(out, self.seq);
        sb::push_u64(out, self.fault_counts.len() as u64);
        for &c in &self.fault_counts {
            sb::push_u64(out, c as u64);
        }
        for &q in &self.quarantined {
            sb::push_bool(out, q);
        }
        Ok(())
    }

    /// Restore state written by [`Engine::save_state`].
    pub fn load_state(&mut self, bytes: &[u8]) -> Result<()> {
        use crate::dropout::statebytes as sb;
        let mut r = sb::Reader::new(bytes);
        self.now = r.f64()?;
        self.version = r.u64()?;
        self.seq = r.u64()?;
        let n = r.u64()? as usize;
        self.fault_counts.clear();
        self.quarantined.clear();
        for _ in 0..n {
            self.fault_counts.push(r.u64()? as u32);
        }
        for _ in 0..n {
            self.quarantined.push(r.boolean()?);
        }
        self.quarantined_total = self.quarantined.iter().filter(|&&q| q).count();
        r.finish()
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Execute one round / aggregation window.
    pub fn step(&mut self, round: usize, ctx: &mut RoundCtx) -> Result<RoundSummary> {
        let summary = if self.policy.continuous() {
            self.step_continuous(round, ctx)
        } else {
            self.step_round(round, ctx)
        }?;
        // Round boundary: enforce the residual-store byte budget. Every
        // buffer a job borrowed is back in the store by now (execute_
        // jobs returns DGC/epoch state before any policy decision), so
        // evicting here is always safe — an in-flight async client that
        // gets evicted simply rehydrates when its arrival is processed.
        ctx.fleet.end_round();
        Ok(summary)
    }

    // ---- shared machinery -------------------------------------------

    /// Sample `k` of `cands` via the coordinator RNG. When `cands` is
    /// the full population this performs exactly the serial loop's
    /// `sample_indices(n, k)` call (bit-compatibility).
    fn sample_from(rng: &mut Pcg64, cands: &[usize], k: usize) -> Vec<usize> {
        let k = k.min(cands.len());
        rng.sample_indices(cands.len(), k)
            .into_iter()
            .map(|i| cands[i])
            .collect()
    }

    /// Serially draw each dispatched client's sub-model and epoch (all
    /// RNG on the coordinator thread, dispatch order), moving per-
    /// client state (DGC buffers, epoch data) into owned jobs.
    ///
    /// With `snapshot_dgc`, also returns a pre-round snapshot of each
    /// client's DGC buffers: `run_client_round` clears the sent top-k
    /// coordinates from the accumulators, which is only correct if the
    /// upload actually reaches the server. A client that is later cut
    /// or churn-dropped never delivered — the caller restores its
    /// snapshot so DGC's no-information-loss invariant holds (the
    /// round never happened from the client's perspective). Callers
    /// pass `snapshot_dgc = false` when exclusion is impossible
    /// (`Sync` with churn off) to skip the 2×`num_params` copy.
    ///
    /// The third return is the clients whose residual-store spill
    /// record failed validation (CRC mismatch / truncation): they are
    /// skipped *before* any RNG draw they would have owned —
    /// materialization itself never touches `ctx.rng`, so the skip
    /// leaves every other client's draw sequence untouched — and the
    /// caller reports them as typed losses instead of panicking.
    fn prepare_jobs(
        ctx: &mut RoundCtx,
        round: usize,
        cohort: &[usize],
        snapshot_dgc: bool,
        epoch_order: &mut Vec<u32>,
    ) -> (Vec<ClientJob>, Vec<Option<DgcState>>, Vec<usize>) {
        let mut backups = Vec::with_capacity(cohort.len());
        let mut jobs = Vec::with_capacity(cohort.len());
        let mut spill_lost = Vec::new();
        let want_sync = ctx.transport.wants_state_sync();
        for &c in cohort {
            // Materialize the client first (resident hit, spill
            // rehydration, or fresh pure derivation) — identical state
            // and RNG position to the old eager fleet entry. A corrupt
            // spill record is a per-client loss, not a crash.
            if let Err(e) = ctx.fleet.try_client(c) {
                eprintln!("warn: {e}; treating client as lost");
                spill_lost.push(c);
                continue;
            }
            let submodel = ctx.strategy.select(round, c, ctx.rng);
            let plan = ctx.plans.get(ctx.spec, &submodel);
            let st = ctx.fleet.client(c);
            // Session-resume snapshot: the client's complete
            // mutable remainder (RNG position, participation
            // count, DGC residuals), captured *before* this round
            // mutates any of it — a resuming transport replays it
            // to a restarted process ahead of the dispatch.
            let sync = if want_sync {
                let (rng_state, rng_inc) = st.rng.to_raw();
                let (u, v) = st.dgc.residuals();
                Some(StateSyncSnapshot {
                    client: c as u32,
                    participations: st.participations as u64,
                    rng_state,
                    rng_inc,
                    dgc_u: u.to_vec(),
                    dgc_v: v.to_vec(),
                })
            } else {
                None
            };
            st.participations += 1;
            let num_samples = st.num_samples;
            // Assemble the epoch into the client's recycled buffer
            // (returned by `execute_jobs` after the round; same
            // RNG draw sequence as the allocating `epoch_data`).
            let mut data = st.take_epoch_buf();
            {
                let _sp = crate::obs::span_ab(
                    crate::obs::Stage::EpochAssembly,
                    round as u64,
                    c as u64,
                );
                ctx.fleet.assemble_epoch(c, ctx.spec, epoch_order, &mut data);
            }
            let dgc = if ctx.cfg.uplink_dgc {
                let taken = ctx.fleet.client(c).take_dgc();
                backups.push(snapshot_dgc.then(|| taken.clone()));
                Some(taken)
            } else {
                backups.push(None);
                None
            };
            jobs.push(ClientJob {
                client: c,
                submodel,
                plan,
                data,
                dgc,
                num_samples,
                sync,
            });
        }
        (jobs, backups, spill_lost)
    }

    /// Run the jobs' local training — in parallel on the worker pool
    /// when the runtime is shareable, serially otherwise — and hand
    /// each client's DGC buffers back to the fleet. Output preserves
    /// dispatch order.
    fn execute_jobs(
        &mut self,
        ctx: &mut RoundCtx,
        round: usize,
        jobs: Vec<ClientJob>,
    ) -> Result<Vec<JobResult>> {
        let seed = round_seed(ctx.cfg.seed, round);
        let deadline = self.policy.deadline_s();
        let parallel = match ctx.runtime {
            RuntimeHost::Parallel(rt) if jobs.len() > 1 => Some(rt.clone()),
            _ => None,
        };
        let mut results = match parallel {
            Some(rt) => {
                let spec = ctx.spec.clone();
                let codec = ctx.downlink.clone();
                let global: Arc<Vec<f32>> = Arc::new(ctx.global.clone());
                let lr = ctx.lr;
                let wsp = Arc::clone(ctx.workspaces);
                let transport = Arc::clone(ctx.transport);
                self.pool.get().map(jobs, move |mut job: ClientJob| {
                    let mut dgc = job.dgc.take();
                    // Checked out only for the job's execution window:
                    // peak scratch = concurrently running jobs (pool
                    // width), not cohort size.
                    let mut ws = wsp.checkout();
                    let result = run_guarded(round, job.client, &job.submodel, || {
                        run_client_round(
                            &spec,
                            rt.as_ref(),
                            &global,
                            &job.submodel,
                            &job.plan,
                            &job.data,
                            lr,
                            codec.as_ref(),
                            dgc.as_mut(),
                            round,
                            seed,
                            job.client,
                            job.num_samples,
                            deadline,
                            job.sync.as_ref(),
                            transport.as_ref(),
                            &mut ws,
                        )
                    });
                    wsp.restore(ws);
                    result.map(|outcome| JobResult {
                        outcome,
                        dgc,
                        data: Some(job.data),
                    })
                })
                .into_iter()
                .collect::<Result<Vec<_>>>()?
            }
            None => {
                let rt = ctx.runtime.get();
                let mut out = Vec::with_capacity(jobs.len());
                for mut job in jobs {
                    let mut dgc = job.dgc.take();
                    let mut ws = ctx.workspaces.checkout();
                    let result = run_guarded(round, job.client, &job.submodel, || {
                        run_client_round(
                            ctx.spec,
                            rt,
                            ctx.global,
                            &job.submodel,
                            &job.plan,
                            &job.data,
                            ctx.lr,
                            ctx.downlink.as_ref(),
                            dgc.as_mut(),
                            round,
                            seed,
                            job.client,
                            job.num_samples,
                            deadline,
                            job.sync.as_ref(),
                            ctx.transport.as_ref(),
                            &mut ws,
                        )
                    });
                    ctx.workspaces.restore(ws);
                    out.push(JobResult {
                        outcome: result?,
                        dgc,
                        data: Some(job.data),
                    });
                }
                out
            }
        };
        for r in &mut results {
            let client = ctx.fleet.client(r.outcome.client);
            if let Some(st) = r.dgc.take() {
                client.put_dgc(st);
            }
            if let Some(d) = r.data.take() {
                client.put_epoch_buf(d);
            }
        }
        Ok(results)
    }

    /// A client's simulated `down + compute + up` duration. The link
    /// comes from the pure `(seed, id)` derivation in lazy-population
    /// mode (no table exists for a million clients).
    fn flight_time(ctx: &RoundCtx, o: &ClientRoundOutcome) -> f64 {
        let link = ctx.net.link(o.client);
        link.down_time(o.down_bytes, &ctx.net.cfg)
            + link.compute_time(o.epoch_flops)
            + link.up_time(o.up_bytes, &ctx.net.cfg)
    }

    // ---- round-scoped policies (Sync, Overselect) -------------------

    fn step_round(&mut self, round: usize, ctx: &mut RoundCtx) -> Result<RoundSummary> {
        let m = ctx.cfg.cohort_size();
        let n = ctx.cfg.num_clients;
        let want = self.policy.dispatch_count(m).min(n);
        let mut cands: Vec<usize> = if self.avail.config().enabled {
            self.avail.online_at(n, ctx.cum_s)
        } else {
            (0..n).collect()
        };
        // Quarantined clients leave the candidate pool. The filter only
        // runs once someone is actually quarantined, so fault-free runs
        // keep the exact candidate vector (and RNG mapping) of old.
        if self.quarantined_total > 0 {
            cands.retain(|&c| !self.is_quarantined(c));
        }
        let cohort = Self::sample_from(ctx.rng, &cands, want);
        // Rollback snapshots (2×num_params f32 per client) are only
        // taken when a client can actually end up excluded — a policy
        // that cuts, churn, a transport that can lose connections, or
        // an active fault plan (injected panics/stalls lose clients).
        let snapshot = self.policy.may_cut()
            || self.avail.config().enabled
            || ctx.transport.may_lose()
            || crate::fault::enabled();
        let (jobs, mut dgc_backups, spill_lost) =
            Self::prepare_jobs(ctx, round, &cohort, snapshot, &mut self.epoch_order);
        let results = self.execute_jobs(ctx, round, jobs)?;
        for &c in &spill_lost {
            self.note_fault(c, n);
        }

        // Arrival offsets (seconds after dispatch) + churn drops +
        // transport losses (a connection died or timed out with this
        // client's exchange in flight — the update never existed, so
        // it can't arrive).
        let k = results.len();
        let mut offsets = Vec::with_capacity(k);
        let mut excluded_flag = vec![false; k];
        let mut dropped = 0usize;
        let mut lost = spill_lost.len();
        for (i, r) in results.iter().enumerate() {
            let off = Self::flight_time(ctx, &r.outcome);
            if r.outcome.lost.is_some() {
                excluded_flag[i] = true;
                lost += 1;
                if crate::fault::enabled() {
                    self.note_fault(r.outcome.client, n);
                }
            } else if !self.avail.is_online(r.outcome.client, ctx.cum_s + off) {
                excluded_flag[i] = true;
                dropped += 1;
            }
            offsets.push(off);
        }

        // Replay arrivals in virtual-time order until the policy (or a
        // deadline, or an empty sky) closes the round.
        let mut order: Vec<usize> = (0..k).filter(|&i| !excluded_flag[i]).collect();
        order.sort_by(|&a, &b| offsets[a].total_cmp(&offsets[b]).then(a.cmp(&b)));
        let deadline = self.policy.deadline_s();
        let mut included = vec![false; k];
        let mut arrived = 0usize;
        let mut close_t = 0.0f64;
        let mut pending = order.len();
        let mut deadline_hit = false;
        for &i in &order {
            if let Some(d) = deadline {
                if offsets[i] > d {
                    deadline_hit = true;
                    break;
                }
            }
            included[i] = true;
            arrived += 1;
            pending -= 1;
            close_t = offsets[i];
            if self.policy.close_after(m, arrived, pending) {
                break;
            }
        }
        if deadline_hit {
            close_t = deadline.unwrap_or(close_t);
        }
        if arrived == 0 {
            // Nothing arrived (all dispatched clients dropped, or no
            // one was online to dispatch). Charge the time the round
            // actually occupied — the deadline, or the would-be
            // arrivals — and, under churn, at least one availability
            // window: `is_online` is a pure function of time, so a
            // frozen clock would re-evaluate the same offline pattern
            // forever and wedge the rest of the run.
            close_t = deadline
                .unwrap_or_else(|| offsets.iter().copied().fold(0.0, f64::max));
            if self.avail.config().enabled {
                close_t = close_t.max(self.avail.config().period_s.max(1e-3));
            }
        }
        let cut = order.len() - arrived;

        // Cut/dropped uploads never reached the server: roll their DGC
        // accumulators back to the pre-round snapshot (no-op for Sync,
        // which includes everyone — bit-compat preserved).
        for (i, r) in results.iter().enumerate() {
            if included[i] {
                continue;
            }
            if let Some(b) = dgc_backups[i].take() {
                ctx.fleet.client(r.outcome.client).put_dgc(b);
            }
        }

        let mut summary = Self::aggregate(
            ctx,
            round,
            results.iter().map(|r| &r.outcome),
            &included,
            |_| 1.0,
            &mut self.global_scratch,
        );
        summary.round_s = close_t;
        summary.arrived = arrived;
        summary.cut = cut;
        summary.dropped = dropped;
        summary.lost = lost;
        summary.quarantined = self.quarantined_total;
        // Round-closing control frames: Ack commits the device-side
        // codec state, Cut rolls it back (the loops above did the same
        // to the host-side shadow).
        for (i, r) in results.iter().enumerate() {
            ctx.transport.finish(r.outcome.client, round as u32, included[i])?;
        }
        Self::recycle_outcomes(ctx, results.into_iter().map(|r| r.outcome));
        self.version += 1;
        if crate::obs::enabled() {
            use crate::obs::metrics as om;
            om::STRAGGLERS_CUT.add(cut as u64);
            om::CLIENTS_DROPPED.add(dropped as u64);
            om::CLIENTS_LOST.add(lost as u64);
            om::ROUNDS_COMPLETED.incr();
            // Round boundary on the virtual clock (`b` = virtual ns).
            crate::obs::mark(
                crate::obs::Stage::RoundMark,
                round as u64,
                ((ctx.cum_s + summary.round_s) * 1e9) as u64,
            );
        }
        Ok(summary)
    }

    // ---- continuous policies (AsyncBuffered) ------------------------

    fn step_continuous(&mut self, round: usize, ctx: &mut RoundCtx) -> Result<RoundSummary> {
        if self.in_flight.len() != ctx.cfg.num_clients {
            self.in_flight = vec![false; ctx.cfg.num_clients];
        }
        let m = ctx.cfg.cohort_size();
        let target = self.policy.dispatch_count(m).min(ctx.cfg.num_clients);
        let window_start = self.now;
        let mut dropped = 0usize;
        // Refill is *leading*: clients aggregated by the previous step
        // are replaced here, dispatched at `self.now` (that step's
        // aggregation close — the same virtual instant a trailing
        // refill would use). Leading keeps the strategy's view
        // consistent: its `select`s for round R always precede round
        // R's `report_loss`es.
        self.refill(ctx, round, target)?;
        if crate::obs::enabled() {
            crate::obs::metrics::QUEUE_DEPTH.set_max(self.heap.len() as u64);
        }

        // Drain arrivals until the buffer fills (or the sky empties).
        let mut buffer: Vec<InFlight> = Vec::new();
        loop {
            match self.heap.pop() {
                Some(mut f) => {
                    self.in_flight[f.outcome.client] = false;
                    self.now = self.now.max(f.arrival);
                    if !self.avail.is_online(f.outcome.client, f.arrival) {
                        dropped += 1;
                        // The upload never landed: undo the round's DGC
                        // accumulator mutation, host-side and (Cut
                        // frame) device-side — before any refill can
                        // re-dispatch this client.
                        if let Some(b) = f.dgc_backup.take() {
                            ctx.fleet.client(f.outcome.client).put_dgc(b);
                        }
                        ctx.transport.finish(f.outcome.client, f.round, false)?;
                        continue;
                    }
                    let full = self.policy.close_after(m, buffer.len() + 1, self.heap.len());
                    buffer.push(f);
                    if full {
                        break;
                    }
                }
                None => {
                    if !buffer.is_empty() {
                        break;
                    }
                    // Nothing in flight: try to refill at the current
                    // clock; if the whole population is offline, idle
                    // one churn window so availability can recover.
                    let before = self.heap.len();
                    self.refill(ctx, round, target)?;
                    if self.heap.len() == before {
                        let idle = self.avail.config().period_s.max(1e-3);
                        self.now += idle;
                        return Ok(RoundSummary {
                            round_s: idle,
                            dropped,
                            lost: std::mem::take(&mut self.pending_lost),
                            quarantined: self.quarantined_total,
                            // Bytes were charged at dispatch for clients
                            // that have since all dropped — report them
                            // here rather than misattributing them to a
                            // later aggregation (or losing them if the
                            // run ends idle).
                            down_bytes: std::mem::take(&mut self.pending_down),
                            down_payload_bytes: std::mem::take(&mut self.pending_down_payload),
                            ..RoundSummary::default()
                        });
                    }
                }
            }
        }

        // Staleness-discounted buffered aggregation, arrival order.
        let included = vec![true; buffer.len()];
        let cur = self.version;
        let policy = &*self.policy;
        let mut summary = Self::aggregate(
            ctx,
            round,
            buffer.iter().map(|f| &f.outcome),
            &included,
            |i| policy.staleness_weight(cur - buffer[i].version),
            &mut self.global_scratch,
        );
        self.version += 1;
        summary.round_s = self.now - window_start;
        summary.arrived = buffer.len();
        summary.dropped = dropped;
        summary.lost = std::mem::take(&mut self.pending_lost);
        summary.quarantined = self.quarantined_total;
        summary.down_bytes = std::mem::take(&mut self.pending_down);
        summary.down_payload_bytes = std::mem::take(&mut self.pending_down_payload);
        // Every buffered update was aggregated: commit device-side
        // codec state before the next refill re-dispatches anyone.
        for f in &buffer {
            ctx.transport.finish(f.outcome.client, f.round, true)?;
        }
        Self::recycle_outcomes(ctx, buffer.into_iter().map(|f| f.outcome));
        if crate::obs::enabled() {
            use crate::obs::metrics as om;
            om::CLIENTS_DROPPED.add(dropped as u64);
            om::CLIENTS_LOST.add(summary.lost as u64);
            om::ROUNDS_COMPLETED.incr();
            crate::obs::mark(
                crate::obs::Stage::RoundMark,
                round as u64,
                (self.now * 1e9) as u64,
            );
        }
        Ok(summary)
    }

    /// Top the in-flight set back up to `target` with clients that are
    /// online and not already in flight, dispatching at `self.now`.
    fn refill(&mut self, ctx: &mut RoundCtx, round: usize, target: usize) -> Result<()> {
        if self.heap.len() >= target {
            return Ok(());
        }
        let now = self.now;
        let cands: Vec<usize> = (0..ctx.cfg.num_clients)
            .filter(|&c| {
                !self.in_flight[c]
                    && self.avail.is_online(c, now)
                    && (self.quarantined_total == 0 || !self.is_quarantined(c))
            })
            .collect();
        if cands.is_empty() {
            return Ok(());
        }
        let picked = Self::sample_from(ctx.rng, &cands, target - self.heap.len());
        // Continuous policies exclude via churn drops — or via
        // transport losses and injected faults, handled below.
        let snapshot =
            self.avail.config().enabled || ctx.transport.may_lose() || crate::fault::enabled();
        let (jobs, dgc_backups, spill_lost) =
            Self::prepare_jobs(ctx, round, &picked, snapshot, &mut self.epoch_order);
        let results = self.execute_jobs(ctx, round, jobs)?;
        for &c in &spill_lost {
            self.pending_lost += 1;
            self.note_fault(c, ctx.cfg.num_clients);
        }
        let mut lost_outcomes = Vec::new();
        for (r, dgc_backup) in results.into_iter().zip(dgc_backups) {
            let o = r.outcome;
            if o.lost.is_some() {
                // The exchange died with its connection before the
                // update existed: roll the host-side DGC snapshot
                // back, tell the device (best-effort Cut), and report
                // the loss with the next aggregation's summary. The
                // client is not in flight — it can be re-dispatched by
                // a later refill.
                if let Some(b) = dgc_backup {
                    ctx.fleet.client(o.client).put_dgc(b);
                }
                ctx.transport.finish(o.client, round as u32, false)?;
                self.pending_lost += 1;
                if crate::fault::enabled() {
                    self.note_fault(o.client, ctx.cfg.num_clients);
                }
                lost_outcomes.push(o);
                continue;
            }
            let dt = Self::flight_time(ctx, &o);
            self.pending_down += o.down_bytes;
            self.pending_down_payload += o.down_payload_bytes;
            self.seq += 1;
            self.in_flight[o.client] = true;
            self.heap.push(InFlight {
                arrival: now + dt,
                seq: self.seq,
                version: self.version,
                round: round as u32,
                outcome: o,
                dgc_backup,
            });
        }
        if !lost_outcomes.is_empty() {
            Self::recycle_outcomes(ctx, lost_outcomes.into_iter());
        }
        Ok(())
    }

    /// FedAvg the included outcomes (iteration order = caller order =
    /// dispatch/arrival order, which fixes the f64 summation order for
    /// reproducibility), update the global, feed the strategy, and
    /// account bytes/losses. The whole round — reset, every add,
    /// finalize — runs as **one** pool dispatch
    /// ([`ShardedFedAvg::aggregate_batch`]: shard workers stay pinned
    /// across the adds); raw-uplink outcomes add through their pack
    /// plan's contiguous kept runs, DGC outcomes (whose masks may
    /// include residual coordinates beyond the plan) stay mask-based.
    /// Both are bit-identical per coordinate to the serial `FedAvg`
    /// reference, and the batch is bit-identical to the per-add
    /// dispatch path (`rust/tests/agg_sharding.rs`). The new global is
    /// built in `global_scratch` and swapped in, so steady-state
    /// rounds allocate no model-sized buffer.
    fn aggregate<'o>(
        ctx: &mut RoundCtx,
        round: usize,
        outcomes: impl Iterator<Item = &'o ClientRoundOutcome> + Clone,
        included: &[bool],
        weight_of: impl Fn(usize) -> f64,
        global_scratch: &mut Vec<f32>,
    ) -> RoundSummary {
        let mut summary = RoundSummary::default();
        let mut loss_sum = 0.0f64;
        let mut keep_sum = 0.0f64;
        let mut count = 0usize;
        let mut ops: Vec<AddOp> = Vec::with_capacity(included.len());
        for (i, o) in outcomes.clone().enumerate() {
            if !included[i] {
                continue;
            }
            // Pure lookup — never materializes (an async client may
            // already be evicted by the time its update aggregates).
            let n_c = ctx.fleet.num_samples(o.client) as f64;
            let w = weight_of(i);
            // `n_c * 1.0 == n_c` exactly, so unit weights stay bit-
            // compatible with the serial reference.
            ops.push(match &o.agg_plan {
                Some(plan) => AddOp::Planned {
                    values: &o.reconstructed,
                    plan: plan.as_ref(),
                    n_c: n_c * w,
                },
                None => AddOp::Masked {
                    values: &o.reconstructed,
                    coord_mask: &o.coord_mask,
                    n_c: n_c * w,
                },
            });
            summary.down_bytes += o.down_bytes;
            summary.up_bytes += o.up_bytes;
            summary.down_payload_bytes += o.down_payload_bytes;
            summary.up_payload_bytes += o.up_payload_bytes;
            loss_sum += o.train_loss as f64;
            keep_sum += o.submodel.keep_fraction();
            count += 1;
        }
        ctx.agg.aggregate_batch(&ops, ctx.global, global_scratch);
        drop(ops);
        std::mem::swap(ctx.global, global_scratch);
        for (i, o) in outcomes.enumerate() {
            if included[i] {
                ctx.strategy.report_loss(round, o.client, o.train_loss as f64);
            }
        }
        ctx.strategy.end_round(round);
        summary.train_loss = loss_sum / count.max(1) as f64;
        summary.keep_fraction = keep_sum / count.max(1) as f64;
        summary
    }

    /// Return a drained batch's outcome buffers (drawn from the
    /// workspace pool inside `run_client_round`) so the next round
    /// reuses them instead of allocating.
    fn recycle_outcomes(ctx: &mut RoundCtx, outcomes: impl Iterator<Item = ClientRoundOutcome>) {
        let mut ws = ctx.workspaces.checkout();
        for o in outcomes {
            ws.give(o.reconstructed);
            ws.give_bool(o.coord_mask);
        }
        ctx.workspaces.restore(ws);
    }
}
