//! Pluggable scheduling policies for the event-driven round engine.
//!
//! A policy answers the engine's four questions and nothing else — the
//! engine owns the virtual clock, the in-flight set and all client
//! state movement:
//!
//! 1. how many clients to put in flight for a round targeting cohort
//!    `m` ([`SchedulerPolicy::dispatch_count`]);
//! 2. whether to close the round after each arrival
//!    ([`SchedulerPolicy::close_after`]);
//! 3. whether a wall-clock deadline cuts stragglers
//!    ([`SchedulerPolicy::deadline_s`]);
//! 4. how to weight an update that trained against a stale global model
//!    ([`SchedulerPolicy::staleness_weight`]).
//!
//! [`SyncPolicy`] reproduces the paper's synchronous FedAvg
//! bit-for-bit; [`OverselectPolicy`] and [`AsyncBufferedPolicy`] are
//! the two standard straggler-mitigation levers from the communication
//! -efficiency literature (over-selection, FedBuff-style buffered
//! asynchrony).

use crate::sched::SchedConfig;

/// Round-closing policy driven by the engine (see module docs).
pub trait SchedulerPolicy: Send {
    fn name(&self) -> &'static str;

    /// Number of clients to put in flight for a round targeting cohort
    /// size `m`. For continuous policies this is the steady-state
    /// concurrency the engine refills to.
    fn dispatch_count(&self, m: usize) -> usize;

    /// Close the round after an arrival? `arrived` counts arrivals
    /// banked this round (including the one just processed);
    /// `in_flight` counts dispatched clients still pending. The engine
    /// always closes on its own when nothing is left in flight.
    fn close_after(&self, m: usize, arrived: usize, in_flight: usize) -> bool;

    /// Deadline (seconds after dispatch) at which the round force-
    /// closes; clients still in flight are cut — their work is
    /// discarded and their bytes are not charged.
    fn deadline_s(&self) -> Option<f64> {
        None
    }

    /// Continuous (buffered-async) operation: in-flight work survives
    /// aggregations, and the engine refills the in-flight set after
    /// every aggregation instead of waiting for a round boundary.
    fn continuous(&self) -> bool {
        false
    }

    /// Can this policy discard a dispatched client's finished work
    /// (quorum/deadline cutting)? Lets the engine skip the per-round
    /// DGC rollback snapshots when exclusion is impossible.
    fn may_cut(&self) -> bool {
        true
    }

    /// Aggregation-weight multiplier for an update whose training
    /// started `staleness` model versions ago.
    fn staleness_weight(&self, _staleness: u64) -> f64 {
        1.0
    }
}

/// Synchronous FedAvg: dispatch exactly `m`, wait for everyone.
/// Reproduces the pre-scheduler serial loop bit-for-bit.
pub struct SyncPolicy;

impl SchedulerPolicy for SyncPolicy {
    fn name(&self) -> &'static str {
        "sync"
    }

    fn dispatch_count(&self, m: usize) -> usize {
        m
    }

    fn close_after(&self, _m: usize, _arrived: usize, in_flight: usize) -> bool {
        in_flight == 0
    }

    fn may_cut(&self) -> bool {
        false // waits for everyone; only churn can exclude a client
    }
}

/// Over-selection (client over-provisioning): dispatch `⌈m·(1+ε)⌉`
/// clients, close after the first `m` arrivals or at the deadline,
/// whichever comes first. Stragglers are cut; only arrived clients'
/// bytes are charged.
pub struct OverselectPolicy {
    /// ε — the over-provisioning fraction.
    pub over_fraction: f64,
    /// Optional hard deadline in seconds after dispatch.
    pub deadline_s: Option<f64>,
}

impl SchedulerPolicy for OverselectPolicy {
    fn name(&self) -> &'static str {
        "overselect"
    }

    fn dispatch_count(&self, m: usize) -> usize {
        ((m as f64) * (1.0 + self.over_fraction.max(0.0))).ceil() as usize
    }

    fn close_after(&self, m: usize, arrived: usize, _in_flight: usize) -> bool {
        arrived >= m
    }

    fn deadline_s(&self) -> Option<f64> {
        self.deadline_s
    }
}

/// FedBuff-style buffered asynchrony: keep `concurrency` clients in
/// flight, aggregate every `buffer_k` arrivals with staleness-
/// discounted weights (`1 / (1 + staleness)^alpha`), refill
/// immediately after each aggregation. Slow clients never gate
/// aggregation cadence — they simply stay in flight.
pub struct AsyncBufferedPolicy {
    /// Aggregate after this many arrivals.
    pub buffer_k: usize,
    /// Staleness discount exponent α.
    pub staleness_alpha: f64,
    /// Steady-state number of clients in flight.
    pub concurrency: usize,
}

impl SchedulerPolicy for AsyncBufferedPolicy {
    fn name(&self) -> &'static str {
        "async_buffered"
    }

    fn dispatch_count(&self, _m: usize) -> usize {
        self.concurrency
    }

    fn close_after(&self, _m: usize, arrived: usize, _in_flight: usize) -> bool {
        arrived >= self.buffer_k.max(1)
    }

    fn continuous(&self) -> bool {
        true
    }

    fn staleness_weight(&self, staleness: u64) -> f64 {
        (1.0 + staleness as f64).powf(-self.staleness_alpha)
    }

    fn may_cut(&self) -> bool {
        false // arrivals always buffer; stragglers stay in flight
    }
}

/// Build a policy from config, resolving the `0 = auto` knobs against
/// the experiment geometry (`m` = cohort size, `n` = population).
pub fn make_policy(
    cfg: &SchedConfig,
    m: usize,
    n: usize,
) -> anyhow::Result<Box<dyn SchedulerPolicy>> {
    Ok(match cfg.policy.as_str() {
        "sync" => Box::new(SyncPolicy),
        "overselect" => Box::new(OverselectPolicy {
            over_fraction: cfg.over_fraction,
            deadline_s: cfg.deadline_s,
        }),
        "async_buffered" => Box::new(AsyncBufferedPolicy {
            buffer_k: if cfg.buffer_k == 0 {
                (m / 2).max(1)
            } else {
                cfg.buffer_k
            },
            staleness_alpha: cfg.staleness_alpha,
            concurrency: if cfg.concurrency == 0 {
                (2 * m).clamp(1, n)
            } else {
                cfg.concurrency.min(n)
            },
        }),
        other => anyhow::bail!(
            "unknown scheduler policy {other:?} (expected sync|overselect|async_buffered)"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_waits_for_everyone() {
        let p = SyncPolicy;
        assert_eq!(p.dispatch_count(6), 6);
        assert!(!p.close_after(6, 5, 1));
        assert!(p.close_after(6, 6, 0));
        assert!(p.deadline_s().is_none());
        assert!(!p.continuous());
        assert_eq!(p.staleness_weight(3), 1.0);
        assert!(!p.may_cut(), "sync never discards finished work");
    }

    #[test]
    fn overselect_overprovisions_and_closes_at_quorum() {
        let p = OverselectPolicy {
            over_fraction: 0.5,
            deadline_s: Some(10.0),
        };
        assert_eq!(p.dispatch_count(6), 9);
        assert_eq!(p.dispatch_count(1), 2);
        assert!(!p.close_after(6, 5, 4));
        assert!(p.close_after(6, 6, 3));
        assert_eq!(p.deadline_s(), Some(10.0));
        assert!(p.may_cut());
    }

    #[test]
    fn async_buffered_discounts_staleness() {
        let p = AsyncBufferedPolicy {
            buffer_k: 3,
            staleness_alpha: 1.0,
            concurrency: 12,
        };
        assert!(p.continuous());
        assert_eq!(p.dispatch_count(6), 12);
        assert!(!p.close_after(6, 2, 10));
        assert!(p.close_after(6, 3, 9));
        assert_eq!(p.staleness_weight(0), 1.0);
        assert_eq!(p.staleness_weight(1), 0.5);
        assert!(p.staleness_weight(9) < p.staleness_weight(1));
    }

    #[test]
    fn factory_resolves_auto_knobs() {
        let mut cfg = SchedConfig::default();
        assert_eq!(make_policy(&cfg, 6, 20).unwrap().name(), "sync");
        cfg.policy = "overselect".into();
        assert_eq!(make_policy(&cfg, 6, 20).unwrap().name(), "overselect");
        cfg.policy = "async_buffered".into();
        let p = make_policy(&cfg, 6, 20).unwrap();
        assert_eq!(p.name(), "async_buffered");
        // auto concurrency = min(2m, n) = 12; auto buffer = m/2 = 3.
        assert_eq!(p.dispatch_count(6), 12);
        assert!(p.close_after(6, 3, 9) && !p.close_after(6, 2, 10));
        cfg.policy = "bogus".into();
        assert!(make_policy(&cfg, 6, 20).is_err());
    }
}
