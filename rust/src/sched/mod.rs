//! Event-driven federation scheduler: parallel cohorts, stragglers,
//! and async aggregation.
//!
//! The paper's convergence-time metric is
//! `t_round = max over cohort(down + compute + up)` — synchronous
//! FedAvg, where the slowest client gates every round. This module
//! generalizes the round loop into a virtual-clock, event-driven
//! engine ([`Engine`]) with pluggable closing policies
//! ([`SchedulerPolicy`]):
//!
//! * [`SyncPolicy`] — the paper's synchronous rounds, bit-identical to
//!   the pre-scheduler serial loop at equal seeds;
//! * [`OverselectPolicy`] — dispatch `⌈m·(1+ε)⌉` clients, close at the
//!   first `m` arrivals or a deadline, cut stragglers;
//! * [`AsyncBufferedPolicy`] — FedBuff-style buffered asynchrony:
//!   aggregate every `K` arrivals with staleness-discounted weights,
//!   keep a fixed number of clients in flight at all times.
//!
//! In-flight clients train in parallel on `util::pool::Pool` whenever
//! the model runtime is thread-safe (the native backend); see
//! `engine.rs` for the determinism story and `README.md` in this
//! directory for the event-loop walkthrough.

pub mod engine;
pub mod policy;

pub use engine::{Engine, RoundCtx, RoundSummary};
pub use policy::{
    make_policy, AsyncBufferedPolicy, OverselectPolicy, SchedulerPolicy, SyncPolicy,
};

use crate::network::ChurnConfig;

/// Scheduler configuration (experiment-config subtree).
#[derive(Clone, Debug)]
pub struct SchedConfig {
    /// Policy: `sync` | `overselect` | `async_buffered`.
    pub policy: String,
    /// Overselect: over-provisioning fraction ε.
    pub over_fraction: f64,
    /// Overselect: optional round deadline in simulated seconds.
    pub deadline_s: Option<f64>,
    /// AsyncBuffered: aggregate every K arrivals (0 = auto:
    /// `max(1, ⌊m/2⌋)`).
    pub buffer_k: usize,
    /// AsyncBuffered: clients kept in flight (0 = auto: min(2m, n)).
    pub concurrency: usize,
    /// AsyncBuffered: staleness discount exponent α in
    /// `w = 1/(1+staleness)^α`.
    pub staleness_alpha: f64,
    /// Per-client availability churn (off by default).
    pub churn: ChurnConfig,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            policy: "sync".into(),
            over_fraction: 0.5,
            deadline_s: None,
            buffer_k: 0,
            concurrency: 0,
            staleness_alpha: 1.0,
            churn: ChurnConfig::default(),
        }
    }
}

impl SchedConfig {
    /// Enable availability churn at the given steady-state
    /// availability (single validation point for the CLI/examples).
    pub fn enable_churn(&mut self, availability: f64) -> anyhow::Result<()> {
        anyhow::ensure!(
            availability > 0.0 && availability <= 1.0,
            "churn availability must be in (0,1], got {availability}"
        );
        self.churn.enabled = true;
        self.churn.availability = availability;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_sync_with_no_churn() {
        let c = SchedConfig::default();
        assert_eq!(c.policy, "sync");
        assert!(!c.churn.enabled);
        assert_eq!(c.buffer_k, 0);
        assert_eq!(c.concurrency, 0);
    }
}
