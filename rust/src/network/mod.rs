//! Wireless network simulation — the paper's convergence-time
//! methodology.
//!
//! "These results are obtained by simulating wireless links between the
//! server and the clients based on the standard network speeds of
//! Verizon 4G LTE ... download speeds between 5 and 12 Mbps and upload
//! speeds between 2 and 5 Mbps. All clients are supposed to experience
//! the same network conditions."
//!
//! Each client's link is sampled once (deterministically per seed) from
//! those ranges. A synchronous FedAvg round finishes when its slowest
//! client finishes, so
//!
//!   t_round = max over cohort ( t_down + t_compute + t_up )
//!
//! with `t_compute = epoch_flops / device_flops` scaled by the
//! *sub-model's* effective FLOPs (AFD's computation saving).
//!
//! The byte counts charged here are **measured wire bytes** from the
//! transport layer ([`crate::transport`]): framed lengths exactly as a
//! socket carries them (payload + header/CRC + round-close control
//! frames), not estimated payload sizes — so simulated link time
//! includes the protocol's real overhead.
//!
//! Beyond the paper's synchronous model, [`Availability`] adds
//! per-client availability churn (deterministic on/off windows sampled
//! per seed) so the event-driven scheduler ([`crate::sched`]) can treat
//! dropped clients as a first-class scenario.

use crate::util::rng::Pcg64;

/// Mbps → bytes/second.
fn mbps_to_bps(mbps: f64) -> f64 {
    mbps * 1_000_000.0 / 8.0
}

#[derive(Clone, Debug)]
pub struct LinkConfig {
    /// Download (server→client) range in Mbps.
    pub down_mbps: (f64, f64),
    /// Upload (client→server) range in Mbps.
    pub up_mbps: (f64, f64),
    /// Client device compute in GFLOP/s (mobile-class range).
    pub device_gflops: (f64, f64),
    /// Fixed per-message latency (s), both directions.
    pub rtt_latency_s: f64,
    /// Sample rates log-uniformly over the ranges instead of
    /// uniformly. A log-uniform fleet has a guaranteed heavy slow
    /// tail (every decade of the range is equally likely), which is
    /// the straggler regime the scheduler policies target. `false`
    /// preserves the paper's uniform sampling exactly.
    pub log_uniform: bool,
}

impl Default for LinkConfig {
    /// The paper's Verizon 4G LTE profile.
    fn default() -> Self {
        LinkConfig {
            down_mbps: (5.0, 12.0),
            up_mbps: (2.0, 5.0),
            device_gflops: (2.0, 8.0),
            rtt_latency_s: 0.05,
            log_uniform: false,
        }
    }
}

impl LinkConfig {
    /// A straggler-heavy profile: the paper's LTE upper ends, but with
    /// the low tails stretched to IoT/edge-class rates and log-uniform
    /// sampling, so a sizable fraction of every fleet is orders of
    /// magnitude slower than the median — the regime over-selection
    /// and buffered asynchrony are built for.
    pub fn straggler_heavy() -> LinkConfig {
        LinkConfig {
            down_mbps: (0.005, 12.0),
            up_mbps: (0.002, 5.0),
            device_gflops: (0.02, 8.0),
            rtt_latency_s: 0.05,
            log_uniform: true,
        }
    }
}

/// One client's sampled network + device characteristics.
#[derive(Clone, Copy, Debug)]
pub struct ClientLink {
    pub down_bps: f64,
    pub up_bps: f64,
    pub device_flops: f64,
}

fn sample_rate(rng: &mut Pcg64, (lo, hi): (f64, f64), log_uniform: bool) -> f64 {
    if log_uniform {
        (rng.uniform(lo.ln(), hi.ln())).exp().clamp(lo, hi)
    } else {
        rng.uniform(lo, hi)
    }
}

impl ClientLink {
    pub fn sample(cfg: &LinkConfig, rng: &mut Pcg64) -> ClientLink {
        ClientLink {
            down_bps: mbps_to_bps(sample_rate(rng, cfg.down_mbps, cfg.log_uniform)),
            up_bps: mbps_to_bps(sample_rate(rng, cfg.up_mbps, cfg.log_uniform)),
            device_flops: sample_rate(rng, cfg.device_gflops, cfg.log_uniform) * 1e9,
        }
    }

    /// Pure per-client derivation: client `id`'s link drawn from its
    /// own RNG stream (`Pcg64::with_stream(seed ^ 0x11e7, id + 1)`,
    /// then the three [`ClientLink::sample`] draws in order). Any
    /// client's link can be derived in isolation, in any order, and is
    /// bit-identical every time — the population engine's lazy path
    /// and the eagerly-cached [`NetworkSim::new`] table both call
    /// exactly this function, so the two agree by construction.
    pub fn derive(cfg: &LinkConfig, seed: u64, id: usize) -> ClientLink {
        let mut rng = Pcg64::with_stream(seed ^ 0x11e7, id as u64 + 1);
        ClientLink::sample(cfg, &mut rng)
    }

    pub fn down_time(&self, bytes: u64, cfg: &LinkConfig) -> f64 {
        cfg.rtt_latency_s + bytes as f64 / self.down_bps
    }

    pub fn up_time(&self, bytes: u64, cfg: &LinkConfig) -> f64 {
        cfg.rtt_latency_s + bytes as f64 / self.up_bps
    }

    pub fn compute_time(&self, flops: f64) -> f64 {
        flops / self.device_flops
    }
}

/// Simulated network. Eager mode caches every client's link in
/// `links`; lazy mode ([`NetworkSim::lazy`]) keeps the table empty and
/// [`NetworkSim::link`] derives on demand — both paths go through the
/// pure [`ClientLink::derive`], so they are bit-identical.
#[derive(Clone, Debug)]
pub struct NetworkSim {
    pub cfg: LinkConfig,
    /// Per-client link cache (empty in lazy mode).
    pub links: Vec<ClientLink>,
    seed: u64,
}

#[derive(Clone, Debug, Default)]
pub struct ClientTiming {
    pub down_s: f64,
    pub compute_s: f64,
    pub up_s: f64,
}

impl ClientTiming {
    pub fn total(&self) -> f64 {
        self.down_s + self.compute_s + self.up_s
    }
}

#[derive(Clone, Debug, Default)]
pub struct RoundTiming {
    pub per_client: Vec<ClientTiming>,
    /// Synchronous round duration = slowest client.
    pub round_s: f64,
    pub down_bytes: u64,
    pub up_bytes: u64,
}

impl NetworkSim {
    pub fn new(cfg: LinkConfig, num_clients: usize, seed: u64) -> NetworkSim {
        let links = (0..num_clients)
            .map(|c| ClientLink::derive(&cfg, seed, c))
            .collect();
        NetworkSim { cfg, links, seed }
    }

    /// No per-client table: links are derived on every
    /// [`NetworkSim::link`] call — O(1) memory for any population size.
    pub fn lazy(cfg: LinkConfig, seed: u64) -> NetworkSim {
        NetworkSim {
            cfg,
            links: Vec::new(),
            seed,
        }
    }

    /// Client `c`'s link: cached when eager, derived when lazy.
    pub fn link(&self, c: usize) -> ClientLink {
        self.links
            .get(c)
            .copied()
            .unwrap_or_else(|| ClientLink::derive(&self.cfg, self.seed, c))
    }

    /// Account one synchronous round. `per_client`: (client id,
    /// downlink bytes, epoch flops, uplink bytes).
    pub fn round(&self, per_client: &[(usize, u64, f64, u64)]) -> RoundTiming {
        let mut timing = RoundTiming::default();
        for &(c, down_b, flops, up_b) in per_client {
            let link = self.link(c);
            let t = ClientTiming {
                down_s: link.down_time(down_b, &self.cfg),
                compute_s: link.compute_time(flops),
                up_s: link.up_time(up_b, &self.cfg),
            };
            timing.round_s = timing.round_s.max(t.total());
            timing.down_bytes += down_b;
            timing.up_bytes += up_b;
            timing.per_client.push(t);
        }
        timing
    }
}

/// Per-client availability churn configuration.
///
/// Availability is piecewise-constant over windows of `period_s`
/// simulated seconds: in each window a client is online with
/// probability `availability`, decided by a stateless hash of
/// `(seed, client, window)` — O(1) to query at any virtual time, no
/// trace storage, and deterministic per seed.
#[derive(Clone, Debug)]
pub struct ChurnConfig {
    /// Disabled by default: every client is always online (the paper's
    /// setting, and required for bit-identical `Sync` scheduling).
    pub enabled: bool,
    /// Probability a client is online in any given window.
    pub availability: f64,
    /// Window length in simulated seconds.
    pub period_s: f64,
}

impl Default for ChurnConfig {
    fn default() -> Self {
        ChurnConfig {
            enabled: false,
            availability: 0.8,
            period_s: 60.0,
        }
    }
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Deterministic per-client on/off availability traces.
#[derive(Clone, Debug)]
pub struct Availability {
    cfg: ChurnConfig,
    seed: u64,
}

impl Availability {
    pub fn new(cfg: ChurnConfig, seed: u64) -> Availability {
        Availability { cfg, seed }
    }

    pub fn config(&self) -> &ChurnConfig {
        &self.cfg
    }

    /// Is `client` online at virtual time `t_s`?
    pub fn is_online(&self, client: usize, t_s: f64) -> bool {
        if !self.cfg.enabled {
            return true;
        }
        let window = (t_s.max(0.0) / self.cfg.period_s.max(1e-9)) as u64;
        let h = splitmix64(
            self.seed
                ^ (client as u64).wrapping_mul(0xd1b5_4a32_d192_ed03)
                ^ window.wrapping_mul(0x2545_f491_4f6c_dd1d),
        );
        let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        u < self.cfg.availability
    }

    /// Clients (of `n`) online at virtual time `t_s`, in index order —
    /// the scheduler's dispatch candidate pool.
    pub fn online_at(&self, n: usize, t_s: f64) -> Vec<usize> {
        (0..n).filter(|&c| self.is_online(c, t_s)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampled_links_stay_in_ranges() {
        let cfg = LinkConfig::default();
        let sim = NetworkSim::new(cfg.clone(), 200, 1);
        for l in &sim.links {
            assert!(l.down_bps >= mbps_to_bps(5.0) && l.down_bps <= mbps_to_bps(12.0));
            assert!(l.up_bps >= mbps_to_bps(2.0) && l.up_bps <= mbps_to_bps(5.0));
            assert!(l.device_flops >= 2e9 && l.device_flops <= 8e9);
        }
        // Paper's asymmetry: downlink faster than uplink on average.
        let avg_down: f64 =
            sim.links.iter().map(|l| l.down_bps).sum::<f64>() / sim.links.len() as f64;
        let avg_up: f64 =
            sim.links.iter().map(|l| l.up_bps).sum::<f64>() / sim.links.len() as f64;
        assert!(avg_down > avg_up);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = NetworkSim::new(LinkConfig::default(), 10, 7);
        let b = NetworkSim::new(LinkConfig::default(), 10, 7);
        for (x, y) in a.links.iter().zip(&b.links) {
            assert_eq!(x.down_bps, y.down_bps);
            assert_eq!(x.up_bps, y.up_bps);
        }
        let c = NetworkSim::new(LinkConfig::default(), 10, 8);
        assert!(a.links[0].down_bps != c.links[0].down_bps);
    }

    #[test]
    fn lazy_links_match_eager_table_bitwise() {
        let cfg = LinkConfig::straggler_heavy();
        let eager = NetworkSim::new(cfg.clone(), 64, 17);
        let lazy = NetworkSim::lazy(cfg, 17);
        assert!(lazy.links.is_empty());
        // Any order, repeated derivation: bit-identical to the table.
        for c in [63usize, 0, 31, 31, 7] {
            let l = lazy.link(c);
            let e = eager.link(c);
            assert_eq!(l.down_bps.to_bits(), e.down_bps.to_bits(), "client {c}");
            assert_eq!(l.up_bps.to_bits(), e.up_bps.to_bits(), "client {c}");
            assert_eq!(
                l.device_flops.to_bits(),
                e.device_flops.to_bits(),
                "client {c}"
            );
        }
    }

    #[test]
    fn round_time_is_max_not_sum() {
        let sim = NetworkSim::new(LinkConfig::default(), 4, 3);
        let jobs: Vec<(usize, u64, f64, u64)> =
            (0..4).map(|c| (c, 1_000_000, 1e9, 500_000)).collect();
        let t = sim.round(&jobs);
        let max_c = t
            .per_client
            .iter()
            .map(|c| c.total())
            .fold(0.0f64, f64::max);
        assert_eq!(t.round_s, max_c);
        let sum_c: f64 = t.per_client.iter().map(|c| c.total()).sum();
        assert!(t.round_s < sum_c);
        assert_eq!(t.down_bytes, 4_000_000);
        assert_eq!(t.up_bytes, 2_000_000);
    }

    #[test]
    fn smaller_payloads_are_faster() {
        let sim = NetworkSim::new(LinkConfig::default(), 1, 5);
        let full = sim.round(&[(0, 4_000_000, 1e9, 4_000_000)]);
        let compressed = sim.round(&[(0, 200_000, 0.75e9, 100_000)]);
        assert!(compressed.round_s < full.round_s / 5.0);
    }

    #[test]
    fn straggler_profile_has_heavy_slow_tail() {
        let cfg = LinkConfig::straggler_heavy();
        let sim = NetworkSim::new(cfg.clone(), 200, 1);
        let (lo, hi) = cfg.down_mbps;
        for l in &sim.links {
            assert!(l.down_bps >= mbps_to_bps(lo) && l.down_bps <= mbps_to_bps(hi));
        }
        // Log-uniform sampling: each decade of the range is equally
        // likely, so a sizable fraction of any fleet sits orders of
        // magnitude below the top rate.
        let slow = sim
            .links
            .iter()
            .filter(|l| l.down_bps < mbps_to_bps(hi / 100.0))
            .count();
        assert!(slow > 20, "slow tail must be heavy: {slow}/200");
        let mx = sim.links.iter().map(|l| l.down_bps).fold(0.0, f64::max);
        let mn = sim
            .links
            .iter()
            .map(|l| l.down_bps)
            .fold(f64::INFINITY, f64::min);
        assert!(mx / mn > 100.0, "spread {mx}/{mn}");
    }

    #[test]
    fn churn_disabled_means_always_online() {
        let a = Availability::new(ChurnConfig::default(), 3);
        for c in 0..50 {
            for t in [0.0, 59.0, 1e6] {
                assert!(a.is_online(c, t));
            }
        }
    }

    #[test]
    fn churn_is_deterministic_and_respects_rate() {
        let cfg = ChurnConfig {
            enabled: true,
            availability: 0.7,
            period_s: 30.0,
        };
        let a = Availability::new(cfg.clone(), 9);
        let b = Availability::new(cfg, 9);
        let mut online = 0usize;
        let mut total = 0usize;
        let mut toggles = 0usize;
        for c in 0..40 {
            let mut prev = None;
            for w in 0..50 {
                let t = w as f64 * 30.0 + 1.0;
                let on = a.is_online(c, t);
                assert_eq!(on, b.is_online(c, t), "determinism");
                // Constant within a window.
                assert_eq!(on, a.is_online(c, t + 25.0));
                if prev == Some(!on) {
                    toggles += 1;
                }
                prev = Some(on);
                online += on as usize;
                total += 1;
            }
        }
        let rate = online as f64 / total as f64;
        assert!((rate - 0.7).abs() < 0.05, "empirical rate {rate}");
        assert!(toggles > 100, "clients must actually churn ({toggles})");
    }

    #[test]
    fn online_at_filters_in_index_order() {
        let cfg = ChurnConfig {
            enabled: true,
            availability: 0.5,
            period_s: 10.0,
        };
        let a = Availability::new(cfg, 4);
        let on = a.online_at(64, 5.0);
        assert!(on.windows(2).all(|w| w[0] < w[1]));
        assert!(!on.is_empty() && on.len() < 64);
        for &c in &on {
            assert!(a.is_online(c, 5.0));
        }
    }

    #[test]
    fn uplink_dominates_for_symmetric_payloads() {
        // 2–5 Mbps up vs 5–12 Mbps down: equal bytes → up slower.
        let sim = NetworkSim::new(LinkConfig::default(), 50, 6);
        for l in &sim.links {
            let down = l.down_time(1_000_000, &sim.cfg);
            let up = l.up_time(1_000_000, &sim.cfg);
            assert!(up > down);
        }
    }
}
