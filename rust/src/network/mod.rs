//! Wireless network simulation — the paper's convergence-time
//! methodology.
//!
//! "These results are obtained by simulating wireless links between the
//! server and the clients based on the standard network speeds of
//! Verizon 4G LTE ... download speeds between 5 and 12 Mbps and upload
//! speeds between 2 and 5 Mbps. All clients are supposed to experience
//! the same network conditions."
//!
//! Each client's link is sampled once (deterministically per seed) from
//! those ranges. A synchronous FedAvg round finishes when its slowest
//! client finishes, so
//!
//!   t_round = max over cohort ( t_down + t_compute + t_up )
//!
//! with `t_compute = epoch_flops / device_flops` scaled by the
//! *sub-model's* effective FLOPs (AFD's computation saving).

use crate::util::rng::Pcg64;

/// Mbps → bytes/second.
fn mbps_to_bps(mbps: f64) -> f64 {
    mbps * 1_000_000.0 / 8.0
}

#[derive(Clone, Debug)]
pub struct LinkConfig {
    /// Download (server→client) range in Mbps.
    pub down_mbps: (f64, f64),
    /// Upload (client→server) range in Mbps.
    pub up_mbps: (f64, f64),
    /// Client device compute in GFLOP/s (mobile-class range).
    pub device_gflops: (f64, f64),
    /// Fixed per-message latency (s), both directions.
    pub rtt_latency_s: f64,
}

impl Default for LinkConfig {
    /// The paper's Verizon 4G LTE profile.
    fn default() -> Self {
        LinkConfig {
            down_mbps: (5.0, 12.0),
            up_mbps: (2.0, 5.0),
            device_gflops: (2.0, 8.0),
            rtt_latency_s: 0.05,
        }
    }
}

/// One client's sampled network + device characteristics.
#[derive(Clone, Debug)]
pub struct ClientLink {
    pub down_bps: f64,
    pub up_bps: f64,
    pub device_flops: f64,
}

impl ClientLink {
    pub fn sample(cfg: &LinkConfig, rng: &mut Pcg64) -> ClientLink {
        ClientLink {
            down_bps: mbps_to_bps(rng.uniform(cfg.down_mbps.0, cfg.down_mbps.1)),
            up_bps: mbps_to_bps(rng.uniform(cfg.up_mbps.0, cfg.up_mbps.1)),
            device_flops: rng.uniform(cfg.device_gflops.0, cfg.device_gflops.1) * 1e9,
        }
    }

    pub fn down_time(&self, bytes: u64, cfg: &LinkConfig) -> f64 {
        cfg.rtt_latency_s + bytes as f64 / self.down_bps
    }

    pub fn up_time(&self, bytes: u64, cfg: &LinkConfig) -> f64 {
        cfg.rtt_latency_s + bytes as f64 / self.up_bps
    }

    pub fn compute_time(&self, flops: f64) -> f64 {
        flops / self.device_flops
    }
}

/// Simulated network: per-client links, sampled once.
#[derive(Clone, Debug)]
pub struct NetworkSim {
    pub cfg: LinkConfig,
    pub links: Vec<ClientLink>,
}

#[derive(Clone, Debug, Default)]
pub struct ClientTiming {
    pub down_s: f64,
    pub compute_s: f64,
    pub up_s: f64,
}

impl ClientTiming {
    pub fn total(&self) -> f64 {
        self.down_s + self.compute_s + self.up_s
    }
}

#[derive(Clone, Debug, Default)]
pub struct RoundTiming {
    pub per_client: Vec<ClientTiming>,
    /// Synchronous round duration = slowest client.
    pub round_s: f64,
    pub down_bytes: u64,
    pub up_bytes: u64,
}

impl NetworkSim {
    pub fn new(cfg: LinkConfig, num_clients: usize, seed: u64) -> NetworkSim {
        let mut rng = Pcg64::with_stream(seed, 0x11e7);
        let links = (0..num_clients)
            .map(|_| ClientLink::sample(&cfg, &mut rng))
            .collect();
        NetworkSim { cfg, links }
    }

    /// Account one synchronous round. `per_client`: (client id,
    /// downlink bytes, epoch flops, uplink bytes).
    pub fn round(&self, per_client: &[(usize, u64, f64, u64)]) -> RoundTiming {
        let mut timing = RoundTiming::default();
        for &(c, down_b, flops, up_b) in per_client {
            let link = &self.links[c];
            let t = ClientTiming {
                down_s: link.down_time(down_b, &self.cfg),
                compute_s: link.compute_time(flops),
                up_s: link.up_time(up_b, &self.cfg),
            };
            timing.round_s = timing.round_s.max(t.total());
            timing.down_bytes += down_b;
            timing.up_bytes += up_b;
            timing.per_client.push(t);
        }
        timing
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampled_links_stay_in_ranges() {
        let cfg = LinkConfig::default();
        let sim = NetworkSim::new(cfg.clone(), 200, 1);
        for l in &sim.links {
            assert!(l.down_bps >= mbps_to_bps(5.0) && l.down_bps <= mbps_to_bps(12.0));
            assert!(l.up_bps >= mbps_to_bps(2.0) && l.up_bps <= mbps_to_bps(5.0));
            assert!(l.device_flops >= 2e9 && l.device_flops <= 8e9);
        }
        // Paper's asymmetry: downlink faster than uplink on average.
        let avg_down: f64 =
            sim.links.iter().map(|l| l.down_bps).sum::<f64>() / sim.links.len() as f64;
        let avg_up: f64 =
            sim.links.iter().map(|l| l.up_bps).sum::<f64>() / sim.links.len() as f64;
        assert!(avg_down > avg_up);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = NetworkSim::new(LinkConfig::default(), 10, 7);
        let b = NetworkSim::new(LinkConfig::default(), 10, 7);
        for (x, y) in a.links.iter().zip(&b.links) {
            assert_eq!(x.down_bps, y.down_bps);
            assert_eq!(x.up_bps, y.up_bps);
        }
        let c = NetworkSim::new(LinkConfig::default(), 10, 8);
        assert!(a.links[0].down_bps != c.links[0].down_bps);
    }

    #[test]
    fn round_time_is_max_not_sum() {
        let sim = NetworkSim::new(LinkConfig::default(), 4, 3);
        let jobs: Vec<(usize, u64, f64, u64)> =
            (0..4).map(|c| (c, 1_000_000, 1e9, 500_000)).collect();
        let t = sim.round(&jobs);
        let max_c = t
            .per_client
            .iter()
            .map(|c| c.total())
            .fold(0.0f64, f64::max);
        assert_eq!(t.round_s, max_c);
        let sum_c: f64 = t.per_client.iter().map(|c| c.total()).sum();
        assert!(t.round_s < sum_c);
        assert_eq!(t.down_bytes, 4_000_000);
        assert_eq!(t.up_bytes, 2_000_000);
    }

    #[test]
    fn smaller_payloads_are_faster() {
        let sim = NetworkSim::new(LinkConfig::default(), 1, 5);
        let full = sim.round(&[(0, 4_000_000, 1e9, 4_000_000)]);
        let compressed = sim.round(&[(0, 200_000, 0.75e9, 100_000)]);
        assert!(compressed.round_s < full.round_s / 5.0);
    }

    #[test]
    fn uplink_dominates_for_symmetric_payloads() {
        // 2–5 Mbps up vs 5–12 Mbps down: equal bytes → up slower.
        let sim = NetworkSim::new(LinkConfig::default(), 50, 6);
        for l in &sim.links {
            let down = l.down_time(1_000_000, &sim.cfg);
            let up = l.up_time(1_000_000, &sim.cfg);
            assert!(up > down);
        }
    }
}
