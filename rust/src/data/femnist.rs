//! Synthetic FEMNIST: 62-class glyph images partitioned by "writer".
//!
//! Each class has a prototype glyph — a deterministic mixture of
//! gaussian strokes on the image grid. Each writer (client) owns a
//! style: a small translation, intensity gain and stroke-width jitter
//! applied to every glyph they "write", plus pixel noise per sample.
//! Non-IID follows LEAF's structure (each writer covers a subset of
//! classes with an own style); IID pools and re-deals.

use crate::data::{partition, ClientDataset, DataConfig, FederatedDataset, Samples};
use crate::model::manifest::VariantSpec;
use crate::util::rng::Pcg64;

/// Deterministic per-class stroke parameters.
struct Prototype {
    /// (cx, cy, sx, sy, amp) gaussian strokes in unit coordinates.
    strokes: Vec<(f32, f32, f32, f32, f32)>,
}

fn prototype(class: usize, seed: u64) -> Prototype {
    let mut rng = Pcg64::with_stream(seed ^ 0xfe31, class as u64 + 1);
    let n = 3 + rng.below(3) as usize;
    let strokes = (0..n)
        .map(|_| {
            (
                rng.uniform(0.2, 0.8) as f32,
                rng.uniform(0.2, 0.8) as f32,
                rng.uniform(0.05, 0.22) as f32,
                rng.uniform(0.05, 0.22) as f32,
                rng.uniform(0.6, 1.0) as f32,
            )
        })
        .collect();
    Prototype { strokes }
}

/// Writer style transform.
struct Style {
    dx: f32,
    dy: f32,
    gain: f32,
    width: f32,
    noise: f32,
}

fn style(rng: &mut Pcg64) -> Style {
    Style {
        dx: rng.uniform(-0.08, 0.08) as f32,
        dy: rng.uniform(-0.08, 0.08) as f32,
        gain: rng.uniform(0.7, 1.3) as f32,
        width: rng.uniform(0.85, 1.2) as f32,
        noise: rng.uniform(0.05, 0.15) as f32,
    }
}

fn render(
    proto: &Prototype,
    st: &Style,
    side: usize,
    rng: &mut Pcg64,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), side * side);
    for py in 0..side {
        for px in 0..side {
            let x = (px as f32 + 0.5) / side as f32 - st.dx;
            let y = (py as f32 + 0.5) / side as f32 - st.dy;
            let mut v = 0.0f32;
            for &(cx, cy, sx, sy, amp) in &proto.strokes {
                let ddx = (x - cx) / (sx * st.width);
                let ddy = (y - cy) / (sy * st.width);
                v += amp * (-0.5 * (ddx * ddx + ddy * ddy)).exp();
            }
            out[py * side + px] =
                (v * st.gain + rng.normal_f32(0.0, st.noise)).clamp(-0.5, 1.5);
        }
    }
}

pub fn generate(spec: &VariantSpec, cfg: &DataConfig) -> FederatedDataset {
    let side = spec.input_shape[0];
    assert_eq!(spec.input_shape.len(), 3, "femnist expects [H, W, C]");
    let per = side * side * spec.input_shape[2];
    let classes = spec.classes;
    let mut rng = Pcg64::with_stream(cfg.seed, 0xfe);
    let protos: Vec<Prototype> = (0..classes).map(|c| prototype(c, cfg.seed)).collect();

    let sizes = partition::client_sizes(cfg, &mut rng);
    // Non-IID: each writer covers ~half the classes (min 2).
    let subsets = partition::class_subsets(
        classes,
        cfg.num_clients,
        (classes / 2).max(2),
        &mut rng,
    );

    let mut train_clients = Vec::with_capacity(cfg.num_clients);
    let mut test_xs: Vec<f32> = Vec::new();
    let mut test_ys: Vec<i32> = Vec::new();

    // First generate per-writer pools (style applied), then either keep
    // them (non-IID) or pool + re-deal (IID).
    let mut all_xs: Vec<f32> = Vec::new();
    let mut all_ys: Vec<i32> = Vec::new();
    let mut writer_ranges = Vec::with_capacity(cfg.num_clients);
    for (w, &n) in sizes.iter().enumerate() {
        let mut wrng = rng.fork(w as u64);
        let st = style(&mut wrng);
        let start = all_ys.len();
        let mut buf = vec![0.0f32; per];
        for _ in 0..n {
            let class = subsets[w][wrng.below(subsets[w].len() as u64) as usize];
            render(&protos[class], &st, side, &mut wrng, &mut buf);
            all_xs.extend_from_slice(&buf);
            all_ys.push(class as i32);
        }
        writer_ranges.push(start..start + n);
    }

    let assignment: Vec<Vec<usize>> = if cfg.iid {
        partition::iid_deal(all_ys.len(), &sizes, &mut rng)
    } else {
        writer_ranges.iter().map(|r| r.clone().collect()).collect()
    };

    for idxs in assignment {
        let n_test = ((idxs.len() as f64) * cfg.test_fraction).round() as usize;
        let (test_idx, train_idx) = idxs.split_at(n_test.min(idxs.len().saturating_sub(1)));
        let mut xs = Vec::with_capacity(train_idx.len() * per);
        let mut ys = Vec::with_capacity(train_idx.len());
        for &i in train_idx {
            xs.extend_from_slice(&all_xs[i * per..(i + 1) * per]);
            ys.push(all_ys[i]);
        }
        for &i in test_idx {
            test_xs.extend_from_slice(&all_xs[i * per..(i + 1) * per]);
            test_ys.push(all_ys[i]);
        }
        train_clients.push(ClientDataset {
            xs: Samples::F32(xs),
            ys,
            per_sample: per,
        });
    }

    FederatedDataset {
        clients: train_clients,
        test: ClientDataset {
            xs: Samples::F32(test_xs),
            ys: test_ys,
            per_sample: per,
        },
    }
}

/// Dense-vector variant for the synthetic MLP runtime (tests/benches):
/// class-centred gaussian blobs over a flat feature vector.
pub fn generate_dense(spec: &VariantSpec, cfg: &DataConfig) -> FederatedDataset {
    let per: usize = spec.input_shape.iter().product();
    let classes = spec.classes;
    let mut rng = Pcg64::with_stream(cfg.seed, 0xde);
    let sizes = partition::client_sizes(cfg, &mut rng);
    let subsets = if cfg.iid {
        vec![(0..classes).collect::<Vec<_>>(); cfg.num_clients]
    } else {
        partition::class_subsets(classes, cfg.num_clients, (classes / 2).max(2), &mut rng)
    };
    // Class centres: ±2 pattern over features, deterministic.
    let centres: Vec<Vec<f32>> = (0..classes)
        .map(|c| {
            let mut crng = Pcg64::with_stream(cfg.seed ^ 0xce, c as u64 + 1);
            (0..per).map(|_| crng.normal_f32(0.0, 1.5)).collect()
        })
        .collect();

    let mut clients = Vec::new();
    let mut test_xs = Vec::new();
    let mut test_ys = Vec::new();
    for (w, &n) in sizes.iter().enumerate() {
        let mut wrng = rng.fork(w as u64 + 1000);
        let n_test = ((n as f64) * cfg.test_fraction).round() as usize;
        let mut xs = Vec::with_capacity(n * per);
        let mut ys = Vec::with_capacity(n);
        for i in 0..n {
            let class = subsets[w][wrng.below(subsets[w].len() as u64) as usize];
            let centre = &centres[class];
            let sample: Vec<f32> = centre
                .iter()
                .map(|&c| c + wrng.normal_f32(0.0, 0.8))
                .collect();
            if i < n_test {
                test_xs.extend_from_slice(&sample);
                test_ys.push(class as i32);
            } else {
                xs.extend_from_slice(&sample);
                ys.push(class as i32);
            }
        }
        clients.push(ClientDataset {
            xs: Samples::F32(xs),
            ys,
            per_sample: per,
        });
    }
    FederatedDataset {
        clients,
        test: ClientDataset {
            xs: Samples::F32(test_xs),
            ys: test_ys,
            per_sample: per,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::mlp_spec;

    fn cnn_like_spec() -> VariantSpec {
        let mut spec = mlp_spec("f", 0, 4, 6, 10, 2, 0.1);
        spec.dataset = "femnist".into();
        spec.input_shape = vec![14, 14, 1];
        spec
    }

    #[test]
    fn generates_requested_structure() {
        let spec = cnn_like_spec();
        let cfg = DataConfig {
            num_clients: 8,
            samples_per_client: (20, 30),
            iid: false,
            test_fraction: 0.2,
            seed: 1,
        };
        let ds = generate(&spec, &cfg);
        assert_eq!(ds.num_clients(), 8);
        for c in &ds.clients {
            assert!(!c.is_empty());
            assert_eq!(c.per_sample, 14 * 14);
            assert!(c.ys.iter().all(|&y| (0..6).contains(&y)));
        }
        assert!(!ds.test.is_empty());
        // Test fraction ≈ 20% of total.
        let total = ds.total_train_samples() + ds.test.len();
        let frac = ds.test.len() as f64 / total as f64;
        assert!((0.1..0.3).contains(&frac), "test frac {frac}");
    }

    #[test]
    fn noniid_writers_have_class_skew() {
        let spec = cnn_like_spec();
        let cfg = DataConfig {
            num_clients: 6,
            samples_per_client: (40, 40),
            iid: false,
            test_fraction: 0.0,
            seed: 2,
        };
        let ds = generate(&spec, &cfg);
        // Each non-IID writer must miss some classes.
        let mut any_skew = false;
        for c in &ds.clients {
            let mut seen = vec![false; 6];
            for &y in &c.ys {
                seen[y as usize] = true;
            }
            if seen.iter().any(|&s| !s) {
                any_skew = true;
            }
        }
        assert!(any_skew, "non-IID writers should not cover all classes");
    }

    #[test]
    fn iid_clients_cover_most_classes() {
        let spec = cnn_like_spec();
        let cfg = DataConfig {
            num_clients: 4,
            samples_per_client: (60, 60),
            iid: true,
            test_fraction: 0.0,
            seed: 3,
        };
        let ds = generate(&spec, &cfg);
        for c in &ds.clients {
            let mut seen = vec![false; 6];
            for &y in &c.ys {
                seen[y as usize] = true;
            }
            let covered = seen.iter().filter(|&&s| s).count();
            assert!(covered >= 4, "IID client covers only {covered}/6 classes");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = cnn_like_spec();
        let cfg = DataConfig {
            num_clients: 3,
            samples_per_client: (10, 12),
            iid: false,
            test_fraction: 0.2,
            seed: 9,
        };
        let a = generate(&spec, &cfg);
        let b = generate(&spec, &cfg);
        assert_eq!(a.clients[0].ys, b.clients[0].ys);
        match (&a.clients[0].xs, &b.clients[0].xs) {
            (Samples::F32(x), Samples::F32(y)) => assert_eq!(x, y),
            _ => panic!(),
        }
    }

    #[test]
    fn classes_are_visually_distinct() {
        // Same-class samples must be closer (L2) than cross-class ones on
        // average — otherwise nothing is learnable.
        let spec = cnn_like_spec();
        let cfg = DataConfig {
            num_clients: 2,
            samples_per_client: (80, 80),
            iid: true,
            test_fraction: 0.0,
            seed: 4,
        };
        let ds = generate(&spec, &cfg);
        let c = &ds.clients[0];
        let per = c.per_sample;
        let xs = match &c.xs {
            Samples::F32(v) => v,
            _ => unreachable!(),
        };
        let mut same = (0.0f64, 0usize);
        let mut diff = (0.0f64, 0usize);
        for i in 0..c.len() {
            for j in (i + 1)..c.len() {
                let d: f64 = (0..per)
                    .map(|k| {
                        let e = (xs[i * per + k] - xs[j * per + k]) as f64;
                        e * e
                    })
                    .sum();
                if c.ys[i] == c.ys[j] {
                    same = (same.0 + d, same.1 + 1);
                } else {
                    diff = (diff.0 + d, diff.1 + 1);
                }
            }
        }
        let same_avg = same.0 / same.1 as f64;
        let diff_avg = diff.0 / diff.1 as f64;
        assert!(
            diff_avg > same_avg * 1.3,
            "same {same_avg:.2} vs diff {diff_avg:.2}"
        );
    }
}
