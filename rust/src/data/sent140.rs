//! Synthetic Sentiment140: 2-class tweet sentiment, client = "user".
//!
//! Tweets are lexicon/template compositions: a sentiment skeleton drawn
//! from positive/negative word lists plus neutral filler, tokenized to
//! ids via a deterministic hash into the model's vocabulary (standing in
//! for a GloVe lookup table, which the model treats as a frozen
//! embedding — exactly the paper's setup). Non-IID: every user has an
//! own filler-vocabulary bias and a sentiment prior; IID pools+re-deals.

use crate::data::{partition, ClientDataset, DataConfig, FederatedDataset, Samples};
use crate::model::manifest::VariantSpec;
use crate::util::rng::Pcg64;

const POSITIVE: &[&str] = &[
    "love", "great", "awesome", "happy", "wonderful", "best", "amazing",
    "excited", "fantastic", "perfect", "beautiful", "win", "delighted",
    "brilliant", "joy", "smile", "sunshine", "sweet", "good", "nice",
];

const NEGATIVE: &[&str] = &[
    "hate", "awful", "terrible", "sad", "horrible", "worst", "angry",
    "disappointed", "broken", "fail", "ugly", "lose", "miserable", "gross",
    "pain", "cry", "rainy", "sour", "bad", "annoying",
];

const FILLER: &[&str] = &[
    "the", "a", "my", "today", "really", "just", "so", "this", "that",
    "morning", "night", "coffee", "work", "school", "phone", "game",
    "movie", "song", "friend", "dog", "cat", "weather", "monday", "friday",
    "weekend", "dinner", "lunch", "train", "bus", "city", "home", "team",
    "match", "show", "book", "class", "test", "traffic", "meeting", "very",
];

/// Deterministic token id for a word (a stand-in for a GloVe row index).
///
/// Id layout (the convention shared with the frozen embedding table in
/// `python/compile/model.py::lstm_init`): 0 = padding; 1..=20 positive
/// lexicon; 21..=40 negative lexicon; 41.. hashed filler. The embedding
/// generator plants a latent sentiment axis on ids 1..=40, emulating the
/// sentiment structure real pretrained GloVe vectors carry.
pub fn token_id(word: &str, vocab: usize) -> i32 {
    if let Some(i) = POSITIVE.iter().position(|w| *w == word) {
        return 1 + i as i32;
    }
    if let Some(i) = NEGATIVE.iter().position(|w| *w == word) {
        return 21 + i as i32;
    }
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in word.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    (41 + (h % (vocab as u64 - 41))) as i32
}

fn compose_tweet(
    label: usize,
    seq: usize,
    vocab: usize,
    filler_bias: &[usize],
    rng: &mut Pcg64,
) -> Vec<i32> {
    let lex = if label == 1 { POSITIVE } else { NEGATIVE };
    // 2-4 sentiment words, rest filler, then pad with 0.
    let n_sent = 2 + rng.below(3) as usize;
    let n_fill = (seq / 2 + rng.below((seq / 3) as u64 + 1) as usize)
        .min(seq.saturating_sub(n_sent));
    let mut words: Vec<i32> = Vec::with_capacity(seq);
    for _ in 0..n_sent {
        words.push(token_id(lex[rng.below(lex.len() as u64) as usize], vocab));
    }
    for _ in 0..n_fill {
        let w = filler_bias[rng.below(filler_bias.len() as u64) as usize];
        words.push(token_id(FILLER[w], vocab));
    }
    rng.shuffle(&mut words);
    words.truncate(seq);
    while words.len() < seq {
        words.push(0); // pad
    }
    words
}

pub fn generate(spec: &VariantSpec, cfg: &DataConfig) -> FederatedDataset {
    let seq = spec.input_shape[0];
    assert_eq!(spec.classes, 2, "sent140 is binary");
    let vocab = spec.vocab.max(64);
    let mut rng = Pcg64::with_stream(cfg.seed, 0x5e);
    let sizes = partition::client_sizes(cfg, &mut rng);

    // Per-user style: filler vocabulary subset + sentiment prior.
    let mut pool_xs: Vec<i32> = Vec::new();
    let mut pool_ys: Vec<i32> = Vec::new();
    for (u, &n) in sizes.iter().enumerate() {
        let mut urng = rng.fork(u as u64 + 77);
        let filler_bias: Vec<usize> = if cfg.iid {
            (0..FILLER.len()).collect()
        } else {
            urng.sample_indices(FILLER.len(), FILLER.len() / 3)
        };
        let pos_prior = if cfg.iid {
            0.5
        } else {
            urng.uniform(0.25, 0.75)
        };
        for _ in 0..n {
            let label = if urng.next_f64() < pos_prior { 1 } else { 0 };
            let tweet = compose_tweet(label, seq, vocab, &filler_bias, &mut urng);
            pool_xs.extend_from_slice(&tweet);
            pool_ys.push(label as i32);
        }
    }

    let assignment: Vec<Vec<usize>> = if cfg.iid {
        partition::iid_deal(pool_ys.len(), &sizes, &mut rng)
    } else {
        let mut out = Vec::with_capacity(sizes.len());
        let mut off = 0;
        for &n in &sizes {
            out.push((off..off + n).collect());
            off += n;
        }
        out
    };

    let mut clients = Vec::with_capacity(cfg.num_clients);
    let mut test_xs = Vec::new();
    let mut test_ys = Vec::new();
    for idxs in assignment {
        let n_test = ((idxs.len() as f64) * cfg.test_fraction).round() as usize;
        let (test_idx, train_idx) =
            idxs.split_at(n_test.min(idxs.len().saturating_sub(1)));
        let mut xs = Vec::with_capacity(train_idx.len() * seq);
        let mut ys = Vec::with_capacity(train_idx.len());
        for &i in train_idx {
            xs.extend_from_slice(&pool_xs[i * seq..(i + 1) * seq]);
            ys.push(pool_ys[i]);
        }
        for &i in test_idx {
            test_xs.extend_from_slice(&pool_xs[i * seq..(i + 1) * seq]);
            test_ys.push(pool_ys[i]);
        }
        clients.push(ClientDataset {
            xs: Samples::I32(xs),
            ys,
            per_sample: seq,
        });
    }

    FederatedDataset {
        clients,
        test: ClientDataset {
            xs: Samples::I32(test_xs),
            ys: test_ys,
            per_sample: seq,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::mlp_spec;

    fn sent_spec() -> VariantSpec {
        let mut spec = mlp_spec("s", 0, 4, 2, 10, 2, 0.1);
        spec.dataset = "sent140".into();
        spec.input_shape = vec![25];
        spec.classes = 2;
        spec.vocab = 2000;
        spec
    }

    #[test]
    fn token_ids_stable_and_in_range() {
        assert_eq!(token_id("love", 2000), token_id("love", 2000));
        assert_ne!(token_id("love", 2000), token_id("hate", 2000));
        for w in POSITIVE.iter().chain(NEGATIVE).chain(FILLER) {
            let t = token_id(w, 2000);
            assert!((1..2000).contains(&t), "{w} -> {t}");
        }
    }

    #[test]
    fn lexicons_are_disjoint() {
        for p in POSITIVE {
            assert!(!NEGATIVE.contains(p), "{p} in both lexicons");
        }
    }

    #[test]
    fn generates_balanced_iid_labels() {
        let cfg = DataConfig {
            num_clients: 6,
            samples_per_client: (100, 100),
            iid: true,
            test_fraction: 0.2,
            seed: 5,
        };
        let ds = generate(&sent_spec(), &cfg);
        let total: usize = ds.clients.iter().map(|c| c.len()).sum();
        let pos: usize = ds
            .clients
            .iter()
            .flat_map(|c| c.ys.iter())
            .filter(|&&y| y == 1)
            .count();
        let frac = pos as f64 / total as f64;
        assert!((0.4..0.6).contains(&frac), "pos frac {frac}");
    }

    #[test]
    fn noniid_users_have_label_skew() {
        let cfg = DataConfig {
            num_clients: 12,
            samples_per_client: (80, 80),
            iid: false,
            test_fraction: 0.0,
            seed: 6,
        };
        let ds = generate(&sent_spec(), &cfg);
        let fracs: Vec<f64> = ds
            .clients
            .iter()
            .map(|c| {
                c.ys.iter().filter(|&&y| y == 1).count() as f64 / c.len() as f64
            })
            .collect();
        let spread = fracs
            .iter()
            .fold(0.0f64, |m, &f| m.max(f))
            - fracs.iter().fold(1.0f64, |m, &f| m.min(f));
        assert!(spread > 0.15, "user priors should vary, spread={spread}");
    }

    #[test]
    fn tweets_are_padded_sequences() {
        let cfg = DataConfig {
            num_clients: 2,
            samples_per_client: (20, 20),
            iid: false,
            test_fraction: 0.0,
            seed: 7,
        };
        let spec = sent_spec();
        let ds = generate(&spec, &cfg);
        for c in &ds.clients {
            let xs = match &c.xs {
                Samples::I32(v) => v,
                _ => panic!(),
            };
            assert_eq!(xs.len(), c.len() * 25);
            assert!(xs.iter().all(|&t| (0..2000).contains(&t)));
        }
    }

    #[test]
    fn sentiment_words_separate_classes() {
        // Positive tweets must contain positive-lexicon tokens and not
        // negative ones (and vice versa) — the learnable signal.
        let cfg = DataConfig {
            num_clients: 1,
            samples_per_client: (200, 200),
            iid: true,
            test_fraction: 0.0,
            seed: 8,
        };
        let spec = sent_spec();
        let ds = generate(&spec, &cfg);
        let c = &ds.clients[0];
        let xs = match &c.xs {
            Samples::I32(v) => v,
            _ => panic!(),
        };
        let pos_ids: Vec<i32> = POSITIVE.iter().map(|w| token_id(w, 2000)).collect();
        let neg_ids: Vec<i32> = NEGATIVE.iter().map(|w| token_id(w, 2000)).collect();
        for (i, &y) in c.ys.iter().enumerate() {
            let toks = &xs[i * 25..(i + 1) * 25];
            let has_pos = toks.iter().any(|t| pos_ids.contains(t));
            let has_neg = toks.iter().any(|t| neg_ids.contains(t));
            if y == 1 {
                assert!(has_pos && !has_neg, "tweet {i} mislabeled");
            } else {
                assert!(has_neg && !has_pos, "tweet {i} mislabeled");
            }
        }
    }
}
