//! Synthetic federated datasets standing in for LEAF (see DESIGN.md §2).
//!
//! The real LEAF benchmark partitions privacy-sensitive user data
//! (handwriting by writer, plays by role, tweets by account). What the
//! AFD experiments *need* from the data is (a) a learnable supervised
//! signal for each of the paper's three model families and (b)
//! controllable statistical heterogeneity across clients. The
//! generators here provide both, deterministically from a seed:
//!
//! * [`femnist`]   — 62-class glyph images, client = "writer" with an
//!   own style transform + class subset (non-IID) or pooled (IID);
//! * [`shakespeare`] — next-character prediction over role-conditioned
//!   Markov text seeded from an embedded public-domain excerpt;
//! * [`sent140`]   — 2-class lexicon/template tweets, client = "user"
//!   with an own vocabulary bias.

pub mod femnist;
pub mod lazy;
pub mod partition;
pub mod sent140;
pub mod shakespeare;

use crate::model::manifest::{DType, VariantSpec};
use crate::runtime::{BatchInput, EpochData, EvalBatch};
use crate::util::rng::Pcg64;

/// Raw per-sample storage (one flat buffer, `n * per_sample` long).
#[derive(Clone, Debug)]
pub enum Samples {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Samples {
    pub fn dtype(&self) -> DType {
        match self {
            Samples::F32(_) => DType::F32,
            Samples::I32(_) => DType::I32,
        }
    }
}

/// One client's local dataset (train split) or a pooled test set.
#[derive(Clone, Debug)]
pub struct ClientDataset {
    pub xs: Samples,
    pub ys: Vec<i32>,
    pub per_sample: usize,
}

impl ClientDataset {
    pub fn len(&self) -> usize {
        self.ys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ys.is_empty()
    }

    fn gather(&self, order: &[usize]) -> (Samples, Vec<i32>) {
        let ys = order.iter().map(|&i| self.ys[i]).collect();
        let xs = match &self.xs {
            Samples::F32(v) => Samples::F32(
                order
                    .iter()
                    .flat_map(|&i| v[i * self.per_sample..(i + 1) * self.per_sample].iter().copied())
                    .collect(),
            ),
            Samples::I32(v) => Samples::I32(
                order
                    .iter()
                    .flat_map(|&i| v[i * self.per_sample..(i + 1) * self.per_sample].iter().copied())
                    .collect(),
            ),
        };
        (xs, ys)
    }

    /// Assemble one local epoch (`num_batches × batch_size` samples) for
    /// the train artifact: a shuffled pass over the local data, cycling
    /// if the client holds fewer samples than one epoch consumes.
    pub fn epoch_data(&self, spec: &VariantSpec, rng: &mut Pcg64) -> EpochData {
        let mut order = Vec::new();
        let mut out = EpochData {
            xs: BatchInput::F32(Vec::new()),
            ys: Vec::new(),
        };
        self.epoch_data_into(spec, rng, &mut order, &mut out);
        out
    }

    /// [`ClientDataset::epoch_data`] into caller-provided buffers: the
    /// shuffle order goes through `order` and the samples/labels into
    /// `out`'s recycled vectors, so a warm buffer assembles an epoch
    /// with zero heap allocations. The RNG draw sequence is identical
    /// to the allocating API (each cycle shuffles a fresh `0..len`
    /// range in place), so trajectories don't depend on which entry
    /// point assembled the epoch.
    pub fn epoch_data_into(
        &self,
        spec: &VariantSpec,
        rng: &mut Pcg64,
        order: &mut Vec<u32>,
        out: &mut EpochData,
    ) {
        let need = spec.samples_per_round();
        // An empty client can never fill an epoch — fail loudly instead
        // of spinning in the cycling loop below.
        assert!(
            !self.is_empty() || need == 0,
            "epoch_data: client dataset is empty but the spec needs {need} samples per round"
        );
        order.clear();
        order.extend(0..self.len() as u32);
        rng.shuffle(&mut order[..]);
        while order.len() < need {
            let start = order.len();
            order.extend(0..self.len() as u32);
            rng.shuffle(&mut order[start..]);
        }
        order.truncate(need);
        let ps = self.per_sample;
        out.ys.clear();
        out.ys.extend(order.iter().map(|&i| self.ys[i as usize]));
        match &self.xs {
            Samples::F32(v) => {
                if !matches!(out.xs, BatchInput::F32(_)) {
                    out.xs = BatchInput::F32(Vec::new());
                }
                if let BatchInput::F32(dst) = &mut out.xs {
                    dst.clear();
                    dst.reserve(order.len() * ps);
                    for &i in order.iter() {
                        let i = i as usize;
                        dst.extend_from_slice(&v[i * ps..(i + 1) * ps]);
                    }
                }
            }
            Samples::I32(v) => {
                if !matches!(out.xs, BatchInput::I32(_)) {
                    out.xs = BatchInput::I32(Vec::new());
                }
                if let BatchInput::I32(dst) = &mut out.xs {
                    dst.clear();
                    dst.reserve(order.len() * ps);
                    for &i in order.iter() {
                        let i = i as usize;
                        dst.extend_from_slice(&v[i * ps..(i + 1) * ps]);
                    }
                }
            }
        }
    }

    /// Full pass as eval batches (tail padded by wrapping; callers use
    /// `limit` to cap eval cost).
    pub fn eval_batches(&self, spec: &VariantSpec, limit: Option<usize>) -> Vec<EvalBatch> {
        let bs = spec.batch_size;
        let n = self.len();
        let nb = n.div_ceil(bs).min(limit.unwrap_or(usize::MAX));
        (0..nb)
            .map(|b| {
                let order: Vec<usize> = (0..bs).map(|i| (b * bs + i) % n).collect();
                let (xs, ys) = self.gather(&order);
                EvalBatch {
                    xs: match xs {
                        Samples::F32(v) => BatchInput::F32(v),
                        Samples::I32(v) => BatchInput::I32(v),
                    },
                    ys,
                }
            })
            .collect()
    }
}

/// A federated dataset: per-client train splits + a pooled test set
/// (the paper reserves 20% of each client's data for testing).
#[derive(Clone, Debug)]
pub struct FederatedDataset {
    pub clients: Vec<ClientDataset>,
    pub test: ClientDataset,
}

impl FederatedDataset {
    pub fn num_clients(&self) -> usize {
        self.clients.len()
    }

    pub fn total_train_samples(&self) -> usize {
        self.clients.iter().map(|c| c.len()).sum()
    }
}

/// Generation knobs shared by the three dataset families.
#[derive(Clone, Debug)]
pub struct DataConfig {
    pub num_clients: usize,
    /// Per-client sample count range (inclusive), drawn uniformly.
    pub samples_per_client: (usize, usize),
    /// IID: pool + shuffle + deal evenly. Non-IID: writer/role/user skew.
    pub iid: bool,
    /// Fraction of each client's data reserved for the pooled test set.
    pub test_fraction: f64,
    pub seed: u64,
}

impl Default for DataConfig {
    fn default() -> Self {
        DataConfig {
            num_clients: 30,
            samples_per_client: (60, 140),
            iid: false,
            test_fraction: 0.2,
            seed: 0,
        }
    }
}

/// Dispatch on the variant's dataset family.
pub fn generate(spec: &VariantSpec, cfg: &DataConfig) -> FederatedDataset {
    match spec.dataset.as_str() {
        "femnist" => femnist::generate(spec, cfg),
        "shakespeare" => shakespeare::generate(spec, cfg),
        "sent140" => sent140::generate(spec, cfg),
        // Pure per-client derivation (same blob model as the legacy
        // `femnist::generate_dense`): keeps eager runs bit-identical
        // to lazy-population runs, which derive the same clients on
        // demand instead of materializing the whole fleet.
        "synthetic" => lazy::generate_lazy(spec, cfg),
        other => panic!("unknown dataset family {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::mlp_spec;

    #[test]
    fn epoch_data_cycles_small_clients() {
        let spec = mlp_spec("t", 4, 8, 3, 10, 5, 0.1); // needs 50 samples
        let ds = ClientDataset {
            xs: Samples::F32((0..12 * 4).map(|i| i as f32).collect()),
            ys: (0..12).map(|i| (i % 3) as i32).collect(),
            per_sample: 4,
        };
        let mut rng = Pcg64::new(0);
        let ep = ds.epoch_data(&spec, &mut rng);
        assert_eq!(ep.ys.len(), 50);
        assert_eq!(ep.xs.len(), 200);
    }

    #[test]
    fn epoch_data_into_matches_allocating_api_and_reuses_buffers() {
        let spec = mlp_spec("t", 4, 8, 3, 10, 5, 0.1);
        let ds = ClientDataset {
            xs: Samples::F32((0..12 * 4).map(|i| i as f32).collect()),
            ys: (0..12).map(|i| (i % 3) as i32).collect(),
            per_sample: 4,
        };
        // Same RNG stream state ⇒ identical epochs through both APIs.
        let mut rng_a = Pcg64::new(9);
        let mut rng_b = Pcg64::new(9);
        let mut order = Vec::new();
        let mut out = EpochData {
            xs: BatchInput::F32(Vec::new()),
            ys: Vec::new(),
        };
        for round in 0..3 {
            let want = ds.epoch_data(&spec, &mut rng_a);
            ds.epoch_data_into(&spec, &mut rng_b, &mut order, &mut out);
            assert_eq!(out.ys, want.ys, "round {round}");
            match (&out.xs, &want.xs) {
                (BatchInput::F32(a), BatchInput::F32(b)) => assert_eq!(a, b),
                _ => panic!("dtype mismatch"),
            }
        }
    }

    #[test]
    fn eval_batches_cover_and_wrap() {
        let spec = mlp_spec("t", 4, 8, 3, 10, 5, 0.1);
        let ds = ClientDataset {
            xs: Samples::F32((0..25 * 4).map(|i| i as f32).collect()),
            ys: (0..25).map(|i| (i % 3) as i32).collect(),
            per_sample: 4,
        };
        let batches = ds.eval_batches(&spec, None);
        assert_eq!(batches.len(), 3); // ceil(25/10)
        assert!(batches.iter().all(|b| b.ys.len() == 10));
        let limited = ds.eval_batches(&spec, Some(2));
        assert_eq!(limited.len(), 2);
    }
}
