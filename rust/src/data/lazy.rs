//! Pure per-client dataset derivation for the lazy population engine.
//!
//! The eager generators in this directory draw every client from one
//! sequential RNG stream (`rng.fork(w)` advances the parent), so client
//! `w`'s data depends on every client before it — fine when the whole
//! fleet is materialized once, fatal when a million-client population
//! must materialize only the sampled cohort. This module re-derives the
//! dense-synthetic family (class-centred gaussian blobs, the native
//! MLP's `"synthetic"` dataset) as **pure functions of
//! `(data_seed, client_id)`**: any client can be built in isolation, in
//! any order, any number of times, and the result is bit-identical
//! every time.
//!
//! Stream map (all `Pcg64::with_stream(seed ^ X, id + 1)`, one stream
//! per concern so adding draws to one never perturbs another):
//!
//! | XOR          | concern                                   |
//! |--------------|-------------------------------------------|
//! | `0x512e`     | client size, then non-IID class subset    |
//! | `0xda7a`     | client sample classes + feature noise     |
//! | `0xce`       | per-class centres (shared with the eager  |
//! |              | generator — already pure per class)       |
//! | `0x7e57`     | the derived pooled test set               |
//!
//! [`generate_lazy`] loops the same pure functions into an ordinary
//! [`FederatedDataset`], which is what the equivalence property test
//! compares against: lazy ≡ eager holds by construction, and the test
//! pins the derivation against accidental stream changes.

use crate::data::{ClientDataset, DataConfig, FederatedDataset, Samples};
use crate::model::manifest::VariantSpec;
use crate::util::rng::Pcg64;

const SIZE_STREAM: u64 = 0x512e;
const SAMPLE_STREAM: u64 = 0xda7a;
const CENTRE_STREAM: u64 = 0xce;
const TEST_STREAM: u64 = 0x7e57;

/// Per-class blob centres, identical draw to the eager
/// `femnist::generate_dense` centres (pure per class already). Built
/// once and shared across client materializations.
pub struct Centres {
    per: usize,
    flat: Vec<f32>,
}

impl Centres {
    pub fn build(seed: u64, classes: usize, per: usize) -> Centres {
        let mut flat = Vec::with_capacity(classes * per);
        for c in 0..classes {
            let mut crng = Pcg64::with_stream(seed ^ CENTRE_STREAM, c as u64 + 1);
            flat.extend((0..per).map(|_| crng.normal_f32(0.0, 1.5)));
        }
        Centres { per, flat }
    }

    fn class(&self, c: usize) -> &[f32] {
        &self.flat[c * self.per..(c + 1) * self.per]
    }
}

/// Pure: client `id`'s local sample count (uniform in the configured
/// inclusive range). One `below` draw from the size stream.
pub fn client_num_samples(cfg: &DataConfig, id: usize) -> usize {
    let (lo, hi) = cfg.samples_per_client;
    let mut rng = Pcg64::with_stream(cfg.seed ^ SIZE_STREAM, id as u64 + 1);
    lo + rng.below((hi - lo + 1) as u64) as usize
}

/// Pure: client `id`'s class subset — every class when IID, otherwise
/// `max(classes/2, 2)` distinct classes (the eager generator's non-IID
/// skew), drawn from the size stream *after* the size draw so the two
/// derivations stay consistent.
fn client_classes(cfg: &DataConfig, classes: usize, id: usize) -> Vec<usize> {
    if cfg.iid {
        return (0..classes).collect();
    }
    let (lo, hi) = cfg.samples_per_client;
    let mut rng = Pcg64::with_stream(cfg.seed ^ SIZE_STREAM, id as u64 + 1);
    let _ = rng.below((hi - lo + 1) as u64); // the size draw
    let k = (classes / 2).max(2).min(classes);
    rng.sample_indices(classes, k)
}

/// Pure: client `id`'s full local dataset. Unlike the eager generator,
/// no per-client test fraction is withheld — the lazy test set is
/// derived independently by [`test_dataset`].
pub fn client_dataset(
    spec: &VariantSpec,
    cfg: &DataConfig,
    centres: &Centres,
    id: usize,
) -> ClientDataset {
    let per: usize = spec.input_shape.iter().product();
    let mut out = ClientDataset {
        xs: Samples::F32(Vec::new()),
        ys: Vec::new(),
        per_sample: per,
    };
    client_dataset_into(spec, cfg, centres, id, &mut out);
    out
}

/// [`client_dataset`] into a recycled buffer (cleared first; capacity
/// reused) — the residual store rematerializes evicted lazy clients
/// through pooled buffers so rehydration doesn't churn the heap.
pub fn client_dataset_into(
    spec: &VariantSpec,
    cfg: &DataConfig,
    centres: &Centres,
    id: usize,
    out: &mut ClientDataset,
) {
    let per: usize = spec.input_shape.iter().product();
    assert_eq!(per, centres.per, "client_dataset: centre width mismatch");
    let n = client_num_samples(cfg, id);
    let subset = client_classes(cfg, spec.classes, id);
    let mut wrng = Pcg64::with_stream(cfg.seed ^ SAMPLE_STREAM, id as u64 + 1);
    out.per_sample = per;
    out.ys.clear();
    out.ys.reserve(n);
    if !matches!(out.xs, Samples::F32(_)) {
        out.xs = Samples::F32(Vec::new());
    }
    let Samples::F32(xs) = &mut out.xs else {
        unreachable!()
    };
    xs.clear();
    xs.reserve(n * per);
    for _ in 0..n {
        let class = subset[wrng.below(subset.len() as u64) as usize];
        let centre = centres.class(class);
        xs.extend(centre.iter().map(|&c| c + wrng.normal_f32(0.0, 0.8)));
        out.ys.push(class as i32);
    }
}

/// Deterministic pooled-test size for lazy mode: the eager generators
/// withhold `test_fraction` of every client's data, which is O(n) —
/// unbounded at population scale. Lazy mode derives an independent
/// test set sized like the eager one but clamped to `[64, 4096]`
/// samples (eval cost is already capped by `eval_batch_limit`).
pub fn test_count(cfg: &DataConfig) -> usize {
    let (lo, hi) = cfg.samples_per_client;
    let avg = (lo + hi) as f64 / 2.0;
    let want = (avg * cfg.num_clients as f64 * cfg.test_fraction).round() as usize;
    want.clamp(64, 4096)
}

/// Pure: the pooled test set — uniformly random classes, same blob
/// model as the clients, own stream.
pub fn test_dataset(spec: &VariantSpec, cfg: &DataConfig, centres: &Centres) -> ClientDataset {
    let per: usize = spec.input_shape.iter().product();
    let n = test_count(cfg);
    let mut rng = Pcg64::with_stream(cfg.seed ^ TEST_STREAM, 1);
    let mut xs = Vec::with_capacity(n * per);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let class = rng.below(spec.classes as u64) as usize;
        let centre = centres.class(class);
        xs.extend(centre.iter().map(|&c| c + rng.normal_f32(0.0, 0.8)));
        ys.push(class as i32);
    }
    ClientDataset {
        xs: Samples::F32(xs),
        ys,
        per_sample: per,
    }
}

/// Materialize the whole population eagerly by looping the pure
/// per-client functions — the reference the lazy path is compared
/// against, and a drop-in dataset for small lazy-config runs.
pub fn generate_lazy(spec: &VariantSpec, cfg: &DataConfig) -> FederatedDataset {
    let per: usize = spec.input_shape.iter().product();
    let centres = Centres::build(cfg.seed, spec.classes, per);
    let clients = (0..cfg.num_clients)
        .map(|id| client_dataset(spec, cfg, &centres, id))
        .collect();
    FederatedDataset {
        clients,
        test: test_dataset(spec, cfg, &centres),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::mlp_spec;

    fn cfg(seed: u64, iid: bool) -> DataConfig {
        DataConfig {
            num_clients: 12,
            samples_per_client: (20, 40),
            iid,
            test_fraction: 0.2,
            seed,
        }
    }

    #[test]
    fn derivation_is_pure_and_order_independent() {
        let spec = mlp_spec("lazy", 16, 8, 4, 5, 2, 0.1);
        let c = cfg(7, false);
        let centres = Centres::build(c.seed, spec.classes, 16);
        // Deriving client 5 twice, and after other clients, is
        // bit-identical.
        let a = client_dataset(&spec, &c, &centres, 5);
        let _ = client_dataset(&spec, &c, &centres, 0);
        let _ = client_dataset(&spec, &c, &centres, 11);
        let b = client_dataset(&spec, &c, &centres, 5);
        assert_eq!(a.ys, b.ys);
        match (&a.xs, &b.xs) {
            (Samples::F32(x), Samples::F32(y)) => assert_eq!(x, y),
            _ => panic!("dtype"),
        }
        assert_eq!(a.len(), client_num_samples(&c, 5));
    }

    #[test]
    fn generate_lazy_matches_per_client_derivation() {
        let spec = mlp_spec("lazy", 16, 8, 4, 5, 2, 0.1);
        for iid in [false, true] {
            let c = cfg(3, iid);
            let ds = generate_lazy(&spec, &c);
            assert_eq!(ds.num_clients(), c.num_clients);
            let centres = Centres::build(c.seed, spec.classes, 16);
            for id in [0usize, 4, 11] {
                let want = client_dataset(&spec, &c, &centres, id);
                assert_eq!(ds.clients[id].ys, want.ys, "iid={iid} id={id}");
                match (&ds.clients[id].xs, &want.xs) {
                    (Samples::F32(x), Samples::F32(y)) => assert_eq!(x, y),
                    _ => panic!("dtype"),
                }
            }
            assert_eq!(ds.test.len(), test_count(&c));
            assert!(ds.test.ys.iter().all(|&y| (y as usize) < spec.classes));
        }
    }

    #[test]
    fn noniid_clients_skip_classes_iid_cover_all() {
        let spec = mlp_spec("lazy", 16, 8, 6, 5, 2, 0.1);
        let centres = Centres::build(11, spec.classes, 16);
        let noniid = client_dataset(&spec, &cfg(11, false), &centres, 2);
        let mut seen = vec![false; 6];
        for &y in &noniid.ys {
            seen[y as usize] = true;
        }
        assert!(seen.iter().any(|&s| !s), "non-IID client covers all classes");
        // Different clients get different subsets (statistically).
        let classes_of = |id: usize| {
            let mut s: Vec<i32> = client_dataset(&spec, &cfg(11, false), &centres, id)
                .ys
                .clone();
            s.sort_unstable();
            s.dedup();
            s
        };
        assert!(
            (0..8).map(classes_of).collect::<std::collections::HashSet<_>>().len() > 1,
            "all clients drew the same class subset"
        );
    }

    #[test]
    fn test_count_is_bounded() {
        let mut c = cfg(0, false);
        c.num_clients = 1_000_000;
        assert_eq!(test_count(&c), 4096);
        c.num_clients = 1;
        assert_eq!(test_count(&c), 64);
    }
}
