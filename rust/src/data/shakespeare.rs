//! Synthetic Shakespeare: next-character prediction, client = "role".
//!
//! LEAF builds this task from *The Complete Works*; each speaking role
//! is a client. We embed a small public-domain excerpt (below) as the
//! base corpus and give every role its own order-2 character Markov
//! chain: the transition counts from the base corpus, re-weighted by a
//! role-specific perturbation, plus a role catch-phrase mixed into the
//! stream. That preserves the properties AFD interacts with — character
//! sequences with shared global structure and per-client distribution
//! shift — with variable client sizes.
//!
//! Character set: 26 lowercase + 26 uppercase + space = 53 classes,
//! matching the paper's "class label between 0 and 52".

use crate::data::{partition, ClientDataset, DataConfig, FederatedDataset, Samples};
use crate::model::manifest::VariantSpec;
use crate::util::rng::Pcg64;

/// Public-domain Shakespeare excerpts (Sonnet 18, Hamlet III.i, Macbeth
/// V.v, Richard III I.i) used to seed the per-role Markov chains.
pub const BASE_CORPUS: &str = "Shall I compare thee to a summers day \
Thou art more lovely and more temperate Rough winds do shake the darling \
buds of May And summers lease hath all too short a date Sometime too hot \
the eye of heaven shines And often is his gold complexion dimmd And every \
fair from fair sometime declines By chance or natures changing course \
untrimmd But thy eternal summer shall not fade Nor lose possession of \
that fair thou owest Nor shall Death brag thou wanderst in his shade \
When in eternal lines to time thou growest So long as men can breathe or \
eyes can see So long lives this and this gives life to thee \
To be or not to be that is the question Whether tis nobler in the mind \
to suffer The slings and arrows of outrageous fortune Or to take arms \
against a sea of troubles And by opposing end them To die to sleep No \
more and by a sleep to say we end The heartache and the thousand natural \
shocks That flesh is heir to tis a consummation Devoutly to be wishd To \
die to sleep To sleep perchance to dream ay theres the rub For in that \
sleep of death what dreams may come When we have shuffled off this \
mortal coil Must give us pause \
Tomorrow and tomorrow and tomorrow Creeps in this petty pace from day \
to day To the last syllable of recorded time And all our yesterdays \
have lighted fools The way to dusty death Out out brief candle Life is \
but a walking shadow a poor player That struts and frets his hour upon \
the stage And then is heard no more It is a tale Told by an idiot full \
of sound and fury Signifying nothing \
Now is the winter of our discontent Made glorious summer by this sun of \
York And all the clouds that lourd upon our house In the deep bosom of \
the ocean buried Now are our brows bound with victorious wreaths Our \
bruised arms hung up for monuments Our stern alarums changed to merry \
meetings Our dreadful marches to delightful measures";

pub const CHARSET_SIZE: usize = 53;

/// Map a char to [0, 53): a-z → 0..26, A-Z → 26..52, everything else → 52
/// (space).
pub fn char_to_class(c: char) -> usize {
    match c {
        'a'..='z' => (c as usize) - ('a' as usize),
        'A'..='Z' => 26 + (c as usize) - ('A' as usize),
        _ => 52,
    }
}

pub fn class_to_char(k: usize) -> char {
    match k {
        0..=25 => (b'a' + k as u8) as char,
        26..=51 => (b'A' + (k - 26) as u8) as char,
        _ => ' ',
    }
}

/// Order-2 Markov transition table over the 53-char alphabet.
struct Markov {
    /// counts[prev2 * 53 * 53 ... ] — flattened [53, 53, 53].
    counts: Vec<f32>,
}

impl Markov {
    fn from_text(text: &str) -> Markov {
        let mut counts = vec![0.0f32; CHARSET_SIZE * CHARSET_SIZE * CHARSET_SIZE];
        let ids: Vec<usize> = text.chars().map(char_to_class).collect();
        for w in ids.windows(3) {
            counts[(w[0] * CHARSET_SIZE + w[1]) * CHARSET_SIZE + w[2]] += 1.0;
        }
        Markov { counts }
    }

    /// Sample the next char given the previous two, with a role-specific
    /// multiplicative perturbation and add-k smoothing.
    fn next(&self, a: usize, b: usize, perturb: &[f32], rng: &mut Pcg64) -> usize {
        let base = (a * CHARSET_SIZE + b) * CHARSET_SIZE;
        let row = &self.counts[base..base + CHARSET_SIZE];
        let mut cum = [0.0f32; CHARSET_SIZE];
        let mut total = 0.0f32;
        for k in 0..CHARSET_SIZE {
            // Sharpened (temperature < 1) transition distribution: the
            // scaled char-LSTM has a fraction of the paper model's
            // capacity, so the synthetic corpus entropy is lowered to
            // keep the achievable next-char accuracy in the paper's
            // ~50% band (DESIGN.md §2).
            let c = row[k] + 0.005;
            total += c * c.sqrt() * perturb[k]; // counts^1.5
            cum[k] = total;
        }
        let r = rng.next_f32() * total;
        cum.iter().position(|&c| c >= r).unwrap_or(CHARSET_SIZE - 1)
    }
}

fn role_text(
    markov: &Markov,
    role: usize,
    len: usize,
    seed: u64,
) -> Vec<usize> {
    let mut rng = Pcg64::with_stream(seed ^ 0x5a4e, role as u64 + 1);
    // Role style: multiplicative preference over the alphabet.
    let perturb: Vec<f32> = (0..CHARSET_SIZE)
        .map(|_| (rng.normal() as f32 * 0.6).exp())
        .collect();
    // Role catch-phrase injected periodically (strong per-client signal).
    let phrases = [
        "my lord the king commands",
        "alas poor soul so sweet",
        "what light through yonder",
        "the crown weighs heavy here",
        "mark me well good friend",
        "by my troth a fool",
    ];
    let phrase: Vec<usize> = phrases[role % phrases.len()]
        .chars()
        .map(char_to_class)
        .collect();

    let mut out = Vec::with_capacity(len);
    let (mut a, mut b) = (52usize, char_to_class('t'));
    while out.len() < len {
        if out.len() % 53 == 40 {
            out.extend_from_slice(&phrase);
            if phrase.len() >= 2 {
                a = phrase[phrase.len() - 2];
                b = phrase[phrase.len() - 1];
            }
            continue;
        }
        let c = markov.next(a, b, &perturb, &mut rng);
        out.push(c);
        a = b;
        b = c;
    }
    out.truncate(len);
    out
}

pub fn generate(spec: &VariantSpec, cfg: &DataConfig) -> FederatedDataset {
    let seq = spec.input_shape[0];
    assert!(spec.classes == CHARSET_SIZE, "shakespeare expects 53 classes");
    let markov = Markov::from_text(BASE_CORPUS);
    let mut rng = Pcg64::with_stream(cfg.seed, 0x5a);
    let sizes = partition::client_sizes(cfg, &mut rng);

    // Per role: generate text of (n_samples + seq) chars; samples are
    // sliding windows (stride ~ seq/4 for de-correlation).
    let stride = (seq / 4).max(1);
    let mut roles: Vec<(Vec<i32>, Vec<i32>)> = Vec::with_capacity(cfg.num_clients);
    for (role, &n) in sizes.iter().enumerate() {
        let text_len = n * stride + seq + 1;
        let text = role_text(&markov, role, text_len, cfg.seed);
        let mut xs = Vec::with_capacity(n * seq);
        let mut ys = Vec::with_capacity(n);
        for s in 0..n {
            let start = s * stride;
            for t in 0..seq {
                xs.push(text[start + t] as i32);
            }
            ys.push(text[start + seq] as i32);
        }
        roles.push((xs, ys));
    }

    // IID: pool all windows and re-deal.
    let assignment: Option<Vec<Vec<usize>>> = if cfg.iid {
        let total: usize = roles.iter().map(|(_, y)| y.len()).sum();
        Some(partition::iid_deal(total, &sizes, &mut rng))
    } else {
        None
    };

    let (pool_xs, pool_ys): (Vec<i32>, Vec<i32>) = {
        let mut pxs = Vec::new();
        let mut pys = Vec::new();
        for (xs, ys) in &roles {
            pxs.extend_from_slice(xs);
            pys.extend_from_slice(ys);
        }
        (pxs, pys)
    };

    let mut clients = Vec::with_capacity(cfg.num_clients);
    let mut test_xs = Vec::new();
    let mut test_ys = Vec::new();
    let mut offset = 0usize;
    for (role, &n) in sizes.iter().enumerate() {
        let idxs: Vec<usize> = match &assignment {
            Some(deal) => deal[role].clone(),
            None => (offset..offset + n).collect(),
        };
        offset += n;
        let n_test = ((idxs.len() as f64) * cfg.test_fraction).round() as usize;
        let (test_idx, train_idx) =
            idxs.split_at(n_test.min(idxs.len().saturating_sub(1)));
        let mut xs = Vec::with_capacity(train_idx.len() * seq);
        let mut ys = Vec::with_capacity(train_idx.len());
        for &i in train_idx {
            xs.extend_from_slice(&pool_xs[i * seq..(i + 1) * seq]);
            ys.push(pool_ys[i]);
        }
        for &i in test_idx {
            test_xs.extend_from_slice(&pool_xs[i * seq..(i + 1) * seq]);
            test_ys.push(pool_ys[i]);
        }
        clients.push(ClientDataset {
            xs: Samples::I32(xs),
            ys,
            per_sample: seq,
        });
    }

    FederatedDataset {
        clients,
        test: ClientDataset {
            xs: Samples::I32(test_xs),
            ys: test_ys,
            per_sample: seq,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::mlp_spec;

    fn lstm_like_spec(seq: usize) -> VariantSpec {
        let mut spec = mlp_spec("s", 0, 4, CHARSET_SIZE, 10, 2, 0.1);
        spec.dataset = "shakespeare".into();
        spec.input_shape = vec![seq];
        spec.classes = CHARSET_SIZE;
        spec.vocab = CHARSET_SIZE;
        spec
    }

    #[test]
    fn charset_mapping_is_total_and_consistent() {
        for k in 0..CHARSET_SIZE {
            assert_eq!(char_to_class(class_to_char(k)), k);
        }
        assert_eq!(char_to_class('!'), 52);
        assert_eq!(char_to_class('z'), 25);
        assert_eq!(char_to_class('A'), 26);
    }

    #[test]
    fn generates_windows_with_valid_ids() {
        let spec = lstm_like_spec(20);
        let cfg = DataConfig {
            num_clients: 5,
            samples_per_client: (30, 50),
            iid: false,
            test_fraction: 0.2,
            seed: 7,
        };
        let ds = generate(&spec, &cfg);
        assert_eq!(ds.num_clients(), 5);
        for c in &ds.clients {
            let xs = match &c.xs {
                Samples::I32(v) => v,
                _ => panic!("expected i32 tokens"),
            };
            assert!(xs.iter().all(|&t| (0..53).contains(&t)));
            assert!(c.ys.iter().all(|&y| (0..53).contains(&y)));
            assert_eq!(xs.len(), c.len() * 20);
        }
        assert!(!ds.test.is_empty());
    }

    #[test]
    fn next_char_depends_on_context() {
        // The generator must be better than uniform: frequent English
        // bigrams (like "th" → 'e'/space) should dominate their context.
        let markov = Markov::from_text(BASE_CORPUS);
        let mut rng = Pcg64::new(0);
        let uniform = vec![1.0f32; CHARSET_SIZE];
        let mut counts = vec![0usize; CHARSET_SIZE];
        for _ in 0..500 {
            let c = markov.next(char_to_class('t'), char_to_class('h'), &uniform, &mut rng);
            counts[c] += 1;
        }
        let e = counts[char_to_class('e')];
        assert!(e > 150, "'the' should dominate after 'th', got e={e}");
    }

    #[test]
    fn roles_differ_noniid() {
        let spec = lstm_like_spec(20);
        let cfg = DataConfig {
            num_clients: 3,
            samples_per_client: (200, 200),
            iid: false,
            test_fraction: 0.0,
            seed: 1,
        };
        let ds = generate(&spec, &cfg);
        // Character distributions across roles must differ measurably.
        let hist = |c: &ClientDataset| -> Vec<f64> {
            let xs = match &c.xs {
                Samples::I32(v) => v,
                _ => panic!(),
            };
            let mut h = vec![0.0f64; CHARSET_SIZE];
            for &t in xs {
                h[t as usize] += 1.0;
            }
            let s: f64 = h.iter().sum();
            h.into_iter().map(|v| v / s).collect()
        };
        let h0 = hist(&ds.clients[0]);
        let h1 = hist(&ds.clients[1]);
        let tv: f64 = h0
            .iter()
            .zip(&h1)
            .map(|(a, b)| (a - b).abs())
            .sum::<f64>()
            / 2.0;
        assert!(tv > 0.05, "roles should be heterogeneous, TV={tv}");
    }
}
