//! Partitioning helpers shared by the dataset generators.

use crate::util::rng::Pcg64;

/// Draw per-client sample counts uniformly from the configured range.
pub fn client_sizes(cfg: &super::DataConfig, rng: &mut Pcg64) -> Vec<usize> {
    let (lo, hi) = cfg.samples_per_client;
    assert!(lo >= 1 && hi >= lo, "bad samples_per_client range");
    (0..cfg.num_clients)
        .map(|_| lo + rng.below((hi - lo + 1) as u64) as usize)
        .collect()
}

/// Non-IID class skew: each client sees `classes_per_client` of the
/// label space (LEAF's writer/role/user effect). Returns per-client
/// class lists.
pub fn class_subsets(
    num_classes: usize,
    num_clients: usize,
    classes_per_client: usize,
    rng: &mut Pcg64,
) -> Vec<Vec<usize>> {
    let k = classes_per_client.clamp(1, num_classes);
    (0..num_clients)
        .map(|_| rng.sample_indices(num_classes, k))
        .collect()
}

/// IID re-deal: pool every sample index, shuffle, deal out contiguous
/// chunks sized like the original clients (the paper's "data is sampled
/// and randomly distributed over the clients").
pub fn iid_deal(total: usize, sizes: &[usize], rng: &mut Pcg64) -> Vec<Vec<usize>> {
    let want: usize = sizes.iter().sum();
    let mut pool: Vec<usize> = (0..total).collect();
    rng.shuffle(&mut pool);
    while pool.len() < want {
        // Sample with replacement if the pool is short (tiny configs).
        pool.push(rng.below(total as u64) as usize);
    }
    let mut out = Vec::with_capacity(sizes.len());
    let mut off = 0;
    for &s in sizes {
        out.push(pool[off..off + s].to_vec());
        off += s;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataConfig;

    #[test]
    fn sizes_in_range() {
        let cfg = DataConfig {
            num_clients: 50,
            samples_per_client: (10, 20),
            ..Default::default()
        };
        let mut rng = Pcg64::new(0);
        let sizes = client_sizes(&cfg, &mut rng);
        assert_eq!(sizes.len(), 50);
        assert!(sizes.iter().all(|&s| (10..=20).contains(&s)));
        assert!(sizes.iter().any(|&s| s != sizes[0]), "sizes should vary");
    }

    #[test]
    fn class_subsets_have_k_distinct() {
        let mut rng = Pcg64::new(1);
        let subs = class_subsets(10, 20, 4, &mut rng);
        for s in &subs {
            let mut t = s.clone();
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), 4);
        }
        // Clients must differ (heterogeneity).
        assert!(subs.iter().any(|s| s != &subs[0]));
    }

    #[test]
    fn iid_deal_covers_requested_sizes() {
        let mut rng = Pcg64::new(2);
        let sizes = vec![5, 7, 3];
        let deal = iid_deal(100, &sizes, &mut rng);
        assert_eq!(deal.iter().map(Vec::len).collect::<Vec<_>>(), sizes);
        assert!(deal.iter().flatten().all(|&i| i < 100));
    }

    #[test]
    fn iid_deal_oversubscribes_with_replacement() {
        let mut rng = Pcg64::new(3);
        let deal = iid_deal(4, &[10], &mut rng);
        assert_eq!(deal[0].len(), 10);
    }
}
