//! Coordinator checkpoint file format: atomic round-boundary
//! snapshots a restarted `afd serve` resumes from **bit-identically**.
//!
//! A checkpoint captures the complete coordinator-side state of a run
//! at a round boundary — the only quiescent point: no client work is
//! in flight, every borrowed buffer is back in its pool, and the
//! residual store has just enforced its byte budget.
//!
//! ```text
//! body  := magic "AFCK" ‖ version u32
//!        ‖ config_fingerprint u64      (FNV-1a of the compact config JSON)
//!        ‖ completed_round u64 ‖ cum_s f64 ‖ lr f64
//!        ‖ rng_state u128 ‖ rng_inc u128
//!        ‖ global  (u64 len ‖ f32 LE …)
//!        ‖ strategy blob (u64 len ‖ bytes)   — SubmodelStrategy::save_state
//!        ‖ engine   blob (u64 len ‖ bytes)   — Engine::save_state
//!        ‖ records (u64 count ‖ fixed-width fields per RoundRecord)
//!        ‖ fleet    blob (u64 len ‖ bytes)   — Population::save_state
//! file  := body ‖ crc32(body) LE
//! ```
//!
//! Everything is little-endian and fixed-width — byte-stable across
//! platforms, no external serialization dependency. Writes go to a
//! sibling temp file and land via `rename`, so a crash mid-write
//! leaves the previous checkpoint intact (readers either see the old
//! complete file or the new complete file, never a torn one). The
//! CRC32 trailer turns torn temp files and disk corruption into typed
//! errors instead of a divergent resume.

use std::path::Path;

use anyhow::{Context, Result};

use crate::config::ExperimentConfig;
use crate::metrics::RoundRecord;
use crate::transport::frame::crc32;

const MAGIC: &[u8; 4] = b"AFCK";
const VERSION: u32 = 1;

/// The deserialized state a checkpoint carries; the [`super::Experiment`]
/// methods own moving it in and out of live coordinator state.
pub struct CheckpointBody {
    pub config_fingerprint: u64,
    pub completed_round: u64,
    pub cum_s: f64,
    pub lr: f32,
    pub rng_state: u128,
    pub rng_inc: u128,
    pub global: Vec<f32>,
    pub strategy: Vec<u8>,
    pub engine: Vec<u8>,
    pub records: Vec<RoundRecord>,
    pub fleet: Vec<u8>,
}

/// FNV-1a over the config's compact JSON: a cheap, dependency-free
/// fingerprint that changes whenever any config knob does. Restoring
/// under a different config would diverge silently — the fingerprint
/// turns that into an immediate typed error.
pub fn config_fingerprint(cfg: &ExperimentConfig) -> u64 {
    let json = cfg.to_json().to_string_compact();
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in json.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_opt_f64(out: &mut Vec<u8>, v: Option<f64>) {
    out.push(v.is_some() as u8);
    push_f64(out, v.unwrap_or(0.0));
}

fn serialize(body: &CheckpointBody) -> Vec<u8> {
    let mut out = Vec::with_capacity(
        64 + body.global.len() * 4
            + body.strategy.len()
            + body.engine.len()
            + body.fleet.len()
            + body.records.len() * 128,
    );
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    push_u64(&mut out, body.config_fingerprint);
    push_u64(&mut out, body.completed_round);
    push_f64(&mut out, body.cum_s);
    push_f64(&mut out, body.lr as f64);
    out.extend_from_slice(&body.rng_state.to_le_bytes());
    out.extend_from_slice(&body.rng_inc.to_le_bytes());
    push_u64(&mut out, body.global.len() as u64);
    for &g in &body.global {
        out.extend_from_slice(&g.to_le_bytes());
    }
    push_u64(&mut out, body.strategy.len() as u64);
    out.extend_from_slice(&body.strategy);
    push_u64(&mut out, body.engine.len() as u64);
    out.extend_from_slice(&body.engine);
    push_u64(&mut out, body.records.len() as u64);
    for r in &body.records {
        push_u64(&mut out, r.round as u64);
        push_f64(&mut out, r.round_s);
        push_f64(&mut out, r.cum_s);
        push_f64(&mut out, r.train_loss);
        push_opt_f64(&mut out, r.eval_acc);
        push_opt_f64(&mut out, r.eval_loss);
        push_u64(&mut out, r.down_bytes);
        push_u64(&mut out, r.up_bytes);
        push_u64(&mut out, r.down_payload_bytes);
        push_u64(&mut out, r.up_payload_bytes);
        push_f64(&mut out, r.keep_fraction);
        push_u64(&mut out, r.arrived as u64);
        push_u64(&mut out, r.cut as u64);
        push_u64(&mut out, r.dropped as u64);
        push_u64(&mut out, r.lost as u64);
        push_u64(&mut out, r.quarantined as u64);
    }
    push_u64(&mut out, body.fleet.len() as u64);
    out.extend_from_slice(&body.fleet);
    out
}

/// Bounds-checked cursor over a checkpoint body; corruption that
/// slips past the CRC (or a logic error) diagnoses, never panics.
struct Rd<'a> {
    bytes: &'a [u8],
    off: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.bytes.len() - self.off {
            anyhow::bail!("checkpoint: truncated body");
        }
        let s = &self.bytes[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn u128(&mut self) -> Result<u128> {
        Ok(u128::from_le_bytes(self.take(16)?.try_into().unwrap()))
    }

    fn opt_f64(&mut self) -> Result<Option<f64>> {
        let some = self.take(1)?[0] != 0;
        let v = self.f64()?;
        Ok(some.then_some(v))
    }

    fn blob(&mut self) -> Result<Vec<u8>> {
        let len = self.u64()? as usize;
        Ok(self.take(len)?.to_vec())
    }
}

/// Atomically write `body` to `path` (sibling temp file + rename).
pub fn write(path: &Path, body: &CheckpointBody) -> Result<()> {
    let mut bytes = serialize(body);
    let crc = crc32(&bytes);
    bytes.extend_from_slice(&crc.to_le_bytes());
    let tmp = path.with_extension("ckpt.tmp");
    std::fs::write(&tmp, &bytes)
        .with_context(|| format!("writing checkpoint temp file {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("publishing checkpoint {}", path.display()))?;
    crate::obs::metrics::CHECKPOINTS_WRITTEN.incr();
    crate::obs::metrics::CHECKPOINT_BYTES.add(bytes.len() as u64);
    crate::obs::span::mark(
        crate::obs::Stage::CheckpointMark,
        body.completed_round,
        bytes.len() as u64,
    );
    Ok(())
}

/// Read and validate a checkpoint: CRC first (whole-file integrity),
/// then magic/version, then the structured body.
pub fn read(path: &Path) -> Result<CheckpointBody> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading checkpoint {}", path.display()))?;
    anyhow::ensure!(bytes.len() >= MAGIC.len() + 8 + 4, "checkpoint: file too short");
    let (payload, trailer) = bytes.split_at(bytes.len() - 4);
    let want = u32::from_le_bytes(trailer.try_into().unwrap());
    let got = crc32(payload);
    anyhow::ensure!(
        want == got,
        "checkpoint: crc mismatch (stored {want:#010x}, computed {got:#010x}) — \
         file is torn or corrupt"
    );
    let mut r = Rd {
        bytes: payload,
        off: 0,
    };
    let magic = r.take(4)?;
    anyhow::ensure!(magic == MAGIC, "checkpoint: bad magic (not a checkpoint file?)");
    let version = u32::from_le_bytes(r.take(4)?.try_into().unwrap());
    anyhow::ensure!(version == VERSION, "checkpoint: unsupported version {version}");
    let config_fingerprint = r.u64()?;
    let completed_round = r.u64()?;
    let cum_s = r.f64()?;
    let lr = r.f64()? as f32;
    let rng_state = r.u128()?;
    let rng_inc = r.u128()?;
    let n_global = r.u64()? as usize;
    anyhow::ensure!(
        n_global.checked_mul(4).is_some_and(|b| b <= payload.len()),
        "checkpoint: implausible global length {n_global}"
    );
    let mut global = Vec::with_capacity(n_global);
    for chunk in r.take(n_global * 4)?.chunks_exact(4) {
        global.push(f32::from_le_bytes(chunk.try_into().unwrap()));
    }
    let strategy = r.blob()?;
    let engine = r.blob()?;
    let n_records = r.u64()? as usize;
    anyhow::ensure!(
        n_records <= payload.len() / 8,
        "checkpoint: implausible record count {n_records}"
    );
    let mut records = Vec::with_capacity(n_records);
    for _ in 0..n_records {
        records.push(RoundRecord {
            round: r.u64()? as usize,
            round_s: r.f64()?,
            cum_s: r.f64()?,
            train_loss: r.f64()?,
            eval_acc: r.opt_f64()?,
            eval_loss: r.opt_f64()?,
            down_bytes: r.u64()?,
            up_bytes: r.u64()?,
            down_payload_bytes: r.u64()?,
            up_payload_bytes: r.u64()?,
            keep_fraction: r.f64()?,
            arrived: r.u64()? as usize,
            cut: r.u64()? as usize,
            dropped: r.u64()? as usize,
            lost: r.u64()? as usize,
            quarantined: r.u64()? as usize,
        });
    }
    let fleet = r.blob()?;
    anyhow::ensure!(r.off == payload.len(), "checkpoint: trailing bytes");
    Ok(CheckpointBody {
        config_fingerprint,
        completed_round,
        cum_s,
        lr,
        rng_state,
        rng_inc,
        global,
        strategy,
        engine,
        records,
        fleet,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_body() -> CheckpointBody {
        CheckpointBody {
            config_fingerprint: 0xfeed_beef,
            completed_round: 7,
            cum_s: 123.5,
            lr: 0.05,
            rng_state: 0x0123_4567_89ab_cdef_0011_2233_4455_6677,
            rng_inc: 0x8899_aabb_ccdd_eeff_1122_3344_5566_7789,
            global: vec![1.5, -2.25, 0.0, f32::MIN_POSITIVE],
            strategy: vec![1, 2, 3],
            engine: vec![],
            records: vec![RoundRecord {
                round: 7,
                round_s: 1.25,
                cum_s: 123.5,
                train_loss: 0.75,
                eval_acc: Some(0.9),
                eval_loss: None,
                down_bytes: 1000,
                up_bytes: 900,
                down_payload_bytes: 800,
                up_payload_bytes: 700,
                keep_fraction: 0.5,
                arrived: 10,
                cut: 1,
                dropped: 2,
                lost: 3,
                quarantined: 1,
            }],
            fleet: vec![9; 33],
        }
    }

    #[test]
    fn body_roundtrips_bitwise() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("afd_ckpt_rt_{}.ckpt", std::process::id()));
        let body = sample_body();
        write(&path, &body).unwrap();
        let back = read(&path).unwrap();
        assert_eq!(back.config_fingerprint, body.config_fingerprint);
        assert_eq!(back.completed_round, body.completed_round);
        assert_eq!(back.cum_s.to_bits(), body.cum_s.to_bits());
        assert_eq!(back.lr.to_bits(), body.lr.to_bits());
        assert_eq!(back.rng_state, body.rng_state);
        assert_eq!(back.rng_inc, body.rng_inc);
        let a: Vec<u32> = back.global.iter().map(|g| g.to_bits()).collect();
        let b: Vec<u32> = body.global.iter().map(|g| g.to_bits()).collect();
        assert_eq!(a, b);
        assert_eq!(back.strategy, body.strategy);
        assert_eq!(back.engine, body.engine);
        assert_eq!(back.fleet, body.fleet);
        assert_eq!(back.records.len(), 1);
        let (x, y) = (&back.records[0], &body.records[0]);
        assert_eq!(x.round, y.round);
        assert_eq!(x.eval_acc, y.eval_acc);
        assert_eq!(x.eval_loss, y.eval_loss);
        assert_eq!(x.quarantined, y.quarantined);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corruption_is_a_typed_error() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("afd_ckpt_bad_{}.ckpt", std::process::id()));
        write(&path, &sample_body()).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[20] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let err = read(&path).unwrap_err().to_string();
        assert!(err.contains("crc mismatch"), "{err}");
        // Truncation (a torn write that somehow bypassed the rename)
        // also diagnoses.
        std::fs::write(&path, &bytes[..10]).unwrap();
        assert!(read(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fingerprint_tracks_config_changes() {
        let a = ExperimentConfig::default();
        let mut b = ExperimentConfig::default();
        assert_eq!(config_fingerprint(&a), config_fingerprint(&b));
        b.seed += 1;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&b));
    }
}
