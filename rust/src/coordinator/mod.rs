//! The federated-learning coordinator: the server of Fig. 1.
//!
//! Per round t (synchronous FedAvg, paper Problem Statement §):
//!
//! 1. sample the cohort S_t (m = ⌈fraction·n⌉ clients);
//! 2. per client: strategy selects a sub-model (score-map logic for
//!    AFD), the packed sub-model is **encoded with the downlink codec**
//!    (8-bit Hadamard quantization) and *framed* — the `RoundOffer` and
//!    `ModelDown` frames travel through the experiment's
//!    [`Transport`] (in-process loopback or real TCP; the client
//!    starts from exactly what the wire delivered);
//! 3. the client runs one local epoch through the [`ModelRuntime`]
//!    (PJRT artifact or native MLP) under the sub-model's masks;
//! 4. the uplink ships the `UpdateUp` frame (DGC-compressed delta or
//!    the raw packed sub-model); the server reconstructs each client's
//!    model from the frame;
//! 5. FedAvg aggregates per coordinate (sample-count weighted),
//!    coordinates nobody held keep their old value — on the engine
//!    path this runs sharded across the worker pool
//!    ([`crate::aggregation::ShardedFedAvg`], bit-identical to the
//!    retained [`FedAvg`] reference);
//! 6. the network simulator charges the round's wall-clock time
//!    (max over the cohort of down + compute + up) on **measured wire
//!    bytes** — framed lengths, control frames included;
//! 7. losses are reported back to the strategy (score-map updates).
//!
//! Steps 1 and 6 are owned by the event-driven scheduler
//! ([`crate::sched`]): the `sync` policy reproduces the synchronous
//! behaviour above bit-for-bit, while `overselect` and
//! `async_buffered` relax it for straggler tolerance. The helpers in
//! this module ([`run_client_round`], [`aggregate_round`],
//! [`feed_strategy`]) stay policy-agnostic.
//!
//! [`Transport`]: crate::transport::Transport

pub mod checkpoint;
pub mod experiment;

pub use experiment::{run_experiment, Experiment};

use std::sync::Arc;

use crate::aggregation::FedAvg;
use crate::compression::{dgc, sparse, DenseCodec, Encoded};
use crate::dropout::SubmodelStrategy;
use crate::model::manifest::VariantSpec;
use crate::model::packing::PackPlan;
use crate::model::submodel::SubModel;
use crate::network::{NetworkSim, RoundTiming};
use crate::runtime::{EpochData, ModelRuntime};
use crate::tensor::kernels::Workspace;
use crate::transport::{
    client_round::ClientEnv, codec_id, frame, LossReason, RoundTripStatus, StateSyncSnapshot,
    Transport,
};

/// Everything exchanged for one client in one round (the framed wire +
/// the server-side bookkeeping needed to reconstruct it).
pub struct ClientRoundOutcome {
    pub client: usize,
    pub submodel: SubModel,
    pub train_loss: f32,
    /// Measured downlink wire bytes: `RoundOffer` + `ModelDown` +
    /// round-close (`Ack`/`Cut`) frame lengths.
    pub down_bytes: u64,
    /// Measured uplink wire bytes: the `UpdateUp` frame length.
    pub up_bytes: u64,
    /// Codec payload alone on the downlink (the encoded sub-model
    /// stream) — `down_bytes - down_payload_bytes` is protocol
    /// overhead (framing, bitmaps, control).
    pub down_payload_bytes: u64,
    /// Update body alone on the uplink (DGC message or raw packed
    /// values).
    pub up_payload_bytes: u64,
    pub epoch_flops: f64,
    /// Server-side reconstruction of the client's post-training model
    /// (full coordinate space) + which coordinates it speaks for.
    /// Both buffers are drawn from the job's [`Workspace`] and escape
    /// with the outcome; the engine hands them back to the workspace
    /// pool once the round's aggregation has consumed them, closing
    /// the allocation-free loop.
    pub reconstructed: Vec<f32>,
    pub coord_mask: Vec<bool>,
    /// The pack plan whose runs are exactly `coord_mask`'s true
    /// coordinates (raw uplink only — `None` when DGC may have shipped
    /// residual coordinates beyond the plan). Lets the sharded
    /// aggregator memcpy-scan contiguous kept runs instead of testing
    /// the mask per coordinate.
    pub agg_plan: Option<Arc<PackPlan>>,
    /// `Some(reason)` when the transport lost this client mid-exchange
    /// (connection death or timeout). A lost outcome carries no bytes,
    /// no loss and no reconstruction — the scheduler excludes it from
    /// aggregation and reports it in `RoundRecord::lost`.
    pub lost: Option<LossReason>,
}

/// Run one client's round through the transport:
/// frame (offer + model) → round-trip → decode the update frame →
/// reconstruct server-side.
///
/// This is the hot path of the whole system: packing runs through the
/// precomputed `plan` (resolved from the coordinator's [`PlanCache`]
/// at dispatch), frames and big temporaries come from the job's
/// [`Workspace`], and — on the loopback transport — the client half
/// executes on this thread via the same
/// [`crate::transport::client_execute`] a remote process runs, so
/// where the client lives never changes the bytes.
///
/// [`PlanCache`]: crate::model::packing::PlanCache
#[allow(clippy::too_many_arguments)]
pub fn run_client_round(
    spec: &VariantSpec,
    runtime: &dyn ModelRuntime,
    global: &[f32],
    submodel: &SubModel,
    plan: &Arc<PackPlan>,
    data: &EpochData,
    lr: f32,
    downlink: &dyn DenseCodec,
    dgc_state: Option<&mut dgc::DgcState>,
    round: usize,
    round_seed: u64,
    client: usize,
    num_samples: usize,
    deadline_s: Option<f64>,
    sync: Option<&StateSyncSnapshot>,
    transport: &dyn Transport,
    ws: &mut Workspace,
) -> anyhow::Result<ClientRoundOutcome> {
    let n = spec.num_params;
    let seed = round_seed ^ (client as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    let round_u = round as u32;
    let client_u = client as u32;
    let expect_dgc = dgc_state.is_some();

    // ---- Frame the downlink -----------------------------------------
    // Buffers come from the arena's byte/f32 sinks; the whole framed
    // exchange allocates nothing once `ws` is warm
    // (`rust/tests/zero_alloc.rs`).
    let mut offer = ws.take_bytes();
    {
        let _sp = crate::obs::span_ab(crate::obs::Stage::FrameEncode, round as u64, client as u64);
        frame::encode_round_offer(
            &mut offer,
            round_u,
            client_u,
            seed,
            lr,
            deadline_s.unwrap_or(f64::NAN),
            submodel,
        );
    }
    let mut packed = ws.take_uncleared(plan.packed_len());
    {
        let _sp = crate::obs::span_ab(crate::obs::Stage::Pack, round as u64, client as u64);
        plan.pack_into(global, &mut packed);
    }
    let mut enc = Encoded {
        bytes: ws.take_bytes(),
    };
    downlink.encode_into(&packed, seed, ws, &mut enc);
    ws.give(packed);
    let down_payload_bytes = enc.wire_bytes();
    let mut model_frame = ws.take_bytes();
    {
        let _sp = crate::obs::span_ab(crate::obs::Stage::FrameEncode, round as u64, client as u64);
        frame::encode_model_down(
            &mut model_frame,
            round_u,
            client_u,
            codec_id(downlink.name()),
            &enc.bytes,
        );
    }
    // Wire accounting: both downlink frames plus the round-closing
    // Ack/Cut control frame (same fixed size either way, so it can be
    // charged at dispatch).
    let down_bytes = offer.len() as u64 + model_frame.len() as u64 + frame::ROUND_CLOSE_WIRE;

    // ---- Exchange ----------------------------------------------------
    let mut reply = ws.take_bytes();
    let status = {
        let mut env = ClientEnv {
            spec,
            runtime,
            codec: downlink,
            base_params: global,
            data,
            dgc: dgc_state,
            submodel,
            plan,
            num_samples: num_samples as u32,
            ws: &mut *ws,
        };
        let _sp = crate::obs::span_ab(crate::obs::Stage::RoundTrip, round as u64, client as u64);
        transport.round_trip(client, &offer, &model_frame, sync, &mut env, &mut reply)?
    };
    ws.give_bytes(offer);
    ws.give_bytes(model_frame);

    if let RoundTripStatus::Lost(reason) = status {
        // The exchange died with its connection. Give every buffer
        // back and return a loss marker: no bytes are charged (the
        // update never contributed), no reconstruction exists, and the
        // scheduler rolls the host-side DGC snapshot back exactly as
        // it does for a straggler cut.
        ws.give_bytes(enc.bytes);
        ws.give_bytes(reply);
        return Ok(ClientRoundOutcome {
            client,
            submodel: submodel.clone(),
            train_loss: 0.0,
            down_bytes: 0,
            up_bytes: 0,
            down_payload_bytes: 0,
            up_payload_bytes: 0,
            epoch_flops: 0.0,
            reconstructed: Vec::new(),
            coord_mask: Vec::new(),
            agg_plan: None,
            lost: Some(reason),
        });
    }

    // ---- Decode the update frame ------------------------------------
    let parse_sp = crate::obs::span_ab(crate::obs::Stage::FrameParse, round as u64, client as u64);
    let (view, used) = frame::parse_frame(&reply)
        .map_err(|e| anyhow::anyhow!("client {client} round {round}: {e}"))?;
    anyhow::ensure!(
        used == reply.len(),
        "client {client} round {round}: trailing bytes after update frame"
    );
    let upd = frame::parse_update_up(&view)
        .map_err(|e| anyhow::anyhow!("client {client} round {round}: {e}"))?;
    drop(parse_sp);
    anyhow::ensure!(
        upd.client == client_u && upd.round == round_u,
        "update frame addresses client {} round {}, expected client {client} \
         round {round}",
        upd.client,
        upd.round
    );
    // The uplink encoding must match what this round dispatched with —
    // a config-diverged remote must fail loudly, not silently change
    // results (the fingerprint handshake only covers model geometry).
    let want_kind = if expect_dgc {
        frame::UPDATE_DGC
    } else {
        frame::UPDATE_RAW
    };
    anyhow::ensure!(
        upd.update_kind == want_kind,
        "client {client} round {round}: update kind {} but the round was \
         dispatched expecting {} — uplink codec config mismatch",
        upd.update_kind,
        want_kind
    );
    let up_bytes = reply.len() as u64;
    let up_payload_bytes = upd.payload.len() as u64;
    let train_loss = upd.loss;

    // ---- Server-side reconstruction ---------------------------------
    // `coord_mask` and `reconstructed` escape with the outcome (the
    // engine returns them to the workspace pool after aggregation).
    let mut coord_mask = ws.take_bool(n);
    plan.mark_coord_mask(&mut coord_mask);
    let (reconstructed, coord_mask, agg_plan) = match upd.update_kind {
        frame::UPDATE_DGC => {
            // The client's starting point: the global model with the
            // sub-model coordinates replaced by what the wire
            // delivered. The server decodes its own downlink stream —
            // deterministic, same seed. (On loopback this is a second
            // decode of bytes the in-process client also decoded; the
            // price of the client half behaving exactly like a remote
            // receiver. The raw branch needs no server-side decode.)
            let mut decoded = ws.take_uncleared(plan.packed_len());
            downlink.decode_slice_into(&enc.bytes, seed, ws, &mut decoded);
            let mut recon = ws.take_uncleared(n);
            recon.copy_from_slice(global);
            {
                let _sp =
                    crate::obs::span_ab(crate::obs::Stage::Unpack, round as u64, client as u64);
                plan.unpack_from(&decoded, &mut recon);
            }
            ws.give(decoded);
            // Scatter the sparse delta straight onto it; the client
            // speaks for its sub-model coords plus any residual coords
            // DGC shipped. Checked decode: a malformed remote body is a
            // diagnosable error, never a panic or a hostile-sized
            // allocation.
            let mut idx = ws.take_u32();
            let mut vals = ws.take_uncleared(0);
            let dn = sparse::try_decode_sparse_into(upd.payload, &mut idx, &mut vals)
                .map_err(|e| {
                    anyhow::anyhow!("client {client} round {round}: DGC update body: {e}")
                })?;
            anyhow::ensure!(
                dn == n,
                "client {client} round {round}: DGC update covers {dn} coordinates, \
                 model has {n}"
            );
            let mut cm = coord_mask;
            for (&i, &v) in idx.iter().zip(vals.iter()) {
                anyhow::ensure!(
                    (i as usize) < n,
                    "client {client} round {round}: DGC index {i} out of range \
                     ({n} params)"
                );
                if v != 0.0 {
                    recon[i as usize] += v;
                    cm[i as usize] = true;
                }
            }
            ws.give_u32(idx);
            ws.give(vals);
            (recon, cm, None)
        }
        _ => {
            // Raw packed sub-model values: `u32 count ‖ count × f32`.
            anyhow::ensure!(
                upd.payload.len() == 4 + 4 * plan.packed_len()
                    && u32::from_le_bytes(upd.payload[0..4].try_into().unwrap()) as usize
                        == plan.packed_len(),
                "client {client} round {round}: raw update body is {} bytes, \
                 plan packs {} values",
                upd.payload.len(),
                plan.packed_len()
            );
            let mut up_vals = ws.take_uncleared(plan.packed_len());
            for (o, c) in up_vals.iter_mut().zip(upd.payload[4..].chunks_exact(4)) {
                *o = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
            let mut recon = ws.take_uncleared(n);
            recon.copy_from_slice(global);
            {
                let _sp =
                    crate::obs::span_ab(crate::obs::Stage::Unpack, round as u64, client as u64);
                plan.unpack_from(&up_vals, &mut recon);
            }
            ws.give(up_vals);
            (recon, coord_mask, Some(Arc::clone(plan)))
        }
    };
    ws.give_bytes(enc.bytes);
    ws.give_bytes(reply);

    // Compute cost of the sub-model epoch: fwd + bwd ≈ 3× fwd FLOPs.
    let epoch_flops = 3.0 * plan.flops_per_sample() * spec.samples_per_round() as f64;

    if crate::obs::enabled() {
        use crate::obs::metrics as om;
        om::BYTES_DOWN_WIRE.add(down_bytes);
        om::BYTES_UP_WIRE.add(up_bytes);
        om::BYTES_DOWN_PAYLOAD.add(down_payload_bytes);
        om::BYTES_UP_PAYLOAD.add(up_payload_bytes);
    }

    Ok(ClientRoundOutcome {
        client,
        submodel: submodel.clone(),
        train_loss,
        down_bytes,
        up_bytes,
        down_payload_bytes,
        up_payload_bytes,
        epoch_flops,
        reconstructed,
        coord_mask,
        agg_plan,
        lost: None,
    })
}

/// Aggregate a round's outcomes into W_{t+1} + charge network time.
///
/// Serial-reference path only: drives the retained single-threaded
/// [`FedAvg`] (always mask-based, never plan-based) so
/// `Experiment::step_serial_reference` stays the independent
/// bit-exactness oracle for the sharded engine path.
pub fn aggregate_round(
    global: &[f32],
    outcomes: &[ClientRoundOutcome],
    sample_counts: &[usize],
    agg: &mut FedAvg,
    net: &NetworkSim,
) -> (Vec<f32>, RoundTiming) {
    agg.reset();
    let mut jobs = Vec::with_capacity(outcomes.len());
    for o in outcomes {
        agg.add_masked(
            &o.reconstructed,
            &o.coord_mask,
            sample_counts[o.client] as f64,
        );
        jobs.push((o.client, o.down_bytes, o.epoch_flops, o.up_bytes));
    }
    let timing = net.round(&jobs);
    (agg.finalize(global), timing)
}

/// Report losses back to the strategy in cohort order, then close the
/// round (Alg. 1 lines 15-23 / Alg. 2 lines 17-25).
pub fn feed_strategy(
    strategy: &mut dyn SubmodelStrategy,
    round: usize,
    outcomes: &[ClientRoundOutcome],
) {
    for o in outcomes {
        strategy.report_loss(round, o.client, o.train_loss as f64);
    }
    strategy.end_round(round);
}
