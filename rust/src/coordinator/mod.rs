//! The federated-learning coordinator: the server of Fig. 1.
//!
//! Per round t (synchronous FedAvg, paper Problem Statement §):
//!
//! 1. sample the cohort S_t (m = ⌈fraction·n⌉ clients);
//! 2. per client: strategy selects a sub-model (score-map logic for
//!    AFD), the packed sub-model is **encoded with the downlink codec**
//!    (8-bit Hadamard quantization) — the client starts from exactly
//!    what the wire delivered;
//! 3. the client runs one local epoch through the [`ModelRuntime`]
//!    (PJRT artifact or native MLP) under the sub-model's masks;
//! 4. the uplink ships either DGC-compressed deltas or the raw packed
//!    sub-model; the server reconstructs each client's model;
//! 5. FedAvg aggregates per coordinate (sample-count weighted),
//!    coordinates nobody held keep their old value — on the engine
//!    path this runs sharded across the worker pool
//!    ([`crate::aggregation::ShardedFedAvg`], bit-identical to the
//!    retained [`FedAvg`] reference);
//! 6. the network simulator charges the round's wall-clock time
//!    (max over the cohort of down + compute + up);
//! 7. losses are reported back to the strategy (score-map updates).
//!
//! Steps 1 and 6 are owned by the event-driven scheduler
//! ([`crate::sched`]): the `sync` policy reproduces the synchronous
//! behaviour above bit-for-bit, while `overselect` and
//! `async_buffered` relax it for straggler tolerance. The helpers in
//! this module ([`run_client_round`], [`aggregate_round`],
//! [`feed_strategy`]) stay policy-agnostic.

pub mod experiment;

pub use experiment::{run_experiment, Experiment};

use std::sync::Arc;

use crate::aggregation::FedAvg;
use crate::compression::{dgc, sparse, DenseCodec, Encoded};
use crate::dropout::SubmodelStrategy;
use crate::model::manifest::VariantSpec;
use crate::model::packing::PackPlan;
use crate::model::submodel::SubModel;
use crate::network::{NetworkSim, RoundTiming};
use crate::runtime::{EpochData, ModelRuntime};
use crate::tensor::kernels::Workspace;

/// Everything exchanged for one client in one round (the simulated
/// wire + the server-side bookkeeping needed to reconstruct it).
pub struct ClientRoundOutcome {
    pub client: usize,
    pub submodel: SubModel,
    pub train_loss: f32,
    pub down_bytes: u64,
    pub up_bytes: u64,
    pub epoch_flops: f64,
    /// Server-side reconstruction of the client's post-training model
    /// (full coordinate space) + which coordinates it speaks for.
    /// Both buffers are drawn from the job's [`Workspace`] and escape
    /// with the outcome; the engine hands them back to the workspace
    /// pool once the round's aggregation has consumed them, closing
    /// the allocation-free loop.
    pub reconstructed: Vec<f32>,
    pub coord_mask: Vec<bool>,
    /// The pack plan whose runs are exactly `coord_mask`'s true
    /// coordinates (raw uplink only — `None` when DGC may have shipped
    /// residual coordinates beyond the plan). Lets the sharded
    /// aggregator memcpy-scan contiguous kept runs instead of testing
    /// the mask per coordinate.
    pub agg_plan: Option<Arc<PackPlan>>,
}

/// Run one client's round: downlink → local train → uplink.
///
/// `global` is W_t; returns the outcome to aggregate. This is the hot
/// path of the whole system: packing runs through the precomputed
/// `plan` (resolved from the coordinator's [`PlanCache`] at dispatch),
/// big temporaries come from the job's [`Workspace`], and training
/// runs in place via [`ModelRuntime::train_epoch_in`].
///
/// [`PlanCache`]: crate::model::packing::PlanCache
#[allow(clippy::too_many_arguments)]
pub fn run_client_round(
    spec: &VariantSpec,
    runtime: &dyn ModelRuntime,
    global: &[f32],
    submodel: &SubModel,
    plan: &Arc<PackPlan>,
    data: &EpochData,
    lr: f32,
    downlink: &dyn DenseCodec,
    dgc_state: Option<&mut dgc::DgcState>,
    round_seed: u64,
    client: usize,
    ws: &mut Workspace,
) -> anyhow::Result<ClientRoundOutcome> {
    let n = spec.num_params;
    // ---- Downlink: pack → encode → (wire) → decode → unpack ---------
    // `take_uncleared` everywhere below: each buffer is fully
    // overwritten before its first read (pack_into clears, the model
    // buffers are copy_from_slice'd, the delta is written by `sub`).
    // Codec wire/scratch buffers come from the arena's byte/u32 sinks,
    // so the whole pipeline allocates nothing once `ws` is warm
    // (`rust/tests/zero_alloc.rs`).
    let mut packed = ws.take_uncleared(plan.packed_len());
    plan.pack_into(global, &mut packed);
    let seed = round_seed ^ (client as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    let mut enc = Encoded {
        bytes: ws.take_bytes(),
    };
    downlink.encode_into(&packed, seed, ws, &mut enc);
    // Kept-unit bitmaps ride along uncompressed (the client must know
    // which units it received).
    let bitmap_bytes = plan.bitmap_bytes();
    let down_bytes = enc.wire_bytes() + bitmap_bytes;
    let mut decoded = ws.take_uncleared(plan.packed_len());
    downlink.decode_into(&enc, seed, ws, &mut decoded);
    ws.give_bytes(enc.bytes);

    // The client's starting point: the global model with the sub-model
    // coordinates replaced by what the wire delivered. Coordinates
    // outside the sub-model exist only server-side; masked training
    // never touches them.
    let mut client_start = ws.take_uncleared(n);
    client_start.copy_from_slice(global);
    plan.unpack_from(&decoded, &mut client_start);
    ws.give(decoded);

    // ---- Local training (one epoch, in place on the model buffer) ---
    let mut model = ws.take_uncleared(n);
    model.copy_from_slice(&client_start);
    let mean_loss = runtime.train_epoch_in(ws, &mut model, submodel.masks_f32(), data, lr)?;

    // ---- Uplink ------------------------------------------------------
    // `coord_mask` and `reconstructed` escape with the outcome (the
    // engine returns them to the workspace pool after aggregation).
    let mut coord_mask = ws.take_bool(n);
    plan.mark_coord_mask(&mut coord_mask);
    let (up_bytes, reconstructed, coord_mask, agg_plan) = match dgc_state {
        Some(st) => {
            // Delta in full coordinate space (zero off-sub-model, so
            // top-k naturally selects sub-model coordinates; residuals
            // from earlier rounds may surface too — genuine DGC
            // accumulation behaviour).
            let mut delta = ws.take_uncleared(n);
            crate::tensor::sub(&model, &client_start, &mut delta);
            let mut varint_scratch = ws.take_bytes();
            let mut msg = ws.take_bytes();
            st.compress_into(&delta, &mut varint_scratch, &mut msg);
            ws.give(delta);
            ws.give_bytes(varint_scratch);
            let up_bytes = msg.len() as u64;
            // Server side: scatter the sparse delta straight onto the
            // client's starting point (no dense intermediate).
            let mut idx = ws.take_u32();
            let mut vals = ws.take_uncleared(0);
            sparse::decode_sparse_into(&msg, &mut idx, &mut vals);
            ws.give_bytes(msg);
            let mut recon = ws.take_uncleared(n);
            recon.copy_from_slice(&client_start);
            // The client speaks for its sub-model coords plus any
            // residual coords DGC shipped.
            let mut cm = coord_mask;
            for (&i, &v) in idx.iter().zip(vals.iter()) {
                if v != 0.0 {
                    recon[i as usize] += v;
                    cm[i as usize] = true;
                }
            }
            ws.give_u32(idx);
            ws.give(vals);
            (up_bytes, recon, cm, None)
        }
        None => {
            // Raw packed sub-model values (reusing the downlink's pack
            // buffer).
            plan.pack_into(&model, &mut packed);
            let up_bytes = 4 * packed.len() as u64 + bitmap_bytes;
            let mut recon = ws.take_uncleared(n);
            recon.copy_from_slice(&client_start);
            plan.unpack_from(&packed, &mut recon);
            (up_bytes, recon, coord_mask, Some(Arc::clone(plan)))
        }
    };

    // Compute cost of the sub-model epoch: fwd + bwd ≈ 3× fwd FLOPs.
    let epoch_flops = 3.0 * plan.flops_per_sample() * spec.samples_per_round() as f64;

    let train_loss = mean_loss;
    ws.give(packed);
    ws.give(client_start);
    ws.give(model);

    Ok(ClientRoundOutcome {
        client,
        submodel: submodel.clone(),
        train_loss,
        down_bytes,
        up_bytes,
        epoch_flops,
        reconstructed,
        coord_mask,
        agg_plan,
    })
}

/// Aggregate a round's outcomes into W_{t+1} + charge network time.
///
/// Serial-reference path only: drives the retained single-threaded
/// [`FedAvg`] (always mask-based, never plan-based) so
/// `Experiment::step_serial_reference` stays the independent
/// bit-exactness oracle for the sharded engine path.
pub fn aggregate_round(
    global: &[f32],
    outcomes: &[ClientRoundOutcome],
    sample_counts: &[usize],
    agg: &mut FedAvg,
    net: &NetworkSim,
) -> (Vec<f32>, RoundTiming) {
    agg.reset();
    let mut jobs = Vec::with_capacity(outcomes.len());
    for o in outcomes {
        agg.add_masked(
            &o.reconstructed,
            &o.coord_mask,
            sample_counts[o.client] as f64,
        );
        jobs.push((o.client, o.down_bytes, o.epoch_flops, o.up_bytes));
    }
    let timing = net.round(&jobs);
    (agg.finalize(global), timing)
}

/// Report losses back to the strategy in cohort order, then close the
/// round (Alg. 1 lines 15-23 / Alg. 2 lines 17-25).
pub fn feed_strategy(
    strategy: &mut dyn SubmodelStrategy,
    round: usize,
    outcomes: &[ClientRoundOutcome],
) {
    for o in outcomes {
        strategy.report_loss(round, o.client, o.train_loss as f64);
    }
    strategy.end_round(round);
}
