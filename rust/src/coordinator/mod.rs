//! The federated-learning coordinator: the server of Fig. 1.
//!
//! Per round t (synchronous FedAvg, paper Problem Statement §):
//!
//! 1. sample the cohort S_t (m = ⌈fraction·n⌉ clients);
//! 2. per client: strategy selects a sub-model (score-map logic for
//!    AFD), the packed sub-model is **encoded with the downlink codec**
//!    (8-bit Hadamard quantization) — the client starts from exactly
//!    what the wire delivered;
//! 3. the client runs one local epoch through the [`ModelRuntime`]
//!    (PJRT artifact or native MLP) under the sub-model's masks;
//! 4. the uplink ships either DGC-compressed deltas or the raw packed
//!    sub-model; the server reconstructs each client's model;
//! 5. FedAvg aggregates per coordinate (sample-count weighted),
//!    coordinates nobody held keep their old value;
//! 6. the network simulator charges the round's wall-clock time
//!    (max over the cohort of down + compute + up);
//! 7. losses are reported back to the strategy (score-map updates).
//!
//! Steps 1 and 6 are owned by the event-driven scheduler
//! ([`crate::sched`]): the `sync` policy reproduces the synchronous
//! behaviour above bit-for-bit, while `overselect` and
//! `async_buffered` relax it for straggler tolerance. The helpers in
//! this module ([`run_client_round`], [`aggregate_round`],
//! [`feed_strategy`]) stay policy-agnostic.

pub mod experiment;

pub use experiment::{run_experiment, Experiment};

use crate::aggregation::FedAvg;
use crate::compression::dgc;
use crate::compression::DenseCodec;
use crate::dropout::SubmodelStrategy;
use crate::model::manifest::VariantSpec;
use crate::model::packing;
use crate::model::submodel::SubModel;
use crate::network::{NetworkSim, RoundTiming};
use crate::runtime::{EpochData, ModelRuntime};

/// Everything exchanged for one client in one round (the simulated
/// wire + the server-side bookkeeping needed to reconstruct it).
pub struct ClientRoundOutcome {
    pub client: usize,
    pub submodel: SubModel,
    pub train_loss: f32,
    pub down_bytes: u64,
    pub up_bytes: u64,
    pub epoch_flops: f64,
    /// Server-side reconstruction of the client's post-training model
    /// (full coordinate space) + which coordinates it speaks for.
    pub reconstructed: Vec<f32>,
    pub coord_mask: Vec<bool>,
}

/// Run one client's round: downlink → local train → uplink.
///
/// `global` is W_t; returns the outcome to aggregate. This is the hot
/// path of the whole system.
#[allow(clippy::too_many_arguments)]
pub fn run_client_round(
    spec: &VariantSpec,
    runtime: &dyn ModelRuntime,
    global: &[f32],
    submodel: &SubModel,
    data: &EpochData,
    lr: f32,
    downlink: &dyn DenseCodec,
    dgc_state: Option<&mut dgc::DgcState>,
    round_seed: u64,
    client: usize,
) -> anyhow::Result<ClientRoundOutcome> {
    // ---- Downlink: pack → encode → (wire) → decode → unpack ---------
    let packed = packing::pack_values(spec, global, submodel);
    let seed = round_seed ^ (client as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    let enc = downlink.encode(&packed, seed);
    // Kept-unit bitmaps ride along uncompressed (the client must know
    // which units it received).
    let bitmap_bytes: u64 = spec
        .mask_groups
        .iter()
        .map(|g| g.size.div_ceil(8) as u64)
        .sum();
    let down_bytes = enc.wire_bytes() + bitmap_bytes;
    let decoded = downlink.decode(&enc, seed);

    // The client's starting point: the global model with the sub-model
    // coordinates replaced by what the wire delivered. Coordinates
    // outside the sub-model exist only server-side; masked training
    // never touches them.
    let mut client_start = global.to_vec();
    packing::unpack_values(spec, &decoded, submodel, &mut client_start);

    // ---- Local training (one epoch; scan over batches inside XLA) ---
    let out = runtime.train_epoch(&client_start, &submodel.masks_f32(), data, lr)?;

    // ---- Uplink ------------------------------------------------------
    let coord_mask = packing::coordinate_mask(spec, submodel);
    let (up_bytes, reconstructed, coord_mask) = match dgc_state {
        Some(st) => {
            // Delta in full coordinate space (zero off-sub-model, so
            // top-k naturally selects sub-model coordinates; residuals
            // from earlier rounds may surface too — genuine DGC
            // accumulation behaviour).
            let mut delta = vec![0.0f32; spec.num_params];
            crate::tensor::sub(&out.params, &client_start, &mut delta);
            let msg = st.compress(&delta);
            let up_bytes = msg.len() as u64;
            let sparse_delta = dgc::decode(&msg);
            let mut recon = client_start.clone();
            crate::tensor::add_assign(&mut recon, &sparse_delta);
            // The client speaks for its sub-model coords plus any
            // residual coords DGC shipped.
            let mut cm = coord_mask;
            for (i, &v) in sparse_delta.iter().enumerate() {
                if v != 0.0 {
                    cm[i] = true;
                }
            }
            (up_bytes, recon, cm)
        }
        None => {
            // Raw packed sub-model values.
            let packed_up = packing::pack_values(spec, &out.params, submodel);
            let up_bytes = 4 * packed_up.len() as u64 + bitmap_bytes;
            let mut recon = client_start.clone();
            packing::unpack_values(spec, &packed_up, submodel, &mut recon);
            (up_bytes, recon, coord_mask)
        }
    };

    // Compute cost of the sub-model epoch: fwd + bwd ≈ 3× fwd FLOPs.
    let epoch_flops = 3.0
        * packing::effective_flops_per_sample(spec, submodel)
        * spec.samples_per_round() as f64;

    Ok(ClientRoundOutcome {
        client,
        submodel: submodel.clone(),
        train_loss: out.mean_loss,
        down_bytes,
        up_bytes,
        epoch_flops,
        reconstructed,
        coord_mask,
    })
}

/// Aggregate a round's outcomes into W_{t+1} + charge network time.
pub fn aggregate_round(
    global: &[f32],
    outcomes: &[ClientRoundOutcome],
    sample_counts: &[usize],
    agg: &mut FedAvg,
    net: &NetworkSim,
) -> (Vec<f32>, RoundTiming) {
    agg.reset();
    let mut jobs = Vec::with_capacity(outcomes.len());
    for o in outcomes {
        agg.add_masked(
            &o.reconstructed,
            &o.coord_mask,
            sample_counts[o.client] as f64,
        );
        jobs.push((o.client, o.down_bytes, o.epoch_flops, o.up_bytes));
    }
    let timing = net.round(&jobs);
    (agg.finalize(global), timing)
}

/// Report losses back to the strategy in cohort order, then close the
/// round (Alg. 1 lines 15-23 / Alg. 2 lines 17-25).
pub fn feed_strategy(
    strategy: &mut dyn SubmodelStrategy,
    round: usize,
    outcomes: &[ClientRoundOutcome],
) {
    for o in outcomes {
        strategy.report_loss(round, o.client, o.train_loss as f64);
    }
    strategy.end_round(round);
}
