//! End-to-end experiment driver: config → full federated run → report.
//!
//! Rounds are driven through the event-driven scheduler
//! ([`crate::sched::Engine`]): the policy decides dispatch width and
//! round closing, in-flight clients train in parallel on the worker
//! pool when the runtime is thread-safe, and the engine charges
//! simulated time from the sampled links. The pre-scheduler serial
//! loop is retained as [`Experiment::step_serial_reference`] — the
//! `sync` policy must reproduce it bit-for-bit (enforced in
//! `rust/tests/sched_policies.rs`).

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::aggregation::{Aggregator, FedAvg};
use crate::clients::Population;
use crate::compression::{make_dense_codec, DenseCodec};
use crate::config::{Backend, ExperimentConfig};
use crate::coordinator::{aggregate_round, feed_strategy, run_client_round};
use crate::data::{self, lazy, FederatedDataset};
use crate::dropout::{make_strategy, SubmodelStrategy};
use crate::metrics::{ExperimentReport, RoundRecord};
use crate::model::manifest::{Manifest, VariantSpec};
use crate::model::packing::PlanCache;
use crate::network::{Availability, NetworkSim};
use crate::runtime::native::mlp_from_config;
use crate::runtime::{EvalOutput, ModelRuntime, RuntimeHost};
use crate::sched::{make_policy, Engine, RoundCtx, RoundSummary};
use crate::tensor::kernels::WorkspacePool;
use crate::transport::{Loopback, Transport};
use crate::util::pool::LazyPool;
use crate::util::rng::Pcg64;

/// A fully-assembled experiment, ready to run round-by-round.
pub struct Experiment {
    pub cfg: ExperimentConfig,
    pub spec: VariantSpec,
    runtime: RuntimeHost,
    /// Eval-side dataset handle. In eager mode the `Arc` is shared
    /// with the population's dataset source; in lazy-population mode
    /// `clients` is empty (per-client data is derived on demand) and
    /// only the derived pooled test set is held.
    dataset: Arc<FederatedDataset>,
    strategy: Box<dyn SubmodelStrategy>,
    downlink: Arc<dyn DenseCodec>,
    /// The client population: pure `(seed, id)` derivation for
    /// immutable parameters, a bounded residual store for mutable
    /// state. Replaces the eager `Vec<ClientState>` fleet.
    fleet: Population,
    net: NetworkSim,
    /// The engine's aggregation path: flat sharded or a hierarchical
    /// tree, per `cfg.sharding` (both bit-identical to the `FedAvg`
    /// reference).
    agg: Aggregator,
    /// Retained single-threaded reference aggregator, built lazily the
    /// first time [`Experiment::step_serial_reference`] runs (test /
    /// debug path only — production rounds never pay for it).
    agg_ref: Option<FedAvg>,
    rng: Pcg64,
    engine: Engine,
    pub global: Vec<f32>,
    records: Vec<RoundRecord>,
    cum_s: f64,
    lr: f32,
    /// Pack-plan LRU cache (keyed by kept-unit bitmap).
    plans: PlanCache,
    /// Scratch workspaces shared across client jobs / worker threads
    /// (`Arc` so the engine can hand it to pool workers, which check
    /// one out only while a job executes).
    workspaces: Arc<WorkspacePool>,
    /// The transport the federation conversation's frames travel
    /// through: in-process [`Loopback`] by default, a real
    /// [`crate::transport::tcp::TcpTransport`] under `afd serve`.
    /// The transport never changes results, only where the client
    /// half runs (`rust/tests/transport_e2e.rs`).
    transport: Arc<dyn Transport>,
}

impl Experiment {
    /// Build with the default in-process loopback transport.
    pub fn build(cfg: &ExperimentConfig) -> Result<Experiment> {
        Experiment::build_with_transport(cfg, Arc::new(Loopback::default()))
    }

    pub fn build_with_transport(
        cfg: &ExperimentConfig,
        transport: Arc<dyn Transport>,
    ) -> Result<Experiment> {
        // Resolve the SIMD dispatch level once, before any kernel or
        // codec runs (workspace construction re-checks the cached
        // probe; this keeps even the first client round off the
        // detection path). Scalar and SIMD paths are bit-identical,
        // so the choice never affects results.
        crate::tensor::simd::init();
        let (runtime, spec, init): (RuntimeHost, VariantSpec, Vec<f32>) =
            match cfg.backend {
                Backend::Pjrt => {
                    let dir = artifacts_dir();
                    let manifest = Manifest::load(&dir)
                        .context("loading artifacts (run `make artifacts`)")?;
                    let client = xla::PjRtClient::cpu()
                        .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
                    let rt = crate::runtime::pjrt::PjrtRuntime::load(
                        &client, &manifest, &cfg.variant,
                    )?;
                    let spec = rt.spec().clone();
                    let init = manifest.load_init_params(&spec)?;
                    // PJRT wrapper types are not `Send`: execute
                    // serially on the coordinator thread.
                    (RuntimeHost::Serial(Box::new(rt)), spec, init)
                }
                Backend::Native => {
                    // Shared construction point with the remote
                    // transport client (`afd client` rebuilds the same
                    // runtime from the shipped config — they can never
                    // drift on model geometry).
                    let (mlp, spec) = mlp_from_config(cfg);
                    let init = mlp.init_params(cfg.seed);
                    // Pure-Rust model: share it across pool workers.
                    (RuntimeHost::Parallel(Arc::new(mlp)), spec, init)
                }
            };

        let mut data_cfg = cfg.data.clone();
        data_cfg.num_clients = cfg.num_clients;
        data_cfg.seed = cfg.seed;
        let (dataset, fleet, net) = if cfg.population.lazy {
            // Lazy populations derive everything from `(seed, id)` at
            // sampling time, which only the synthetic generator and the
            // pure native runtime support.
            anyhow::ensure!(
                matches!(cfg.backend, Backend::Native) && spec.dataset == "synthetic",
                "population.lazy requires the native backend with the \
                 synthetic dataset (got backend {:?}, dataset {:?})",
                cfg.backend,
                spec.dataset
            );
            let per: usize = spec.input_shape.iter().product();
            let centres = lazy::Centres::build(data_cfg.seed, spec.classes, per);
            // Eval-only dataset shell: no per-client shards are ever
            // materialized here, just the pooled test set (identical to
            // the eager generator's — same derivation streams).
            let dataset = Arc::new(FederatedDataset {
                clients: Vec::new(),
                test: lazy::test_dataset(&spec, &data_cfg, &centres),
            });
            let fleet = Population::lazy(
                spec.clone(),
                data_cfg.clone(),
                cfg.dgc.clone(),
                cfg.seed,
                &cfg.population,
            );
            let net = NetworkSim::lazy(cfg.link.clone(), cfg.seed);
            (dataset, fleet, net)
        } else {
            let dataset = Arc::new(data::generate(&spec, &data_cfg));
            anyhow::ensure!(
                dataset.num_clients() == cfg.num_clients,
                "dataset generator returned wrong client count"
            );
            let fleet = Population::eager(
                Arc::clone(&dataset),
                cfg.dgc.clone(),
                cfg.seed,
                &cfg.population,
            );
            let net = NetworkSim::new(cfg.link.clone(), cfg.num_clients, cfg.seed);
            (dataset, fleet, net)
        };

        let strategy = make_strategy(&cfg.dropout, &spec, cfg.num_clients, cfg.fdr)?;
        let downlink: Arc<dyn DenseCodec> = Arc::from(make_dense_codec(&cfg.downlink)?);
        // One worker pool serves both parallel local training (engine)
        // and sharded aggregation — they never overlap in time. Lazy:
        // its threads spawn on the first fan-out, so serial-only runs
        // (PJRT, the reference path, single-shard small models) never
        // create them; the width is known up front for shard sizing.
        let pool = Arc::new(LazyPool::default_for_machine());
        if crate::obs::enabled() {
            crate::obs::metrics::POOL_WIDTH.set(pool.size() as u64);
        }
        let agg = Aggregator::from_config(&cfg.sharding, spec.num_params, Arc::clone(&pool));
        let lr = cfg.lr_override.unwrap_or(spec.lr);
        let policy = make_policy(&cfg.sched, cfg.cohort_size(), cfg.num_clients)?;
        let engine = Engine::new(
            policy,
            Availability::new(cfg.sched.churn.clone(), cfg.seed),
            pool,
        );

        Ok(Experiment {
            cfg: cfg.clone(),
            runtime,
            dataset,
            strategy,
            downlink,
            fleet,
            net,
            agg,
            agg_ref: None,
            rng: Pcg64::with_stream(cfg.seed, 0xe4be),
            engine,
            global: init,
            records: Vec::new(),
            cum_s: 0.0,
            spec,
            lr,
            plans: PlanCache::default(),
            workspaces: Arc::new(WorkspacePool::new()),
            transport,
        })
    }

    /// Read-only view of the client population (integration tests and
    /// tools inspect the residual store through it).
    pub fn population(&self) -> &Population {
        &self.fleet
    }

    /// Execute one federated round through the scheduler; returns the
    /// round's record.
    pub fn step(&mut self, round: usize) -> Result<RoundRecord> {
        crate::obs::metrics::CURRENT_ROUND.set(round as u64);
        let mut ctx = RoundCtx {
            cfg: &self.cfg,
            spec: &self.spec,
            runtime: &self.runtime,
            strategy: self.strategy.as_mut(),
            downlink: &self.downlink,
            fleet: &mut self.fleet,
            net: &self.net,
            agg: &mut self.agg,
            rng: &mut self.rng,
            global: &mut self.global,
            lr: self.lr,
            cum_s: self.cum_s,
            plans: &self.plans,
            workspaces: &self.workspaces,
            transport: &self.transport,
        };
        let s = self.engine.step(round, &mut ctx)?;
        self.cum_s += s.round_s;
        self.finish_round(round, &s)
    }

    /// The pre-scheduler serial round loop, kept as the bit-exactness
    /// reference for the `sync` policy (and for debugging the engine):
    /// same RNG call sequence, same aggregation order, same network
    /// accounting — `RoundRecord`s must match [`Experiment::step`]
    /// byte-for-byte at equal seeds when `sched.policy == "sync"` and
    /// churn is disabled.
    pub fn step_serial_reference(&mut self, round: usize) -> Result<RoundRecord> {
        let m = self.cfg.cohort_size();
        let cohort = self.rng.sample_indices(self.cfg.num_clients, m);

        let mut outcomes = Vec::with_capacity(m);
        for &c in &cohort {
            let sm = self.strategy.select(round, c, &mut self.rng);
            let plan = self.plans.get(&self.spec, &sm);
            let num_samples = self.fleet.num_samples(c);
            // Same per-client call order as the engine: bump
            // participations, then draw the epoch from the client's
            // own RNG stream.
            self.fleet.client(c).participations += 1;
            let data = self.fleet.epoch_data(c, &self.spec);
            let dgc_state = if self.cfg.uplink_dgc {
                Some(&mut self.fleet.client(c).dgc)
            } else {
                None
            };
            let mut ws = self.workspaces.checkout();
            let outcome = run_client_round(
                &self.spec,
                self.runtime.get(),
                &self.global,
                &sm,
                &plan,
                &data,
                self.lr,
                self.downlink.as_ref(),
                dgc_state,
                round,
                self.cfg.seed ^ (round as u64) << 20,
                c,
                num_samples,
                None,
                None,
                self.transport.as_ref(),
                &mut ws,
            )?;
            self.workspaces.restore(ws);
            outcomes.push(outcome);
        }

        let sizes: Vec<usize> = (0..self.cfg.num_clients)
            .map(|c| self.fleet.num_samples(c))
            .collect();
        let num_params = self.spec.num_params;
        let agg_ref = self.agg_ref.get_or_insert_with(|| FedAvg::new(num_params));
        let (new_global, timing) =
            aggregate_round(&self.global, &outcomes, &sizes, agg_ref, &self.net);
        self.global = new_global;
        feed_strategy(self.strategy.as_mut(), round, &outcomes);
        // Every serial-reference update is aggregated: Ack them all
        // (the engine's sync policy does exactly the same).
        for o in &outcomes {
            self.transport.finish(o.client, round as u32, true)?;
        }
        // Same round boundary as the engine path: enforce the residual
        // store's byte budget (no-op for unbudgeted populations).
        self.fleet.end_round();

        self.cum_s += timing.round_s;
        let count = outcomes.len().max(1) as f64;
        let s = RoundSummary {
            round_s: timing.round_s,
            down_bytes: timing.down_bytes,
            up_bytes: timing.up_bytes,
            down_payload_bytes: outcomes.iter().map(|o| o.down_payload_bytes).sum(),
            up_payload_bytes: outcomes.iter().map(|o| o.up_payload_bytes).sum(),
            train_loss: outcomes.iter().map(|o| o.train_loss as f64).sum::<f64>() / count,
            keep_fraction: outcomes
                .iter()
                .map(|o| o.submodel.keep_fraction())
                .sum::<f64>()
                / count,
            arrived: outcomes.len(),
            cut: 0,
            dropped: 0,
            lost: 0,
            quarantined: 0,
        };
        self.finish_round(round, &s)
    }

    /// Shared record assembly + (simulation-free) periodic evaluation.
    fn finish_round(&mut self, round: usize, s: &RoundSummary) -> Result<RoundRecord> {
        let (eval_acc, eval_loss) = if round % self.cfg.eval_every == 0
            || round == self.cfg.rounds
        {
            let ev = self.evaluate()?;
            if crate::obs::enabled() {
                crate::obs::metrics::EVALS_RUN.incr();
            }
            (Some(ev.accuracy()), Some(ev.mean_loss()))
        } else {
            (None, None)
        };

        let rec = RoundRecord {
            round,
            round_s: s.round_s,
            cum_s: self.cum_s,
            train_loss: s.train_loss,
            eval_acc,
            eval_loss,
            down_bytes: s.down_bytes,
            up_bytes: s.up_bytes,
            down_payload_bytes: s.down_payload_bytes,
            up_payload_bytes: s.up_payload_bytes,
            keep_fraction: s.keep_fraction,
            arrived: s.arrived,
            cut: s.cut,
            dropped: s.dropped,
            lost: s.lost,
            quarantined: s.quarantined,
        };
        self.records.push(rec.clone());
        Ok(rec)
    }

    /// Round records accumulated so far (checkpointing and tools).
    pub fn records(&self) -> &[RoundRecord] {
        &self.records
    }

    /// Atomically write a coordinator checkpoint capturing everything
    /// a restored process needs to continue bit-identically: global
    /// model, strategy / scheduler / policy state, the coordinator RNG
    /// cursor, per-client mutable state (RNG cursors, participation
    /// counts, DGC residuals — resident and spilled alike), simulated
    /// clock and the round records emitted so far. Call at a round
    /// boundary; `completed_round` is the last round whose record is
    /// in `self.records`.
    pub fn save_checkpoint(
        &mut self,
        path: &std::path::Path,
        completed_round: u64,
    ) -> Result<()> {
        let mut strategy = Vec::new();
        self.strategy.save_state(&mut strategy);
        let mut engine = Vec::new();
        self.engine.save_state(&mut engine)?;
        let mut fleet = Vec::new();
        self.fleet
            .save_state(&mut fleet)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let (rng_state, rng_inc) = self.rng.to_raw();
        let body = super::checkpoint::CheckpointBody {
            config_fingerprint: super::checkpoint::config_fingerprint(&self.cfg),
            completed_round,
            cum_s: self.cum_s,
            lr: self.lr,
            rng_state,
            rng_inc,
            global: std::mem::take(&mut self.global),
            strategy,
            engine,
            records: std::mem::take(&mut self.records),
            fleet,
        };
        let result = super::checkpoint::write(path, &body);
        // The big buffers were only lent to the body (no model-sized
        // copy); hand them back whether or not the write succeeded.
        self.global = body.global;
        self.records = body.records;
        result
    }

    /// Restore state written by [`Experiment::save_checkpoint`] into a
    /// freshly built experiment with the *same* config; returns the
    /// last completed round, so driving `step` for rounds
    /// `completed+1..=cfg.rounds` continues the original run
    /// bit-identically.
    pub fn restore_from_checkpoint(&mut self, path: &std::path::Path) -> Result<u64> {
        let body = super::checkpoint::read(path)?;
        let want = super::checkpoint::config_fingerprint(&self.cfg);
        anyhow::ensure!(
            body.config_fingerprint == want,
            "checkpoint config fingerprint {:#018x} does not match this run's \
             {want:#018x} — refusing to resume under a different config",
            body.config_fingerprint
        );
        anyhow::ensure!(
            body.global.len() == self.spec.num_params,
            "checkpoint global has {} params, model has {}",
            body.global.len(),
            self.spec.num_params
        );
        self.strategy.load_state(&body.strategy)?;
        self.engine.load_state(&body.engine)?;
        self.fleet.restore_state(&body.fleet)?;
        self.rng = Pcg64::from_raw(body.rng_state, body.rng_inc);
        self.global = body.global;
        self.cum_s = body.cum_s;
        self.lr = body.lr;
        self.records = body.records;
        crate::obs::metrics::RESTORES.incr();
        crate::obs::span::mark(crate::obs::Stage::RestoreMark, body.completed_round, 0);
        Ok(body.completed_round)
    }

    /// Evaluate the current global model on the pooled test set.
    pub fn evaluate(&self) -> Result<EvalOutput> {
        let mut total = EvalOutput::default();
        for batch in self
            .dataset
            .test
            .eval_batches(&self.spec, self.cfg.eval_batch_limit)
        {
            let ev = self.runtime.get().evaluate(&self.global, &batch)?;
            total.merge(&ev);
        }
        Ok(total)
    }

    /// Run to completion (or until the target accuracy is reached).
    pub fn run(mut self) -> Result<ExperimentReport> {
        let mut converged = None;
        for round in 1..=self.cfg.rounds {
            let rec = self.step(round)?;
            if let (Some(target), Some(acc)) = (self.cfg.target_accuracy, rec.eval_acc) {
                if converged.is_none() && acc >= target {
                    converged = Some((round, self.cum_s));
                    // Keep running to the configured horizon unless the
                    // caller asked for early stop via rounds; the paper
                    // trains a fixed number of rounds and reads the
                    // convergence time off the curve.
                }
            }
            if crate::util::logging::enabled(crate::util::logging::Level::Debug) {
                crate::debug!(
                    "round {round}: loss {:.4} acc {:?} t {:.1}s",
                    rec.train_loss,
                    rec.eval_acc,
                    rec.cum_s
                );
            }
        }
        // End the session cleanly (`Bye` to remote clients; no-op on
        // the loopback transport).
        self.transport.shutdown()?;
        Ok(ExperimentReport {
            method: self.cfg.method_label(),
            variant: self.cfg.variant.clone(),
            seed: self.cfg.seed,
            records: self.records,
            converged,
        })
    }
}

/// Resolve the artifacts directory relative to the crate root.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Convenience wrapper: build + run.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<ExperimentReport> {
    Experiment::build(cfg)?.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Preset;

    /// Native-backend end-to-end: the whole coordinator stack must learn.
    #[test]
    fn native_experiment_learns() {
        let mut cfg = ExperimentConfig::preset(Preset::NativeSmoke);
        cfg.rounds = 30;
        cfg.eval_every = 5;
        let report = run_experiment(&cfg).unwrap();
        assert_eq!(report.records.len(), 30);
        let first = report
            .records
            .iter()
            .find_map(|r| r.eval_acc)
            .unwrap();
        let best = report.best_accuracy();
        assert!(
            best > first + 0.1 || best > 0.8,
            "should learn: first {first:.3} best {best:.3}"
        );
        assert!(report.total_sim_seconds() > 0.0);
        assert!(report.total_down_bytes() > 0);
    }

    #[test]
    fn afd_reduces_bytes_vs_no_compression() {
        let mut base = ExperimentConfig::preset(Preset::NativeSmoke);
        // Large enough that payloads (not the fixed RTT latency)
        // dominate the link time — the regime the paper studies.
        base.native_dims = (128, 256, 10);
        let mut none = base.clone();
        none.dropout = "none".into();
        none.downlink = "raw".into();
        none.uplink_dgc = false;
        none.rounds = 5;
        let mut afd = base.clone();
        afd.dropout = "afd_multi".into();
        afd.downlink = "quant8".into();
        afd.uplink_dgc = true;
        afd.rounds = 5;

        let r_none = run_experiment(&none).unwrap();
        let r_afd = run_experiment(&afd).unwrap();
        assert!(
            r_afd.total_down_bytes() * 3 < r_none.total_down_bytes(),
            "downlink must shrink: {} vs {}",
            r_afd.total_down_bytes(),
            r_none.total_down_bytes()
        );
        assert!(
            r_afd.total_up_bytes() * 5 < r_none.total_up_bytes(),
            "uplink must shrink: {} vs {}",
            r_afd.total_up_bytes(),
            r_none.total_up_bytes()
        );
        assert!(r_afd.total_sim_seconds() < r_none.total_sim_seconds() / 2.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut cfg = ExperimentConfig::preset(Preset::NativeSmoke);
        cfg.rounds = 6;
        let a = run_experiment(&cfg).unwrap();
        let b = run_experiment(&cfg).unwrap();
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.train_loss, y.train_loss);
            assert_eq!(x.eval_acc, y.eval_acc);
            assert_eq!(x.down_bytes, y.down_bytes);
        }
        cfg.seed = 1;
        let c = run_experiment(&cfg).unwrap();
        assert!(a.records[0].train_loss != c.records[0].train_loss);
    }

    #[test]
    fn all_strategies_run_native() {
        for strat in ["none", "fd", "afd_multi", "afd_single"] {
            let mut cfg = ExperimentConfig::preset(Preset::NativeSmoke);
            cfg.dropout = strat.into();
            cfg.rounds = 4;
            cfg.eval_every = 2;
            let r = run_experiment(&cfg)
                .unwrap_or_else(|e| panic!("{strat} failed: {e}"));
            assert_eq!(r.records.len(), 4);
            if strat == "none" {
                assert!(r.records.iter().all(|rec| rec.keep_fraction == 1.0));
            } else {
                assert!(r.records.iter().all(|rec| rec.keep_fraction < 1.0));
            }
        }
    }

    #[test]
    fn all_sched_policies_run_native() {
        for preset in [
            Preset::NativeSmoke,
            Preset::NativeSmokeOverselect,
            Preset::NativeSmokeAsync,
        ] {
            let mut cfg = ExperimentConfig::preset(preset);
            cfg.rounds = 6;
            cfg.eval_every = 3;
            let r = run_experiment(&cfg)
                .unwrap_or_else(|e| panic!("{:?} failed: {e}", cfg.sched.policy));
            assert_eq!(r.records.len(), 6);
            assert!(r.total_sim_seconds() > 0.0, "{}", cfg.sched.policy);
            assert!(
                r.records.iter().all(|rec| rec.arrived > 0),
                "{} must aggregate someone every round",
                cfg.sched.policy
            );
        }
    }

    /// The tentpole contract: a lazily-materialized population (pure
    /// `(seed, id)` derivation + residual store) reproduces the eager
    /// fleet bit-for-bit through whole runs, with and without a byte
    /// budget forcing evictions mid-run.
    #[test]
    fn lazy_population_matches_eager_bitwise() {
        let mut eager = ExperimentConfig::preset(Preset::NativeSmoke);
        eager.rounds = 6;
        eager.eval_every = 3;
        eager.uplink_dgc = true;
        let mut lazy_cfg = eager.clone();
        lazy_cfg.population.lazy = true;
        let mut budgeted = lazy_cfg.clone();
        budgeted.population.store_budget_bytes = 16 << 10; // forces spills
        let a = run_experiment(&eager).unwrap();
        for cfg in [&lazy_cfg, &budgeted] {
            let b = run_experiment(cfg).unwrap();
            for (x, y) in a.records.iter().zip(&b.records) {
                assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits());
                assert_eq!(
                    x.eval_acc.map(f64::to_bits),
                    y.eval_acc.map(f64::to_bits)
                );
                assert_eq!(x.down_bytes, y.down_bytes);
                assert_eq!(x.round_s.to_bits(), y.round_s.to_bits());
            }
        }
    }

    /// Hierarchical aggregation is a pure topology knob: tree rounds
    /// must match flat rounds bit-for-bit through a whole run.
    #[test]
    fn tree_aggregation_matches_flat_bitwise() {
        let mut flat = ExperimentConfig::preset(Preset::NativeSmoke);
        flat.rounds = 5;
        flat.eval_every = 2;
        let a = run_experiment(&flat).unwrap();
        for (levels, fanout) in [(2, 4), (3, 2)] {
            let mut tree = flat.clone();
            tree.sharding.tree_levels = levels;
            tree.sharding.tree_fanout = fanout;
            let b = run_experiment(&tree).unwrap();
            for (x, y) in a.records.iter().zip(&b.records) {
                assert_eq!(
                    x.train_loss.to_bits(),
                    y.train_loss.to_bits(),
                    "levels={levels} fanout={fanout}"
                );
                assert_eq!(x.eval_acc.map(f64::to_bits), y.eval_acc.map(f64::to_bits));
            }
        }
    }

    /// The shipped population preset must run end-to-end: 100k-client
    /// lazy population, 256-client cohorts, tree aggregation, bounded
    /// residual store.
    #[test]
    fn native_population_preset_runs_bounded() {
        let mut cfg = ExperimentConfig::preset(Preset::NativePopulation);
        cfg.rounds = 2;
        cfg.eval_every = 2;
        let mut exp = Experiment::build(&cfg).unwrap();
        assert!(exp.fleet.is_lazy());
        let budget = exp.fleet.store().budget_bytes();
        assert!(budget > 0, "population preset must set a store budget");
        for round in 1..=2 {
            let rec = exp.step(round).unwrap();
            assert!(rec.arrived > 0);
            assert!(
                exp.fleet.store().resident_bytes() <= budget,
                "round {round}: resident {} > budget {budget}",
                exp.fleet.store().resident_bytes()
            );
        }
    }

    #[test]
    fn churn_drops_clients_and_stays_deterministic() {
        let mut cfg = ExperimentConfig::preset(Preset::NativeSmoke);
        cfg.rounds = 10;
        cfg.eval_every = 5;
        cfg.sched.churn.enabled = true;
        cfg.sched.churn.availability = 0.5;
        cfg.sched.churn.period_s = 5.0;
        let a = run_experiment(&cfg).unwrap();
        let b = run_experiment(&cfg).unwrap();
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.train_loss, y.train_loss);
            assert_eq!(x.dropped, y.dropped);
        }
        let dropped: usize = a.records.iter().map(|r| r.dropped).sum();
        assert!(dropped > 0, "50% availability must drop someone");
        // The run survives drops and still learns something.
        assert!(a.best_accuracy() > 0.0);
    }
}
