//! Sub-model packing: exact transmitted-size accounting + value pack/unpack.
//!
//! The paper's communication saving comes from shipping only "the
//! necessary parameters that are not affected by the selective dropping
//! of the activations". For a weight matrix that means deleting the
//! columns of dropped output units and the rows of dropped input units
//! (with the repeat/fixed patterns the manifest records for conv→dense
//! flattening and LSTM recurrent blocks).
//!
//! Training itself runs on the masked full model (numerically identical;
//! see DESIGN.md), but the bytes placed on the simulated link — and the
//! round-trip tests in `rust/tests/packing_equivalence.rs` — use the real
//! packed layout implemented here.
//!
//! Two implementations coexist:
//!
//! * the legacy one-shot functions ([`pack_values`], [`unpack_values`],
//!   [`coordinate_mask`]) rebuild the kept row/col index lists on every
//!   call — simple, and retained as the reference;
//! * [`PackPlan`] precomputes the packed layout once per
//!   `(VariantSpec, SubModel)` pair as maximal contiguous runs, giving
//!   allocation-free [`PackPlan::pack_into`] / [`PackPlan::unpack_from`]
//!   on the hot path. [`PlanCache`] LRU-caches plans on the coordinator
//!   keyed by the kept-unit bitmap (AFD's recorded activation sets make
//!   bitmaps recur across rounds).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::model::manifest::{AxisPack, ParamSeg, VariantSpec};
use crate::model::submodel::SubModel;

/// Kept row/col index lists for one parameter under a sub-model.
fn axis_indices(
    pack: &Option<AxisPack>,
    full_extent: usize,
    spec: &VariantSpec,
    sm: &SubModel,
) -> Vec<usize> {
    match pack {
        None => (0..full_extent).collect(),
        Some(ap) => {
            let g = spec
                .group_index(&ap.group)
                .expect("validated at manifest load");
            let kept: Vec<usize> = sm.keep[g]
                .iter()
                .enumerate()
                .filter_map(|(i, &k)| if k { Some(i) } else { None })
                .collect();
            let mut idx = Vec::with_capacity(kept.len() * ap.repeat + ap.fixed);
            // Unit-fastest tiling: position p of `repeat` ⇒ rows p*count + u.
            for p in 0..ap.repeat {
                for &u in &kept {
                    idx.push(p * ap.count + u);
                }
            }
            // Fixed block (e.g. LSTM recurrent rows) sits after the tiled part.
            for j in 0..ap.fixed {
                idx.push(ap.count * ap.repeat + j);
            }
            idx.sort_unstable();
            idx
        }
    }
}

/// Packed element count of one parameter under a sub-model.
pub fn packed_param_elems(seg: &ParamSeg, spec: &VariantSpec, sm: &SubModel) -> usize {
    let rows = match &seg.rows {
        None => seg.rows_extent(),
        Some(ap) => ap.packed_extent(sm.kept_for(spec, &ap.group)),
    };
    let cols = match &seg.cols {
        None => seg.cols_extent(),
        Some(ap) => ap.packed_extent(sm.kept_for(spec, &ap.group)),
    };
    rows * cols
}

/// Total packed f32 element count of the transmissible sub-model.
pub fn packed_model_elems(spec: &VariantSpec, sm: &SubModel) -> usize {
    spec.params
        .iter()
        .filter(|p| p.transmit)
        .map(|p| packed_param_elems(p, spec, sm))
        .sum()
}

/// Wire bytes for a *raw f32* packed sub-model: values + the kept-unit
/// bitmap per group (the client must learn which units it holds).
pub fn submodel_wire_bytes(spec: &VariantSpec, sm: &SubModel) -> u64 {
    let values = 4 * packed_model_elems(spec, sm) as u64;
    let bitmap: u64 = spec
        .mask_groups
        .iter()
        .map(|g| g.size.div_ceil(8) as u64)
        .sum();
    values + bitmap
}

/// Extract packed values from a flat full-model vector.
///
/// Layout: parameters in manifest order (transmit-only); within one
/// parameter, kept rows ascending × kept cols ascending (row-major).
pub fn pack_values(spec: &VariantSpec, full: &[f32], sm: &SubModel) -> Vec<f32> {
    assert_eq!(full.len(), spec.num_params);
    let mut out = Vec::with_capacity(packed_model_elems(spec, sm));
    for seg in spec.params.iter().filter(|p| p.transmit) {
        let rows = axis_indices(&seg.rows, seg.rows_extent(), spec, sm);
        let cols = axis_indices(&seg.cols, seg.cols_extent(), spec, sm);
        let stride = seg.cols_extent();
        let base = seg.offset;
        for &r in &rows {
            let row_base = base + r * stride;
            for &c in &cols {
                out.push(full[row_base + c]);
            }
        }
    }
    out
}

/// Scatter packed values back into a flat full-model vector. Dropped
/// coordinates are left untouched (the server's stale copy persists —
/// exactly the paper's recovery step, Fig. 1 step 7).
pub fn unpack_values(spec: &VariantSpec, packed: &[f32], sm: &SubModel, full: &mut [f32]) {
    assert_eq!(full.len(), spec.num_params);
    let mut k = 0;
    for seg in spec.params.iter().filter(|p| p.transmit) {
        let rows = axis_indices(&seg.rows, seg.rows_extent(), spec, sm);
        let cols = axis_indices(&seg.cols, seg.cols_extent(), spec, sm);
        let stride = seg.cols_extent();
        let base = seg.offset;
        for &r in &rows {
            let row_base = base + r * stride;
            for &c in &cols {
                full[row_base + c] = packed[k];
                k += 1;
            }
        }
    }
    assert_eq!(k, packed.len(), "packed length mismatch");
}

/// Coordinate mask: true for every flat index that belongs to the
/// sub-model (transmit params only). Used by FedAvg's mask-aware
/// aggregation and by the uplink delta compressor.
pub fn coordinate_mask(spec: &VariantSpec, sm: &SubModel) -> Vec<bool> {
    let mut mask = vec![false; spec.num_params];
    for seg in spec.params.iter().filter(|p| p.transmit) {
        let rows = axis_indices(&seg.rows, seg.rows_extent(), spec, sm);
        let cols = axis_indices(&seg.cols, seg.cols_extent(), spec, sm);
        let stride = seg.cols_extent();
        for &r in &rows {
            let row_base = seg.offset + r * stride;
            for &c in &cols {
                mask[row_base + c] = true;
            }
        }
    }
    mask
}

/// Precomputed gather/scatter program for one `(VariantSpec, SubModel)`
/// pair. The packed layout (identical, element for element, to
/// [`pack_values`]'s output order) is flattened into maximal contiguous
/// runs of full-model coordinates, so pack/unpack become a sequence of
/// `memcpy`s with no per-call index rebuilding — and no allocations
/// when the caller reuses the output buffer.
pub struct PackPlan {
    /// `(start, len)` runs into the flat full-model vector, in packed
    /// order.
    runs: Vec<(u32, u32)>,
    packed_len: usize,
    num_params: usize,
    bitmap_bytes: u64,
    flops_per_sample: f64,
}

impl PackPlan {
    pub fn build(spec: &VariantSpec, sm: &SubModel) -> PackPlan {
        assert!(
            spec.num_params <= u32::MAX as usize,
            "flat model too large for u32 plan indices"
        );
        let mut runs: Vec<(u32, u32)> = Vec::new();
        let mut packed_len = 0usize;
        for seg in spec.params.iter().filter(|p| p.transmit) {
            let rows = axis_indices(&seg.rows, seg.rows_extent(), spec, sm);
            let cols = axis_indices(&seg.cols, seg.cols_extent(), spec, sm);
            let stride = seg.cols_extent();
            for &r in &rows {
                let row_base = seg.offset + r * stride;
                for &c in &cols {
                    let idx = (row_base + c) as u32;
                    match runs.last_mut() {
                        Some((s, l)) if *s + *l == idx => *l += 1,
                        _ => runs.push((idx, 1)),
                    }
                    packed_len += 1;
                }
            }
        }
        let bitmap_bytes = spec
            .mask_groups
            .iter()
            .map(|g| g.size.div_ceil(8) as u64)
            .sum();
        PackPlan {
            runs,
            packed_len,
            num_params: spec.num_params,
            bitmap_bytes,
            flops_per_sample: effective_flops_per_sample(spec, sm),
        }
    }

    /// Packed f32 element count (== [`packed_model_elems`]).
    pub fn packed_len(&self) -> usize {
        self.packed_len
    }

    /// Number of contiguous runs (diagnostics; lower is faster).
    pub fn run_count(&self) -> usize {
        self.runs.len()
    }

    /// The packed-order `(start, len)` runs into the flat full-model
    /// vector. Runs are disjoint (every packed coordinate appears in
    /// exactly one run); the sharded aggregator walks them instead of
    /// testing a full-length coordinate mask per coordinate.
    pub fn runs(&self) -> &[(u32, u32)] {
        &self.runs
    }

    /// Flat full-model length this plan was built for.
    pub fn num_params(&self) -> usize {
        self.num_params
    }

    /// Kept-unit bitmap bytes that ride along with raw payloads.
    pub fn bitmap_bytes(&self) -> u64 {
        self.bitmap_bytes
    }

    /// Wire bytes of the raw-f32 packed sub-model
    /// (== [`submodel_wire_bytes`]).
    pub fn wire_bytes(&self) -> u64 {
        4 * self.packed_len as u64 + self.bitmap_bytes
    }

    /// Cached [`effective_flops_per_sample`] for this sub-model.
    pub fn flops_per_sample(&self) -> f64 {
        self.flops_per_sample
    }

    /// Gather packed values out of a flat full-model vector into `out`
    /// (cleared first; allocation-free once `out`'s capacity is warm).
    pub fn pack_into(&self, full: &[f32], out: &mut Vec<f32>) {
        assert_eq!(full.len(), self.num_params);
        out.clear();
        out.reserve(self.packed_len);
        for &(s, l) in &self.runs {
            let s = s as usize;
            out.extend_from_slice(&full[s..s + l as usize]);
        }
    }

    /// Scatter packed values back into a flat full-model vector;
    /// dropped coordinates are left untouched (paper Fig. 1 step 7).
    pub fn unpack_from(&self, packed: &[f32], full: &mut [f32]) {
        assert_eq!(full.len(), self.num_params);
        assert_eq!(packed.len(), self.packed_len, "packed length mismatch");
        let mut k = 0usize;
        for &(s, l) in &self.runs {
            let s = s as usize;
            let l = l as usize;
            full[s..s + l].copy_from_slice(&packed[k..k + l]);
            k += l;
        }
    }

    /// Set `mask[i] = true` for every sub-model coordinate (the
    /// caller clears/reuses the buffer; == [`coordinate_mask`] when
    /// starting from all-false).
    pub fn mark_coord_mask(&self, mask: &mut [bool]) {
        assert_eq!(mask.len(), self.num_params);
        for &(s, l) in &self.runs {
            let s = s as usize;
            mask[s..s + l as usize].fill(true);
        }
    }
}

/// Coordinator-side LRU cache of [`PackPlan`]s keyed by the kept-unit
/// bitmap. AFD re-uses recorded activation sets across rounds (and the
/// no-dropout baselines use one full-model plan forever), so recurring
/// bitmaps hit; random-dropout misses still win because one built plan
/// serves the round's five pack/unpack/mask passes.
pub struct PlanCache {
    inner: Mutex<PlanCacheInner>,
    capacity: usize,
}

struct PlanCacheInner {
    map: HashMap<Vec<u64>, (u64, Arc<PackPlan>)>,
    tick: u64,
}

impl PlanCache {
    pub const DEFAULT_CAPACITY: usize = 64;

    pub fn new(capacity: usize) -> PlanCache {
        PlanCache {
            inner: Mutex::new(PlanCacheInner {
                map: HashMap::new(),
                tick: 0,
            }),
            capacity: capacity.max(1),
        }
    }

    /// One `u64` identifying an optional axis pack (`u64::MAX` for an
    /// unpacked axis). A plan's layout for a given kept-unit bitmap is
    /// fully determined by each transmit segment's offset, extents and
    /// axis packs, so folding these into the key makes one cache safe
    /// to share across variants.
    fn axis_code(spec: &VariantSpec, ap: &Option<AxisPack>) -> u64 {
        match ap {
            None => u64::MAX,
            Some(a) => {
                let g = spec.group_index(&a.group).unwrap_or(62) as u64;
                (a.count as u64)
                    | ((a.repeat as u64) << 20)
                    | ((a.fixed as u64) << 40)
                    | (g << 57)
            }
        }
    }

    /// Cache key: the spec's packing layout (per segment: offset,
    /// extents, transmit flag, axis packs) followed by, per group, the
    /// unit count then the packed kept-unit bits.
    fn key(spec: &VariantSpec, sm: &SubModel) -> Vec<u64> {
        let mut key = Vec::with_capacity(1 + spec.params.len() * 4 + sm.keep.len() * 2);
        key.push(spec.num_params as u64);
        for seg in &spec.params {
            key.push((seg.offset as u64) | ((seg.transmit as u64) << 63));
            key.push((seg.rows_extent() as u64) | ((seg.cols_extent() as u64) << 32));
            key.push(Self::axis_code(spec, &seg.rows));
            key.push(Self::axis_code(spec, &seg.cols));
        }
        for keep in &sm.keep {
            key.push(keep.len() as u64);
            let mut word = 0u64;
            for (i, &k) in keep.iter().enumerate() {
                if k {
                    word |= 1 << (i % 64);
                }
                if i % 64 == 63 {
                    key.push(word);
                    word = 0;
                }
            }
            if keep.len() % 64 != 0 {
                key.push(word);
            }
        }
        key
    }

    /// Fetch (or build and cache) the plan for `sm`.
    pub fn get(&self, spec: &VariantSpec, sm: &SubModel) -> Arc<PackPlan> {
        let key = Self::key(spec, sm);
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        if let Some((t, plan)) = g.map.get_mut(&key) {
            *t = tick;
            return plan.clone();
        }
        let plan = Arc::new(PackPlan::build(spec, sm));
        if g.map.len() >= self.capacity {
            if let Some(oldest) = g
                .map
                .iter()
                .min_by_key(|(_, (t, _))| *t)
                .map(|(k, _)| k.clone())
            {
                g.map.remove(&oldest);
            }
        }
        g.map.insert(key, (tick, plan.clone()));
        plan
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for PlanCache {
    fn default() -> PlanCache {
        PlanCache::new(PlanCache::DEFAULT_CAPACITY)
    }
}

/// Effective FLOPs per sample for a sub-model (compute-time simulation:
/// the paper's claim that AFD also reduces client computation).
pub fn effective_flops_per_sample(spec: &VariantSpec, sm: &SubModel) -> f64 {
    spec.params
        .iter()
        .map(|p| {
            if p.flops_per_sample == 0.0 {
                return 0.0;
            }
            let rf = match &p.rows {
                None => 1.0,
                Some(ap) => {
                    ap.packed_extent(sm.kept_for(spec, &ap.group)) as f64
                        / ap.full_extent() as f64
                }
            };
            let cf = match &p.cols {
                None => 1.0,
                Some(ap) => {
                    ap.packed_extent(sm.kept_for(spec, &ap.group)) as f64
                        / ap.full_extent() as f64
                }
            };
            p.flops_per_sample * rf * cf
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::tiny_spec;

    fn numbered(spec: &VariantSpec) -> Vec<f32> {
        (0..spec.num_params).map(|i| i as f32).collect()
    }

    #[test]
    fn full_submodel_packs_all_transmit_params() {
        let spec = tiny_spec();
        let sm = SubModel::full(&spec);
        assert_eq!(packed_model_elems(&spec, &sm), 33); // 34 minus frozen
        let full = numbered(&spec);
        let packed = pack_values(&spec, &full, &sm);
        assert_eq!(packed.len(), 33);
        // frozen param (index 33) must not appear
        assert!(!packed.contains(&33.0));
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let spec = tiny_spec();
        let sm = SubModel::from_kept_indices(&spec, &[vec![1, 3]]);
        let full = numbered(&spec);
        let packed = pack_values(&spec, &full, &sm);
        // w1 cols {1,3}: 6 rows × 2 cols = 12; b1: 2; w2 rows {1,3}: 2; b2: 1
        assert_eq!(packed.len(), 12 + 2 + 2 + 1);
        let mut out = vec![-1.0; spec.num_params];
        unpack_values(&spec, &packed, &sm, &mut out);
        let cm = coordinate_mask(&spec, &sm);
        for i in 0..spec.num_params {
            if cm[i] {
                assert_eq!(out[i], full[i], "index {i}");
            } else {
                assert_eq!(out[i], -1.0, "index {i} must be untouched");
            }
        }
    }

    #[test]
    fn coordinate_mask_counts_match_elems() {
        let spec = tiny_spec();
        for kept in [vec![0usize], vec![0, 1, 2], vec![1, 3]] {
            let sm = SubModel::from_kept_indices(&spec, &[kept]);
            let cm = coordinate_mask(&spec, &sm);
            assert_eq!(
                cm.iter().filter(|&&b| b).count(),
                packed_model_elems(&spec, &sm)
            );
        }
    }

    #[test]
    fn wire_bytes_include_bitmap() {
        let spec = tiny_spec();
        let sm = SubModel::full(&spec);
        assert_eq!(submodel_wire_bytes(&spec, &sm), 4 * 33 + 1);
    }

    #[test]
    fn plan_matches_legacy_pack_unpack() {
        let spec = tiny_spec();
        let full = numbered(&spec);
        for kept in [vec![0usize, 1, 2, 3], vec![1, 3], vec![2]] {
            let sm = SubModel::from_kept_indices(&spec, &[kept]);
            let plan = PackPlan::build(&spec, &sm);
            assert_eq!(plan.packed_len(), packed_model_elems(&spec, &sm));
            assert_eq!(plan.wire_bytes(), submodel_wire_bytes(&spec, &sm));
            assert_eq!(
                plan.flops_per_sample(),
                effective_flops_per_sample(&spec, &sm)
            );
            let mut packed = Vec::new();
            plan.pack_into(&full, &mut packed);
            assert_eq!(packed, pack_values(&spec, &full, &sm));
            let mut a = vec![-1.0; spec.num_params];
            let mut b = vec![-1.0; spec.num_params];
            plan.unpack_from(&packed, &mut a);
            unpack_values(&spec, &packed, &sm, &mut b);
            assert_eq!(a, b);
            let mut cm = vec![false; spec.num_params];
            plan.mark_coord_mask(&mut cm);
            assert_eq!(cm, coordinate_mask(&spec, &sm));
        }
    }

    #[test]
    fn plan_merges_contiguous_runs() {
        let spec = tiny_spec();
        let sm = SubModel::full(&spec);
        let plan = PackPlan::build(&spec, &sm);
        // A full sub-model packs each transmit segment as few runs —
        // far fewer than one per element.
        assert!(plan.run_count() < plan.packed_len() / 2);
    }

    #[test]
    fn plan_cache_hits_and_evicts() {
        let spec = tiny_spec();
        let cache = PlanCache::new(2);
        let a = SubModel::from_kept_indices(&spec, &[vec![0, 1]]);
        let b = SubModel::from_kept_indices(&spec, &[vec![2, 3]]);
        let c = SubModel::from_kept_indices(&spec, &[vec![1, 2]]);
        let p1 = cache.get(&spec, &a);
        let p2 = cache.get(&spec, &a);
        assert!(std::sync::Arc::ptr_eq(&p1, &p2), "same bitmap must hit");
        let _ = cache.get(&spec, &b);
        assert_eq!(cache.len(), 2);
        let _ = cache.get(&spec, &c); // evicts the LRU entry
        assert_eq!(cache.len(), 2);
        // Post-eviction lookups still produce correct plans.
        let p3 = cache.get(&spec, &a);
        assert_eq!(p3.packed_len(), packed_model_elems(&spec, &a));
    }

    #[test]
    fn plan_cache_distinguishes_structurally_similar_specs() {
        // Same num_params, param count and group count — only a
        // transmit flag differs. One shared cache must not hand spec
        // B a plan built for spec A.
        let spec_a = tiny_spec();
        let mut spec_b = tiny_spec();
        let flipped = spec_b.params.iter().position(|p| p.transmit).unwrap();
        spec_b.params[flipped].transmit = false;
        let cache = PlanCache::default();
        let sm = SubModel::full(&spec_a);
        let pa = cache.get(&spec_a, &sm);
        let pb = cache.get(&spec_b, &sm);
        assert_eq!(pa.packed_len(), packed_model_elems(&spec_a, &sm));
        assert_eq!(pb.packed_len(), packed_model_elems(&spec_b, &sm));
        assert_ne!(pa.packed_len(), pb.packed_len());
    }

    #[test]
    fn flops_scale_with_dropping() {
        let spec = tiny_spec();
        let full = SubModel::full(&spec);
        let half = SubModel::from_kept_indices(&spec, &[vec![0, 1]]);
        let f_full = effective_flops_per_sample(&spec, &full);
        let f_half = effective_flops_per_sample(&spec, &half);
        assert_eq!(f_full, 56.0);
        // w1: 48 * 0.5 (cols) = 24 ; w2: 8 * 0.5 (rows) = 4
        assert_eq!(f_half, 28.0);
    }
}
