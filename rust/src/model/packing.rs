//! Sub-model packing: exact transmitted-size accounting + value pack/unpack.
//!
//! The paper's communication saving comes from shipping only "the
//! necessary parameters that are not affected by the selective dropping
//! of the activations". For a weight matrix that means deleting the
//! columns of dropped output units and the rows of dropped input units
//! (with the repeat/fixed patterns the manifest records for conv→dense
//! flattening and LSTM recurrent blocks).
//!
//! Training itself runs on the masked full model (numerically identical;
//! see DESIGN.md), but the bytes placed on the simulated link — and the
//! round-trip tests in `rust/tests/packing_equivalence.rs` — use the real
//! packed layout implemented here.

use crate::model::manifest::{AxisPack, ParamSeg, VariantSpec};
use crate::model::submodel::SubModel;

/// Kept row/col index lists for one parameter under a sub-model.
fn axis_indices(
    pack: &Option<AxisPack>,
    full_extent: usize,
    spec: &VariantSpec,
    sm: &SubModel,
) -> Vec<usize> {
    match pack {
        None => (0..full_extent).collect(),
        Some(ap) => {
            let g = spec
                .group_index(&ap.group)
                .expect("validated at manifest load");
            let kept: Vec<usize> = sm.keep[g]
                .iter()
                .enumerate()
                .filter_map(|(i, &k)| if k { Some(i) } else { None })
                .collect();
            let mut idx = Vec::with_capacity(kept.len() * ap.repeat + ap.fixed);
            // Unit-fastest tiling: position p of `repeat` ⇒ rows p*count + u.
            for p in 0..ap.repeat {
                for &u in &kept {
                    idx.push(p * ap.count + u);
                }
            }
            // Fixed block (e.g. LSTM recurrent rows) sits after the tiled part.
            for j in 0..ap.fixed {
                idx.push(ap.count * ap.repeat + j);
            }
            idx.sort_unstable();
            idx
        }
    }
}

/// Packed element count of one parameter under a sub-model.
pub fn packed_param_elems(seg: &ParamSeg, spec: &VariantSpec, sm: &SubModel) -> usize {
    let rows = match &seg.rows {
        None => seg.rows_extent(),
        Some(ap) => ap.packed_extent(sm.kept_for(spec, &ap.group)),
    };
    let cols = match &seg.cols {
        None => seg.cols_extent(),
        Some(ap) => ap.packed_extent(sm.kept_for(spec, &ap.group)),
    };
    rows * cols
}

/// Total packed f32 element count of the transmissible sub-model.
pub fn packed_model_elems(spec: &VariantSpec, sm: &SubModel) -> usize {
    spec.params
        .iter()
        .filter(|p| p.transmit)
        .map(|p| packed_param_elems(p, spec, sm))
        .sum()
}

/// Wire bytes for a *raw f32* packed sub-model: values + the kept-unit
/// bitmap per group (the client must learn which units it holds).
pub fn submodel_wire_bytes(spec: &VariantSpec, sm: &SubModel) -> u64 {
    let values = 4 * packed_model_elems(spec, sm) as u64;
    let bitmap: u64 = spec
        .mask_groups
        .iter()
        .map(|g| g.size.div_ceil(8) as u64)
        .sum();
    values + bitmap
}

/// Extract packed values from a flat full-model vector.
///
/// Layout: parameters in manifest order (transmit-only); within one
/// parameter, kept rows ascending × kept cols ascending (row-major).
pub fn pack_values(spec: &VariantSpec, full: &[f32], sm: &SubModel) -> Vec<f32> {
    assert_eq!(full.len(), spec.num_params);
    let mut out = Vec::with_capacity(packed_model_elems(spec, sm));
    for seg in spec.params.iter().filter(|p| p.transmit) {
        let rows = axis_indices(&seg.rows, seg.rows_extent(), spec, sm);
        let cols = axis_indices(&seg.cols, seg.cols_extent(), spec, sm);
        let stride = seg.cols_extent();
        let base = seg.offset;
        for &r in &rows {
            let row_base = base + r * stride;
            for &c in &cols {
                out.push(full[row_base + c]);
            }
        }
    }
    out
}

/// Scatter packed values back into a flat full-model vector. Dropped
/// coordinates are left untouched (the server's stale copy persists —
/// exactly the paper's recovery step, Fig. 1 step 7).
pub fn unpack_values(spec: &VariantSpec, packed: &[f32], sm: &SubModel, full: &mut [f32]) {
    assert_eq!(full.len(), spec.num_params);
    let mut k = 0;
    for seg in spec.params.iter().filter(|p| p.transmit) {
        let rows = axis_indices(&seg.rows, seg.rows_extent(), spec, sm);
        let cols = axis_indices(&seg.cols, seg.cols_extent(), spec, sm);
        let stride = seg.cols_extent();
        let base = seg.offset;
        for &r in &rows {
            let row_base = base + r * stride;
            for &c in &cols {
                full[row_base + c] = packed[k];
                k += 1;
            }
        }
    }
    assert_eq!(k, packed.len(), "packed length mismatch");
}

/// Coordinate mask: true for every flat index that belongs to the
/// sub-model (transmit params only). Used by FedAvg's mask-aware
/// aggregation and by the uplink delta compressor.
pub fn coordinate_mask(spec: &VariantSpec, sm: &SubModel) -> Vec<bool> {
    let mut mask = vec![false; spec.num_params];
    for seg in spec.params.iter().filter(|p| p.transmit) {
        let rows = axis_indices(&seg.rows, seg.rows_extent(), spec, sm);
        let cols = axis_indices(&seg.cols, seg.cols_extent(), spec, sm);
        let stride = seg.cols_extent();
        for &r in &rows {
            let row_base = seg.offset + r * stride;
            for &c in &cols {
                mask[row_base + c] = true;
            }
        }
    }
    mask
}

/// Effective FLOPs per sample for a sub-model (compute-time simulation:
/// the paper's claim that AFD also reduces client computation).
pub fn effective_flops_per_sample(spec: &VariantSpec, sm: &SubModel) -> f64 {
    spec.params
        .iter()
        .map(|p| {
            if p.flops_per_sample == 0.0 {
                return 0.0;
            }
            let rf = match &p.rows {
                None => 1.0,
                Some(ap) => {
                    ap.packed_extent(sm.kept_for(spec, &ap.group)) as f64
                        / ap.full_extent() as f64
                }
            };
            let cf = match &p.cols {
                None => 1.0,
                Some(ap) => {
                    ap.packed_extent(sm.kept_for(spec, &ap.group)) as f64
                        / ap.full_extent() as f64
                }
            };
            p.flops_per_sample * rf * cf
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::tiny_spec;

    fn numbered(spec: &VariantSpec) -> Vec<f32> {
        (0..spec.num_params).map(|i| i as f32).collect()
    }

    #[test]
    fn full_submodel_packs_all_transmit_params() {
        let spec = tiny_spec();
        let sm = SubModel::full(&spec);
        assert_eq!(packed_model_elems(&spec, &sm), 33); // 34 minus frozen
        let full = numbered(&spec);
        let packed = pack_values(&spec, &full, &sm);
        assert_eq!(packed.len(), 33);
        // frozen param (index 33) must not appear
        assert!(!packed.contains(&33.0));
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let spec = tiny_spec();
        let sm = SubModel::from_kept_indices(&spec, &[vec![1, 3]]);
        let full = numbered(&spec);
        let packed = pack_values(&spec, &full, &sm);
        // w1 cols {1,3}: 6 rows × 2 cols = 12; b1: 2; w2 rows {1,3}: 2; b2: 1
        assert_eq!(packed.len(), 12 + 2 + 2 + 1);
        let mut out = vec![-1.0; spec.num_params];
        unpack_values(&spec, &packed, &sm, &mut out);
        let cm = coordinate_mask(&spec, &sm);
        for i in 0..spec.num_params {
            if cm[i] {
                assert_eq!(out[i], full[i], "index {i}");
            } else {
                assert_eq!(out[i], -1.0, "index {i} must be untouched");
            }
        }
    }

    #[test]
    fn coordinate_mask_counts_match_elems() {
        let spec = tiny_spec();
        for kept in [vec![0usize], vec![0, 1, 2], vec![1, 3]] {
            let sm = SubModel::from_kept_indices(&spec, &[kept]);
            let cm = coordinate_mask(&spec, &sm);
            assert_eq!(
                cm.iter().filter(|&&b| b).count(),
                packed_model_elems(&spec, &sm)
            );
        }
    }

    #[test]
    fn wire_bytes_include_bitmap() {
        let spec = tiny_spec();
        let sm = SubModel::full(&spec);
        assert_eq!(submodel_wire_bytes(&spec, &sm), 4 * 33 + 1);
    }

    #[test]
    fn flops_scale_with_dropping() {
        let spec = tiny_spec();
        let full = SubModel::full(&spec);
        let half = SubModel::from_kept_indices(&spec, &[vec![0, 1]]);
        let f_full = effective_flops_per_sample(&spec, &full);
        let f_half = effective_flops_per_sample(&spec, &half);
        assert_eq!(f_full, 56.0);
        // w1: 48 * 0.5 (cols) = 24 ; w2: 8 * 0.5 (rows) = 4
        assert_eq!(f_half, 28.0);
    }
}
