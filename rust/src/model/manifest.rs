//! Parsed form of `artifacts/manifest.json` — the contract with L2.
//!
//! The AOT pipeline (python/compile/aot.py) records everything the
//! coordinator must know about each lowered model variant: parameter
//! segments (name/shape/offset into the flat vector), which mask group
//! packs which axis of which parameter, argument orders of the train and
//! eval artifacts, data shapes and the paper's learning rate. The Rust
//! side never guesses — it parses this file or fails loudly.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// How one axis of a parameter packs under a mask group (see
/// `python/compile/model.py::AxisPack` for the authoritative semantics).
#[derive(Clone, Debug, PartialEq)]
pub struct AxisPack {
    pub group: String,
    pub count: usize,
    pub repeat: usize,
    pub fixed: usize,
}

impl AxisPack {
    pub fn full_extent(&self) -> usize {
        self.count * self.repeat + self.fixed
    }

    pub fn packed_extent(&self, kept: usize) -> usize {
        kept * self.repeat + self.fixed
    }
}

/// One parameter tensor's segment in the flat model vector.
#[derive(Clone, Debug)]
pub struct ParamSeg {
    pub name: String,
    pub shape: Vec<usize>,
    pub size: usize,
    pub offset: usize,
    pub trainable: bool,
    pub transmit: bool,
    /// Packing along the flattened leading extent (matmul rows).
    pub rows: Option<AxisPack>,
    /// Packing along the last axis (matmul cols / bias index).
    pub cols: Option<AxisPack>,
    pub flops_per_sample: f64,
}

impl ParamSeg {
    /// Flattened leading extent (= matmul rows; 1 for biases).
    pub fn rows_extent(&self) -> usize {
        if self.shape.len() <= 1 {
            1
        } else {
            self.shape[..self.shape.len() - 1].iter().product()
        }
    }

    pub fn cols_extent(&self) -> usize {
        *self.shape.last().unwrap_or(&1)
    }

    pub fn range(&self) -> std::ops::Range<usize> {
        self.offset..self.offset + self.size
    }
}

/// A droppable-unit group (conv filters / dense units / LSTM units).
#[derive(Clone, Debug)]
pub struct MaskGroup {
    pub name: String,
    pub size: usize,
    pub kind: String,
}

/// One lowered model variant.
#[derive(Clone, Debug)]
pub struct VariantSpec {
    pub name: String,
    pub kind: String,    // "cnn" | "lstm"
    pub dataset: String, // "femnist" | "shakespeare" | "sent140"
    pub lr: f32,
    pub batch_size: usize,
    pub num_batches: usize,
    pub classes: usize,
    pub vocab: usize, // 0 for image models
    pub input_shape: Vec<usize>,
    pub input_dtype: DType,
    pub num_params: usize,
    pub params: Vec<ParamSeg>,
    pub mask_groups: Vec<MaskGroup>,
    pub train_hlo: String,
    pub eval_hlo: String,
    pub init_params: String,
    pub train_args: Vec<String>,
    pub train_outputs: Vec<String>,
    pub eval_args: Vec<String>,
    pub eval_outputs: Vec<String>,
}

impl VariantSpec {
    pub fn param(&self, name: &str) -> Option<&ParamSeg> {
        self.params.iter().find(|p| p.name == name)
    }

    pub fn group_index(&self, name: &str) -> Option<usize> {
        self.mask_groups.iter().position(|g| g.name == name)
    }

    /// Total droppable units across all groups.
    pub fn total_units(&self) -> usize {
        self.mask_groups.iter().map(|g| g.size).sum()
    }

    /// Samples consumed per local epoch (one train artifact call).
    pub fn samples_per_round(&self) -> usize {
        self.batch_size * self.num_batches
    }

    /// Bytes of a full uncompressed transmissible model.
    pub fn transmit_bytes_full(&self) -> u64 {
        self.params
            .iter()
            .filter(|p| p.transmit)
            .map(|p| 4 * p.size as u64)
            .sum()
    }

    /// Order-sensitive FNV-1a fold of everything that determines the
    /// packed wire layout and the training geometry: parameter
    /// offsets/extents/flags, axis packs, mask-group sizes, batch
    /// shape. The transport handshake compares fingerprints so a
    /// coordinator and a remote client built from diverged configs
    /// fail loudly before the first round instead of decoding each
    /// other's payloads into garbage.
    pub fn layout_fingerprint(&self) -> u64 {
        fn axis_vals(spec: &VariantSpec, ap: &Option<AxisPack>, vals: &mut Vec<u64>) {
            match ap {
                None => vals.push(u64::MAX),
                Some(a) => {
                    vals.push(a.count as u64);
                    vals.push(a.repeat as u64);
                    vals.push(a.fixed as u64);
                    vals.push(spec.group_index(&a.group).unwrap_or(usize::MAX) as u64);
                }
            }
        }
        let mut vals: Vec<u64> = vec![
            self.num_params as u64,
            self.batch_size as u64,
            self.num_batches as u64,
            self.classes as u64,
            self.params.len() as u64,
        ];
        for seg in &self.params {
            vals.push(seg.offset as u64);
            vals.push(seg.size as u64);
            vals.push(seg.rows_extent() as u64);
            vals.push(seg.cols_extent() as u64);
            vals.push((seg.transmit as u64) | ((seg.trainable as u64) << 1));
            axis_vals(self, &seg.rows, &mut vals);
            axis_vals(self, &seg.cols, &mut vals);
        }
        vals.push(self.mask_groups.len() as u64);
        for g in &self.mask_groups {
            vals.push(g.size as u64);
        }
        crate::util::fnv1a_u64s(vals)
    }
}

/// Standalone kernel artifacts (L1 exercised directly from Rust).
#[derive(Clone, Debug)]
pub struct KernelArtifacts {
    pub masked_dense_hlo: String,
    pub masked_dense_dims: (usize, usize, usize),
    pub hadamard_hlo: String,
    pub hadamard_len: usize,
    pub hadamard_block: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub init_seed: u64,
    pub variants: BTreeMap<String, VariantSpec>,
    pub kernels: Option<KernelArtifacts>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let root = json::parse_file(&path)?;
        let mut variants = BTreeMap::new();
        let vmap = root
            .req("variants")?
            .as_obj()
            .ok_or_else(|| anyhow!("manifest: variants must be an object"))?;
        for (name, v) in vmap {
            variants.insert(
                name.clone(),
                parse_variant(v).with_context(|| format!("variant {name}"))?,
            );
        }
        let kernels = match root.get("kernels") {
            Some(k) if !k.is_null() => Some(parse_kernels(k)?),
            _ => None,
        };
        Ok(Manifest {
            dir: dir.to_path_buf(),
            init_seed: root
                .get("init_seed")
                .and_then(|j| j.as_f64())
                .unwrap_or(0.0) as u64,
            variants,
            kernels,
        })
    }

    pub fn variant(&self, name: &str) -> Result<&VariantSpec> {
        self.variants
            .get(name)
            .ok_or_else(|| anyhow!("manifest has no variant {name:?}; have {:?}",
                                   self.variants.keys().collect::<Vec<_>>()))
    }

    /// Read a variant's initial parameters (little-endian f32 file).
    pub fn load_init_params(&self, spec: &VariantSpec) -> Result<Vec<f32>> {
        let path = self.dir.join(&spec.init_params);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() != 4 * spec.num_params {
            bail!(
                "{}: expected {} bytes, found {}",
                path.display(),
                4 * spec.num_params,
                bytes.len()
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

fn get_usize(j: &Json, key: &str) -> Result<usize> {
    j.req(key)?
        .as_usize()
        .ok_or_else(|| anyhow!("{key}: expected number"))
}

fn get_str(j: &Json, key: &str) -> Result<String> {
    Ok(j.req(key)?
        .as_str()
        .ok_or_else(|| anyhow!("{key}: expected string"))?
        .to_string())
}

fn get_str_list(j: &Json, key: &str) -> Result<Vec<String>> {
    j.req(key)?
        .as_arr()
        .ok_or_else(|| anyhow!("{key}: expected array"))?
        .iter()
        .map(|v| {
            v.as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow!("{key}: expected string items"))
        })
        .collect()
}

fn parse_axis_pack(j: &Json) -> Result<Option<AxisPack>> {
    if j.is_null() {
        return Ok(None);
    }
    Ok(Some(AxisPack {
        group: get_str(j, "group")?,
        count: get_usize(j, "count")?,
        repeat: get_usize(j, "repeat")?,
        fixed: get_usize(j, "fixed")?,
    }))
}

fn parse_variant(v: &Json) -> Result<VariantSpec> {
    let params = v
        .req("params")?
        .as_arr()
        .ok_or_else(|| anyhow!("params: expected array"))?
        .iter()
        .map(|p| -> Result<ParamSeg> {
            Ok(ParamSeg {
                name: get_str(p, "name")?,
                shape: p
                    .req("shape")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("shape: expected array"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<_>>()?,
                size: get_usize(p, "size")?,
                offset: get_usize(p, "offset")?,
                trainable: p.req("trainable")?.as_bool().unwrap_or(true),
                transmit: p.req("transmit")?.as_bool().unwrap_or(true),
                rows: parse_axis_pack(p.req("rows")?)?,
                cols: parse_axis_pack(p.req("cols")?)?,
                flops_per_sample: p
                    .get("flops_per_sample")
                    .and_then(|f| f.as_f64())
                    .unwrap_or(0.0),
            })
        })
        .collect::<Result<Vec<_>>>()?;

    let mask_groups = v
        .req("mask_groups")?
        .as_arr()
        .ok_or_else(|| anyhow!("mask_groups: expected array"))?
        .iter()
        .map(|g| -> Result<MaskGroup> {
            Ok(MaskGroup {
                name: get_str(g, "name")?,
                size: get_usize(g, "size")?,
                kind: get_str(g, "kind")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;

    let input_dtype = match get_str(v, "input_dtype")?.as_str() {
        "f32" => DType::F32,
        "i32" => DType::I32,
        other => bail!("unknown input_dtype {other:?}"),
    };

    let spec = VariantSpec {
        name: get_str(v, "name")?,
        kind: get_str(v, "kind")?,
        dataset: get_str(v, "dataset")?,
        lr: v.req("lr")?.as_f64().ok_or_else(|| anyhow!("lr"))? as f32,
        batch_size: get_usize(v, "batch_size")?,
        num_batches: get_usize(v, "num_batches")?,
        classes: get_usize(v, "classes")?,
        vocab: v
            .get("cfg")
            .and_then(|c| c.get("vocab"))
            .and_then(|x| x.as_usize())
            .unwrap_or(0),
        input_shape: v
            .req("input_shape")?
            .as_arr()
            .ok_or_else(|| anyhow!("input_shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<_>>()?,
        input_dtype,
        num_params: get_usize(v, "num_params")?,
        params,
        mask_groups,
        train_hlo: get_str(v, "train_hlo")?,
        eval_hlo: get_str(v, "eval_hlo")?,
        init_params: get_str(v, "init_params")?,
        train_args: get_str_list(v, "train_args")?,
        train_outputs: get_str_list(v, "train_outputs")?,
        eval_args: get_str_list(v, "eval_args")?,
        eval_outputs: get_str_list(v, "eval_outputs")?,
    };

    // Structural validation: fail at load, not mid-experiment.
    let mut off = 0;
    for p in &spec.params {
        if p.offset != off {
            bail!("param {} offset {} != expected {}", p.name, p.offset, off);
        }
        let numel: usize = p.shape.iter().product();
        if numel != p.size {
            bail!("param {} size {} != shape product {}", p.name, p.size, numel);
        }
        off += p.size;
        for (ap, extent) in [
            (&p.rows, p.rows_extent()),
            (&p.cols, p.cols_extent()),
        ] {
            if let Some(ap) = ap {
                if spec.mask_groups.iter().all(|g| g.name != ap.group) {
                    bail!("param {} references unknown group {}", p.name, ap.group);
                }
                if ap.full_extent() != extent {
                    bail!(
                        "param {}: pack extent {} != axis extent {}",
                        p.name,
                        ap.full_extent(),
                        extent
                    );
                }
            }
        }
    }
    if off != spec.num_params {
        bail!("num_params {} != sum of segments {}", spec.num_params, off);
    }
    Ok(spec)
}

fn parse_kernels(k: &Json) -> Result<KernelArtifacts> {
    let md = k.req("masked_dense")?;
    let hr = k.req("hadamard_roundtrip")?;
    Ok(KernelArtifacts {
        masked_dense_hlo: get_str(md, "hlo")?,
        masked_dense_dims: (
            get_usize(md, "m")?,
            get_usize(md, "k")?,
            get_usize(md, "n")?,
        ),
        hadamard_hlo: get_str(hr, "hlo")?,
        hadamard_len: get_usize(hr, "length")?,
        hadamard_block: get_usize(hr, "block")?,
    })
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// A miniature but structurally-valid manifest for unit tests.
    pub(crate) fn tiny_manifest_json() -> String {
        r#"{
  "format_version": 1,
  "init_seed": 0,
  "kernels": null,
  "variants": {
   "tiny": {
    "name": "tiny", "kind": "cnn", "dataset": "femnist",
    "cfg": {"vocab": 0},
    "lr": 0.1, "batch_size": 2, "num_batches": 3, "classes": 4,
    "input_shape": [6], "input_dtype": "f32", "num_params": 34,
    "params": [
      {"name": "w1", "shape": [6, 4], "size": 24, "offset": 0,
       "trainable": true, "transmit": true,
       "rows": null, "cols": {"group": "h", "count": 4, "repeat": 1, "fixed": 0},
       "flops_per_sample": 48},
      {"name": "b1", "shape": [4], "size": 4, "offset": 24,
       "trainable": true, "transmit": true,
       "rows": null, "cols": {"group": "h", "count": 4, "repeat": 1, "fixed": 0},
       "flops_per_sample": 0},
      {"name": "w2", "shape": [4, 1], "size": 4, "offset": 28,
       "trainable": true, "transmit": true,
       "rows": {"group": "h", "count": 4, "repeat": 1, "fixed": 0}, "cols": null,
       "flops_per_sample": 8},
      {"name": "b2", "shape": [1], "size": 1, "offset": 32,
       "trainable": true, "transmit": true, "rows": null, "cols": null,
       "flops_per_sample": 0},
      {"name": "frozen", "shape": [1], "size": 1, "offset": 33,
       "trainable": false, "transmit": false, "rows": null, "cols": null,
       "flops_per_sample": 0}
    ],
    "mask_groups": [{"name": "h", "size": 4, "kind": "dense_units"}],
    "train_hlo": "train_tiny.hlo.txt", "eval_hlo": "eval_tiny.hlo.txt",
    "init_params": "tiny.init.bin",
    "train_args": ["w1","b1","w2","b2","frozen","mask:h","xs","ys","lr"],
    "train_outputs": ["w1","b1","w2","b2","frozen","mean_loss"],
    "eval_args": ["w1","b1","w2","b2","frozen","x","y"],
    "eval_outputs": ["loss_sum","correct"]
   }
  }
}"#
        .to_string()
    }

    pub(crate) fn tiny_spec() -> VariantSpec {
        let root = crate::util::json::parse(&tiny_manifest_json()).unwrap();
        parse_variant(root.get("variants").unwrap().get("tiny").unwrap()).unwrap()
    }

    #[test]
    fn parses_tiny_manifest() {
        let spec = tiny_spec();
        assert_eq!(spec.num_params, 34);
        assert_eq!(spec.params.len(), 5);
        assert_eq!(spec.mask_groups.len(), 1);
        assert_eq!(spec.param("w2").unwrap().rows.as_ref().unwrap().group, "h");
        assert_eq!(spec.transmit_bytes_full(), 4 * 33);
        assert_eq!(spec.samples_per_round(), 6);
        assert_eq!(spec.total_units(), 4);
    }

    #[test]
    fn layout_fingerprint_is_stable_and_layout_sensitive() {
        let a = tiny_spec();
        assert_eq!(a.layout_fingerprint(), tiny_spec().layout_fingerprint());
        // A flipped transmit flag changes the wire layout — and the
        // fingerprint.
        let mut b = tiny_spec();
        let i = b.params.iter().position(|p| p.transmit).unwrap();
        b.params[i].transmit = false;
        assert_ne!(a.layout_fingerprint(), b.layout_fingerprint());
        // Different batch geometry also moves it (epoch draws differ).
        let mut c = tiny_spec();
        c.batch_size += 1;
        assert_ne!(a.layout_fingerprint(), c.layout_fingerprint());
    }

    #[test]
    fn rejects_bad_offsets() {
        let mut text = tiny_manifest_json();
        text = text.replace("\"offset\": 24", "\"offset\": 25");
        let root = crate::util::json::parse(&text).unwrap();
        let res = parse_variant(root.get("variants").unwrap().get("tiny").unwrap());
        assert!(res.is_err());
    }

    #[test]
    fn rejects_unknown_group() {
        let text = tiny_manifest_json().replace("\"group\": \"h\"", "\"group\": \"zz\"");
        let root = crate::util::json::parse(&text).unwrap();
        assert!(parse_variant(root.get("variants").unwrap().get("tiny").unwrap()).is_err());
    }

    #[test]
    fn loads_real_manifest_if_built() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this checkout
        }
        let man = Manifest::load(&dir).unwrap();
        assert!(man.variants.contains_key("femnist_small"));
        for spec in man.variants.values() {
            let init = man.load_init_params(spec).unwrap();
            assert_eq!(init.len(), spec.num_params);
            assert!(init.iter().all(|v| v.is_finite()));
        }
        assert!(man.kernels.is_some());
    }
}
