//! Model metadata layer: the manifest contract with L2, sub-model
//! representation, packing/byte-accounting and FLOPs scaling.

pub mod manifest;
pub mod packing;
pub mod submodel;

pub use manifest::{AxisPack, DType, Manifest, MaskGroup, ParamSeg, VariantSpec};
pub use submodel::SubModel;
