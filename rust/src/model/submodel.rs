//! Sub-model representation: which units of each mask group are kept.
//!
//! A `SubModel` is the server-side object the AFD strategies produce
//! each round (paper Fig. 1 step 1). It converts to the f32 masks the
//! train artifact consumes, and drives both the packing byte-accounting
//! and the FLOPs scaling of the compute-time simulation.

use crate::model::manifest::VariantSpec;

#[derive(Clone, Debug, PartialEq)]
pub struct SubModel {
    /// keep[g][u] — indexed like `spec.mask_groups`.
    pub keep: Vec<Vec<bool>>,
    /// The 0/1 f32 masks derived from `keep`, built once at
    /// construction so the training hot path borrows instead of
    /// re-materializing them every epoch.
    masks: Vec<Vec<f32>>,
}

fn masks_from_keep(keep: &[Vec<bool>]) -> Vec<Vec<f32>> {
    keep.iter()
        .map(|g| g.iter().map(|&k| if k { 1.0 } else { 0.0 }).collect())
        .collect()
}

impl SubModel {
    /// Build from the kept-unit bitsets (masks derived eagerly).
    pub fn from_keep(keep: Vec<Vec<bool>>) -> SubModel {
        let masks = masks_from_keep(&keep);
        SubModel { keep, masks }
    }

    /// Full model (nothing dropped).
    pub fn full(spec: &VariantSpec) -> SubModel {
        SubModel::from_keep(spec.mask_groups.iter().map(|g| vec![true; g.size]).collect())
    }

    /// From kept-index lists (validated).
    pub fn from_kept_indices(spec: &VariantSpec, kept: &[Vec<usize>]) -> SubModel {
        assert_eq!(kept.len(), spec.mask_groups.len());
        let mut keep: Vec<Vec<bool>> = spec
            .mask_groups
            .iter()
            .map(|g| vec![false; g.size])
            .collect();
        for (g, idxs) in kept.iter().enumerate() {
            for &u in idxs {
                assert!(u < keep[g].len(), "unit {u} out of range for group {g}");
                keep[g][u] = true;
            }
        }
        SubModel::from_keep(keep)
    }

    /// Kept-unit indices per group (ascending).
    pub fn kept_indices(&self) -> Vec<Vec<usize>> {
        self.keep
            .iter()
            .map(|g| {
                g.iter()
                    .enumerate()
                    .filter_map(|(i, &k)| if k { Some(i) } else { None })
                    .collect()
            })
            .collect()
    }

    pub fn kept_counts(&self) -> Vec<usize> {
        self.keep
            .iter()
            .map(|g| g.iter().filter(|&&k| k).count())
            .collect()
    }

    /// The 0/1 f32 masks fed to the train artifact, per group
    /// (precomputed at construction; borrowing, not allocating).
    pub fn masks_f32(&self) -> &[Vec<f32>] {
        &self.masks
    }

    /// Kept count for a named group.
    pub fn kept_for(&self, spec: &VariantSpec, group: &str) -> usize {
        match spec.group_index(group) {
            Some(g) => self.keep[g].iter().filter(|&&k| k).count(),
            None => 0,
        }
    }

    /// Fraction of all droppable units kept (diagnostics).
    pub fn keep_fraction(&self) -> f64 {
        let total: usize = self.keep.iter().map(|g| g.len()).sum();
        if total == 0 {
            return 1.0;
        }
        let kept: usize = self.kept_counts().iter().sum();
        kept as f64 / total as f64
    }

    pub fn is_full(&self) -> bool {
        self.keep.iter().all(|g| g.iter().all(|&k| k))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::tiny_spec;

    #[test]
    fn full_keeps_everything() {
        let spec = tiny_spec();
        let sm = SubModel::full(&spec);
        assert!(sm.is_full());
        assert_eq!(sm.kept_counts(), vec![4]);
        assert_eq!(sm.keep_fraction(), 1.0);
    }

    #[test]
    fn from_indices_roundtrip() {
        let spec = tiny_spec();
        let sm = SubModel::from_kept_indices(&spec, &[vec![0, 2]]);
        assert_eq!(sm.kept_indices(), vec![vec![0, 2]]);
        assert_eq!(sm.kept_counts(), vec![2]);
        assert_eq!(sm.masks_f32(), vec![vec![1.0, 0.0, 1.0, 0.0]]);
        assert_eq!(sm.keep_fraction(), 0.5);
        assert!(!sm.is_full());
        assert_eq!(sm.kept_for(&spec, "h"), 2);
        assert_eq!(sm.kept_for(&spec, "nope"), 0);
    }

    #[test]
    #[should_panic]
    fn out_of_range_unit_panics() {
        let spec = tiny_spec();
        SubModel::from_kept_indices(&spec, &[vec![9]]);
    }
}
