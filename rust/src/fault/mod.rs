//! Deterministic fault injection.
//!
//! Failures are a *sweepable input* to the engine, the same way seeds
//! are: whether a fault fires at a given seam is a pure function of
//! `(fault_seed, site, a, b)` where `(a, b)` are site-specific keys
//! (typically round and client, or client and participation count).
//! Two runs with the same config and the same fault plan inject the
//! same faults at the same places — so every fault scenario is
//! reproducible and every fault class can be pinned to one of exactly
//! two buckets:
//!
//! * **masked** — the run is bit-identical to the fault-free run
//!   (JSONL records + model hash), because a recovery path absorbed
//!   the fault (partial-write resume, reconnect + `StateSync` replay,
//!   duplicate-frame drop);
//! * **typed loss** — the run completes with a nonzero `lost` /
//!   `quarantined` count or a diagnosable `Err`, never a panic and
//!   never a silently different result.
//!
//! The gate mirrors `obs`: a single relaxed atomic load
//! ([`enabled`]) guards every site, so with the default empty plan
//! the fault machinery costs one predictable branch per seam and the
//! warm client round stays zero-alloc. Unlike `obs` there is no cargo
//! feature — the plan is a pure runtime input (`--fault-plan` /
//! `--fault-seed`, or the `fault_*` config keys).
//!
//! Clients that keep faulting are quarantined after
//! `fault_quarantine_after` faulted rounds: the scheduler stops
//! selecting them and reports the count as the `quarantined` column.
//!
//! See `rust/src/fault/README.md` for the site taxonomy and the plan
//! grammar.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::Once;

/// Named injection seams. The discriminant indexes
/// `obs::metrics::FAULTS_INJECTED` and the rate table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Site {
    /// Socket write fails mid-flush (TCP) / dispatch never reaches the
    /// client (loopback).
    SockWrite = 0,
    /// Socket read fails (TCP) / reply is dropped on the way back
    /// (loopback).
    SockRead = 1,
    /// `write(2)` accepts only part of the buffer. Masked: the flush
    /// loop resumes mid-buffer.
    PartialWrite = 2,
    /// A frame is corrupted in flight, upstream of the CRC check.
    FrameCorrupt = 3,
    /// A frame is delayed past the round deadline.
    FrameDelay = 4,
    /// A frame is delivered twice. Masked: parsing a frame is
    /// idempotent and the pipeline matcher ignores stale duplicates.
    FrameDup = 5,
    /// A `ResidualStore` spill write is truncated short of the record.
    SpillTruncate = 6,
    /// A spilled record is corrupted on disk before rehydration.
    SpillCorrupt = 7,
    /// A worker-pool training job panics.
    WorkerPanic = 8,
    /// A client's clock stalls past the round deadline.
    ClockStall = 9,
}

/// Number of fault sites; length of the per-site rate and counter
/// tables.
pub const SITE_COUNT: usize = 10;

/// Every site, in discriminant order.
pub const ALL_SITES: [Site; SITE_COUNT] = [
    Site::SockWrite,
    Site::SockRead,
    Site::PartialWrite,
    Site::FrameCorrupt,
    Site::FrameDelay,
    Site::FrameDup,
    Site::SpillTruncate,
    Site::SpillCorrupt,
    Site::WorkerPanic,
    Site::ClockStall,
];

impl Site {
    /// Stable snake_case name used in the plan grammar and stats keys.
    pub fn name(self) -> &'static str {
        match self {
            Site::SockWrite => "sock_write",
            Site::SockRead => "sock_read",
            Site::PartialWrite => "partial_write",
            Site::FrameCorrupt => "frame_corrupt",
            Site::FrameDelay => "frame_delay",
            Site::FrameDup => "frame_dup",
            Site::SpillTruncate => "spill_truncate",
            Site::SpillCorrupt => "spill_corrupt",
            Site::WorkerPanic => "worker_panic",
            Site::ClockStall => "clock_stall",
        }
    }

    /// Inverse of [`Site::name`].
    pub fn from_name(name: &str) -> Option<Site> {
        ALL_SITES.iter().copied().find(|s| s.name() == name)
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static SEED: AtomicU64 = AtomicU64::new(0);
static QUARANTINE_AFTER: AtomicU32 = AtomicU32::new(3);

// Per-site fire rate in parts-per-million. Repeat-initializer idiom:
// the const is a template, each array slot gets a fresh atomic.
#[allow(clippy::declare_interior_mutable_const)]
const RATE_SLOT: AtomicU32 = AtomicU32::new(0);
static RATE_PPM: [AtomicU32; SITE_COUNT] = [RATE_SLOT; SITE_COUNT];

/// True when a fault plan with at least one nonzero rate is installed.
/// One relaxed load; every injection seam checks this first, so the
/// default (no plan) costs a single predictable branch.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// How many faulted rounds a client survives before quarantine.
#[inline]
pub fn quarantine_after() -> u32 {
    QUARANTINE_AFTER.load(Ordering::Relaxed)
}

/// splitmix64 finalizer — the pure mixing core of the plan function.
#[inline]
fn mix(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    x
}

/// Pure plan function: does `site` fire at keys `(a, b)` under
/// `(seed, ppm)`? No global state — unit-testable and stable across
/// platforms.
#[inline]
pub fn decide(seed: u64, ppm: u32, site: Site, a: u64, b: u64) -> bool {
    if ppm == 0 {
        return false;
    }
    let mut h = mix(seed ^ (0xfa17_0000 + site as u64));
    h = mix(h ^ a.wrapping_mul(0x9e37_79b9_7f4a_7c15));
    h = mix(h ^ b.rotate_left(32));
    (h % 1_000_000) < ppm as u64
}

/// Deterministic hash of `(seed, site, a, b)` — used by sites that
/// need a reproducible auxiliary value (e.g. which byte to corrupt).
#[inline]
pub fn derive(site: Site, a: u64, b: u64) -> u64 {
    let seed = SEED.load(Ordering::Relaxed);
    let mut h = mix(seed ^ (0xfa17_1000 + site as u64));
    h = mix(h ^ a.wrapping_mul(0x9e37_79b9_7f4a_7c15));
    mix(h ^ b.rotate_left(32))
}

/// Does `site` fire at keys `(a, b)` under the installed plan?
/// Increments the per-site `FAULTS_INJECTED` counter when it does
/// (unconditionally — fault accounting is part of the run's output,
/// not of the optional trace).
#[inline]
pub fn should(site: Site, a: u64, b: u64) -> bool {
    if !enabled() {
        return false;
    }
    let seed = SEED.load(Ordering::Relaxed);
    let ppm = RATE_PPM[site as usize].load(Ordering::Relaxed);
    let fire = decide(seed, ppm, site, a, b);
    if fire {
        crate::obs::metrics::FAULTS_INJECTED[site as usize].incr();
        // Instant on the merged timeline (no-op unless tracing is on);
        // carries the site discriminant and the first plan key.
        crate::obs::span::mark(crate::obs::Stage::FaultMark, site as u64, a);
    }
    fire
}

/// Parse a plan string into per-site ppm rates. Grammar:
/// `site:prob[,site:prob...]` with `prob` in `[0, 1]`, or `all:prob`
/// to set every site. Empty string → all zeros (disabled).
fn parse_plan(plan: &str) -> anyhow::Result<[u32; SITE_COUNT]> {
    let mut rates = [0u32; SITE_COUNT];
    let plan = plan.trim();
    if plan.is_empty() {
        return Ok(rates);
    }
    for part in plan.split(',') {
        let part = part.trim();
        let (name, prob) = part
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("fault plan entry `{part}`: expected `site:prob`"))?;
        let p: f64 = prob
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("fault plan entry `{part}`: bad probability"))?;
        if !(0.0..=1.0).contains(&p) {
            anyhow::bail!("fault plan entry `{part}`: probability outside [0, 1]");
        }
        let ppm = (p * 1_000_000.0).round() as u32;
        let name = name.trim();
        if name == "all" {
            for r in rates.iter_mut() {
                *r = ppm;
            }
        } else {
            let site = Site::from_name(name).ok_or_else(|| {
                anyhow::anyhow!(
                    "fault plan entry `{part}`: unknown site `{name}` (see fault/README.md)"
                )
            })?;
            rates[site as usize] = ppm;
        }
    }
    Ok(rates)
}

/// Quiet the default panic hook for injected worker panics: they are
/// expected, caught by the engine, and classified as typed losses —
/// their backtraces would drown real diagnostics in a chaos run. Any
/// other panic still prints through the previous hook.
fn install_panic_filter() {
    static HOOK: Once = Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| info.payload().downcast_ref::<&str>().copied())
                .is_some_and(|s| s.starts_with("injected fault:"));
            if !injected {
                prev(info);
            }
        }));
    });
}

/// Install a fault plan process-wide. Parses fully before committing
/// anything, so a bad plan leaves the previous state untouched.
/// Enables injection iff any rate is nonzero.
pub fn install(plan: &str, seed: u64, quarantine_after: u32) -> anyhow::Result<()> {
    let rates = parse_plan(plan)?;
    if quarantine_after == 0 {
        anyhow::bail!("fault_quarantine_after must be >= 1");
    }
    install_panic_filter();
    SEED.store(seed, Ordering::Relaxed);
    QUARANTINE_AFTER.store(quarantine_after, Ordering::Relaxed);
    let mut any = false;
    for (slot, &ppm) in RATE_PPM.iter().zip(rates.iter()) {
        slot.store(ppm, Ordering::Relaxed);
        any |= ppm > 0;
    }
    ENABLED.store(any, Ordering::Relaxed);
    Ok(())
}

/// Disable injection and zero all rates. Tests call this in teardown.
pub fn reset() {
    ENABLED.store(false, Ordering::Relaxed);
    SEED.store(0, Ordering::Relaxed);
    QUARANTINE_AFTER.store(3, Ordering::Relaxed);
    for slot in RATE_PPM.iter() {
        slot.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    // These tests exercise only the pure functions — they never flip
    // the global ENABLED flag, because lib unit tests run in parallel
    // in one process and an active plan would leak into unrelated
    // tests. Integration tests (`tests/fault_injection.rs`) own the
    // global state and serialize on a mutex.
    use super::*;

    #[test]
    fn site_names_roundtrip() {
        for s in ALL_SITES {
            assert_eq!(Site::from_name(s.name()), Some(s));
        }
        assert_eq!(Site::from_name("nope"), None);
    }

    #[test]
    fn decide_is_deterministic_and_seed_sensitive() {
        let a = decide(7, 500_000, Site::FrameCorrupt, 3, 11);
        let b = decide(7, 500_000, Site::FrameCorrupt, 3, 11);
        assert_eq!(a, b);
        // Different seeds must disagree somewhere on a small grid.
        let mut differs = false;
        for r in 0..16u64 {
            for c in 0..16u64 {
                if decide(1, 500_000, Site::SockRead, r, c)
                    != decide(2, 500_000, Site::SockRead, r, c)
                {
                    differs = true;
                }
            }
        }
        assert!(differs);
    }

    #[test]
    fn decide_rate_edges() {
        for k in 0..64u64 {
            assert!(!decide(9, 0, Site::WorkerPanic, k, k));
            assert!(decide(9, 1_000_000, Site::WorkerPanic, k, k));
        }
    }

    #[test]
    fn decide_rate_is_roughly_calibrated() {
        let mut fired = 0usize;
        let n = 10_000u64;
        for k in 0..n {
            if decide(42, 100_000, Site::SpillCorrupt, k, 0) {
                fired += 1;
            }
        }
        // 10% nominal; allow a generous band.
        assert!(fired > 500 && fired < 1500, "fired {fired}/{n}");
    }

    #[test]
    fn parse_plan_grammar() {
        let r = parse_plan("frame_corrupt:0.25, clock_stall:0.5").unwrap();
        assert_eq!(r[Site::FrameCorrupt as usize], 250_000);
        assert_eq!(r[Site::ClockStall as usize], 500_000);
        assert_eq!(r[Site::SockWrite as usize], 0);

        let r = parse_plan("all:0.01").unwrap();
        for v in r {
            assert_eq!(v, 10_000);
        }

        assert_eq!(parse_plan("").unwrap(), [0; SITE_COUNT]);
        assert!(parse_plan("bogus:0.5").is_err());
        assert!(parse_plan("sock_read:1.5").is_err());
        assert!(parse_plan("sock_read").is_err());
    }
}
