//! The client side of one federation round, as a pure function of
//! frames: decode what the wire delivered, train locally, encode the
//! update. Both transports run exactly this code — the loopback
//! in-process path calls it directly and the remote `afd client`
//! process calls it from its socket loop — which is what makes the two
//! bit-identical.
//!
//! ## Off-sub-model independence
//!
//! [`ClientEnv::base_params`] is the device-resident full parameter
//! vector the offered sub-model lands on. Its off-sub-model values
//! never influence the update: masked training leaves dropped
//! coordinates bit-untouched, the raw uplink packs only sub-model
//! coordinates, and the DGC delta is exactly zero wherever
//! `model == start`. So the loopback hands the server's global in
//! (matching the pre-transport pipeline bit-for-bit) while a remote
//! client keeps a zeros vector — and both produce identical update
//! frames (`rust/tests/transport_e2e.rs::client_base_params_do_not_
//! affect_update`).
//!
//! ## Scratch
//!
//! Every buffer is drawn from the [`Workspace`] arena (f32 scratch,
//! byte sinks), so a warm client execution allocates nothing — the
//! transport layer extends the PR 4 zero-alloc contract instead of
//! breaking it.

use anyhow::Result;

use crate::compression::dgc::DgcState;
use crate::compression::DenseCodec;
use crate::model::manifest::VariantSpec;
use crate::model::packing::PackPlan;
use crate::model::submodel::SubModel;
use crate::runtime::{EpochData, ModelRuntime};
use crate::tensor::kernels::Workspace;
use crate::transport::frame;

/// Everything the client half of a round needs, supplied by whichever
/// process hosts the device state (the engine job in-process, the
/// `afd client` loop remotely).
pub struct ClientEnv<'a> {
    pub spec: &'a VariantSpec,
    pub runtime: &'a dyn ModelRuntime,
    pub codec: &'a dyn DenseCodec,
    /// Device-resident full parameter vector (see module docs: its
    /// off-sub-model values cannot influence the update).
    pub base_params: &'a [f32],
    pub data: &'a EpochData,
    /// Persistent DGC accumulators (`None` ⇒ raw packed uplink).
    pub dgc: Option<&'a mut DgcState>,
    /// The offered sub-model + its pack plan, resolved by the host
    /// (the coordinator's cache in-process, the client's own cache
    /// remotely — plans are pure functions of `(spec, submodel)`).
    pub submodel: &'a SubModel,
    pub plan: &'a PackPlan,
    /// Local sample count reported on the uplink (the FedAvg weight).
    pub num_samples: u32,
    pub ws: &'a mut Workspace,
}

/// Execute the client half of one round: decode the `ModelDown` codec
/// payload, train one local epoch, and write the complete `UpdateUp`
/// frame into `reply` (cleared first; capacity reused).
///
/// `round`/`client`/`seed`/`lr` come from the parsed `RoundOffer`;
/// `model_payload` is the parsed `ModelDown` codec body.
pub fn client_execute(
    round: u32,
    client: u32,
    seed: u64,
    lr: f32,
    model_payload: &[u8],
    env: &mut ClientEnv<'_>,
    reply: &mut Vec<u8>,
) -> Result<()> {
    let n = env.spec.num_params;
    anyhow::ensure!(
        env.base_params.len() == n,
        "client {client}: base params hold {} values, spec has {n}",
        env.base_params.len()
    );
    // Validate the codec body's self-declared geometry before decoding
    // so a mis-matched (but CRC-valid) payload errors instead of
    // panicking inside the codec.
    anyhow::ensure!(
        model_payload.len() >= 4,
        "client {client} round {round}: ModelDown body is {} bytes (needs ≥ 4)",
        model_payload.len()
    );
    let declared = u32::from_le_bytes(model_payload[0..4].try_into().unwrap()) as usize;
    anyhow::ensure!(
        declared == env.plan.packed_len(),
        "client {client} round {round}: downlink payload declares {declared} values, \
         the offered sub-model packs {} — config/codec mismatch",
        env.plan.packed_len()
    );
    let want_len = env.codec.wire_len(declared);
    anyhow::ensure!(
        model_payload.len() as u64 == want_len,
        "client {client} round {round}: ModelDown body is {} bytes, codec {} \
         needs {want_len} for {declared} values",
        model_payload.len(),
        env.codec.name()
    );

    let ws = &mut *env.ws;

    // ---- Downlink: decode → land on the device parameter vector -----
    let mut decoded = ws.take_uncleared(env.plan.packed_len());
    env.codec.decode_slice_into(model_payload, seed, ws, &mut decoded);
    let mut start = ws.take_uncleared(n);
    start.copy_from_slice(env.base_params);
    {
        let _sp = crate::obs::span_ab(crate::obs::Stage::Unpack, round as u64, client as u64);
        env.plan.unpack_from(&decoded, &mut start);
    }
    ws.give(decoded);

    // ---- Local training (one epoch, in place) ------------------------
    let mut model = ws.take_uncleared(n);
    model.copy_from_slice(&start);
    let masks = env.submodel.masks_f32();
    let loss = env.runtime.train_epoch_in(ws, &mut model, masks, env.data, lr)?;

    // ---- Uplink: encode the update frame -----------------------------
    reply.clear();
    match env.dgc.as_deref_mut() {
        Some(st) => {
            // Full-coordinate delta (zero off-sub-model; residuals
            // from earlier rounds may surface — genuine DGC
            // accumulation behaviour).
            let mut delta = ws.take_uncleared(n);
            crate::tensor::sub(&model, &start, &mut delta);
            let mut varint = ws.take_bytes();
            let mut msg = ws.take_bytes();
            st.compress_into(&delta, &mut varint, &mut msg);
            ws.give(delta);
            ws.give_bytes(varint);
            let enc_sp =
                crate::obs::span_ab(crate::obs::Stage::FrameEncode, round as u64, client as u64);
            let base = frame::begin_update_up(
                reply,
                round,
                client,
                env.num_samples,
                loss,
                frame::UPDATE_DGC,
            );
            reply.extend_from_slice(&msg);
            frame::end_frame(reply, base);
            drop(enc_sp);
            ws.give_bytes(msg);
        }
        None => {
            let mut packed = ws.take_uncleared(env.plan.packed_len());
            {
                let _sp = crate::obs::span_ab(crate::obs::Stage::Pack, round as u64, client as u64);
                env.plan.pack_into(&model, &mut packed);
            }
            let enc_sp =
                crate::obs::span_ab(crate::obs::Stage::FrameEncode, round as u64, client as u64);
            let base = frame::begin_update_up(
                reply,
                round,
                client,
                env.num_samples,
                loss,
                frame::UPDATE_RAW,
            );
            reply.extend_from_slice(&(packed.len() as u32).to_le_bytes());
            for v in packed.iter() {
                reply.extend_from_slice(&v.to_le_bytes());
            }
            frame::end_frame(reply, base);
            drop(enc_sp);
            ws.give(packed);
        }
    }
    ws.give(start);
    ws.give(model);
    Ok(())
}
