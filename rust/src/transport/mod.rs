//! Wire protocol + transport layer: the federation conversation as
//! framed bytes, runnable in-process or over real sockets.
//!
//! Until this layer existed the repo's codecs produced real payloads
//! but nothing ever *framed* them: there was no message format, no
//! transport, and no way to split the coordinator from its clients
//! across processes. This module closes that gap:
//!
//! * [`frame`] — the versioned, CRC32-checked, length-prefixed binary
//!   frame format for the whole conversation (`RoundOffer`,
//!   `ModelDown`, `UpdateUp`, `Ack`/`Cut`, the
//!   `Hello`/`Config`/`Ready`/`Bye` session envelope, `StateSync`
//!   resume records, and the `Telemetry` side channel shipping remote
//!   span/counter/histogram snapshots home);
//! * [`client_round`] — the client side of one round as a pure
//!   function of frames ([`client_round::client_execute`]): decode the
//!   offered sub-model and payload, train locally, encode the update.
//!   Shared verbatim by the in-process and remote paths, which is what
//!   makes them bit-identical;
//! * [`loopback`] — the in-process [`Transport`]: the engine path the
//!   experiments always ran, now speaking frames;
//! * [`tcp`] — the real `std::net` transport: a coordinator process
//!   (`afd serve`) drives a swarm of client processes (`afd client`)
//!   over TCP. One coordinator thread multiplexes every socket with
//!   readiness-based non-blocking I/O; offers pipeline (several
//!   in-flight rounds per connection, matched by `(round, client)`);
//!   `Hello` carries a session token so a restarted client process
//!   resumes its open rounds; and a dead or timed-out connection
//!   converts its in-flight clients into policy-visible losses
//!   ([`RoundTripStatus::Lost`]) instead of ending the run.
//!
//! ## The conversation
//!
//! ```text
//! session:   client ── Hello(token) ─▶ server ── Config(token) ─▶ client ── Ready ─▶ server
//! per round: server ── [StateSync] ‖ RoundOffer ‖ ModelDown ─▶ client
//!            client ── UpdateUp [‖ Telemetry] ─▶ server
//!            server ── Ack (aggregated) | Cut (discarded) ─▶ client
//! shutdown:  server ── Bye ─▶ client
//! ```
//!
//! The optional `Telemetry` frame (wire v3, tracing-enabled clients
//! only) is consumed out-of-band by the coordinator: it never matches
//! an open round and its bytes are accounted in `TELEMETRY_BYTES`
//! rather than `RoundRecord`, so arming telemetry cannot perturb
//! results (`rust/tests/obs_distributed.rs`).
//!
//! `Ack`/`Cut` carry the round-closing decision to the device: a DGC
//! client clears sent coordinates from its accumulators when it
//! uploads, which is only correct if the upload is aggregated — `Cut`
//! tells it to roll the snapshot back (the engine performs the same
//! rollback on its host-side state).
//!
//! ## Bit-identity contract
//!
//! The transport can never change results, only where they run: a
//! fixed-seed experiment produces byte-identical model parameters,
//! losses and per-round byte counts over [`loopback::Loopback`] and
//! over [`tcp::TcpTransport`] (`rust/tests/transport_e2e.rs`, plus the
//! CI socket smoke). This holds because both ends of the conversation
//! run [`client_round::client_execute`] on identical frame bytes, all
//! RNG is derived from the config seed on both sides, and a client's
//! update is independent of its off-sub-model parameter values
//! (masked training leaves them untouched and deltas are zero there —
//! asserted by `client_base_params_do_not_affect_update`).
//!
//! ## Byte accounting
//!
//! `RoundRecord::{down,up}_bytes` are **measured wire bytes** — the
//! exact framed lengths a socket carries, control frames included —
//! and `{down,up}_payload_bytes` are the codec payloads alone, so the
//! protocol's framing overhead is visible next to the codec savings
//! (`metrics::render_table`'s Framing column). The network simulator
//! charges link time on the wire numbers.
//!
//! See `rust/src/transport/README.md` for the frame grammar and the
//! zero-allocation scratch contract.

pub mod client_round;
pub mod frame;
pub mod loopback;
pub mod tcp;

pub use client_round::{client_execute, ClientEnv};
pub use loopback::Loopback;

use anyhow::Result;

/// Why a round trip failed to complete.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LossReason {
    /// The connection (or a pending reconnect) exceeded the configured
    /// I/O timeout.
    Timeout,
    /// The connection died and session resume was off (or the client
    /// was dispatched to a connection that is currently vacant).
    Disconnected,
}

/// Outcome of [`Transport::round_trip`]: either the update frame
/// arrived in `reply`, or the client was lost in transit and the
/// scheduler should treat it as a cut.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundTripStatus {
    Delivered,
    Lost(LossReason),
}

/// The complete mutable remainder of one logical client's state,
/// captured by the engine *before* a round mutates it — exactly the
/// residual store's spill record (RNG position, participation count,
/// DGC residuals; everything else derives from `(seed, id)`). A
/// resuming transport ships this as a `StateSync` frame so a restarted
/// client process rejoins bit-exactly.
#[derive(Clone, Debug, Default)]
pub struct StateSyncSnapshot {
    pub client: u32,
    pub participations: u64,
    pub rng_state: u128,
    pub rng_inc: u128,
    pub dgc_u: Vec<f32>,
    pub dgc_v: Vec<f32>,
}

/// One federation transport: delivers a round's frames to a logical
/// client and returns its update frame. Implementations decide *where*
/// the client computation happens — in-process on the calling thread
/// ([`Loopback`]) or in a remote process over a socket
/// ([`tcp::TcpTransport`]).
///
/// `Send + Sync` because the engine fans round-trips for different
/// clients out across its worker pool.
pub trait Transport: Send + Sync {
    fn name(&self) -> &'static str;

    /// Can this transport lose a dispatched client mid-exchange
    /// (return [`RoundTripStatus::Lost`])? When true the engine always
    /// takes pre-round DGC rollback snapshots, exactly as it does when
    /// a policy can cut stragglers. Loopback can't lose anyone.
    fn may_lose(&self) -> bool {
        false
    }

    /// Should the engine capture a pre-round [`StateSyncSnapshot`] for
    /// every dispatched client? Only transports that replay rounds to
    /// restarted processes need one; the default (and loopback) answer
    /// is no, keeping the host path free of the capture cost.
    fn wants_state_sync(&self) -> bool {
        false
    }

    /// Exchange one client round: deliver the `RoundOffer` and
    /// `ModelDown` frames, obtain the `UpdateUp` frame into `reply`
    /// (cleared first; capacity reused).
    ///
    /// `env` is the host-side client context. The loopback transport
    /// executes the client with it; a socket transport ignores it (the
    /// remote process owns the real device state, which evolves
    /// identically — see the module docs' bit-identity contract).
    /// `sync` is the pre-round snapshot captured when
    /// [`Transport::wants_state_sync`] asked for one; a socket
    /// transport ships it ahead of a dispatch that follows a
    /// reconnect.
    ///
    /// I/O failure is not an error: a transport that loses the client
    /// mid-exchange returns `Ok(RoundTripStatus::Lost(_))` and the
    /// scheduler converts the loss into a policy-visible cut
    /// (`RoundRecord::lost`). `Err` is reserved for protocol
    /// violations that indicate a broken build, not a broken network.
    fn round_trip(
        &self,
        client: usize,
        offer: &[u8],
        model: &[u8],
        sync: Option<&StateSyncSnapshot>,
        env: &mut ClientEnv<'_>,
        reply: &mut Vec<u8>,
    ) -> Result<RoundTripStatus>;

    /// Deliver the round-closing decision for one exchanged round:
    /// `included` sends `Ack` (commit device-side codec state), else
    /// `Cut` (roll it back). The engine performs the same
    /// commit/rollback on its host-side state, so loopback needs no
    /// wire action. Best-effort on sockets: a decision addressed to a
    /// dead connection is dropped (the next dispatch to that session
    /// carries a `StateSync` that supersedes it).
    fn finish(&self, client: usize, round: u32, included: bool) -> Result<()>;

    /// End the session (`Bye` to every remote client; no-op in
    /// process).
    fn shutdown(&self) -> Result<()> {
        Ok(())
    }
}

/// Codec id byte carried in `ModelDown` so an endpoint configured with
/// the wrong downlink codec fails loudly instead of decoding garbage.
pub fn codec_id(name: &str) -> u8 {
    match name {
        "raw_f32" => 0,
        "quant8" => 1,
        _ => 0xff,
    }
}
