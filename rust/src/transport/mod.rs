//! Wire protocol + transport layer: the federation conversation as
//! framed bytes, runnable in-process or over real sockets.
//!
//! Until this layer existed the repo's codecs produced real payloads
//! but nothing ever *framed* them: there was no message format, no
//! transport, and no way to split the coordinator from its clients
//! across processes. This module closes that gap:
//!
//! * [`frame`] — the versioned, CRC32-checked, length-prefixed binary
//!   frame format for the whole conversation (`RoundOffer`,
//!   `ModelDown`, `UpdateUp`, `Ack`/`Cut`, plus the
//!   `Hello`/`Config`/`Ready`/`Bye` session envelope);
//! * [`client_round`] — the client side of one round as a pure
//!   function of frames ([`client_round::client_execute`]): decode the
//!   offered sub-model and payload, train locally, encode the update.
//!   Shared verbatim by the in-process and remote paths, which is what
//!   makes them bit-identical;
//! * [`loopback`] — the in-process [`Transport`]: the engine path the
//!   experiments always ran, now speaking frames;
//! * [`tcp`] — the real `std::net` transport: a coordinator process
//!   (`afd serve`) drives a swarm of client processes (`afd client`)
//!   over TCP, one framed request/response conversation per logical
//!   client.
//!
//! ## The conversation
//!
//! ```text
//! session:   client ── Hello ─▶ server ── Config ─▶ client ── Ready ─▶ server
//! per round: server ── RoundOffer ‖ ModelDown ─▶ client
//!            client ── UpdateUp ─▶ server
//!            server ── Ack (aggregated) | Cut (discarded) ─▶ client
//! shutdown:  server ── Bye ─▶ client
//! ```
//!
//! `Ack`/`Cut` carry the round-closing decision to the device: a DGC
//! client clears sent coordinates from its accumulators when it
//! uploads, which is only correct if the upload is aggregated — `Cut`
//! tells it to roll the snapshot back (the engine performs the same
//! rollback on its host-side state).
//!
//! ## Bit-identity contract
//!
//! The transport can never change results, only where they run: a
//! fixed-seed experiment produces byte-identical model parameters,
//! losses and per-round byte counts over [`loopback::Loopback`] and
//! over [`tcp::TcpTransport`] (`rust/tests/transport_e2e.rs`, plus the
//! CI socket smoke). This holds because both ends of the conversation
//! run [`client_round::client_execute`] on identical frame bytes, all
//! RNG is derived from the config seed on both sides, and a client's
//! update is independent of its off-sub-model parameter values
//! (masked training leaves them untouched and deltas are zero there —
//! asserted by `client_base_params_do_not_affect_update`).
//!
//! ## Byte accounting
//!
//! `RoundRecord::{down,up}_bytes` are **measured wire bytes** — the
//! exact framed lengths a socket carries, control frames included —
//! and `{down,up}_payload_bytes` are the codec payloads alone, so the
//! protocol's framing overhead is visible next to the codec savings
//! (`metrics::render_table`'s Framing column). The network simulator
//! charges link time on the wire numbers.
//!
//! See `rust/src/transport/README.md` for the frame grammar and the
//! zero-allocation scratch contract.

pub mod client_round;
pub mod frame;
pub mod loopback;
pub mod tcp;

pub use client_round::{client_execute, ClientEnv};
pub use loopback::Loopback;

use anyhow::Result;

/// One federation transport: delivers a round's frames to a logical
/// client and returns its update frame. Implementations decide *where*
/// the client computation happens — in-process on the calling thread
/// ([`Loopback`]) or in a remote process over a socket
/// ([`tcp::TcpTransport`]).
///
/// `Send + Sync` because the engine fans round-trips for different
/// clients out across its worker pool.
pub trait Transport: Send + Sync {
    fn name(&self) -> &'static str;

    /// Exchange one client round: deliver the `RoundOffer` and
    /// `ModelDown` frames, obtain the `UpdateUp` frame into `reply`
    /// (cleared first; capacity reused).
    ///
    /// `env` is the host-side client context. The loopback transport
    /// executes the client with it; a socket transport ignores it (the
    /// remote process owns the real device state, which evolves
    /// identically — see the module docs' bit-identity contract).
    fn round_trip(
        &self,
        client: usize,
        offer: &[u8],
        model: &[u8],
        env: &mut ClientEnv<'_>,
        reply: &mut Vec<u8>,
    ) -> Result<()>;

    /// Deliver the round-closing decision for one exchanged round:
    /// `included` sends `Ack` (commit device-side codec state), else
    /// `Cut` (roll it back). The engine performs the same
    /// commit/rollback on its host-side state, so loopback needs no
    /// wire action.
    fn finish(&self, client: usize, round: u32, included: bool) -> Result<()>;

    /// End the session (`Bye` to every remote client; no-op in
    /// process).
    fn shutdown(&self) -> Result<()> {
        Ok(())
    }
}

/// Codec id byte carried in `ModelDown` so an endpoint configured with
/// the wrong downlink codec fails loudly instead of decoding garbage.
pub fn codec_id(name: &str) -> u8 {
    match name {
        "raw_f32" => 0,
        "quant8" => 1,
        _ => 0xff,
    }
}
