//! Real socket transport, v2: the coordinator (`afd serve`) drives a
//! swarm of client processes (`afd client`) over `std::net` TCP with
//! non-blocking multiplexed I/O, pipelined rounds, and session resume.
//!
//! ## Topology
//!
//! The coordinator serves a fixed number of connection *slots*; each
//! client process builds the *full* deterministic client fleet from the
//! config the server ships in the handshake (datasets, per-client RNG
//! streams, DGC accumulators are all pure functions of the seed), and
//! logical client `c` is routed to slot `c % conns`. Any client
//! process can therefore adopt any slot — a restarted process that
//! takes a dead slot resumes its open rounds bit-exactly.
//!
//! ## Coordinator threads
//!
//! Two background threads own all socket I/O:
//!
//! * the **acceptor** keeps listening for the lifetime of the run: it
//!   handshakes each connection (blocking, with read *and* write
//!   timeouts) and installs it into a slot — `Hello(0)` takes the
//!   lowest vacant slot, `Hello(token)` reclaims slot `token - 1`
//!   (taking it over if an old socket still occupies it);
//! * the **event loop** multiplexes every installed socket with
//!   non-blocking reads/writes (readiness via `poll(2)` on Linux, a
//!   short tick elsewhere), matches `UpdateUp` replies to open rounds,
//!   and enforces per-round deadlines.
//!
//! Engine worker threads never touch a socket: [`TcpTransport::round_trip`]
//! enqueues the round's frames under the shared lock and waits on a
//! condvar, so many rounds pipeline over one connection — the
//! per-connection `Mutex<TcpStream>` of v1 (one blocked thread per
//! in-flight round, head-of-line blocking across slots) is gone.
//!
//! ## Session resume
//!
//! The `Config` frame carries a session token (`slot + 1`). A client
//! that reconnects — same process after a TCP reset, or a restarted
//! process taking the vacant slot — gets every still-open round
//! replayed in `(round, client)` order, each preceded (once per
//! reconnect generation) by a `StateSync` frame holding the engine's
//! pre-round snapshot of that logical client, so the remote fleet
//! state rejoins bit-exactly. `StateSync` bytes are *excluded* from
//! `RoundRecord` byte accounting (they are recovery traffic, tracked
//! by the `resync_bytes` counter), which keeps a fixed-seed run over
//! flaky-but-recovering TCP byte-identical to loopback.
//!
//! ## Loss conversion
//!
//! A dead connection no longer ends the run. With resume off (or past
//! the per-round deadline even with resume on), the in-flight rounds
//! of the dead connection resolve as [`RoundTripStatus::Lost`] and the
//! engine converts them into policy-visible cuts (`RoundRecord::lost`);
//! `Err` from this transport means a protocol violation, not a broken
//! network. See `rust/src/transport/README.md` for the full contract.

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::{Backend, ExperimentConfig, TransportConfig};
use crate::data;
use crate::fault::{self, Site};
use crate::model::packing::PlanCache;
use crate::model::submodel::SubModel;
use crate::runtime::native::mlp_from_config;
use crate::transport::client_round::{client_execute, ClientEnv};
use crate::transport::frame::{self, FrameKind};
use crate::transport::{codec_id, LossReason, RoundTripStatus, StateSyncSnapshot, Transport};
use crate::util::rng::Pcg64;

/// Most in-flight rounds either side tracks per connection: the
/// server's open-round map and the remote's offer queue / rollback
/// snapshots are all bounded by it, so a runaway peer cannot grow
/// either process without bound.
pub const MAX_PIPELINE: usize = 64;

/// Socket timeout for the handshake phase (before the config's
/// `transport.io_timeout_s` is known on the client side).
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(600);

/// How long the acceptor sleeps between non-blocking accept attempts.
const ACCEPT_PAUSE: Duration = Duration::from_millis(50);

/// Event-loop readiness wait (poll(2) timeout on Linux; the
/// no-readiness fallback ticks at half this).
#[cfg(target_os = "linux")]
const EVENT_TICK_MS: i32 = 10;

/// Lock that survives a poisoned mutex: a panicking engine worker must
/// not wedge the event loop (or vice versa) — the shared state is a
/// message board whose entries are individually complete, so the data
/// is consistent regardless of where the panicker died.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Read one whole frame (header + payload + CRC) from a stream into
/// `buf` (cleared; capacity reused). Validates the magic and the
/// length cap *before* trusting the prefix, so a corrupt peer cannot
/// make the reader allocate unboundedly or stall on a bogus length;
/// CRC/version are verified by the caller's `parse_frame`.
fn read_frame_into(stream: &mut TcpStream, buf: &mut Vec<u8>) -> Result<()> {
    buf.clear();
    buf.resize(frame::HEADER_LEN, 0);
    stream.read_exact(&mut buf[..]).context("reading frame header")?;
    anyhow::ensure!(
        buf[0..2] == frame::MAGIC,
        "bad frame magic from peer: {:02x?}",
        &buf[0..2]
    );
    let len = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
    anyhow::ensure!(
        len <= frame::MAX_PAYLOAD,
        "oversized frame from peer: {len}-byte payload (cap {})",
        frame::MAX_PAYLOAD
    );
    let total = frame::HEADER_LEN + len + frame::CRC_LEN;
    buf.resize(total, 0);
    let body = &mut buf[frame::HEADER_LEN..];
    stream.read_exact(body).context("reading frame body")?;
    Ok(())
}

// ---------------------------------------------------------------------
// Shared coordinator state
// ---------------------------------------------------------------------

/// One in-flight round on a connection slot. The waiting engine thread
/// owns removal; the event loop and acceptor only ever set `done`.
struct OpenEntry {
    /// Encoded `StateSync` frame to precede the offer after a
    /// reconnect (present iff the engine captured a snapshot).
    sync: Option<Vec<u8>>,
    /// `RoundOffer` ‖ `ModelDown`, kept whole for replay.
    msg: Vec<u8>,
    /// Enqueue time + io_timeout; refreshed when a reconnect replays
    /// the entry. Outliving it fails the whole connection.
    deadline: Instant,
    /// Set exactly once: the reply frame, or the loss that ate it.
    done: Option<Result<Vec<u8>, LossReason>>,
}

/// One connection slot: the socket (if currently connected) plus its
/// I/O buffers, open rounds, and resume bookkeeping.
struct ConnState {
    stream: Option<TcpStream>,
    /// Reconnect count for this slot; bumps on every re-install.
    generation: u64,
    /// Whether any client ever completed a handshake into this slot
    /// (distinguishes "first connect" from "reconnect").
    ever_connected: bool,
    /// Outgoing bytes not yet written; `wpos` marks the partial-write
    /// offset so a short non-blocking write resumes mid-buffer.
    out: Vec<u8>,
    wpos: usize,
    /// Incoming bytes not yet assembled into a whole frame.
    rbuf: Vec<u8>,
    /// In-flight rounds keyed by `(round, client)`; BTreeMap so replay
    /// order is deterministic.
    open: BTreeMap<(u32, u32), OpenEntry>,
    /// Send order of open entries — TCP preserves order and the remote
    /// serves offers in arrival order, so replies match FIFO.
    sent: VecDeque<(u32, u32)>,
    /// Generation at which each logical client last received a
    /// `StateSync`, so one reconnect syncs each client exactly once.
    last_synced: HashMap<u32, u64>,
    /// Merge-registry id of the remote process behind this slot (set at
    /// handshake), for routing inbound `Telemetry` frames.
    remote_id: Option<usize>,
}

impl ConnState {
    fn new() -> ConnState {
        ConnState {
            stream: None,
            generation: 0,
            ever_connected: false,
            out: Vec::new(),
            wpos: 0,
            rbuf: Vec::new(),
            open: BTreeMap::new(),
            sent: VecDeque::new(),
            last_synced: HashMap::new(),
            remote_id: None,
        }
    }
}

struct Shared {
    conns: Vec<ConnState>,
    stopping: bool,
}

/// Drain `conn.out` with non-blocking writes. Returns false when the
/// connection died mid-write.
fn flush_conn(conn: &mut ConnState) -> bool {
    let Some(stream) = conn.stream.as_mut() else {
        return true;
    };
    let mut limit = conn.out.len();
    if fault::enabled() && conn.wpos < limit {
        if fault::should(Site::SockWrite, conn.generation, conn.wpos as u64) {
            // Injected write error: the connection dies exactly like a
            // peer reset mid-flush would kill it.
            return false;
        }
        if limit - conn.wpos > 1
            && fault::should(Site::PartialWrite, conn.generation, conn.wpos as u64)
        {
            // Injected short write: stop mid-buffer this tick; `wpos`
            // resumes from the cut next tick — fully masked.
            limit = conn.wpos + (limit - conn.wpos) / 2;
        }
    }
    while conn.wpos < limit {
        match stream.write(&conn.out[conn.wpos..limit]) {
            Ok(0) => return false,
            Ok(n) => conn.wpos += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    if conn.wpos == conn.out.len() {
        conn.out.clear();
        conn.wpos = 0;
    }
    true
}

/// Pull whatever the socket has into `conn.rbuf` without blocking.
/// Returns false on EOF or a hard error.
fn read_conn(conn: &mut ConnState, scratch: &mut [u8]) -> bool {
    let Some(stream) = conn.stream.as_mut() else {
        return true;
    };
    if fault::should(Site::SockRead, conn.generation, conn.rbuf.len() as u64) {
        // Injected read error: indistinguishable from EOF / ECONNRESET.
        return false;
    }
    loop {
        match stream.read(scratch) {
            Ok(0) => return false,
            Ok(n) => conn.rbuf.extend_from_slice(&scratch[..n]),
            Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
}

/// Extract every complete frame from `conn.rbuf` and resolve the open
/// rounds they answer. Returns whether any waiter should be woken;
/// `Err(())` means the peer broke protocol and the connection must die.
fn drain_frames(conn: &mut ConnState) -> Result<bool, ()> {
    let mut off = 0;
    let mut notify = false;
    loop {
        let avail = conn.rbuf.len() - off;
        if avail < frame::HEADER_LEN {
            break;
        }
        let h = &conn.rbuf[off..off + frame::HEADER_LEN];
        if h[0..2] != frame::MAGIC {
            return Err(());
        }
        let len = u32::from_le_bytes(h[4..8].try_into().unwrap()) as usize;
        if len > frame::MAX_PAYLOAD {
            return Err(());
        }
        let kind = h[3];
        let total = frame::HEADER_LEN + len + frame::CRC_LEN;
        if avail < total {
            break;
        }
        if FrameKind::from_u8(kind) == Some(FrameKind::Telemetry) {
            // Pure side channel: merge into the remote registry and
            // consume the bytes without touching the FIFO — replies
            // still match open rounds in send order. A frame that fails
            // the full parse (CRC, grammar) is a protocol violation
            // like any other malformed inbound frame.
            let whole = &conn.rbuf[off..off + total];
            let Ok((view, _)) = frame::parse_frame(whole) else {
                return Err(());
            };
            let Ok(msg) = frame::parse_telemetry(&view) else {
                return Err(());
            };
            if let Some(id) = conn.remote_id {
                crate::obs::remote::ingest(id, &msg);
            }
            if crate::obs::enabled() {
                crate::obs::metrics::TELEMETRY_BYTES.add(total as u64);
            }
            off += total;
            continue;
        }
        if FrameKind::from_u8(kind) != Some(FrameKind::UpdateUp) {
            return Err(());
        }
        // FIFO matching: the oldest sent-and-still-open entry owns this
        // reply. (Entries a waiter already collected, or that a prior
        // generation failed, linger in `sent` — skip them.)
        let key = loop {
            match conn.sent.pop_front() {
                Some(k) => {
                    if conn.open.get(&k).is_some_and(|e| e.done.is_none()) {
                        break Some(k);
                    }
                }
                None => break None,
            }
        };
        let Some(k) = key else {
            return Err(());
        };
        if fault::should(Site::FrameCorrupt, k.0 as u64, k.1 as u64) {
            // Injected wire corruption: a real receiver rejects the
            // frame on CRC and abandons the connection. The matched
            // round resolves as the same typed loss a dead socket
            // produces; the protocol-death return kills the rest.
            conn.open.get_mut(&k).expect("matched entry").done =
                Some(Err(LossReason::Disconnected));
            return Err(());
        }
        // No parse here beyond the header: `run_client_round` runs the
        // one full parse — CRC, kind, payload grammar — over the reply.
        let bytes = conn.rbuf[off..off + total].to_vec();
        conn.open.get_mut(&k).expect("matched entry").done = Some(Ok(bytes));
        notify = true;
        off += total;
    }
    if off > 0 {
        conn.rbuf.drain(..off);
    }
    Ok(notify)
}

/// The connection died (EOF, I/O error, protocol violation). With
/// resume on, open rounds stay pending for a reconnect replay (their
/// original deadlines still bound the wait); with resume off they
/// become immediate `Disconnected` losses.
fn kill_conn(conn: &mut ConnState, resume: bool) {
    conn.stream = None;
    conn.out.clear();
    conn.wpos = 0;
    conn.rbuf.clear();
    conn.sent.clear();
    if !resume {
        for e in conn.open.values_mut() {
            if e.done.is_none() {
                e.done = Some(Err(LossReason::Disconnected));
            }
        }
    }
}

/// An open round outlived its deadline: resume or not, the transport
/// gives up on the whole connection and fails every pending round.
fn expire_conn(conn: &mut ConnState) {
    conn.stream = None;
    conn.out.clear();
    conn.wpos = 0;
    conn.rbuf.clear();
    conn.sent.clear();
    for e in conn.open.values_mut() {
        if e.done.is_none() {
            e.done = Some(Err(LossReason::Timeout));
        }
    }
}

// ---------------------------------------------------------------------
// Readiness wait
// ---------------------------------------------------------------------

#[cfg(target_os = "linux")]
fn raw_fds(sh: &Shared) -> Vec<i32> {
    use std::os::unix::io::AsRawFd;
    sh.conns
        .iter()
        .filter_map(|c| c.stream.as_ref().map(|s| s.as_raw_fd()))
        .collect()
}

/// Block until any of `fds` is readable or `timeout_ms` passes —
/// poll(2) via FFI, so the event loop wakes the moment a reply lands
/// instead of always paying the full tick.
#[cfg(target_os = "linux")]
fn poll_readable(fds: &[i32], timeout_ms: i32) {
    #[repr(C)]
    struct PollFd {
        fd: i32,
        events: i16,
        revents: i16,
    }
    const POLLIN: i16 = 0x001;
    extern "C" {
        fn poll(fds: *mut PollFd, nfds: u64, timeout: i32) -> i32;
    }
    if fds.is_empty() {
        std::thread::sleep(Duration::from_millis(timeout_ms.max(0) as u64));
        return;
    }
    let mut pfds: Vec<PollFd> = fds
        .iter()
        .map(|&fd| PollFd {
            fd,
            events: POLLIN,
            revents: 0,
        })
        .collect();
    // SAFETY: `pfds` is a valid exclusively-borrowed pollfd array whose
    // length is passed as nfds; poll(2) writes only within it and keeps
    // no reference past the call. Readiness is a hint — the sweep does
    // non-blocking I/O on every socket regardless — so a failing or
    // racing poll (even against a concurrently closed fd) only costs a
    // tick of latency, never correctness.
    unsafe {
        let _ = poll(pfds.as_mut_ptr(), pfds.len() as u64, timeout_ms);
    }
}

/// The coordinator event loop: one thread, every socket, non-blocking.
/// Each tick flushes pending writes, ingests replies, resolves open
/// rounds, and enforces deadlines; it exits once `stopping` is set and
/// the goodbye bytes have drained (or a short grace period passes).
fn event_loop(shared: Arc<(Mutex<Shared>, Condvar)>, resume: bool) {
    let (m, cvar) = &*shared;
    let mut scratch = vec![0u8; 64 * 1024];
    let mut stop_deadline: Option<Instant> = None;
    loop {
        {
            let mut sh = lock(m);
            let mut notify = false;
            for conn in sh.conns.iter_mut() {
                if conn.stream.is_none() {
                    continue;
                }
                let mut alive = flush_conn(conn);
                if alive {
                    alive = read_conn(conn, &mut scratch);
                }
                // Frames buffered before the death still count — a
                // reply that made it out of the peer is a valid reply.
                match drain_frames(conn) {
                    Ok(n) => notify |= n,
                    Err(()) => alive = false,
                }
                if !alive {
                    kill_conn(conn, resume);
                    notify = true;
                }
            }
            let now = Instant::now();
            for conn in sh.conns.iter_mut() {
                if conn
                    .open
                    .values()
                    .any(|e| e.done.is_none() && e.deadline <= now)
                {
                    expire_conn(conn);
                    if crate::obs::enabled() {
                        crate::obs::metrics::TRANSPORT_TIMEOUTS.incr();
                    }
                    notify = true;
                }
            }
            if notify {
                cvar.notify_all();
            }
            if sh.stopping {
                let flushed = sh
                    .conns
                    .iter()
                    .all(|c| c.stream.is_none() || c.out.is_empty());
                let dl = *stop_deadline.get_or_insert(now + Duration::from_secs(1));
                if flushed || now >= dl {
                    break;
                }
            }
        }
        #[cfg(target_os = "linux")]
        {
            let fds = {
                let sh = lock(m);
                raw_fds(&sh)
            };
            poll_readable(&fds, EVENT_TICK_MS);
        }
        #[cfg(not(target_os = "linux"))]
        std::thread::sleep(Duration::from_millis(5));
    }
}

// ---------------------------------------------------------------------
// Acceptor
// ---------------------------------------------------------------------

/// Handshake one accepted socket and install it into a slot. Failure
/// drops the socket; the acceptor keeps serving.
fn handshake_and_install(
    mut stream: TcpStream,
    shared: &Arc<(Mutex<Shared>, Condvar)>,
    cfg_json: &str,
    fingerprint: u64,
    io_timeout: Duration,
    resume: bool,
) -> Result<()> {
    stream.set_nodelay(true)?;
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(io_timeout))?;
    stream.set_write_timeout(Some(io_timeout))?;
    let mut buf = Vec::new();
    read_frame_into(&mut stream, &mut buf).context("reading Hello")?;
    let (view, _) = frame::parse_frame(&buf).context("Hello frame")?;
    let token = frame::parse_hello(&view)?;
    let slot = {
        let sh = lock(&shared.0);
        if token == 0 {
            // Fresh client: lowest vacant slot. A restarted process
            // (no token — it died with the old one) lands on its
            // predecessor's slot this way and resumes it.
            sh.conns
                .iter()
                .position(|c| c.stream.is_none())
                .context("no vacant connection slot for a new client")?
        } else {
            let slot = (token - 1) as usize;
            anyhow::ensure!(slot < sh.conns.len(), "Hello token {token} out of range");
            slot
        }
    };
    let mut out = Vec::new();
    frame::encode_config(&mut out, fingerprint, (slot + 1) as u64, cfg_json);
    stream.write_all(&out).context("sending Config")?;
    read_frame_into(&mut stream, &mut buf).context("waiting for Ready")?;
    let (view, _) = frame::parse_frame(&buf)?;
    let (theirs, client_now_ns) = frame::parse_ready(&view)?;
    anyhow::ensure!(
        theirs == fingerprint,
        "peer derived layout fingerprint {theirs:#018x}, server has \
         {fingerprint:#018x} — mismatched configs or binaries"
    );
    stream.set_nonblocking(true)?;

    // Telemetry identity: one named remote process per slot, stable
    // across reconnects (a restarted process resuming the slot keeps
    // the same merged-trace track). The Ready clock sample seeds the
    // monotonic offset before any Telemetry frame arrives.
    let remote_id = crate::obs::remote::register(&format!("client-slot-{slot}"));
    if client_now_ns > 0 {
        crate::obs::remote::anchor(remote_id, client_now_ns);
    }

    let mut sh = lock(&shared.0);
    if sh.stopping {
        return Ok(());
    }
    let conn = &mut sh.conns[slot];
    conn.remote_id = Some(remote_id);
    // Takeover: a token reconnect may beat the event loop to a half-dead
    // socket — drop whatever occupied the slot and start its I/O fresh.
    conn.stream = None;
    conn.out.clear();
    conn.wpos = 0;
    conn.rbuf.clear();
    conn.sent.clear();
    if conn.ever_connected {
        conn.generation += 1;
        if crate::obs::enabled() {
            crate::obs::metrics::CONN_RECONNECTS.incr();
        }
        // Session resume is an instant on the merged timeline.
        crate::obs::span::mark(
            crate::obs::Stage::ResumeMark,
            slot as u64,
            (slot + 1) as u64,
        );
        if resume {
            // Replay every still-open round in deterministic key order,
            // each client's first entry preceded by its StateSync.
            let gen = conn.generation;
            let now = Instant::now();
            let mut resync = 0u64;
            for (key, e) in conn.open.iter_mut() {
                if e.done.is_some() {
                    continue;
                }
                e.deadline = now + io_timeout;
                if let Some(sf) = e.sync.as_deref() {
                    if conn.last_synced.get(&key.1) != Some(&gen) {
                        conn.out.extend_from_slice(sf);
                        conn.last_synced.insert(key.1, gen);
                        resync += sf.len() as u64;
                    }
                }
                conn.out.extend_from_slice(&e.msg);
                conn.sent.push_back(*key);
            }
            if resync > 0 && crate::obs::enabled() {
                crate::obs::metrics::RESYNC_BYTES.add(resync);
            }
        } else {
            // Without resume the rounds written to the dead socket are
            // unrecoverable — fail any the event loop hasn't already.
            for e in conn.open.values_mut() {
                if e.done.is_none() {
                    e.done = Some(Err(LossReason::Disconnected));
                }
            }
        }
    } else {
        conn.ever_connected = true;
    }
    conn.stream = Some(stream);
    drop(sh);
    shared.1.notify_all();
    Ok(())
}

/// Accept loop: non-blocking accepts for the lifetime of the run, so
/// clients can join, die, and rejoin at any point.
fn acceptor_loop(
    listener: TcpListener,
    shared: Arc<(Mutex<Shared>, Condvar)>,
    cfg_json: String,
    fingerprint: u64,
    io_timeout: Duration,
    resume: bool,
) {
    let _ = listener.set_nonblocking(true);
    loop {
        if lock(&shared.0).stopping {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                // A failed handshake must not kill the acceptor: drop
                // the socket and serve the next connection attempt.
                let _ = handshake_and_install(
                    stream,
                    &shared,
                    &cfg_json,
                    fingerprint,
                    io_timeout,
                    resume,
                );
            }
            Err(_) => std::thread::sleep(ACCEPT_PAUSE),
        }
    }
}

// ---------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------

/// A bound listener that has not started serving yet (split from
/// [`TcpTransport`] so callers can learn the ephemeral port — tests
/// bind `127.0.0.1:0` — before any client connects).
pub struct TcpServer {
    listener: TcpListener,
}

/// Bind with `SO_REUSEADDR` so a restarted coordinator can reclaim its
/// port immediately: a crash leaves the old connections parked in
/// `TIME_WAIT`/`FIN_WAIT` for up to a minute, during which a plain
/// `TcpListener::bind` fails with `EADDRINUSE` — exactly the window a
/// `--restore` supervisor restarts in. Linux/IPv4 only; anything else
/// falls back to the std bind (the flag is a restart-latency
/// optimization, never a correctness requirement).
#[cfg(target_os = "linux")]
fn bind_reuseaddr(addr: &str) -> std::io::Result<TcpListener> {
    use std::net::ToSocketAddrs;
    use std::os::unix::io::FromRawFd;

    let Some(SocketAddr::V4(v4)) = addr
        .to_socket_addrs()?
        .find(|a| matches!(a, SocketAddr::V4(_)))
    else {
        return TcpListener::bind(addr);
    };

    #[repr(C)]
    struct SockAddrIn {
        sin_family: u16,
        sin_port: u16, // network byte order
        sin_addr: u32, // network byte order
        sin_zero: [u8; 8],
    }
    const AF_INET: i32 = 2;
    const SOCK_STREAM: i32 = 1;
    const SOL_SOCKET: i32 = 1;
    const SO_REUSEADDR: i32 = 2;
    extern "C" {
        fn socket(domain: i32, ty: i32, protocol: i32) -> i32;
        fn setsockopt(fd: i32, level: i32, name: i32, value: *const i32, len: u32) -> i32;
        fn bind(fd: i32, addr: *const SockAddrIn, len: u32) -> i32;
        fn listen(fd: i32, backlog: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    let sa = SockAddrIn {
        sin_family: AF_INET as u16,
        sin_port: v4.port().to_be(),
        sin_addr: u32::from(*v4.ip()).to_be(),
        sin_zero: [0; 8],
    };
    // SAFETY: plain syscalls on an fd this function owns until the
    // `from_raw_fd` handoff; `sa` outlives the `bind` call. Every
    // failure reads `last_os_error` before anything can overwrite
    // errno, then closes the fd.
    unsafe {
        let fd = socket(AF_INET, SOCK_STREAM, 0);
        if fd < 0 {
            return Err(std::io::Error::last_os_error());
        }
        let one: i32 = 1;
        if setsockopt(fd, SOL_SOCKET, SO_REUSEADDR, &one, 4) != 0
            || bind(fd, &sa, std::mem::size_of::<SockAddrIn>() as u32) != 0
            || listen(fd, 128) != 0
        {
            let err = std::io::Error::last_os_error();
            let _ = close(fd);
            return Err(err);
        }
        Ok(TcpListener::from_raw_fd(fd))
    }
}

#[cfg(not(target_os = "linux"))]
fn bind_reuseaddr(addr: &str) -> std::io::Result<TcpListener> {
    TcpListener::bind(addr)
}

impl TcpServer {
    pub fn bind(addr: &str) -> Result<TcpServer> {
        let listener = bind_reuseaddr(addr).with_context(|| format!("binding {addr}"))?;
        Ok(TcpServer { listener })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Start the acceptor and event-loop threads, then block until all
    /// `conns` slots have completed a first handshake (each: read
    /// `Hello`, send `Config` with the experiment JSON, `fingerprint`
    /// and the slot's session token, require a `Ready` echoing the
    /// fingerprint). The acceptor keeps running afterwards so dead
    /// clients can reconnect mid-run.
    pub fn accept_clients(
        self,
        conns: usize,
        cfg_json: &str,
        fingerprint: u64,
        tcfg: &TransportConfig,
    ) -> Result<TcpTransport> {
        anyhow::ensure!(conns > 0, "a TCP transport needs at least one connection");
        anyhow::ensure!(
            tcfg.io_timeout_s > 0.0,
            "transport.io_timeout_s must be positive"
        );
        let io_timeout = Duration::from_secs_f64(tcfg.io_timeout_s);
        let shared = Arc::new((
            Mutex::new(Shared {
                conns: (0..conns).map(|_| ConnState::new()).collect(),
                stopping: false,
            }),
            Condvar::new(),
        ));
        let acceptor = std::thread::Builder::new()
            .name("afd-acceptor".into())
            .spawn({
                let shared = shared.clone();
                let cfg_json = cfg_json.to_string();
                let resume = tcfg.resume;
                let listener = self.listener;
                move || acceptor_loop(listener, shared, cfg_json, fingerprint, io_timeout, resume)
            })
            .context("spawning acceptor thread")?;
        let events = std::thread::Builder::new()
            .name("afd-transport".into())
            .spawn({
                let shared = shared.clone();
                let resume = tcfg.resume;
                move || event_loop(shared, resume)
            })
            .context("spawning transport event loop")?;
        // Same startup contract as v1: the experiment begins only once
        // the whole fleet has said hello.
        {
            let (m, cvar) = &*shared;
            let mut sh = lock(m);
            while !sh.conns.iter().all(|c| c.ever_connected) {
                let r = cvar
                    .wait_timeout(sh, Duration::from_millis(100))
                    .unwrap_or_else(|e| e.into_inner());
                sh = r.0;
            }
        }
        Ok(TcpTransport {
            shared,
            nconns: conns,
            io_timeout,
            resume: tcfg.resume,
            acceptor: Mutex::new(Some(acceptor)),
            events: Mutex::new(Some(events)),
        })
    }
}

/// The server side of the socket transport: engine threads enqueue
/// framed rounds into per-slot buffers and wait; the background event
/// loop owns every socket.
pub struct TcpTransport {
    shared: Arc<(Mutex<Shared>, Condvar)>,
    nconns: usize,
    io_timeout: Duration,
    resume: bool,
    acceptor: Mutex<Option<JoinHandle<()>>>,
    events: Mutex<Option<JoinHandle<()>>>,
}

impl TcpTransport {
    /// Enqueue one round on slot `idx` and wait for its resolution.
    /// Split from [`Transport::round_trip`] so tests can drive the
    /// pipeline without building a full `ClientEnv`.
    fn exchange(
        &self,
        idx: usize,
        round: u32,
        client: u32,
        offer: &[u8],
        model: &[u8],
        sync: Option<&StateSyncSnapshot>,
        reply: &mut Vec<u8>,
    ) -> Result<RoundTripStatus> {
        let key = (round, client);
        let sync_frame = sync.map(|s| {
            let mut b = Vec::new();
            frame::encode_state_sync(
                &mut b,
                s.client,
                s.participations,
                s.rng_state,
                s.rng_inc,
                &s.dgc_u,
                &s.dgc_v,
            );
            b
        });
        let (m, cvar) = &*self.shared;
        let mut sh = lock(m);
        anyhow::ensure!(!sh.stopping, "round trip after shutdown");
        {
            let conn = &mut sh.conns[idx];
            anyhow::ensure!(
                !conn.open.contains_key(&key),
                "duplicate in-flight exchange for round {round} client {client}"
            );
            anyhow::ensure!(
                conn.open.len() < MAX_PIPELINE,
                "pipeline depth cap hit on slot {idx} ({MAX_PIPELINE} open rounds)"
            );
            if conn.stream.is_none() && !self.resume {
                // Nothing to write to and nobody will replay it.
                return Ok(RoundTripStatus::Lost(LossReason::Disconnected));
            }
            let mut msg = Vec::with_capacity(offer.len() + model.len());
            msg.extend_from_slice(offer);
            msg.extend_from_slice(model);
            if conn.stream.is_some() {
                if let Some(sf) = sync_frame.as_deref() {
                    // First dispatch to this client since the slot's
                    // last reconnect carries its state snapshot.
                    if conn.generation > 0 && conn.last_synced.get(&client) != Some(&conn.generation)
                    {
                        conn.out.extend_from_slice(sf);
                        conn.last_synced.insert(client, conn.generation);
                        if crate::obs::enabled() {
                            crate::obs::metrics::RESYNC_BYTES.add(sf.len() as u64);
                        }
                    }
                }
                conn.out.extend_from_slice(&msg);
                conn.sent.push_back(key);
            }
            // Slot vacant with resume on: the entry waits — a reconnect
            // replays it, or the deadline scan converts it to a loss.
            conn.open.insert(
                key,
                OpenEntry {
                    sync: sync_frame,
                    msg,
                    deadline: Instant::now() + self.io_timeout,
                    done: None,
                },
            );
            if crate::obs::enabled() {
                crate::obs::metrics::PIPELINE_DEPTH.set_max(conn.open.len() as u64);
            }
        }
        loop {
            let ready = match sh.conns[idx].open.get(&key) {
                Some(e) => e.done.is_some(),
                None => anyhow::bail!("in-flight exchange entry vanished"),
            };
            if ready {
                let e = sh.conns[idx].open.remove(&key).unwrap();
                return Ok(match e.done.unwrap() {
                    Ok(bytes) => {
                        reply.clear();
                        reply.extend_from_slice(&bytes);
                        RoundTripStatus::Delivered
                    }
                    Err(reason) => RoundTripStatus::Lost(reason),
                });
            }
            if sh.stopping {
                sh.conns[idx].open.remove(&key);
                return Ok(RoundTripStatus::Lost(LossReason::Disconnected));
            }
            let r = cvar
                .wait_timeout(sh, Duration::from_millis(100))
                .unwrap_or_else(|e| e.into_inner());
            sh = r.0;
        }
    }

    /// Force a `StateSync` ahead of the first dispatch to every client.
    /// Called after a coordinator restart (`afd serve --restore`): the
    /// clients re-attaching to the new process carry fleet state from
    /// whatever round their previous coordinator last closed, which the
    /// restored engine must overwrite before reusing them — exactly the
    /// reconnect-generation machinery, applied to generation-0 slots.
    pub fn mark_recovered(&self) {
        let mut sh = lock(&self.shared.0);
        for conn in sh.conns.iter_mut() {
            if conn.generation == 0 {
                conn.generation = 1;
            }
            conn.last_synced.clear();
        }
    }

    /// Stop both background threads and wait for them. Idempotent.
    fn halt(&self) {
        {
            let mut sh = lock(&self.shared.0);
            sh.stopping = true;
        }
        self.shared.1.notify_all();
        for slot in [&self.acceptor, &self.events] {
            let handle = lock(slot).take();
            if let Some(h) = handle {
                let _ = h.join();
            }
        }
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.halt();
    }
}

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn may_lose(&self) -> bool {
        true
    }

    fn wants_state_sync(&self) -> bool {
        self.resume
    }

    fn round_trip(
        &self,
        client: usize,
        offer: &[u8],
        model: &[u8],
        sync: Option<&StateSyncSnapshot>,
        _env: &mut ClientEnv<'_>,
        reply: &mut Vec<u8>,
    ) -> Result<RoundTripStatus> {
        let idx = client % self.nconns;
        // One span per slot on a synthetic track: Perfetto shows each
        // TCP connection as its own lane, so pipelining depth per slot
        // is visible at a glance.
        let _sp = crate::obs::span_on_track(
            crate::obs::Stage::RoundTrip,
            crate::obs::CONN_TRACK_BASE + idx as u32,
            client as u64,
            idx as u64,
        );
        if crate::obs::enabled() {
            crate::obs::metrics::CONN_ROUND_TRIPS[idx % crate::obs::metrics::CONN_SLOTS].incr();
        }
        // The trait ships opaque frames; recover the (round, client)
        // pipeline key from the offer itself (cheap — offers are tiny
        // next to the model payload).
        let (view, _) = frame::parse_frame(offer).context("round_trip offer frame")?;
        let o = frame::parse_round_offer(&view)?;
        anyhow::ensure!(
            o.client as usize == client,
            "offer frame addresses client {}, round_trip called for {client}",
            o.client
        );
        self.exchange(idx, o.round, o.client, offer, model, sync, reply)
    }

    fn finish(&self, client: usize, round: u32, included: bool) -> Result<()> {
        thread_local! {
            /// Reused close-frame buffer: `finish` runs once per
            /// exchanged round, hot enough that a fresh Vec per call
            /// showed up in allocation profiles.
            static CLOSE_BUF: RefCell<Vec<u8>> = RefCell::new(Vec::new());
        }
        CLOSE_BUF.with(|b| {
            let out = &mut *b.borrow_mut();
            out.clear();
            frame::encode_round_close(out, included, round, client as u32);
            let idx = client % self.nconns;
            let mut sh = lock(&self.shared.0);
            let conn = &mut sh.conns[idx];
            // Best effort: a decision addressed to a vacant slot is
            // dropped — the next dispatch to that session carries a
            // StateSync that supersedes it.
            if conn.stream.is_some() {
                conn.out.extend_from_slice(out);
            }
        });
        Ok(())
    }

    fn shutdown(&self) -> Result<()> {
        {
            let mut sh = lock(&self.shared.0);
            let mut bye = Vec::new();
            frame::encode_bye(&mut bye);
            for conn in sh.conns.iter_mut() {
                if conn.stream.is_some() {
                    conn.out.extend_from_slice(&bye);
                }
            }
        }
        self.halt();
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Remote client process
// ---------------------------------------------------------------------

/// Knobs for [`run_client_loop`].
#[derive(Clone, Debug)]
pub struct ClientOptions {
    /// How long to keep retrying the initial connect while the server
    /// comes up.
    pub connect_retry_s: f64,
    /// Reconnect window after a dropped connection; `<= 0` disables
    /// resume and the drop becomes the process's error.
    pub reconnect_s: f64,
    /// Exit (abruptly, without `Bye` — simulating a crash) after
    /// serving this many `ModelDown` rounds. Test/chaos hook.
    pub exit_after: Option<u64>,
}

impl Default for ClientOptions {
    fn default() -> Self {
        ClientOptions {
            connect_retry_s: 10.0,
            reconnect_s: 30.0,
            exit_after: None,
        }
    }
}

/// Why [`run_client_loop`] returned successfully.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClientEnd {
    /// The server said `Bye`: the experiment finished.
    Bye,
    /// The `exit_after` crash hook fired.
    ExitAfter,
}

struct PendingOffer {
    round: u32,
    client: u32,
    seed: u64,
    lr: f32,
    submodel: SubModel,
}

/// Deterministic capped exponential backoff for redial attempts: base
/// 100 ms doubling to a 5 s ceiling, with seed-derived jitter in
/// `[cap/2, cap]` so a restarted fleet does not dial in lockstep — yet
/// the same `(seed, attempt)` always sleeps the same, keeping chaos
/// runs reproducible.
pub fn backoff_delay(seed: u64, attempt: u32) -> Duration {
    const BASE_MS: u64 = 100;
    const CAP_MS: u64 = 5_000;
    let cap = (BASE_MS << attempt.min(6)).min(CAP_MS);
    // splitmix64 over (seed, attempt): cheap, stateless, deterministic.
    let mut z = seed ^ (attempt as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^= z >> 31;
    Duration::from_millis(cap / 2 + z % (cap / 2 + 1))
}

/// Dial `addr`, retrying with capped exponential backoff while the
/// window lasts. `seed` derives the jitter: the initial connect uses
/// the process id (fleet members spread out), a reconnect uses the
/// session token (deterministic per logical slot).
fn connect_within(addr: &str, window_s: f64, seed: u64) -> Result<TcpStream> {
    let deadline = Instant::now() + Duration::from_secs_f64(window_s.max(0.0));
    let mut attempt = 0u32;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                let now = Instant::now();
                if now >= deadline {
                    return Err(e).with_context(|| format!("connecting to {addr}"));
                }
                let delay = backoff_delay(seed, attempt).min(deadline - now);
                attempt = attempt.saturating_add(1);
                std::thread::sleep(delay);
            }
        }
    }
}

/// `Hello(token)` → `Config`; returns the server's fingerprint, the
/// (possibly newly assigned) session token, and the config JSON.
/// `Ready` is sent by the caller once it has validated the config.
fn client_handshake(
    stream: &mut TcpStream,
    token: u64,
    io_timeout: Duration,
    buf: &mut Vec<u8>,
    out: &mut Vec<u8>,
) -> Result<(u64, u64, String)> {
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(io_timeout))?;
    stream.set_write_timeout(Some(io_timeout))?;
    out.clear();
    frame::encode_hello(out, token);
    stream.write_all(out).context("sending Hello")?;
    read_frame_into(stream, buf).context("waiting for Config")?;
    let (view, _) = frame::parse_frame(buf).context("Config frame")?;
    let (fp, tok, json) = frame::parse_config(&view)?;
    Ok((fp, tok, json.to_string()))
}

/// The `afd client` main loop: connect (retrying while the server
/// comes up), handshake, then serve rounds until `Bye`.
///
/// The process rebuilds the whole deterministic environment from the
/// config the server ships — native runtime, dataset shards, fleet
/// RNG/DGC state — and executes each offered round through the same
/// [`client_execute`] the loopback path runs. Offers queue (the server
/// pipelines several rounds per connection) and are matched to their
/// `ModelDown` by `(round, client)`; DGC residuals are snapshotted per
/// round — bounded by [`MAX_PIPELINE`], never fleet-sized — and
/// committed on `Ack` / rolled back on `Cut`, mirroring the engine's
/// host-side bookkeeping exactly.
///
/// A dropped connection is not fatal while `reconnect_s` allows: the
/// loop redials with its session token, the server replays the open
/// rounds, and the `StateSync` frames it prefixes restore any state
/// this process mutated for rounds whose outcome it missed.
pub fn run_client_loop(addr: &str, opts: &ClientOptions) -> Result<ClientEnd> {
    // ---- connect + first handshake -----------------------------------
    let mut buf = Vec::new();
    let mut out = Vec::new();
    let mut stream = connect_within(addr, opts.connect_retry_s, std::process::id() as u64)?;
    let (server_fp, mut token, json_text) =
        client_handshake(&mut stream, 0, HANDSHAKE_TIMEOUT, &mut buf, &mut out)?;
    let json = crate::util::json::parse(&json_text)
        .map_err(|e| anyhow::anyhow!("config JSON from server: {e}"))?;
    let mut cfg = ExperimentConfig::default();
    cfg.apply_json(&json)?;
    anyhow::ensure!(
        cfg.backend == Backend::Native,
        "remote clients support the native backend only (PJRT artifacts \
         execute in the coordinator process)"
    );

    // ---- deterministic environment (pure function of the config) ----
    let (mlp, spec) = mlp_from_config(&cfg);
    let fp = spec.layout_fingerprint();
    anyhow::ensure!(
        fp == server_fp,
        "layout fingerprint mismatch: server {server_fp:#018x}, local {fp:#018x} \
         — diverged configs or binaries"
    );
    anyhow::ensure!(
        spec.params.iter().all(|p| p.transmit),
        "remote execution needs every parameter transmissible (non-transmit \
         parameters would be untrained zeros on the device)"
    );
    let mut data_cfg = cfg.data.clone();
    data_cfg.num_clients = cfg.num_clients;
    data_cfg.seed = cfg.seed;
    // Same population the coordinator holds: lazy mode derives each
    // client on demand (a remote peer of a million-client federation
    // must not eagerly build the whole fleet), eager mode shares one
    // generated dataset.
    let mut fleet = if cfg.population.lazy {
        anyhow::ensure!(
            spec.dataset == "synthetic",
            "population.lazy requires the synthetic dataset"
        );
        crate::clients::Population::lazy(
            spec.clone(),
            data_cfg.clone(),
            cfg.dgc.clone(),
            cfg.seed,
            &cfg.population,
        )
    } else {
        let dataset = data::generate(&spec, &data_cfg);
        anyhow::ensure!(
            dataset.num_clients() == cfg.num_clients,
            "dataset generator returned wrong client count"
        );
        crate::clients::Population::eager(
            std::sync::Arc::new(dataset),
            cfg.dgc.clone(),
            cfg.seed,
            &cfg.population,
        )
    };
    let codec = crate::compression::make_dense_codec(&cfg.downlink)?;
    let my_codec_id = codec_id(codec.name());
    let plans = PlanCache::default();
    let mut ws = crate::tensor::kernels::Workspace::new();
    let base = vec![0.0f32; spec.num_params];
    let mut order: Vec<u32> = Vec::new();
    let mut reply = Vec::new();

    // Both directions time out: a stalled reader on the far side must
    // surface as an error here, not a hang (the session loop then
    // treats it like any other drop).
    let io_timeout = Duration::from_secs_f64(cfg.transport.io_timeout_s.max(1.0));
    stream.set_read_timeout(Some(io_timeout))?;
    stream.set_write_timeout(Some(io_timeout))?;
    out.clear();
    frame::encode_ready(&mut out, fp, crate::obs::span::monotonic_ns());
    stream.write_all(&out).context("sending Ready")?;

    // ---- session state -----------------------------------------------
    // Telemetry side channel (armed by AFD_TRACE=1): delta-ships this
    // process's span rings, counters and histograms right after each
    // UpdateUp. Preallocated so a warm round stays zero-alloc.
    let mut shipper = crate::obs::remote::Shipper::new();
    let mut tele: Vec<u8> = Vec::with_capacity(64 * 1024);
    let mut offers: VecDeque<PendingOffer> = VecDeque::new();
    // Rollback snapshots are residuals-only and capped: v1 cloned whole
    // `DgcState`s into a fleet-sized table, which at million-client
    // scale dwarfed the ResidualStore byte budget.
    let mut pending: HashMap<u32, (Vec<f32>, Vec<f32>)> = HashMap::new();
    let (mut sync_u, mut sync_v) = (Vec::new(), Vec::new());
    let mut served: u64 = 0;

    // ---- round service loop ------------------------------------------
    let end = 'session: loop {
        let drop_err: anyhow::Error = 'serve: loop {
            if let Some(n) = opts.exit_after {
                if served >= n {
                    break 'session ClientEnd::ExitAfter;
                }
            }
            if let Err(e) = read_frame_into(&mut stream, &mut buf) {
                break 'serve e;
            }
            let (view, used) = frame::parse_frame(&buf).context("frame from server")?;
            anyhow::ensure!(used == buf.len(), "trailing bytes after frame");
            match view.kind {
                FrameKind::StateSync => {
                    let s = frame::parse_state_sync(&view)?;
                    let c = s.client as usize;
                    anyhow::ensure!(c < fleet.len(), "StateSync for unknown client {c}");
                    s.read_residuals(&mut sync_u, &mut sync_v);
                    let st = fleet.client(c);
                    st.participations = s.participations as usize;
                    st.rng = Pcg64::from_raw(s.rng_state, s.rng_inc);
                    st.dgc.restore_residuals(&sync_u, &sync_v);
                    // Whatever round the snapshot predates supersedes
                    // any rollback point this process was holding.
                    pending.remove(&s.client);
                }
                FrameKind::RoundOffer => {
                    anyhow::ensure!(
                        offers.len() < MAX_PIPELINE,
                        "offer queue overflow: {} offers pending (cap {MAX_PIPELINE})",
                        offers.len()
                    );
                    let o = frame::parse_round_offer(&view)?;
                    anyhow::ensure!(
                        o.group_count() == spec.mask_groups.len(),
                        "offer carries {} mask groups, spec has {}",
                        o.group_count(),
                        spec.mask_groups.len()
                    );
                    let submodel = o.submodel();
                    for (g, keep) in submodel.keep.iter().enumerate() {
                        anyhow::ensure!(
                            keep.len() == spec.mask_groups[g].size,
                            "offer group {g} has {} units, spec has {}",
                            keep.len(),
                            spec.mask_groups[g].size
                        );
                    }
                    anyhow::ensure!(
                        !offers.iter().any(|p| p.round == o.round && p.client == o.client),
                        "duplicate RoundOffer for round {} client {}",
                        o.round,
                        o.client
                    );
                    offers.push_back(PendingOffer {
                        round: o.round,
                        client: o.client,
                        seed: o.seed,
                        lr: o.lr,
                        submodel,
                    });
                }
                FrameKind::ModelDown => {
                    let md = frame::parse_model_down(&view)?;
                    let pos = offers
                        .iter()
                        .position(|o| o.round == md.round && o.client == md.client)
                        .with_context(|| {
                            format!(
                                "ModelDown for round {} client {} without a matching RoundOffer",
                                md.round, md.client
                            )
                        })?;
                    let offer = offers.remove(pos).expect("indexed offer");
                    anyhow::ensure!(
                        md.codec == my_codec_id,
                        "server encodes with codec id {}, this client is configured \
                         for {} ({})",
                        md.codec,
                        my_codec_id,
                        codec.name()
                    );
                    let c = md.client as usize;
                    anyhow::ensure!(c < fleet.len(), "client id {c} out of range");
                    // Mirror the coordinator's dispatch-time bookkeeping:
                    // same epoch RNG draw, same DGC snapshot discipline.
                    let plan = plans.get(&spec, &offer.submodel);
                    let num_samples = fleet.num_samples(c) as u32;
                    fleet.client(c).participations += 1;
                    let mut epoch = fleet.client(c).take_epoch_buf();
                    fleet.assemble_epoch(c, &spec, &mut order, &mut epoch);
                    if cfg.uplink_dgc {
                        anyhow::ensure!(
                            pending.len() < MAX_PIPELINE,
                            "rollback snapshot budget exceeded (cap {MAX_PIPELINE})"
                        );
                        let (u, v) = fleet.client(c).dgc.residuals();
                        pending.insert(md.client, (u.to_vec(), v.to_vec()));
                    }
                    let mut env = ClientEnv {
                        spec: &spec,
                        runtime: &mlp,
                        codec: codec.as_ref(),
                        base_params: &base,
                        data: &epoch,
                        dgc: if cfg.uplink_dgc {
                            Some(&mut fleet.client(c).dgc)
                        } else {
                            None
                        },
                        submodel: &offer.submodel,
                        plan: &plan,
                        num_samples,
                        ws: &mut ws,
                    };
                    client_execute(
                        offer.round,
                        md.client,
                        offer.seed,
                        offer.lr,
                        md.payload,
                        &mut env,
                        &mut reply,
                    )?;
                    let write_res = stream.write_all(&reply);
                    fleet.client(c).put_epoch_buf(epoch);
                    // Dispatch boundary: keep the resident set inside
                    // the byte budget (no-op for unbudgeted populations).
                    fleet.end_round();
                    served += 1;
                    if let Err(e) = write_res {
                        break 'serve anyhow::anyhow!("sending UpdateUp: {e}");
                    }
                    if crate::obs::enabled() {
                        tele.clear();
                        shipper.encode_into(&mut tele, offer.round);
                        if let Err(e) = stream.write_all(&tele) {
                            break 'serve anyhow::anyhow!("sending Telemetry: {e}");
                        }
                    }
                }
                FrameKind::Ack | FrameKind::Cut => {
                    let close = frame::parse_round_close(&view)?;
                    let c = close.client as usize;
                    anyhow::ensure!(c < fleet.len(), "round close for unknown client {c}");
                    match view.kind {
                        // Aggregated: the post-upload accumulators are
                        // now the truth — drop the rollback point.
                        FrameKind::Ack => {
                            pending.remove(&close.client);
                        }
                        // Discarded: the upload never landed — restore
                        // the pre-round residuals (DGC keeps its
                        // no-information-loss invariant).
                        _ => {
                            if let Some((u, v)) = pending.remove(&close.client) {
                                fleet.client(c).dgc.restore_residuals(&u, &v);
                            }
                        }
                    }
                }
                FrameKind::Bye => break 'session ClientEnd::Bye,
                other => anyhow::bail!("unexpected {other:?} frame mid-session"),
            }
        };
        // ---- dropped: resume the session or give up ------------------
        anyhow::ensure!(
            opts.reconnect_s > 0.0,
            "connection to coordinator lost (reconnect disabled): {drop_err:#}"
        );
        offers.clear();
        // Safe to forget rollback points: the server syncs every client
        // it touches after a reconnect before its next round.
        pending.clear();
        stream = connect_within(addr, opts.reconnect_s, token)
            .with_context(|| format!("reconnecting after: {drop_err:#}"))?;
        let (sfp, tok, _json) =
            client_handshake(&mut stream, token, io_timeout, &mut buf, &mut out)?;
        anyhow::ensure!(sfp == fp, "server fingerprint changed across reconnect");
        token = tok;
        out.clear();
        frame::encode_ready(&mut out, fp, crate::obs::span::monotonic_ns());
        stream.write_all(&out).context("sending Ready after reconnect")?;
    };
    Ok(end)
}

#[cfg(test)]
mod tests {
    use super::*;

    const FP: u64 = 0xfeed_beef_cafe_0001;

    fn test_cfg(io_timeout_s: f64, resume: bool) -> TransportConfig {
        TransportConfig {
            io_timeout_s,
            resume,
        }
    }

    /// Minimal fake remote: handshake only, leaving the socket in the
    /// caller's hands. Returns the stream and the session token.
    fn fake_client(addr: &str, token: u64) -> (TcpStream, u64) {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut out = Vec::new();
        frame::encode_hello(&mut out, token);
        s.write_all(&out).unwrap();
        let mut buf = Vec::new();
        read_frame_into(&mut s, &mut buf).unwrap();
        let (view, _) = frame::parse_frame(&buf).unwrap();
        let (fp, tok, _json) = frame::parse_config(&view).unwrap();
        assert_eq!(fp, FP);
        out.clear();
        frame::encode_ready(&mut out, fp, 1);
        s.write_all(&out).unwrap();
        (s, tok)
    }

    fn serve_one(io_timeout_s: f64, resume: bool) -> (TcpTransport, TcpStream, u64) {
        let server = TcpServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let tcfg = test_cfg(io_timeout_s, resume);
        let h = std::thread::spawn(move || server.accept_clients(1, "{}", FP, &tcfg));
        let (stream, token) = fake_client(&addr, 0);
        let transport = h.join().unwrap().unwrap();
        (transport, stream, token)
    }

    fn offer_frame(round: u32, client: u32) -> Vec<u8> {
        let sm = SubModel::from_keep(vec![vec![true, false, true]]);
        let mut out = Vec::new();
        frame::encode_round_offer(&mut out, round, client, 99, 0.1, 0.0, &sm);
        out
    }

    fn model_frame(round: u32, client: u32) -> Vec<u8> {
        let mut out = Vec::new();
        frame::encode_model_down(&mut out, round, client, 0, &[1, 2, 3]);
        out
    }

    fn update_up_frame(round: u32, client: u32) -> Vec<u8> {
        let mut out = Vec::new();
        let base = frame::begin_frame(&mut out, FrameKind::UpdateUp);
        out.extend_from_slice(&round.to_le_bytes());
        out.extend_from_slice(&client.to_le_bytes());
        frame::end_frame(&mut out, base);
        out
    }

    /// Read `RoundOffer` ‖ `ModelDown` off a fake client socket and
    /// return the offer's pipeline key.
    fn read_round(s: &mut TcpStream, buf: &mut Vec<u8>) -> (u32, u32) {
        read_frame_into(s, buf).unwrap();
        let (view, _) = frame::parse_frame(buf).unwrap();
        assert_eq!(view.kind, FrameKind::RoundOffer);
        let o = frame::parse_round_offer(&view).unwrap();
        let key = (o.round, o.client);
        read_frame_into(s, buf).unwrap();
        let (view, _) = frame::parse_frame(buf).unwrap();
        assert_eq!(view.kind, FrameKind::ModelDown);
        key
    }

    #[test]
    fn backoff_is_deterministic_capped_and_jittered() {
        for attempt in 0..12u32 {
            let d = backoff_delay(42, attempt);
            assert_eq!(
                d,
                backoff_delay(42, attempt),
                "same (seed, attempt) must sleep the same"
            );
            let cap = (100u64 << attempt.min(6)).min(5_000);
            let ms = d.as_millis() as u64;
            assert!(
                ms >= cap / 2 && ms <= cap,
                "attempt {attempt}: {ms} ms outside [{}, {cap}]",
                cap / 2
            );
        }
        // Different seeds must not redial in lockstep on every attempt.
        assert!((0..12u32).any(|a| backoff_delay(1, a) != backoff_delay(2, a)));
    }

    #[test]
    fn shared_lock_recovers_from_poison() {
        let m = Mutex::new(5i32);
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = m.lock().unwrap();
            panic!("poison the lock");
        }));
        assert!(m.is_poisoned());
        // The old `.lock().unwrap()` would propagate the panic here.
        assert_eq!(*lock(&m), 5);
    }

    #[test]
    fn stalled_connection_surfaces_as_timeout_loss() {
        let (transport, stream, _token) = serve_one(0.3, true);
        // The fake never reads nor replies: the round must resolve as
        // a timeout loss, not hang the caller.
        let mut reply = Vec::new();
        let st = transport
            .exchange(0, 0, 0, &offer_frame(0, 0), &model_frame(0, 0), None, &mut reply)
            .unwrap();
        assert_eq!(st, RoundTripStatus::Lost(LossReason::Timeout));
        drop(stream);
        transport.shutdown().unwrap();
    }

    #[test]
    fn dead_connection_without_resume_is_a_disconnect_loss() {
        let (transport, stream, _token) = serve_one(10.0, false);
        drop(stream); // client crashes
        std::thread::sleep(Duration::from_millis(300)); // event loop notices EOF
        let mut reply = Vec::new();
        let st = transport
            .exchange(0, 0, 0, &offer_frame(0, 0), &model_frame(0, 0), None, &mut reply)
            .unwrap();
        assert_eq!(st, RoundTripStatus::Lost(LossReason::Disconnected));
        transport.shutdown().unwrap();
    }

    #[test]
    fn pipelined_rounds_match_replies_to_their_exchange() {
        let (transport, mut stream, _token) = serve_one(10.0, true);
        let transport = Arc::new(transport);
        // Fake remote: read two full rounds first (so both are in
        // flight simultaneously), then answer them in arrival order.
        let remote = std::thread::spawn(move || {
            let mut buf = Vec::new();
            let keys = [read_round(&mut stream, &mut buf), read_round(&mut stream, &mut buf)];
            for (r, c) in keys {
                stream.write_all(&update_up_frame(r, c)).unwrap();
            }
            stream
        });
        let spawn_exchange = |round: u32, client: u32| {
            let t = transport.clone();
            std::thread::spawn(move || {
                let mut reply = Vec::new();
                let st = t
                    .exchange(
                        0,
                        round,
                        client,
                        &offer_frame(round, client),
                        &model_frame(round, client),
                        None,
                        &mut reply,
                    )
                    .unwrap();
                (st, reply)
            })
        };
        let e1 = spawn_exchange(7, 0);
        let e2 = spawn_exchange(7, 1);
        for (handle, want) in [(e1, (7u32, 0u32)), (e2, (7u32, 1u32))] {
            let (st, reply) = handle.join().unwrap();
            assert_eq!(st, RoundTripStatus::Delivered);
            let (view, _) = frame::parse_frame(&reply).unwrap();
            assert_eq!(view.kind, FrameKind::UpdateUp);
            let r = u32::from_le_bytes(view.payload[0..4].try_into().unwrap());
            let c = u32::from_le_bytes(view.payload[4..8].try_into().unwrap());
            // FIFO matching must hand each exchange its own reply no
            // matter which thread enqueued first.
            assert_eq!((r, c), want);
        }
        drop(remote.join().unwrap());
        transport.shutdown().unwrap();
    }

    #[test]
    fn reconnect_replays_open_round_with_state_sync() {
        let server = TcpServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let tcfg = test_cfg(10.0, true);
        let h = std::thread::spawn(move || server.accept_clients(1, "{}", FP, &tcfg));
        let (mut a, token) = fake_client(&addr, 0);
        let transport = Arc::new(h.join().unwrap().unwrap());
        assert_eq!(token, 1);

        let snap = StateSyncSnapshot {
            client: 0,
            participations: 5,
            rng_state: 11,
            rng_inc: 13,
            dgc_u: vec![1.5],
            dgc_v: vec![-2.5],
        };
        let t = transport.clone();
        let s2 = snap.clone();
        let ex = std::thread::spawn(move || {
            let mut reply = Vec::new();
            let st = t
                .exchange(
                    0,
                    3,
                    0,
                    &offer_frame(3, 0),
                    &model_frame(3, 0),
                    Some(&s2),
                    &mut reply,
                )
                .unwrap();
            (st, reply)
        });
        // First connection receives the round plainly (generation 0 ⇒
        // no StateSync), then dies without answering.
        let mut buf = Vec::new();
        assert_eq!(read_round(&mut a, &mut buf), (3, 0));
        drop(a);

        // Reconnect with the session token: the replay must lead with
        // the snapshot, then repeat the round.
        let (mut b, token2) = fake_client(&addr, token);
        assert_eq!(token2, token);
        read_frame_into(&mut b, &mut buf).unwrap();
        let (view, _) = frame::parse_frame(&buf).unwrap();
        assert_eq!(view.kind, FrameKind::StateSync);
        let s = frame::parse_state_sync(&view).unwrap();
        assert_eq!(s.client, snap.client);
        assert_eq!(s.participations, snap.participations);
        assert_eq!(s.rng_state, snap.rng_state);
        assert_eq!(s.rng_inc, snap.rng_inc);
        let (mut u, mut v) = (Vec::new(), Vec::new());
        s.read_residuals(&mut u, &mut v);
        assert_eq!((u, v), (snap.dgc_u.clone(), snap.dgc_v.clone()));
        assert_eq!(read_round(&mut b, &mut buf), (3, 0));
        b.write_all(&update_up_frame(3, 0)).unwrap();

        let (st, reply) = ex.join().unwrap();
        assert_eq!(st, RoundTripStatus::Delivered);
        assert!(!reply.is_empty());
        drop(b);
        transport.shutdown().unwrap();
    }
}
