//! Real socket transport: the coordinator (`afd serve`) drives a swarm
//! of client processes (`afd client`) over `std::net` TCP.
//!
//! ## Topology
//!
//! The coordinator accepts a fixed number of connections; each client
//! process builds the *full* deterministic client fleet from the
//! config the server ships in the handshake (datasets, per-client RNG
//! streams, DGC accumulators are all pure functions of the seed), and
//! logical client `c` is routed to connection `c % conns`. Any
//! connection could therefore serve any logical client — the static
//! routing just pins each client's state evolution to one process.
//!
//! ## Handshake
//!
//! `Hello` (client) → `Config` (server: experiment JSON + the model
//! layout fingerprint) → `Ready` (client echoes the fingerprint it
//! derived from the config). A client whose rebuilt spec fingerprints
//! differently — diverged binaries, wrong config — is rejected before
//! the first round with both fingerprints in the error.
//!
//! ## Rounds
//!
//! [`TcpTransport::round_trip`] locks the client's connection, writes
//! the `RoundOffer` + `ModelDown` frames, and blocks for the `UpdateUp`
//! reply; the per-connection mutex serializes logical clients that
//! share a connection (the remote loop is strictly request/response),
//! while different connections proceed in parallel under the engine's
//! worker pool. `finish` delivers `Ack`/`Cut` so the remote commits or
//! rolls back its DGC snapshot exactly when the engine does the same
//! to its host-side shadow; `shutdown` sends `Bye`.
//!
//! The host-side [`ClientEnv`] is ignored here — the remote process
//! owns the real device state. Both evolve identically (same frames,
//! same seeds, same code: [`client_execute`]), which is what the
//! TCP-vs-loopback bit-identity test and the CI socket smoke pin.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::{Backend, ExperimentConfig};
use crate::data;
use crate::model::packing::PlanCache;
use crate::model::submodel::SubModel;
use crate::runtime::native::mlp_from_config;
use crate::transport::client_round::{client_execute, ClientEnv};
use crate::transport::frame::{self, FrameKind};
use crate::transport::{codec_id, Transport};

/// Socket read timeout: generous enough for a slow remote epoch, small
/// enough that a dead peer surfaces as an error instead of a hang.
const IO_TIMEOUT: Duration = Duration::from_secs(600);

/// Read one whole frame (header + payload + CRC) from a stream into
/// `buf` (cleared; capacity reused). Validates the magic and the
/// length cap *before* trusting the prefix, so a corrupt peer cannot
/// make the reader allocate unboundedly or stall on a bogus length;
/// CRC/version are verified by the caller's `parse_frame`.
fn read_frame_into(stream: &mut TcpStream, buf: &mut Vec<u8>) -> Result<()> {
    buf.clear();
    buf.resize(frame::HEADER_LEN, 0);
    stream.read_exact(&mut buf[..]).context("reading frame header")?;
    anyhow::ensure!(
        buf[0..2] == frame::MAGIC,
        "bad frame magic from peer: {:02x?}",
        &buf[0..2]
    );
    let len = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
    anyhow::ensure!(
        len <= frame::MAX_PAYLOAD,
        "oversized frame from peer: {len}-byte payload (cap {})",
        frame::MAX_PAYLOAD
    );
    let total = frame::HEADER_LEN + len + frame::CRC_LEN;
    buf.resize(total, 0);
    let body = &mut buf[frame::HEADER_LEN..];
    stream.read_exact(body).context("reading frame body")?;
    Ok(())
}

/// A bound listener that has not accepted its clients yet (split from
/// [`TcpTransport`] so callers can learn the ephemeral port — tests
/// bind `127.0.0.1:0` — before any client connects).
pub struct TcpServer {
    listener: TcpListener,
}

impl TcpServer {
    pub fn bind(addr: &str) -> Result<TcpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(TcpServer { listener })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept `conns` client connections and run the handshake with
    /// each: read `Hello`, send `Config` (the experiment JSON +
    /// `fingerprint`), require a `Ready` echoing the same fingerprint.
    pub fn accept_clients(
        self,
        conns: usize,
        cfg_json: &str,
        fingerprint: u64,
    ) -> Result<TcpTransport> {
        anyhow::ensure!(conns > 0, "a TCP transport needs at least one connection");
        let mut accepted = Vec::with_capacity(conns);
        let mut buf = Vec::new();
        let mut out = Vec::new();
        for i in 0..conns {
            let (mut stream, peer) = self
                .listener
                .accept()
                .with_context(|| format!("accepting client connection {i}"))?;
            stream.set_nodelay(true)?;
            stream.set_read_timeout(Some(IO_TIMEOUT))?;
            read_frame_into(&mut stream, &mut buf)
                .with_context(|| format!("handshake with {peer}"))?;
            let (view, _) = frame::parse_frame(&buf)
                .with_context(|| format!("handshake frame from {peer}"))?;
            anyhow::ensure!(
                view.kind == FrameKind::Hello,
                "peer {peer} opened with {:?}, expected Hello",
                view.kind
            );
            out.clear();
            frame::encode_config(&mut out, fingerprint, cfg_json);
            stream.write_all(&out).context("sending Config")?;
            read_frame_into(&mut stream, &mut buf)
                .with_context(|| format!("waiting for Ready from {peer}"))?;
            let (view, _) = frame::parse_frame(&buf)?;
            let theirs = frame::parse_ready(&view)?;
            anyhow::ensure!(
                theirs == fingerprint,
                "peer {peer} derived layout fingerprint {theirs:#018x}, server has \
                 {fingerprint:#018x} — mismatched configs or binaries"
            );
            accepted.push(Mutex::new(stream));
        }
        Ok(TcpTransport { conns: accepted })
    }
}

/// The server side of the socket transport: one framed request/response
/// channel per accepted connection, logical clients routed statically.
pub struct TcpTransport {
    conns: Vec<Mutex<TcpStream>>,
}

impl TcpTransport {
    fn conn(&self, client: usize) -> &Mutex<TcpStream> {
        &self.conns[client % self.conns.len()]
    }
}

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn round_trip(
        &self,
        client: usize,
        offer: &[u8],
        model: &[u8],
        _env: &mut ClientEnv<'_>,
        reply: &mut Vec<u8>,
    ) -> Result<()> {
        let idx = client % self.conns.len();
        // One span per connection on a synthetic track: Perfetto shows
        // each TCP connection as its own lane, so serialization of
        // logical clients sharing a connection is visible at a glance.
        let _sp = crate::obs::span_on_track(
            crate::obs::Stage::RoundTrip,
            crate::obs::CONN_TRACK_BASE + idx as u32,
            client as u64,
            idx as u64,
        );
        if crate::obs::enabled() {
            crate::obs::metrics::CONN_ROUND_TRIPS[idx % crate::obs::metrics::CONN_SLOTS].incr();
        }
        let mut stream = self.conns[idx].lock().unwrap();
        stream
            .write_all(offer)
            .with_context(|| format!("sending RoundOffer to client {client}"))?;
        stream
            .write_all(model)
            .with_context(|| format!("sending ModelDown to client {client}"))?;
        // No parse here: `read_frame_into` validated magic and length,
        // and the caller (`run_client_round`) runs the one full parse —
        // CRC, kind, payload grammar — over the reply. Parsing twice
        // would double the largest CRC pass of the conversation.
        read_frame_into(&mut stream, reply)
            .with_context(|| format!("waiting for UpdateUp from client {client}"))?;
        Ok(())
    }

    fn finish(&self, client: usize, round: u32, included: bool) -> Result<()> {
        let mut out = Vec::with_capacity(frame::ROUND_CLOSE_WIRE as usize);
        frame::encode_round_close(&mut out, included, round, client as u32);
        let mut stream = self.conn(client).lock().unwrap();
        stream
            .write_all(&out)
            .with_context(|| format!("sending round close to client {client}"))
    }

    fn shutdown(&self) -> Result<()> {
        let mut out = Vec::new();
        frame::encode_bye(&mut out);
        for conn in &self.conns {
            // Best effort: a client that already vanished must not turn
            // a finished experiment into an error.
            let _ = conn.lock().unwrap().write_all(&out);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Remote client process
// ---------------------------------------------------------------------

struct PendingOffer {
    round: u32,
    client: u32,
    seed: u64,
    lr: f32,
    submodel: SubModel,
}

/// The `afd client` main loop: connect (retrying while the server
/// comes up), handshake, then serve rounds until `Bye`.
///
/// The process rebuilds the whole deterministic environment from the
/// config the server ships — native runtime, dataset shards, fleet
/// RNG/DGC state — and executes each offered round through the same
/// [`client_execute`] the loopback path runs. DGC state is snapshotted
/// per round and committed on `Ack` / rolled back on `Cut`, mirroring
/// the engine's host-side bookkeeping exactly.
pub fn run_client_loop(addr: &str, connect_retry_s: f64) -> Result<()> {
    // ---- connect (the server may still be binding) -------------------
    let deadline = Instant::now() + Duration::from_secs_f64(connect_retry_s.max(0.0));
    let mut stream = loop {
        match TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(e).with_context(|| format!("connecting to {addr}"));
                }
                std::thread::sleep(Duration::from_millis(200));
            }
        }
    };
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(IO_TIMEOUT))?;

    // ---- handshake ---------------------------------------------------
    let mut buf = Vec::new();
    let mut out = Vec::new();
    frame::encode_hello(&mut out);
    stream.write_all(&out).context("sending Hello")?;
    read_frame_into(&mut stream, &mut buf).context("waiting for Config")?;
    let (view, _) = frame::parse_frame(&buf).context("Config frame")?;
    let (server_fp, json_text) = frame::parse_config(&view)?;
    let json = crate::util::json::parse(json_text)
        .map_err(|e| anyhow::anyhow!("config JSON from server: {e}"))?;
    let mut cfg = ExperimentConfig::default();
    cfg.apply_json(&json)?;
    anyhow::ensure!(
        cfg.backend == Backend::Native,
        "remote clients support the native backend only (PJRT artifacts \
         execute in the coordinator process)"
    );

    // ---- deterministic environment (pure function of the config) ----
    let (mlp, spec) = mlp_from_config(&cfg);
    let fp = spec.layout_fingerprint();
    anyhow::ensure!(
        fp == server_fp,
        "layout fingerprint mismatch: server {server_fp:#018x}, local {fp:#018x} \
         — diverged configs or binaries"
    );
    anyhow::ensure!(
        spec.params.iter().all(|p| p.transmit),
        "remote execution needs every parameter transmissible (non-transmit \
         parameters would be untrained zeros on the device)"
    );
    let mut data_cfg = cfg.data.clone();
    data_cfg.num_clients = cfg.num_clients;
    data_cfg.seed = cfg.seed;
    // Same population the coordinator holds: lazy mode derives each
    // client on demand (a remote peer of a million-client federation
    // must not eagerly build the whole fleet), eager mode shares one
    // generated dataset.
    let mut fleet = if cfg.population.lazy {
        anyhow::ensure!(
            spec.dataset == "synthetic",
            "population.lazy requires the synthetic dataset"
        );
        crate::clients::Population::lazy(
            spec.clone(),
            data_cfg.clone(),
            cfg.dgc.clone(),
            cfg.seed,
            &cfg.population,
        )
    } else {
        let dataset = data::generate(&spec, &data_cfg);
        anyhow::ensure!(
            dataset.num_clients() == cfg.num_clients,
            "dataset generator returned wrong client count"
        );
        crate::clients::Population::eager(
            std::sync::Arc::new(dataset),
            cfg.dgc.clone(),
            cfg.seed,
            &cfg.population,
        )
    };
    let codec = crate::compression::make_dense_codec(&cfg.downlink)?;
    let my_codec_id = codec_id(codec.name());
    let plans = PlanCache::default();
    let mut ws = crate::tensor::kernels::Workspace::new();
    let base = vec![0.0f32; spec.num_params];
    let mut order: Vec<u32> = Vec::new();
    let mut reply = Vec::new();
    let mut pending_offer: Option<PendingOffer> = None;
    let mut pending_dgc: Vec<Option<crate::compression::dgc::DgcState>> =
        (0..fleet.len()).map(|_| None).collect();

    out.clear();
    frame::encode_ready(&mut out, fp);
    stream.write_all(&out).context("sending Ready")?;

    // ---- round service loop ------------------------------------------
    loop {
        read_frame_into(&mut stream, &mut buf).context("waiting for next frame")?;
        let (view, used) = frame::parse_frame(&buf).context("frame from server")?;
        anyhow::ensure!(used == buf.len(), "trailing bytes after frame");
        match view.kind {
            FrameKind::RoundOffer => {
                anyhow::ensure!(
                    pending_offer.is_none(),
                    "interleaved RoundOffer before the previous ModelDown"
                );
                let o = frame::parse_round_offer(&view)?;
                anyhow::ensure!(
                    o.group_count() == spec.mask_groups.len(),
                    "offer carries {} mask groups, spec has {}",
                    o.group_count(),
                    spec.mask_groups.len()
                );
                let submodel = o.submodel();
                for (g, keep) in submodel.keep.iter().enumerate() {
                    anyhow::ensure!(
                        keep.len() == spec.mask_groups[g].size,
                        "offer group {g} has {} units, spec has {}",
                        keep.len(),
                        spec.mask_groups[g].size
                    );
                }
                pending_offer = Some(PendingOffer {
                    round: o.round,
                    client: o.client,
                    seed: o.seed,
                    lr: o.lr,
                    submodel,
                });
            }
            FrameKind::ModelDown => {
                let offer = pending_offer
                    .take()
                    .context("ModelDown without a preceding RoundOffer")?;
                let md = frame::parse_model_down(&view)?;
                anyhow::ensure!(
                    md.client == offer.client && md.round == offer.round,
                    "ModelDown for client {} round {} after offer for client {} \
                     round {}",
                    md.client,
                    md.round,
                    offer.client,
                    offer.round
                );
                anyhow::ensure!(
                    md.codec == my_codec_id,
                    "server encodes with codec id {}, this client is configured \
                     for {} ({})",
                    md.codec,
                    my_codec_id,
                    codec.name()
                );
                let c = md.client as usize;
                anyhow::ensure!(c < fleet.len(), "client id {c} out of range");
                // Mirror the coordinator's dispatch-time bookkeeping:
                // same epoch RNG draw, same DGC snapshot discipline.
                let plan = plans.get(&spec, &offer.submodel);
                let num_samples = fleet.num_samples(c) as u32;
                fleet.client(c).participations += 1;
                let mut epoch = fleet.client(c).take_epoch_buf();
                fleet.assemble_epoch(c, &spec, &mut order, &mut epoch);
                if cfg.uplink_dgc {
                    pending_dgc[c] = Some(fleet.client(c).dgc.clone());
                }
                let mut env = ClientEnv {
                    spec: &spec,
                    runtime: &mlp,
                    codec: codec.as_ref(),
                    base_params: &base,
                    data: &epoch,
                    dgc: if cfg.uplink_dgc {
                        Some(&mut fleet.client(c).dgc)
                    } else {
                        None
                    },
                    submodel: &offer.submodel,
                    plan: &plan,
                    num_samples,
                    ws: &mut ws,
                };
                client_execute(
                    offer.round,
                    md.client,
                    offer.seed,
                    offer.lr,
                    md.payload,
                    &mut env,
                    &mut reply,
                )?;
                stream.write_all(&reply).context("sending UpdateUp")?;
                fleet.client(c).put_epoch_buf(epoch);
                // Dispatch boundary: keep the resident set inside the
                // byte budget (no-op for unbudgeted populations).
                fleet.end_round();
            }
            FrameKind::Ack | FrameKind::Cut => {
                let close = frame::parse_round_close(&view)?;
                let c = close.client as usize;
                anyhow::ensure!(c < fleet.len(), "round close for unknown client {c}");
                match view.kind {
                    // Aggregated: the post-upload accumulators are now
                    // the truth — drop the snapshot.
                    FrameKind::Ack => {
                        pending_dgc[c] = None;
                    }
                    // Discarded: the upload never landed — restore the
                    // pre-round accumulators (DGC keeps its
                    // no-information-loss invariant).
                    _ => {
                        if let Some(snap) = pending_dgc[c].take() {
                            fleet.client(c).dgc = snap;
                        }
                    }
                }
            }
            FrameKind::Bye => return Ok(()),
            other => anyhow::bail!("unexpected {other:?} frame mid-session"),
        }
    }
}
