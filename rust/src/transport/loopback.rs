//! In-process transport: the engine path the experiments always ran,
//! now speaking frames.
//!
//! [`Loopback`] executes the client half of a round on the calling
//! thread, against the host-side [`ClientEnv`] the engine job carries
//! — but it consumes the *frames*, not the job's structures: the offer
//! and model frames are fully parsed (magic, version, CRC, payload
//! grammar) exactly as a remote receiver would parse them, and the
//! update comes back as a framed reply. The transport layer therefore
//! exercises the real wire format on every round of every test, while
//! adding zero threads, zero sockets and zero copies beyond the frames
//! themselves.
//!
//! `finish`/`shutdown` are no-ops: the device state lives host-side,
//! where the engine already performs the Ack/Cut commit-or-rollback on
//! its own fleet structures.
//!
//! With tracing enabled the loopback also mirrors the **distributed
//! telemetry plane** in-process: after each delivered round it runs a
//! [`crate::obs::remote::Shipper`] over the local rings, encodes a real
//! `Telemetry` frame, parses it back and merges it into the remote
//! registry under the process name `"loopback"` — so every test that
//! runs traced loopback rounds exercises the full encode → parse →
//! merge path without a socket.

use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::fault::{self, Site};
use crate::transport::client_round::{client_execute, ClientEnv};
use crate::transport::frame;
use crate::transport::{LossReason, RoundTripStatus, StateSyncSnapshot, Transport};

/// The in-process [`Transport`] (default for every experiment).
#[derive(Default)]
pub struct Loopback {
    /// Telemetry mirror: shipper cursors + frame buffer + registry id,
    /// shared across the engine's worker threads.
    tele: Mutex<Option<(crate::obs::remote::Shipper, Vec<u8>, usize)>>,
}

impl Loopback {
    /// Delta-ship the local rings/counters through the real wire
    /// format and merge the result, as a remote client would at a
    /// round boundary. Any parse failure here is a bug in the encoder,
    /// so it surfaces loudly in tests via `expect`.
    fn mirror_telemetry(&self, round: u32) {
        let mut guard = self.tele.lock().unwrap_or_else(|e| e.into_inner());
        let (shipper, buf, id) = guard.get_or_insert_with(|| {
            (
                crate::obs::remote::Shipper::new(),
                Vec::with_capacity(64 * 1024),
                crate::obs::remote::register("loopback"),
            )
        });
        crate::obs::remote::anchor(*id, crate::obs::span::monotonic_ns());
        buf.clear();
        shipper.encode_into(buf, round);
        let (view, _) = frame::parse_frame(buf).expect("self-encoded telemetry frame");
        let msg = frame::parse_telemetry(&view).expect("self-encoded telemetry payload");
        crate::obs::remote::ingest(*id, &msg);
        crate::obs::metrics::TELEMETRY_BYTES.add(buf.len() as u64);
    }
}

impl Transport for Loopback {
    fn name(&self) -> &'static str {
        "loopback"
    }

    /// Loopback cannot genuinely lose anyone — but an active fault
    /// plan injects losses at the same seams the socket transport has,
    /// so the engine must take its rollback snapshots.
    fn may_lose(&self) -> bool {
        fault::enabled()
    }

    fn round_trip(
        &self,
        client: usize,
        offer: &[u8],
        model: &[u8],
        _sync: Option<&StateSyncSnapshot>,
        env: &mut ClientEnv<'_>,
        reply: &mut Vec<u8>,
    ) -> Result<RoundTripStatus> {
        // Parse both frames with full integrity checks — the loopback
        // is a real receiver, not a shortcut around the protocol.
        let parse_sp = crate::obs::span_ab(crate::obs::Stage::FrameParse, client as u64, 0);
        let (offer_view, used) = frame::parse_frame(offer)
            .with_context(|| format!("loopback: offer frame for client {client}"))?;
        anyhow::ensure!(used == offer.len(), "loopback: trailing bytes after offer frame");
        let offer_msg = frame::parse_round_offer(&offer_view)?;
        let (model_view, used) = frame::parse_frame(model)
            .with_context(|| format!("loopback: model frame for client {client}"))?;
        anyhow::ensure!(used == model.len(), "loopback: trailing bytes after model frame");
        let model_msg = frame::parse_model_down(&model_view)?;
        drop(parse_sp);

        anyhow::ensure!(
            offer_msg.client as usize == client && model_msg.client as usize == client,
            "loopback: frames address client {}/{} but were routed to {client}",
            offer_msg.client,
            model_msg.client
        );
        anyhow::ensure!(
            offer_msg.round == model_msg.round,
            "loopback: offer round {} but model round {}",
            offer_msg.round,
            model_msg.round
        );
        // The frame must describe exactly the sub-model the host
        // resolved the plan for (cheap bitmap compare, no allocation).
        debug_assert!(
            offer_msg.matches_submodel(env.submodel),
            "loopback: offer bitmap does not match the dispatched sub-model"
        );

        // Injected faults, keyed `(round, client)` — the loopback
        // mirrors every seam the socket transport has, so fault plans
        // exercise the engine's loss handling without sockets. Each
        // class lands in exactly one bucket: a typed loss or a fully
        // masked (bit-identical) event — never an `Err`.
        let (fr, fc) = (offer_msg.round as u64, client as u64);
        if fault::enabled() {
            if fault::should(Site::SockWrite, fr, fc) {
                // The dispatch never reaches the device.
                reply.clear();
                return Ok(RoundTripStatus::Lost(LossReason::Disconnected));
            }
            if fault::should(Site::FrameDelay, fr, fc) {
                // Delivered, but past the I/O budget.
                reply.clear();
                return Ok(RoundTripStatus::Lost(LossReason::Timeout));
            }
        }

        client_execute(
            offer_msg.round,
            offer_msg.client,
            offer_msg.seed,
            offer_msg.lr,
            model_msg.payload,
            env,
            reply,
        )?;

        if fault::enabled() {
            if fault::should(Site::SockRead, fr, fc) {
                // The update was sent but the read side died first.
                reply.clear();
                return Ok(RoundTripStatus::Lost(LossReason::Disconnected));
            }
            if fault::should(Site::FrameCorrupt, fr, fc) && !reply.is_empty() {
                // Flip one reply byte pre-CRC-check: the receiver must
                // reject the frame, converting corruption into the
                // same typed loss a dead connection produces.
                let idx =
                    (fault::derive(Site::FrameCorrupt, fr, fc) as usize) % reply.len();
                reply[idx] ^= 0x40;
                debug_assert!(
                    frame::parse_frame(reply).is_err(),
                    "CRC must reject a corrupted update frame"
                );
                reply.clear();
                return Ok(RoundTripStatus::Lost(LossReason::Disconnected));
            }
            if fault::should(Site::FrameDup, fr, fc) {
                // Duplicate delivery: the second copy parses fine but
                // exchanges are matched by (round, client), so it is
                // discarded — fully masked.
                let _ = frame::parse_frame(reply);
            }
            // Site::PartialWrite needs no action here: the loopback
            // "writes" in one piece, and the socket transport resumes
            // short writes from its cursor — fully masked by design.
            let _ = fault::should(Site::PartialWrite, fr, fc);
        }
        if crate::obs::enabled() {
            self.mirror_telemetry(offer_msg.round);
        }
        Ok(RoundTripStatus::Delivered)
    }

    fn finish(&self, _client: usize, _round: u32, _included: bool) -> Result<()> {
        Ok(())
    }
}
