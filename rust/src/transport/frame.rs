//! The wire frame format: versioned, CRC32-checked, length-prefixed.
//!
//! Every message of the federation conversation travels as one frame:
//!
//! ```text
//! offset  size  field
//! 0       2     magic      b"AF"
//! 2       1     version    WIRE_VERSION (= 3)
//! 3       1     kind       FrameKind as u8
//! 4       4     len        u32 LE, payload length in bytes
//! 8       len   payload    kind-specific (see the message structs)
//! 8+len   4     crc32      u32 LE, IEEE CRC-32 over bytes [0, 8+len)
//! ```
//!
//! The CRC covers the header *and* the payload, so a corrupted kind,
//! length or payload byte is always detected (CRC-32 catches every
//! single-bit error outright); the length prefix is capped at
//! [`MAX_PAYLOAD`] so a corrupt prefix fails fast as
//! [`FrameError::Oversized`] instead of stalling a reader. Decoding is
//! fully checked — every malformed input maps to a [`FrameError`]
//! variant naming what broke; no input panics and no parse loops
//! unboundedly (`rust/tests/transport_frames.rs`).
//!
//! ## Zero-allocation contract
//!
//! Encoders append to a caller-provided `Vec<u8>` sink (the
//! [`Workspace`] byte pool on the hot path), so a warm sink frames a
//! message with zero heap allocations; [`parse_frame`] and the payload
//! readers borrow from the input buffer and never copy
//! (`rust/tests/zero_alloc.rs`).
//!
//! [`Workspace`]: crate::tensor::kernels::Workspace

use crate::model::submodel::SubModel;

pub const MAGIC: [u8; 2] = *b"AF";
/// v2: `Hello` carries a session token, `Config` echoes the assigned
/// token, `StateSync` exists, and `RoundOffer` kept-unit bitmaps may be
/// run-length encoded (see [`encode_round_offer`]).
///
/// v3: the `Telemetry` frame exists (client → coordinator span rings,
/// counter deltas and histogram snapshots, see [`parse_telemetry`]),
/// and `Ready` carries the client's monotonic clock reading next to
/// the fingerprint so the coordinator can align remote timelines
/// (handshake-time offset exchange; see `obs/remote.rs`).
pub const WIRE_VERSION: u8 = 3;
pub const HEADER_LEN: usize = 8;
pub const CRC_LEN: usize = 4;
/// Fixed per-frame overhead: header + trailing CRC.
pub const FRAME_OVERHEAD: u64 = (HEADER_LEN + CRC_LEN) as u64;
/// Upper bound on a frame payload (256 MiB): a corrupt or hostile
/// length prefix is rejected before any reader tries to honor it.
pub const MAX_PAYLOAD: usize = 1 << 28;

/// Frame type tags (the protocol's message vocabulary).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    /// Client process → server: opens a connection.
    Hello = 1,
    /// Server → client process: full experiment config (JSON) + the
    /// server's model layout fingerprint.
    Config = 2,
    /// Client process → server: config applied, fingerprints agree.
    Ready = 3,
    /// Server → client: one round's dispatch (round id, seed,
    /// deadline, learning rate, kept-unit bitmaps per mask group).
    RoundOffer = 4,
    /// Server → client: the codec-encoded global sub-model payload.
    ModelDown = 5,
    /// Client → server: the encoded update (DGC sparse message or raw
    /// packed values) + local loss and sample count.
    UpdateUp = 6,
    /// Server → client: the update was aggregated — commit local
    /// codec state (DGC accumulators).
    Ack = 7,
    /// Server → client: the update was discarded (straggler cut or
    /// churn drop) — roll local codec state back.
    Cut = 8,
    /// Server → client: the experiment is over.
    Bye = 9,
    /// Server → client: authoritative pre-round client state (RNG
    /// position, participation count, DGC residuals) pushed before a
    /// replayed or post-reconnect dispatch, so a restarted client
    /// process resumes bit-exactly where the coordinator's host-side
    /// shadow fleet says it should.
    StateSync = 10,
    /// Client process → server: observability snapshot — per-thread
    /// span-ring deltas, counter/gauge deltas, and stage-histogram
    /// deltas — piggybacked after `UpdateUp` at round boundaries.
    /// Pure side channel: never acked, never counted against
    /// `RoundRecord` byte accounting (`TELEMETRY_BYTES` tracks it
    /// separately, like `RESYNC_BYTES`).
    Telemetry = 11,
}

impl FrameKind {
    pub fn from_u8(v: u8) -> Option<FrameKind> {
        Some(match v {
            1 => FrameKind::Hello,
            2 => FrameKind::Config,
            3 => FrameKind::Ready,
            4 => FrameKind::RoundOffer,
            5 => FrameKind::ModelDown,
            6 => FrameKind::UpdateUp,
            7 => FrameKind::Ack,
            8 => FrameKind::Cut,
            9 => FrameKind::Bye,
            10 => FrameKind::StateSync,
            11 => FrameKind::Telemetry,
            _ => return None,
        })
    }
}

// ---------------------------------------------------------------------
// CRC-32 (IEEE 802.3 polynomial, reflected), table built at compile time
// ---------------------------------------------------------------------

const fn build_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 {
                0xedb8_8320 ^ (c >> 1)
            } else {
                c >> 1
            };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const CRC_TABLE: [u32; 256] = build_crc_table();

/// IEEE CRC-32 (the zlib/Ethernet polynomial).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = !0u32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    !c
}

// ---------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------

/// Every way a frame can fail to decode, with the numbers needed to
/// diagnose it. Malformed input is *always* one of these — never a
/// panic, never an unbounded loop.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FrameError {
    /// Fewer bytes than the frame claims (or than a header needs).
    Truncated { need: usize, have: usize },
    BadMagic { got: [u8; 2] },
    BadVersion { got: u8, want: u8 },
    UnknownKind { got: u8 },
    /// Length prefix exceeds [`MAX_PAYLOAD`].
    Oversized { len: usize, max: usize },
    BadCrc { got: u32, want: u32 },
    /// The frame decoded but its payload is malformed; `what` names
    /// the field that broke.
    BadPayload { kind: FrameKind, what: &'static str },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Truncated { need, have } => {
                write!(f, "truncated frame: need {need} bytes, have {have}")
            }
            FrameError::BadMagic { got } => {
                write!(f, "bad frame magic {got:02x?} (want {MAGIC:02x?})")
            }
            FrameError::BadVersion { got, want } => {
                write!(f, "wire version mismatch: got {got}, want {want}")
            }
            FrameError::UnknownKind { got } => write!(f, "unknown frame kind {got}"),
            FrameError::Oversized { len, max } => {
                write!(f, "oversized length prefix: {len} bytes (cap {max})")
            }
            FrameError::BadCrc { got, want } => {
                write!(f, "frame CRC mismatch: got {got:#010x}, want {want:#010x}")
            }
            FrameError::BadPayload { kind, what } => {
                write!(f, "malformed {kind:?} payload: {what}")
            }
        }
    }
}

impl std::error::Error for FrameError {}

// ---------------------------------------------------------------------
// Frame encode / decode
// ---------------------------------------------------------------------

/// Append a frame header for `kind` to `out` with a placeholder length;
/// returns the frame's base offset for [`end_frame`].
pub fn begin_frame(out: &mut Vec<u8>, kind: FrameKind) -> usize {
    let base = out.len();
    out.extend_from_slice(&MAGIC);
    out.push(WIRE_VERSION);
    out.push(kind as u8);
    out.extend_from_slice(&0u32.to_le_bytes());
    base
}

/// Patch the length prefix and append the CRC; the frame occupies
/// `out[base..]` afterwards.
pub fn end_frame(out: &mut Vec<u8>, base: usize) {
    let payload_len = out.len() - base - HEADER_LEN;
    assert!(
        payload_len <= MAX_PAYLOAD,
        "frame payload {payload_len} exceeds MAX_PAYLOAD ({MAX_PAYLOAD})"
    );
    let len = (payload_len as u32).to_le_bytes();
    out[base + 4..base + 8].copy_from_slice(&len);
    let crc = crc32(&out[base..]);
    out.extend_from_slice(&crc.to_le_bytes());
    if crate::obs::enabled() {
        // The kind byte sits at header offset 3 (see module docs).
        let kind = out[base + 3] as usize % crate::obs::metrics::FRAME_KIND_SLOTS;
        crate::obs::metrics::FRAMES_SENT[kind].incr();
        crate::obs::metrics::FRAME_BYTES.observe((out.len() - base) as u64);
    }
}

/// A decoded frame borrowing its payload from the input buffer.
#[derive(Clone, Copy, Debug)]
pub struct FrameView<'a> {
    pub kind: FrameKind,
    pub payload: &'a [u8],
}

/// Parse one frame from the head of `buf`; returns the view and the
/// byte count consumed. Zero-copy: the view borrows `buf`.
pub fn parse_frame(buf: &[u8]) -> Result<(FrameView<'_>, usize), FrameError> {
    let min = HEADER_LEN + CRC_LEN;
    if buf.len() < min {
        return Err(FrameError::Truncated {
            need: min,
            have: buf.len(),
        });
    }
    if buf[0..2] != MAGIC {
        return Err(FrameError::BadMagic {
            got: [buf[0], buf[1]],
        });
    }
    if buf[2] != WIRE_VERSION {
        return Err(FrameError::BadVersion {
            got: buf[2],
            want: WIRE_VERSION,
        });
    }
    let kind = FrameKind::from_u8(buf[3]).ok_or(FrameError::UnknownKind { got: buf[3] })?;
    let len = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
    if len > MAX_PAYLOAD {
        return Err(FrameError::Oversized {
            len,
            max: MAX_PAYLOAD,
        });
    }
    let total = HEADER_LEN + len + CRC_LEN;
    if buf.len() < total {
        return Err(FrameError::Truncated {
            need: total,
            have: buf.len(),
        });
    }
    let want = crc32(&buf[..HEADER_LEN + len]);
    let got = u32::from_le_bytes(buf[HEADER_LEN + len..total].try_into().unwrap());
    if got != want {
        if crate::obs::enabled() {
            crate::obs::metrics::CRC_FAILURES.incr();
        }
        return Err(FrameError::BadCrc { got, want });
    }
    let payload = &buf[HEADER_LEN..HEADER_LEN + len];
    if crate::obs::enabled() {
        crate::obs::metrics::FRAMES_PARSED[kind as usize % crate::obs::metrics::FRAME_KIND_SLOTS]
            .incr();
    }
    Ok((FrameView { kind, payload }, total))
}

// ---------------------------------------------------------------------
// Payload cursor
// ---------------------------------------------------------------------

/// Checked cursor over a frame payload; every read names its field so
/// a short payload produces a diagnosable [`FrameError::BadPayload`].
pub struct PayloadReader<'a> {
    kind: FrameKind,
    buf: &'a [u8],
    pos: usize,
}

impl<'a> PayloadReader<'a> {
    pub fn new(view: &FrameView<'a>) -> PayloadReader<'a> {
        PayloadReader {
            kind: view.kind,
            buf: view.payload,
            pos: 0,
        }
    }

    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], FrameError> {
        if self.buf.len() - self.pos < n {
            return Err(FrameError::BadPayload {
                kind: self.kind,
                what,
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self, what: &'static str) -> Result<u8, FrameError> {
        Ok(self.take(1, what)?[0])
    }

    pub fn u16(&mut self, what: &'static str) -> Result<u16, FrameError> {
        Ok(u16::from_le_bytes(self.take(2, what)?.try_into().unwrap()))
    }

    pub fn u32(&mut self, what: &'static str) -> Result<u32, FrameError> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    pub fn u64(&mut self, what: &'static str) -> Result<u64, FrameError> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    pub fn f32(&mut self, what: &'static str) -> Result<f32, FrameError> {
        Ok(f32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    pub fn f64(&mut self, what: &'static str) -> Result<f64, FrameError> {
        Ok(f64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    pub fn bytes(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], FrameError> {
        self.take(n, what)
    }

    /// Everything not yet consumed (trailing variable-length body).
    pub fn rest(self) -> &'a [u8] {
        &self.buf[self.pos..]
    }
}

// ---------------------------------------------------------------------
// Protocol messages
// ---------------------------------------------------------------------

/// Group body encodings for `RoundOffer` kept-unit sets.
///
/// Keep decisions are per *unit*, and units inside a mask group are
/// kept or dropped in long stretches whenever the dropout policy keeps
/// contiguous score ranges — so the wire carries whichever of two
/// encodings is smaller for that group, chosen deterministically by
/// the encoder (ties go to the raw bitmap):
pub const GROUP_BITMAP: u8 = 0;
pub const GROUP_RLE: u8 = 1;

fn varint_len(mut v: u64) -> usize {
    let mut n = 1;
    while v >= 0x80 {
        v >>= 7;
        n += 1;
    }
    n
}

fn push_varint(out: &mut Vec<u8>, mut v: u64) {
    while v >= 0x80 {
        out.push((v as u8 & 0x7f) | 0x80);
        v >>= 7;
    }
    out.push(v as u8);
}

/// Read one LEB128 varint from `buf[pos..]`; advances `pos`. Errors if
/// the region ends mid-varint or the value exceeds 32 bits (run
/// lengths can never exceed a group's `u32` unit count).
fn read_varint(buf: &[u8], pos: &mut usize, what: &'static str) -> Result<u64, FrameError> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        if *pos >= buf.len() || shift > 28 {
            return Err(FrameError::BadPayload {
                kind: FrameKind::RoundOffer,
                what,
            });
        }
        let b = buf[*pos];
        *pos += 1;
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            if v > u32::MAX as u64 {
                return Err(FrameError::BadPayload {
                    kind: FrameKind::RoundOffer,
                    what,
                });
            }
            return Ok(v);
        }
        shift += 7;
    }
}

/// Byte length of the RLE body for one kept-set: alternating run
/// lengths (kept first; a leading zero run means unit 0 is dropped).
fn rle_body_len(keep: &[bool]) -> usize {
    let mut n = 0usize;
    let mut cur = true;
    let mut run = 0u64;
    for &k in keep {
        if k == cur {
            run += 1;
        } else {
            n += varint_len(run);
            cur = k;
            run = 1;
        }
    }
    if !keep.is_empty() {
        n += varint_len(run);
    }
    n
}

fn push_rle_body(out: &mut Vec<u8>, keep: &[bool]) {
    let mut cur = true;
    let mut run = 0u64;
    for &k in keep {
        if k == cur {
            run += 1;
        } else {
            push_varint(out, run);
            cur = k;
            run = 1;
        }
    }
    if !keep.is_empty() {
        push_varint(out, run);
    }
}

/// `RoundOffer` payload:
/// `u32 round ‖ u32 client ‖ u64 seed ‖ f32 lr ‖ f64 deadline_s (NaN =
/// none) ‖ u16 group count ‖ per group: u32 unit count ‖ u8 tag ‖
/// body`. Tag [`GROUP_BITMAP`]: `⌈count/8⌉` bitmap bytes (bit i of
/// byte i/8 = unit i kept). Tag [`GROUP_RLE`]: LEB128 run lengths
/// alternating kept/dropped, kept first (a leading zero run means unit
/// 0 is dropped); runs sum to exactly `count` and the body ends with
/// the last run. The encoder emits whichever body is shorter, so
/// dense contiguous keep patterns cost bytes proportional to their
/// run count instead of the unit count.
#[derive(Clone, Copy, Debug)]
pub struct RoundOfferMsg<'a> {
    pub round: u32,
    pub client: u32,
    pub seed: u64,
    pub lr: f32,
    pub deadline_s: f64,
    /// Raw per-group `u32 count ‖ u8 tag ‖ body` region (zero-copy;
    /// walk with [`RoundOfferMsg::for_each_group`] or materialize with
    /// [`RoundOfferMsg::submodel`]).
    groups: &'a [u8],
    group_count: u16,
}

pub fn encode_round_offer(
    out: &mut Vec<u8>,
    round: u32,
    client: u32,
    seed: u64,
    lr: f32,
    deadline_s: f64,
    submodel: &SubModel,
) {
    let base = begin_frame(out, FrameKind::RoundOffer);
    out.extend_from_slice(&round.to_le_bytes());
    out.extend_from_slice(&client.to_le_bytes());
    out.extend_from_slice(&seed.to_le_bytes());
    out.extend_from_slice(&lr.to_le_bytes());
    out.extend_from_slice(&deadline_s.to_le_bytes());
    let groups = &submodel.keep;
    assert!(groups.len() <= u16::MAX as usize, "too many mask groups");
    out.extend_from_slice(&(groups.len() as u16).to_le_bytes());
    for keep in groups {
        assert!(keep.len() <= u32::MAX as usize);
        out.extend_from_slice(&(keep.len() as u32).to_le_bytes());
        let raw = keep.len().div_ceil(8);
        let rle = rle_body_len(keep);
        if rle < raw {
            out.push(GROUP_RLE);
            push_rle_body(out, keep);
        } else {
            out.push(GROUP_BITMAP);
            let start = out.len();
            out.resize(start + raw, 0);
            for (i, &k) in keep.iter().enumerate() {
                if k {
                    out[start + i / 8] |= 1 << (i % 8);
                }
            }
        }
    }
    end_frame(out, base);
}

/// Validate (or re-walk) one group body starting at `groups[*pos]`,
/// which must already sit past the count header. Returns the tag.
fn walk_group_body(groups: &[u8], pos: &mut usize, count: usize) -> Result<u8, FrameError> {
    if *pos >= groups.len() {
        return Err(FrameError::BadPayload {
            kind: FrameKind::RoundOffer,
            what: "group encoding tag",
        });
    }
    let tag = groups[*pos];
    *pos += 1;
    match tag {
        GROUP_BITMAP => {
            let bm = count.div_ceil(8);
            if groups.len() - *pos < bm {
                return Err(FrameError::BadPayload {
                    kind: FrameKind::RoundOffer,
                    what: "group bitmap",
                });
            }
            *pos += bm;
        }
        GROUP_RLE => {
            let mut total = 0u64;
            while total < count as u64 {
                total += read_varint(groups, pos, "group run length")?;
            }
            if total != count as u64 {
                return Err(FrameError::BadPayload {
                    kind: FrameKind::RoundOffer,
                    what: "group runs exceed unit count",
                });
            }
        }
        _ => {
            return Err(FrameError::BadPayload {
                kind: FrameKind::RoundOffer,
                what: "unknown group encoding tag",
            });
        }
    }
    Ok(tag)
}

pub fn parse_round_offer<'a>(view: &FrameView<'a>) -> Result<RoundOfferMsg<'a>, FrameError> {
    if view.kind != FrameKind::RoundOffer {
        return Err(FrameError::BadPayload {
            kind: view.kind,
            what: "expected RoundOffer",
        });
    }
    let mut r = PayloadReader::new(view);
    let round = r.u32("round")?;
    let client = r.u32("client")?;
    let seed = r.u64("seed")?;
    let lr = r.f32("lr")?;
    let deadline_s = r.f64("deadline_s")?;
    let group_count = r.u16("group count")?;
    let groups = r.rest();
    // Validate the group region up front so later walks can't run off
    // the end.
    let mut pos = 0usize;
    for _ in 0..group_count {
        if groups.len() - pos < 4 {
            return Err(FrameError::BadPayload {
                kind: FrameKind::RoundOffer,
                what: "group count header",
            });
        }
        let count = u32::from_le_bytes(groups[pos..pos + 4].try_into().unwrap()) as usize;
        pos += 4;
        walk_group_body(groups, &mut pos, count)?;
    }
    if pos != groups.len() {
        return Err(FrameError::BadPayload {
            kind: FrameKind::RoundOffer,
            what: "trailing bytes after groups",
        });
    }
    Ok(RoundOfferMsg {
        round,
        client,
        seed,
        lr,
        deadline_s,
        groups,
        group_count,
    })
}

/// One group's kept-unit set, borrowing its encoded body (raw bitmap
/// or RLE); walk it with [`GroupBits::for_each_bit`] — no allocation
/// either way. The body was validated at parse time.
#[derive(Clone, Copy, Debug)]
pub struct GroupBits<'a> {
    count: usize,
    tag: u8,
    body: &'a [u8],
}

impl GroupBits<'_> {
    pub fn count(&self) -> usize {
        self.count
    }

    /// Call `f(unit index, kept)` for every unit in order.
    pub fn for_each_bit(&self, mut f: impl FnMut(usize, bool)) {
        if self.tag == GROUP_BITMAP {
            for i in 0..self.count {
                f(i, self.body[i / 8] & (1 << (i % 8)) != 0);
            }
        } else {
            let mut pos = 0usize;
            let mut kept = true;
            let mut i = 0usize;
            while i < self.count {
                let run = read_varint(self.body, &mut pos, "validated run").unwrap() as usize;
                for _ in 0..run {
                    f(i, kept);
                    i += 1;
                }
                kept = !kept;
            }
        }
    }
}

impl<'a> RoundOfferMsg<'a> {
    pub fn group_count(&self) -> usize {
        self.group_count as usize
    }

    /// Walk the kept-unit sets without materializing them:
    /// `f(group index, bits)`. The region was validated at parse time.
    pub fn for_each_group(&self, mut f: impl FnMut(usize, GroupBits<'a>)) {
        let mut pos = 0usize;
        for g in 0..self.group_count as usize {
            let head = self.groups[pos..pos + 4].try_into().unwrap();
            let count = u32::from_le_bytes(head) as usize;
            pos += 4;
            let body_start = pos + 1;
            let tag = walk_group_body(self.groups, &mut pos, count).unwrap();
            f(
                g,
                GroupBits {
                    count,
                    tag,
                    body: &self.groups[body_start..pos],
                },
            );
        }
    }

    /// Materialize the offered sub-model (allocates; remote clients
    /// only — the loopback path reuses the coordinator's `SubModel`).
    pub fn submodel(&self) -> SubModel {
        let mut keep: Vec<Vec<bool>> = Vec::with_capacity(self.group_count as usize);
        self.for_each_group(|_, bits| {
            let mut units = vec![false; bits.count()];
            bits.for_each_bit(|i, k| units[i] = k);
            keep.push(units);
        });
        SubModel::from_keep(keep)
    }

    /// Does the offered bitmap equal this sub-model's kept sets?
    /// (Loopback sanity check: the frame must describe exactly the
    /// sub-model the coordinator dispatched.)
    pub fn matches_submodel(&self, sm: &SubModel) -> bool {
        if self.group_count as usize != sm.keep.len() {
            return false;
        }
        let mut ok = true;
        self.for_each_group(|g, bits| {
            if bits.count() != sm.keep[g].len() {
                ok = false;
                return;
            }
            bits.for_each_bit(|i, k| {
                if k != sm.keep[g][i] {
                    ok = false;
                }
            });
        });
        ok
    }
}

/// `ModelDown` payload: `u32 round ‖ u32 client ‖ u8 codec id ‖ codec
/// wire bytes`.
#[derive(Clone, Copy, Debug)]
pub struct ModelDownMsg<'a> {
    pub round: u32,
    pub client: u32,
    pub codec: u8,
    pub payload: &'a [u8],
}

pub fn encode_model_down(out: &mut Vec<u8>, round: u32, client: u32, codec: u8, payload: &[u8]) {
    let base = begin_frame(out, FrameKind::ModelDown);
    out.extend_from_slice(&round.to_le_bytes());
    out.extend_from_slice(&client.to_le_bytes());
    out.push(codec);
    out.extend_from_slice(payload);
    end_frame(out, base);
}

pub fn parse_model_down<'a>(view: &FrameView<'a>) -> Result<ModelDownMsg<'a>, FrameError> {
    if view.kind != FrameKind::ModelDown {
        return Err(FrameError::BadPayload {
            kind: view.kind,
            what: "expected ModelDown",
        });
    }
    let mut r = PayloadReader::new(view);
    let round = r.u32("round")?;
    let client = r.u32("client")?;
    let codec = r.u8("codec id")?;
    Ok(ModelDownMsg {
        round,
        client,
        codec,
        payload: r.rest(),
    })
}

/// Uplink payload encodings.
pub const UPDATE_RAW: u8 = 0;
pub const UPDATE_DGC: u8 = 1;

/// `UpdateUp` payload: `u32 round ‖ u32 client ‖ u32 sample count ‖
/// f32 local loss ‖ u8 update kind (UPDATE_RAW | UPDATE_DGC) ‖ body`.
/// Raw body: `u32 packed count ‖ count × f32 LE`; DGC body: one
/// `sparse::encode_sparse` message.
#[derive(Clone, Copy, Debug)]
pub struct UpdateUpMsg<'a> {
    pub round: u32,
    pub client: u32,
    pub samples: u32,
    pub loss: f32,
    pub update_kind: u8,
    pub payload: &'a [u8],
}

/// Begin an `UpdateUp` frame through the fixed fields; the caller
/// appends the body and calls [`end_frame`] with the returned base.
pub fn begin_update_up(
    out: &mut Vec<u8>,
    round: u32,
    client: u32,
    samples: u32,
    loss: f32,
    update_kind: u8,
) -> usize {
    let base = begin_frame(out, FrameKind::UpdateUp);
    out.extend_from_slice(&round.to_le_bytes());
    out.extend_from_slice(&client.to_le_bytes());
    out.extend_from_slice(&samples.to_le_bytes());
    out.extend_from_slice(&loss.to_le_bytes());
    out.push(update_kind);
    base
}

pub fn parse_update_up<'a>(view: &FrameView<'a>) -> Result<UpdateUpMsg<'a>, FrameError> {
    if view.kind != FrameKind::UpdateUp {
        return Err(FrameError::BadPayload {
            kind: view.kind,
            what: "expected UpdateUp",
        });
    }
    let mut r = PayloadReader::new(view);
    let round = r.u32("round")?;
    let client = r.u32("client")?;
    let samples = r.u32("samples")?;
    let loss = r.f32("loss")?;
    let update_kind = r.u8("update kind")?;
    if update_kind != UPDATE_RAW && update_kind != UPDATE_DGC {
        return Err(FrameError::BadPayload {
            kind: FrameKind::UpdateUp,
            what: "unknown update kind",
        });
    }
    Ok(UpdateUpMsg {
        round,
        client,
        samples,
        loss,
        update_kind,
        payload: r.rest(),
    })
}

/// `Ack` / `Cut` payload: `u32 round ‖ u32 client`.
#[derive(Clone, Copy, Debug)]
pub struct RoundCloseMsg {
    pub round: u32,
    pub client: u32,
}

pub fn encode_round_close(out: &mut Vec<u8>, included: bool, round: u32, client: u32) {
    let kind = if included { FrameKind::Ack } else { FrameKind::Cut };
    let base = begin_frame(out, kind);
    out.extend_from_slice(&round.to_le_bytes());
    out.extend_from_slice(&client.to_le_bytes());
    end_frame(out, base);
}

pub fn parse_round_close(view: &FrameView<'_>) -> Result<RoundCloseMsg, FrameError> {
    if view.kind != FrameKind::Ack && view.kind != FrameKind::Cut {
        return Err(FrameError::BadPayload {
            kind: view.kind,
            what: "expected Ack or Cut",
        });
    }
    let mut r = PayloadReader::new(view);
    Ok(RoundCloseMsg {
        round: r.u32("round")?,
        client: r.u32("client")?,
    })
}

/// Wire length of an `Ack`/`Cut` frame (fixed: 8-byte payload).
pub const ROUND_CLOSE_WIRE: u64 = FRAME_OVERHEAD + 8;

/// `Config` payload: `u64 layout fingerprint ‖ u64 session token ‖
/// UTF-8 config JSON`. The token is the coordinator-assigned session
/// identity the client presents in `Hello` to resume after a
/// reconnect (never zero).
pub fn encode_config(out: &mut Vec<u8>, fingerprint: u64, token: u64, json: &str) {
    let base = begin_frame(out, FrameKind::Config);
    out.extend_from_slice(&fingerprint.to_le_bytes());
    out.extend_from_slice(&token.to_le_bytes());
    out.extend_from_slice(json.as_bytes());
    end_frame(out, base);
}

pub fn parse_config<'a>(view: &FrameView<'a>) -> Result<(u64, u64, &'a str), FrameError> {
    if view.kind != FrameKind::Config {
        return Err(FrameError::BadPayload {
            kind: view.kind,
            what: "expected Config",
        });
    }
    let mut r = PayloadReader::new(view);
    let fp = r.u64("fingerprint")?;
    let token = r.u64("session token")?;
    let json = std::str::from_utf8(r.rest()).map_err(|_| FrameError::BadPayload {
        kind: FrameKind::Config,
        what: "config JSON is not UTF-8",
    })?;
    Ok((fp, token, json))
}

/// `Hello` payload: `u64 session token` — zero for a brand-new client
/// process, or the token a previous `Config` assigned to resume that
/// session's open rounds after a reconnect.
pub fn encode_hello(out: &mut Vec<u8>, token: u64) {
    let base = begin_frame(out, FrameKind::Hello);
    out.extend_from_slice(&token.to_le_bytes());
    end_frame(out, base);
}

pub fn parse_hello(view: &FrameView<'_>) -> Result<u64, FrameError> {
    if view.kind != FrameKind::Hello {
        return Err(FrameError::BadPayload {
            kind: view.kind,
            what: "expected Hello",
        });
    }
    PayloadReader::new(view).u64("session token")
}

/// `Ready` payload: `u64 fingerprint ‖ u64 client monotonic now (ns)`.
/// The clock reading is the handshake half of remote timeline
/// alignment: the coordinator subtracts it from its own monotonic
/// clock at parse time to get a first offset estimate, later refined
/// by per-round `Telemetry` anchors (see `obs/remote.rs`).
pub fn encode_ready(out: &mut Vec<u8>, fingerprint: u64, now_ns: u64) {
    let base = begin_frame(out, FrameKind::Ready);
    out.extend_from_slice(&fingerprint.to_le_bytes());
    out.extend_from_slice(&now_ns.to_le_bytes());
    end_frame(out, base);
}

/// Returns `(fingerprint, client_now_ns)`; a clock-less peer that sent
/// only the fingerprint reads back as `now_ns = 0` (no alignment).
pub fn parse_ready(view: &FrameView<'_>) -> Result<(u64, u64), FrameError> {
    if view.kind != FrameKind::Ready {
        return Err(FrameError::BadPayload {
            kind: view.kind,
            what: "expected Ready",
        });
    }
    let mut r = PayloadReader::new(view);
    let fp = r.u64("fingerprint")?;
    let now_ns = r.u64("client clock").unwrap_or(0);
    Ok((fp, now_ns))
}

pub fn encode_bye(out: &mut Vec<u8>) {
    let base = begin_frame(out, FrameKind::Bye);
    end_frame(out, base);
}

/// `StateSync` payload: `u32 client ‖ u64 participations ‖ 16-byte
/// u128 LE RNG state ‖ 16-byte u128 LE RNG stream ‖ u32 residual len ‖
/// len × f32 LE momentum (u) ‖ len × f32 LE velocity (v)`.
///
/// This is exactly the residual store's spill record for one logical
/// client — the complete mutable remainder of its state (everything
/// not derivable from `(seed, id)`), captured by the coordinator
/// before the round mutates it. A restarted client process that
/// applies a `StateSync` before the dispatch that follows it is
/// bit-identical to one that lived through every prior round.
#[derive(Clone, Copy, Debug)]
pub struct StateSyncMsg<'a> {
    pub client: u32,
    pub participations: u64,
    pub rng_state: u128,
    pub rng_inc: u128,
    residual_len: usize,
    body: &'a [u8],
}

pub fn encode_state_sync(
    out: &mut Vec<u8>,
    client: u32,
    participations: u64,
    rng_state: u128,
    rng_inc: u128,
    u: &[f32],
    v: &[f32],
) {
    assert_eq!(u.len(), v.len(), "state sync: u/v length mismatch");
    assert!(u.len() <= u32::MAX as usize);
    let base = begin_frame(out, FrameKind::StateSync);
    out.extend_from_slice(&client.to_le_bytes());
    out.extend_from_slice(&participations.to_le_bytes());
    out.extend_from_slice(&rng_state.to_le_bytes());
    out.extend_from_slice(&rng_inc.to_le_bytes());
    out.extend_from_slice(&(u.len() as u32).to_le_bytes());
    for &x in u {
        out.extend_from_slice(&x.to_le_bytes());
    }
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    end_frame(out, base);
}

pub fn parse_state_sync<'a>(view: &FrameView<'a>) -> Result<StateSyncMsg<'a>, FrameError> {
    if view.kind != FrameKind::StateSync {
        return Err(FrameError::BadPayload {
            kind: view.kind,
            what: "expected StateSync",
        });
    }
    let mut r = PayloadReader::new(view);
    let client = r.u32("client")?;
    let participations = r.u64("participations")?;
    let rng_state = u128::from_le_bytes(r.bytes(16, "rng state")?.try_into().unwrap());
    let rng_inc = u128::from_le_bytes(r.bytes(16, "rng stream")?.try_into().unwrap());
    let residual_len = r.u32("residual len")? as usize;
    let body = r.rest();
    if body.len() != residual_len.saturating_mul(8) {
        return Err(FrameError::BadPayload {
            kind: FrameKind::StateSync,
            what: "residual body length",
        });
    }
    Ok(StateSyncMsg {
        client,
        participations,
        rng_state,
        rng_inc,
        residual_len,
        body,
    })
}

impl StateSyncMsg<'_> {
    pub fn residual_len(&self) -> usize {
        self.residual_len
    }

    /// Decode the momentum (`u`) and velocity (`v`) residual vectors
    /// into the caller's buffers (cleared first; capacity reused).
    pub fn read_residuals(&self, u: &mut Vec<f32>, v: &mut Vec<f32>) {
        u.clear();
        v.clear();
        u.reserve(self.residual_len);
        v.reserve(self.residual_len);
        for i in 0..self.residual_len {
            let at = i * 4;
            u.push(f32::from_le_bytes(self.body[at..at + 4].try_into().unwrap()));
        }
        let voff = self.residual_len * 4;
        for i in 0..self.residual_len {
            let at = voff + i * 4;
            v.push(f32::from_le_bytes(self.body[at..at + 4].try_into().unwrap()));
        }
    }
}

// ---------------------------------------------------------------------
// Telemetry (wire v3)
// ---------------------------------------------------------------------

/// Caps on `Telemetry` section counts: a hostile count field is
/// rejected before any reader honors it, and no single frame can carry
/// an unbounded snapshot (the shipper truncates and reports drops
/// instead).
pub const MAX_TELEMETRY_THREADS: usize = 256;
pub const MAX_TELEMETRY_NAME: usize = 96;
/// Per-thread span cap — one full ring (`obs::span::RING_CAPACITY`).
pub const MAX_TELEMETRY_SPANS: usize = 16384;
pub const MAX_TELEMETRY_COUNTERS: usize = 256;
pub const MAX_TELEMETRY_GAUGES: usize = 64;
pub const MAX_TELEMETRY_HISTS: usize = 64;
/// Stage tags and histogram bucket indices must fall below this.
pub const TELEMETRY_STAGE_LIMIT: u8 = 64;

/// Streaming encoder for `Telemetry` frames.
///
/// `Telemetry` payload:
/// `u32 round ‖ u64 sender monotonic now (ns) ‖
///  u32 thread count ‖ per thread: u32 tid ‖ u8 name len ‖ name bytes ‖
///  u64 ring drops ‖ u32 span count ‖ per span: u8 stage ‖ u32 track ‖
///  u64 start_ns ‖ u64 dur_ns ‖ u64 a ‖ u64 b ‖
///  u32 counter count ‖ per counter: u8 id ‖ u64 delta ‖
///  u32 gauge count ‖ per gauge: u8 id ‖ u64 value ‖
///  u32 histogram count ‖ per histogram: u8 stage ‖ u64 Δcount ‖
///  u64 Δsum ‖ u8 nonzero buckets ‖ per bucket: u8 index ‖ u64 Δ`.
///
/// All four sections are mandatory, in that order (a snapshot with
/// nothing to say encodes four zero counts). Counts are patched in
/// place, so the encoder appends to a caller-provided sink and a warm
/// sink frames a snapshot with zero heap allocations — the client-side
/// shipper (`obs/remote.rs`) relies on this to keep the warm round
/// alloc-free with telemetry live.
pub struct TelemetryEncoder<'o> {
    out: &'o mut Vec<u8>,
    base: usize,
    sect_at: usize,
    sect_n: u32,
    thread_at: usize,
    thread_n: u32,
    hist_at: usize,
    hist_n: u8,
}

const NO_PATCH: usize = usize::MAX;

impl<'o> TelemetryEncoder<'o> {
    pub fn begin(out: &'o mut Vec<u8>, round: u32, now_ns: u64) -> TelemetryEncoder<'o> {
        let base = begin_frame(out, FrameKind::Telemetry);
        out.extend_from_slice(&round.to_le_bytes());
        out.extend_from_slice(&now_ns.to_le_bytes());
        TelemetryEncoder {
            out,
            base,
            sect_at: NO_PATCH,
            sect_n: 0,
            thread_at: NO_PATCH,
            thread_n: 0,
            hist_at: NO_PATCH,
            hist_n: 0,
        }
    }

    fn sect_begin(&mut self) {
        debug_assert_eq!(self.sect_at, NO_PATCH, "previous section still open");
        self.sect_at = self.out.len();
        self.out.extend_from_slice(&0u32.to_le_bytes());
        self.sect_n = 0;
    }

    fn sect_end(&mut self) {
        let n = self.sect_n.to_le_bytes();
        self.out[self.sect_at..self.sect_at + 4].copy_from_slice(&n);
        self.sect_at = NO_PATCH;
    }

    pub fn begin_threads(&mut self) {
        self.sect_begin();
    }

    fn close_thread(&mut self) {
        if self.thread_at != NO_PATCH {
            let n = self.thread_n.to_le_bytes();
            self.out[self.thread_at..self.thread_at + 4].copy_from_slice(&n);
            self.thread_at = NO_PATCH;
        }
    }

    /// Open one thread record; spans recorded until the next
    /// `begin_thread`/`end_threads` belong to it.
    pub fn begin_thread(&mut self, tid: u32, name: &str, dropped: u64) {
        self.close_thread();
        let name = &name.as_bytes()[..name.len().min(MAX_TELEMETRY_NAME)];
        self.out.extend_from_slice(&tid.to_le_bytes());
        self.out.push(name.len() as u8);
        self.out.extend_from_slice(name);
        self.out.extend_from_slice(&dropped.to_le_bytes());
        self.thread_at = self.out.len();
        self.out.extend_from_slice(&0u32.to_le_bytes());
        self.thread_n = 0;
        self.sect_n += 1;
    }

    pub fn span(&mut self, stage: u8, track: u32, start_ns: u64, dur_ns: u64, a: u64, b: u64) {
        debug_assert!(self.thread_at != NO_PATCH, "span outside a thread");
        debug_assert!(stage < TELEMETRY_STAGE_LIMIT);
        self.out.push(stage);
        self.out.extend_from_slice(&track.to_le_bytes());
        self.out.extend_from_slice(&start_ns.to_le_bytes());
        self.out.extend_from_slice(&dur_ns.to_le_bytes());
        self.out.extend_from_slice(&a.to_le_bytes());
        self.out.extend_from_slice(&b.to_le_bytes());
        self.thread_n += 1;
    }

    pub fn end_threads(&mut self) {
        self.close_thread();
        self.sect_end();
    }

    pub fn begin_counters(&mut self) {
        self.sect_begin();
    }

    pub fn counter(&mut self, id: u8, delta: u64) {
        self.out.push(id);
        self.out.extend_from_slice(&delta.to_le_bytes());
        self.sect_n += 1;
    }

    pub fn end_counters(&mut self) {
        self.sect_end();
    }

    pub fn begin_gauges(&mut self) {
        self.sect_begin();
    }

    pub fn gauge(&mut self, id: u8, value: u64) {
        self.out.push(id);
        self.out.extend_from_slice(&value.to_le_bytes());
        self.sect_n += 1;
    }

    pub fn end_gauges(&mut self) {
        self.sect_end();
    }

    pub fn begin_hists(&mut self) {
        self.sect_begin();
    }

    fn close_hist(&mut self) {
        if self.hist_at != NO_PATCH {
            self.out[self.hist_at] = self.hist_n;
            self.hist_at = NO_PATCH;
        }
    }

    pub fn begin_hist(&mut self, stage: u8, d_count: u64, d_sum: u64) {
        debug_assert!(stage < TELEMETRY_STAGE_LIMIT);
        self.close_hist();
        self.out.push(stage);
        self.out.extend_from_slice(&d_count.to_le_bytes());
        self.out.extend_from_slice(&d_sum.to_le_bytes());
        self.hist_at = self.out.len();
        self.out.push(0);
        self.hist_n = 0;
        self.sect_n += 1;
    }

    pub fn bucket(&mut self, index: u8, delta: u64) {
        debug_assert!(self.hist_at != NO_PATCH, "bucket outside a histogram");
        debug_assert!(index < TELEMETRY_STAGE_LIMIT);
        self.out.push(index);
        self.out.extend_from_slice(&delta.to_le_bytes());
        self.hist_n += 1;
    }

    pub fn end_hists(&mut self) {
        self.close_hist();
        self.sect_end();
    }

    /// Seal the frame (length patch + CRC).
    pub fn finish(self) {
        debug_assert_eq!(self.sect_at, NO_PATCH, "a section is still open");
        end_frame(self.out, self.base);
    }
}

/// One span record inside a parsed `Telemetry` frame. Timestamps are
/// on the *sender's* monotonic clock; the merge layer realigns them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TelemetrySpan {
    pub stage: u8,
    pub track: u32,
    pub start_ns: u64,
    pub dur_ns: u64,
    pub a: u64,
    pub b: u64,
}

#[derive(Clone, Debug)]
pub struct TelemetryThread {
    pub tid: u32,
    pub name: String,
    pub dropped: u64,
    pub spans: Vec<TelemetrySpan>,
}

#[derive(Clone, Debug)]
pub struct TelemetryHist {
    pub stage: u8,
    pub d_count: u64,
    pub d_sum: u64,
    pub buckets: Vec<(u8, u64)>,
}

#[derive(Clone, Debug, Default)]
pub struct TelemetryMsg {
    pub round: u32,
    pub sender_now_ns: u64,
    pub threads: Vec<TelemetryThread>,
    pub counters: Vec<(u8, u64)>,
    pub gauges: Vec<(u8, u64)>,
    pub hists: Vec<TelemetryHist>,
}

fn bad_telemetry(what: &'static str) -> FrameError {
    FrameError::BadPayload {
        kind: FrameKind::Telemetry,
        what,
    }
}

/// Parse a `Telemetry` frame into an owned message (coordinator side —
/// off the zero-alloc path by design). Every count field is capped,
/// every stage tag and bucket index bounds-checked, and trailing bytes
/// are rejected, so a hostile payload is a typed error, never a panic
/// or an unbounded allocation.
pub fn parse_telemetry(view: &FrameView<'_>) -> Result<TelemetryMsg, FrameError> {
    if view.kind != FrameKind::Telemetry {
        return Err(FrameError::BadPayload {
            kind: view.kind,
            what: "expected Telemetry",
        });
    }
    let mut r = PayloadReader::new(view);
    let round = r.u32("round")?;
    let sender_now_ns = r.u64("sender clock")?;

    let nthreads = r.u32("thread count")? as usize;
    if nthreads > MAX_TELEMETRY_THREADS {
        return Err(bad_telemetry("thread count"));
    }
    let mut threads = Vec::with_capacity(nthreads);
    for _ in 0..nthreads {
        let tid = r.u32("thread id")?;
        let nlen = r.u8("thread name length")? as usize;
        if nlen > MAX_TELEMETRY_NAME {
            return Err(bad_telemetry("thread name length"));
        }
        let name = std::str::from_utf8(r.bytes(nlen, "thread name")?)
            .map_err(|_| bad_telemetry("thread name is not UTF-8"))?
            .to_string();
        let dropped = r.u64("ring drop count")?;
        let nspans = r.u32("span count")? as usize;
        if nspans > MAX_TELEMETRY_SPANS {
            return Err(bad_telemetry("span count"));
        }
        let mut spans = Vec::with_capacity(nspans);
        for _ in 0..nspans {
            let stage = r.u8("span stage")?;
            if stage >= TELEMETRY_STAGE_LIMIT {
                return Err(bad_telemetry("span stage"));
            }
            spans.push(TelemetrySpan {
                stage,
                track: r.u32("span track")?,
                start_ns: r.u64("span start")?,
                dur_ns: r.u64("span duration")?,
                a: r.u64("span arg a")?,
                b: r.u64("span arg b")?,
            });
        }
        threads.push(TelemetryThread {
            tid,
            name,
            dropped,
            spans,
        });
    }

    let ncounters = r.u32("counter count")? as usize;
    if ncounters > MAX_TELEMETRY_COUNTERS {
        return Err(bad_telemetry("counter count"));
    }
    let mut counters = Vec::with_capacity(ncounters);
    for _ in 0..ncounters {
        counters.push((r.u8("counter id")?, r.u64("counter delta")?));
    }

    let ngauges = r.u32("gauge count")? as usize;
    if ngauges > MAX_TELEMETRY_GAUGES {
        return Err(bad_telemetry("gauge count"));
    }
    let mut gauges = Vec::with_capacity(ngauges);
    for _ in 0..ngauges {
        gauges.push((r.u8("gauge id")?, r.u64("gauge value")?));
    }

    let nhists = r.u32("histogram count")? as usize;
    if nhists > MAX_TELEMETRY_HISTS {
        return Err(bad_telemetry("histogram count"));
    }
    let mut hists = Vec::with_capacity(nhists);
    for _ in 0..nhists {
        let stage = r.u8("histogram stage")?;
        if stage >= TELEMETRY_STAGE_LIMIT {
            return Err(bad_telemetry("histogram stage"));
        }
        let d_count = r.u64("histogram count delta")?;
        let d_sum = r.u64("histogram sum delta")?;
        let nbuckets = r.u8("bucket count")? as usize;
        if nbuckets > TELEMETRY_STAGE_LIMIT as usize {
            return Err(bad_telemetry("bucket count"));
        }
        let mut buckets = Vec::with_capacity(nbuckets);
        for _ in 0..nbuckets {
            let idx = r.u8("bucket index")?;
            if idx >= TELEMETRY_STAGE_LIMIT {
                return Err(bad_telemetry("bucket index"));
            }
            buckets.push((idx, r.u64("bucket delta")?));
        }
        hists.push(TelemetryHist {
            stage,
            d_count,
            d_sum,
            buckets,
        });
    }

    if !r.rest().is_empty() {
        return Err(bad_telemetry("trailing bytes"));
    }
    Ok(TelemetryMsg {
        round,
        sender_now_ns,
        threads,
        counters,
        gauges,
        hists,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_reference_vectors() {
        // The canonical IEEE CRC-32 check value.
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xe8b7_be43);
    }

    #[test]
    fn frame_roundtrip_and_overhead() {
        let mut out = Vec::new();
        let base = begin_frame(&mut out, FrameKind::Hello);
        out.extend_from_slice(b"xyz");
        end_frame(&mut out, base);
        assert_eq!(out.len() as u64, FRAME_OVERHEAD + 3);
        let (view, used) = parse_frame(&out).unwrap();
        assert_eq!(used, out.len());
        assert_eq!(view.kind, FrameKind::Hello);
        assert_eq!(view.payload, b"xyz");
    }

    #[test]
    fn frames_concatenate() {
        let mut out = Vec::new();
        encode_hello(&mut out, 0);
        encode_ready(&mut out, 7, 1234);
        encode_bye(&mut out);
        let (a, ua) = parse_frame(&out).unwrap();
        assert_eq!(a.kind, FrameKind::Hello);
        assert_eq!(parse_hello(&a).unwrap(), 0);
        let (b, ub) = parse_frame(&out[ua..]).unwrap();
        assert_eq!(b.kind, FrameKind::Ready);
        assert_eq!(parse_ready(&b).unwrap(), (7, 1234));
        let (c, uc) = parse_frame(&out[ua + ub..]).unwrap();
        assert_eq!(c.kind, FrameKind::Bye);
        assert_eq!(ua + ub + uc, out.len());
    }

    #[test]
    fn version_and_kind_rejection() {
        let mut out = Vec::new();
        encode_hello(&mut out, 0);
        let mut v = out.clone();
        v[2] = WIRE_VERSION + 1;
        // Re-seal (CRC covers header + payload) so only the version
        // differs from a valid frame.
        let n = v.len();
        let crc = crc32(&v[..n - CRC_LEN]).to_le_bytes();
        v[n - 4..].copy_from_slice(&crc);
        assert!(matches!(
            parse_frame(&v),
            Err(FrameError::BadVersion { got, .. }) if got == WIRE_VERSION + 1
        ));
        let mut k = out.clone();
        k[3] = 0xee;
        let n = k.len();
        let crc = crc32(&k[..n - CRC_LEN]).to_le_bytes();
        k[n - 4..].copy_from_slice(&crc);
        assert!(matches!(
            parse_frame(&k),
            Err(FrameError::UnknownKind { got: 0xee })
        ));
    }

    #[test]
    fn oversized_length_prefix_fails_fast() {
        let mut out = Vec::new();
        encode_hello(&mut out, 0);
        out[4..8].copy_from_slice(&(u32::MAX).to_le_bytes());
        match parse_frame(&out) {
            Err(FrameError::Oversized { len, max }) => {
                assert_eq!(len, u32::MAX as usize);
                assert_eq!(max, MAX_PAYLOAD);
            }
            other => panic!("want Oversized, got {other:?}"),
        }
    }

    #[test]
    fn hello_and_config_roundtrip_session_tokens() {
        let mut out = Vec::new();
        encode_hello(&mut out, 0xdead_beef_cafe_f00d);
        let (h, _) = parse_frame(&out).unwrap();
        assert_eq!(parse_hello(&h).unwrap(), 0xdead_beef_cafe_f00d);

        let mut out = Vec::new();
        encode_config(&mut out, 42, 3, "{\"rounds\": 1}");
        let (c, _) = parse_frame(&out).unwrap();
        let (fp, token, json) = parse_config(&c).unwrap();
        assert_eq!((fp, token), (42, 3));
        assert_eq!(json, "{\"rounds\": 1}");
    }

    #[test]
    fn state_sync_roundtrips() {
        let u = [1.0f32, -2.5, 0.0, 3.25];
        let v = [0.5f32, 0.0, -1.0, 8.0];
        let mut out = Vec::new();
        encode_state_sync(&mut out, 9, 17, (0x0123_4567_89ab_cdef_u128 << 64) | 7, 99, &u, &v);
        let (view, used) = parse_frame(&out).unwrap();
        assert_eq!(used, out.len());
        let msg = parse_state_sync(&view).unwrap();
        assert_eq!(msg.client, 9);
        assert_eq!(msg.participations, 17);
        assert_eq!(msg.rng_state, (0x0123_4567_89ab_cdef_u128 << 64) | 7);
        assert_eq!(msg.rng_inc, 99);
        assert_eq!(msg.residual_len(), 4);
        let (mut ru, mut rv) = (Vec::new(), Vec::new());
        msg.read_residuals(&mut ru, &mut rv);
        assert_eq!(ru, u);
        assert_eq!(rv, v);
    }

    #[test]
    fn state_sync_rejects_short_residual_body() {
        let mut out = Vec::new();
        encode_state_sync(&mut out, 1, 0, 0, 0, &[1.0; 3], &[2.0; 3]);
        // Claim one more residual than the body carries, re-seal.
        let at = HEADER_LEN + 4 + 8 + 16 + 16;
        out[at..at + 4].copy_from_slice(&4u32.to_le_bytes());
        let n = out.len();
        let crc = crc32(&out[..n - CRC_LEN]).to_le_bytes();
        out[n - 4..].copy_from_slice(&crc);
        let (view, _) = parse_frame(&out).unwrap();
        assert!(matches!(
            parse_state_sync(&view),
            Err(FrameError::BadPayload { what: "residual body length", .. })
        ));
    }

    #[test]
    fn telemetry_roundtrips_every_section() {
        let mut out = Vec::new();
        let mut enc = TelemetryEncoder::begin(&mut out, 12, 9_876_543_210);
        enc.begin_threads();
        enc.begin_thread(0, "main", 3);
        enc.span(5, 0, 100, 40, 12, 7);
        enc.span(11, 0, 150, 0, 12, 2_000_000_000);
        enc.begin_thread(2, "pool-1", 0);
        enc.end_threads();
        enc.begin_counters();
        enc.counter(4, 17);
        enc.counter(0, 1 << 40);
        enc.end_counters();
        enc.begin_gauges();
        enc.gauge(1, 8);
        enc.end_gauges();
        enc.begin_hists();
        enc.begin_hist(5, 2, 140, );
        enc.bucket(6, 1);
        enc.bucket(7, 1);
        enc.begin_hist(8, 1, 40);
        enc.bucket(6, 1);
        enc.end_hists();
        enc.finish();

        let (view, used) = parse_frame(&out).unwrap();
        assert_eq!(used, out.len());
        assert_eq!(view.kind, FrameKind::Telemetry);
        let msg = parse_telemetry(&view).unwrap();
        assert_eq!(msg.round, 12);
        assert_eq!(msg.sender_now_ns, 9_876_543_210);
        assert_eq!(msg.threads.len(), 2);
        assert_eq!(msg.threads[0].name, "main");
        assert_eq!(msg.threads[0].dropped, 3);
        assert_eq!(msg.threads[0].spans.len(), 2);
        assert_eq!(
            msg.threads[0].spans[0],
            TelemetrySpan {
                stage: 5,
                track: 0,
                start_ns: 100,
                dur_ns: 40,
                a: 12,
                b: 7
            }
        );
        assert_eq!(msg.threads[1].tid, 2);
        assert!(msg.threads[1].spans.is_empty());
        assert_eq!(msg.counters, vec![(4, 17), (0, 1 << 40)]);
        assert_eq!(msg.gauges, vec![(1, 8)]);
        assert_eq!(msg.hists.len(), 2);
        assert_eq!(msg.hists[0].stage, 5);
        assert_eq!(msg.hists[0].d_sum, 140);
        assert_eq!(msg.hists[0].buckets, vec![(6, 1), (7, 1)]);
    }

    #[test]
    fn empty_telemetry_is_four_zero_counts() {
        let mut out = Vec::new();
        let mut enc = TelemetryEncoder::begin(&mut out, 0, 0);
        enc.begin_threads();
        enc.end_threads();
        enc.begin_counters();
        enc.end_counters();
        enc.begin_gauges();
        enc.end_gauges();
        enc.begin_hists();
        enc.end_hists();
        enc.finish();
        // round + clock + four u32 section counts.
        assert_eq!(out.len() as u64, FRAME_OVERHEAD + 4 + 8 + 16);
        let (view, _) = parse_frame(&out).unwrap();
        let msg = parse_telemetry(&view).unwrap();
        assert!(msg.threads.is_empty() && msg.counters.is_empty());
        assert!(msg.gauges.is_empty() && msg.hists.is_empty());
    }

    #[test]
    fn telemetry_rejects_hostile_counts() {
        let mut out = Vec::new();
        let mut enc = TelemetryEncoder::begin(&mut out, 1, 2);
        enc.begin_threads();
        enc.end_threads();
        enc.begin_counters();
        enc.end_counters();
        enc.begin_gauges();
        enc.end_gauges();
        enc.begin_hists();
        enc.end_hists();
        enc.finish();
        // Thread-count field sits right after round + clock.
        let at = HEADER_LEN + 4 + 8;
        for hostile in [u32::MAX, (MAX_TELEMETRY_THREADS + 1) as u32] {
            let mut v = out.clone();
            v[at..at + 4].copy_from_slice(&hostile.to_le_bytes());
            let n = v.len();
            let crc = crc32(&v[..n - CRC_LEN]).to_le_bytes();
            v[n - 4..].copy_from_slice(&crc);
            let (view, _) = parse_frame(&v).unwrap();
            let got = parse_telemetry(&view);
            assert!(
                matches!(got, Err(FrameError::BadPayload { .. })),
                "hostile thread count {hostile}: {got:?}"
            );
        }
    }

    fn offer_for(keep: Vec<Vec<bool>>) -> Vec<u8> {
        let sm = SubModel::from_keep(keep);
        let mut out = Vec::new();
        encode_round_offer(&mut out, 3, 5, 11, 0.1, f64::NAN, &sm);
        out
    }

    fn decode_keep(buf: &[u8]) -> Vec<Vec<bool>> {
        let (view, used) = parse_frame(buf).unwrap();
        assert_eq!(used, buf.len());
        parse_round_offer(&view).unwrap().submodel().keep
    }

    #[test]
    fn run_heavy_bitmaps_compress_and_roundtrip() {
        // 512 units kept in two long stretches: RLE wins by a wide
        // margin over the 64-byte raw bitmap, and decodes identically.
        let mut long = vec![true; 512];
        for k in long.iter_mut().take(300).skip(40) {
            *k = false;
        }
        let all = vec![true; 257];
        let none = vec![false; 63];
        let cases = vec![long, all, none, vec![], vec![false], vec![true]];
        for keep in cases {
            let buf = offer_for(vec![keep.clone()]);
            assert_eq!(decode_keep(&buf), vec![keep]);
        }
    }

    #[test]
    fn alternating_bitmaps_fall_back_to_raw() {
        // Worst case for RLE (every unit is its own run): the encoder
        // must pick the raw bitmap, which costs ⌈n/8⌉ bytes.
        let keep: Vec<bool> = (0..256).map(|i| i % 2 == 0).collect();
        let buf = offer_for(vec![keep.clone()]);
        assert_eq!(decode_keep(&buf), vec![keep]);
        // Frame size: fixed fields + one group header + tag + 32 bitmap
        // bytes (RLE would need 256 varints).
        assert_eq!(buf.len() as u64, FRAME_OVERHEAD + 30 + 4 + 1 + 32);
    }

    #[test]
    fn rle_runs_must_sum_to_unit_count() {
        // Hand-build a group whose runs overshoot the declared count.
        let mut out = Vec::new();
        let base = begin_frame(&mut out, FrameKind::RoundOffer);
        out.extend_from_slice(&0u32.to_le_bytes()); // round
        out.extend_from_slice(&0u32.to_le_bytes()); // client
        out.extend_from_slice(&0u64.to_le_bytes()); // seed
        out.extend_from_slice(&0.1f32.to_le_bytes()); // lr
        out.extend_from_slice(&f64::NAN.to_le_bytes()); // deadline
        out.extend_from_slice(&1u16.to_le_bytes()); // group count
        out.extend_from_slice(&10u32.to_le_bytes()); // unit count
        out.push(GROUP_RLE);
        out.push(7); // kept run
        out.push(7); // dropped run: 14 > 10
        end_frame(&mut out, base);
        let (view, _) = parse_frame(&out).unwrap();
        assert!(matches!(
            parse_round_offer(&view),
            Err(FrameError::BadPayload { what: "group runs exceed unit count", .. })
        ));
    }
}
