//! Single-Model Adaptive Federated Dropout — Algorithm 2 of the paper.
//!
//! One **global** activation score map `M` at the server; a single
//! sub-model `w_t` per round, shared by every selected client. The
//! recording signal is the *average* loss of the round's cohort
//! (Alg. 2 line 17): if `l̄_t < l̄` the round's activation set is
//! recorded and credited with `(l̄ − l̄_t)/l̄`; otherwise the next round
//! falls back to weighted random selection.
//!
//! The paper notes this mode is robust to small client fractions (the
//! score signal no longer depends on how often an individual client is
//! selected) but is only reliable in IID settings, where the average
//! loss of different cohorts is comparable round-to-round — our IID
//! benches (Table 2 / Fig. 3) use it accordingly.

use crate::dropout::score_map::ScoreMap;
use crate::dropout::SubmodelStrategy;
use crate::model::manifest::VariantSpec;
use crate::model::submodel::SubModel;
use crate::util::rng::Pcg64;

pub struct SingleModelAfd {
    spec: VariantSpec,
    fdr: f64,
    score_map: ScoreMap,
    last_avg_loss: f64,
    recorded: bool,
    recorded_submodel: Option<SubModel>,
    /// The round's shared sub-model + collected cohort losses.
    current: Option<SubModel>,
    current_round: usize,
    round_losses: Vec<f64>,
}

impl SingleModelAfd {
    pub fn new(spec: &VariantSpec, fdr: f64) -> Self {
        assert!((0.0..1.0).contains(&fdr), "FDR must be in [0,1), got {fdr}");
        SingleModelAfd {
            spec: spec.clone(),
            fdr,
            score_map: ScoreMap::zeros(spec),
            last_avg_loss: 0.0, // paper initialises l ← 0
            recorded: false,
            recorded_submodel: None,
            current: None,
            current_round: 0,
            round_losses: Vec::new(),
        }
    }

    pub fn score_map(&self) -> &ScoreMap {
        &self.score_map
    }

    pub fn recorded(&self) -> bool {
        self.recorded
    }

    fn build_round_submodel(&mut self, round: usize, rng: &mut Pcg64) -> SubModel {
        if round <= 1 {
            // Line 10: random selection in the first round.
            ScoreMap::uniform_select(&self.spec, self.fdr, rng)
        } else if self.recorded {
            // Line 5: reuse the recorded activation set A.
            self.recorded_submodel
                .clone()
                .expect("recorded implies stored sub-model")
        } else {
            // Line 7: weighted random selection from M.
            self.score_map.weighted_select(&self.spec, self.fdr, rng)
        }
    }
}

impl SubmodelStrategy for SingleModelAfd {
    fn select(&mut self, round: usize, _client: usize, rng: &mut Pcg64) -> SubModel {
        if self.current_round != round || self.current.is_none() {
            // First client of the round: build the shared sub-model.
            let sm = self.build_round_submodel(round, rng);
            self.current = Some(sm);
            self.current_round = round;
            self.round_losses.clear();
        }
        self.current.clone().unwrap()
    }

    fn report_loss(&mut self, round: usize, _client: usize, loss: f64) {
        // Synchronous rounds report at exactly `current_round`; the
        // async scheduler can deliver a straggler's loss in a later
        // round (it folds into that round's average — the algorithm's
        // buffered-async approximation). Reports can never precede the
        // select that opened their round.
        debug_assert!(round >= self.current_round, "{round} < {}", self.current_round);
        self.round_losses.push(loss);
    }

    fn end_round(&mut self, _round: usize) {
        let Some(sm) = self.current.take() else {
            return;
        };
        if self.round_losses.is_empty() {
            return;
        }
        // Line 17: l̄_t = (1/m) Σ l_t^c over the cohort.
        let avg = self.round_losses.iter().sum::<f64>() / self.round_losses.len() as f64;
        // Lines 18-24.
        if self.last_avg_loss > 0.0 && avg < self.last_avg_loss {
            let delta = (self.last_avg_loss - avg) / self.last_avg_loss;
            self.score_map.credit(&sm, delta);
            self.recorded_submodel = Some(sm);
            self.recorded = true;
        } else {
            self.recorded = false;
        }
        self.last_avg_loss = avg;
        self.round_losses.clear();
    }

    fn name(&self) -> &'static str {
        "afd_single"
    }

    fn fdr(&self) -> f64 {
        self.fdr
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        use crate::dropout::statebytes as sb;
        sb::push_f64(out, self.last_avg_loss);
        sb::push_bool(out, self.recorded);
        sb::push_score_map(out, &self.score_map);
        sb::push_opt_submodel(out, self.recorded_submodel.as_ref());
        sb::push_opt_submodel(out, self.current.as_ref());
        sb::push_u64(out, self.current_round as u64);
        sb::push_u64(out, self.round_losses.len() as u64);
        for &l in &self.round_losses {
            sb::push_f64(out, l);
        }
    }

    fn load_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        use crate::dropout::statebytes as sb;
        let mut r = sb::Reader::new(bytes);
        self.last_avg_loss = r.f64()?;
        self.recorded = r.boolean()?;
        r.score_map_into(&mut self.score_map)?;
        self.recorded_submodel = r.opt_submodel()?;
        self.current = r.opt_submodel()?;
        self.current_round = r.u64()? as usize;
        let n = r.u64()? as usize;
        self.round_losses.clear();
        for _ in 0..n {
            self.round_losses.push(r.f64()?);
        }
        r.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::tiny_spec;

    #[test]
    fn whole_cohort_shares_one_submodel() {
        let spec = tiny_spec();
        let mut s = SingleModelAfd::new(&spec, 0.25);
        let mut rng = Pcg64::new(0);
        let a = s.select(1, 0, &mut rng);
        let b = s.select(1, 5, &mut rng);
        let c = s.select(1, 9, &mut rng);
        assert_eq!(a, b);
        assert_eq!(b, c);
        // New round → (possibly) new sub-model, but freshly built.
        for cl in [0, 5, 9] {
            s.report_loss(1, cl, 1.0);
        }
        s.end_round(1);
        let d = s.select(2, 0, &mut rng);
        assert_eq!(d.kept_counts(), vec![3]);
    }

    #[test]
    fn average_loss_improvement_records() {
        let spec = tiny_spec();
        let mut s = SingleModelAfd::new(&spec, 0.5);
        let mut rng = Pcg64::new(1);
        let _ = s.select(1, 0, &mut rng);
        s.report_loss(1, 0, 4.0);
        s.report_loss(1, 1, 2.0); // avg 3.0
        s.end_round(1);
        assert!(!s.recorded(), "first round cannot record (l starts at 0)");

        let sm2 = s.select(2, 0, &mut rng);
        s.report_loss(2, 0, 2.0);
        s.report_loss(2, 1, 1.0); // avg 1.5 < 3.0 → record, delta 0.5
        s.end_round(2);
        assert!(s.recorded());
        let m = s.score_map();
        for (g, keep) in sm2.keep.iter().enumerate() {
            for (u, &k) in keep.iter().enumerate() {
                assert_eq!(m.scores[g][u], if k { 0.5 } else { 0.0 });
            }
        }
        // Round 3 reuses the recorded sub-model.
        let sm3 = s.select(3, 7, &mut rng);
        assert_eq!(sm3, sm2);
    }

    #[test]
    fn regression_unrecords() {
        let spec = tiny_spec();
        let mut s = SingleModelAfd::new(&spec, 0.25);
        let mut rng = Pcg64::new(2);
        for (round, losses) in [(1usize, [3.0, 3.0]), (2, [1.0, 1.0]), (3, [5.0, 5.0])] {
            let _ = s.select(round, 0, &mut rng);
            for (c, l) in losses.iter().enumerate() {
                s.report_loss(round, c, *l);
            }
            s.end_round(round);
        }
        assert!(!s.recorded());
        // avg loss path: 3 → 1 (recorded) → 5 (unrecorded)
        assert!(s.score_map().total() > 0.0);
    }

    #[test]
    fn state_roundtrips_through_save_load() {
        let spec = tiny_spec();
        let mut s = SingleModelAfd::new(&spec, 0.25);
        let mut rng = Pcg64::new(5);
        for (round, losses) in [(1usize, [4.0, 2.0]), (2, [2.0, 1.0]), (3, [1.5, 0.5])] {
            let _ = s.select(round, 0, &mut rng);
            for (c, l) in losses.iter().enumerate() {
                s.report_loss(round, c, *l);
            }
            s.end_round(round);
        }
        let mut blob = Vec::new();
        s.save_state(&mut blob);
        let mut t = SingleModelAfd::new(&spec, 0.25);
        t.load_state(&blob).unwrap();
        assert_eq!(t.recorded(), s.recorded());
        let mut ra = Pcg64::new(11);
        let mut rb = Pcg64::new(11);
        assert_eq!(s.select(4, 0, &mut ra), t.select(4, 0, &mut rb));
        assert!(t.load_state(&blob[..blob.len() - 2]).is_err());
    }

    #[test]
    fn empty_round_is_noop() {
        let spec = tiny_spec();
        let mut s = SingleModelAfd::new(&spec, 0.25);
        s.end_round(1); // no select, no losses — must not panic
        assert!(!s.recorded());
    }
}
