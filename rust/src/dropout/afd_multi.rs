//! Multi-Model Adaptive Federated Dropout — Algorithm 1 of the paper.
//!
//! The server keeps, **per client c**: an activation score map `M_c`
//! (zeros at start), the latest local loss `l_c` (0 at start), a
//! `recorded` flag and the last recorded activation set `A_c`.
//!
//! Per round t, for each selected client c:
//! * first participation (t = 1 for c)     → uniform random k% sub-model;
//! * `recorded`                            → reuse `A_c` (the activations
//!   proven beneficial last time, Alg. 1 line 7);
//! * otherwise                             → weighted random selection
//!   with weights `M_c` (line 9).
//!
//! After local training reports `l_t^c`:
//! * `l_t^c < l_c` → record `A_c` := the sub-model used, credit its
//!   activations with `(l_c − l_t^c)/l_c` in `M_c`, `recorded` := true;
//! * else          → `recorded` := false.
//! * `l_c` := `l_t^c` either way (lines 15-23).
//!
//! Note on the pseudocode: the paper writes a single `Recorded` variable
//! but tests and updates it inside the per-client loop immediately after
//! that client's own comparison; the only consistent reading (and the
//! one matching the narrative "for the subsequent round of local
//! training, we use the same subset A_c") is a per-client flag, which is
//! what we implement.

use crate::dropout::score_map::ScoreMap;
use crate::dropout::SubmodelStrategy;
use crate::model::manifest::VariantSpec;
use crate::model::submodel::SubModel;
use crate::util::rng::Pcg64;

struct ClientState {
    score_map: ScoreMap,
    last_loss: f64,
    recorded: bool,
    recorded_submodel: Option<SubModel>,
    /// Sub-model actually used this round (set by `select`).
    current: Option<SubModel>,
    participated: bool,
}

pub struct MultiModelAfd {
    spec: VariantSpec,
    fdr: f64,
    clients: Vec<ClientState>,
}

impl MultiModelAfd {
    pub fn new(spec: &VariantSpec, num_clients: usize, fdr: f64) -> Self {
        assert!((0.0..1.0).contains(&fdr), "FDR must be in [0,1), got {fdr}");
        let clients = (0..num_clients)
            .map(|_| ClientState {
                score_map: ScoreMap::zeros(spec),
                last_loss: 0.0, // paper initialises l_c ← 0
                recorded: false,
                recorded_submodel: None,
                current: None,
                participated: false,
            })
            .collect();
        MultiModelAfd {
            spec: spec.clone(),
            fdr,
            clients,
        }
    }

    /// Read-only view of a client's score map (diagnostics / tests).
    pub fn score_map(&self, client: usize) -> &ScoreMap {
        &self.clients[client].score_map
    }

    pub fn recorded(&self, client: usize) -> bool {
        self.clients[client].recorded
    }
}

impl SubmodelStrategy for MultiModelAfd {
    fn select(&mut self, _round: usize, client: usize, rng: &mut Pcg64) -> SubModel {
        let st = &mut self.clients[client];
        let sm = if !st.participated {
            // Line 12: random selection on the client's first round.
            ScoreMap::uniform_select(&self.spec, self.fdr, rng)
        } else if st.recorded {
            // Line 7: reuse the recorded activation set A_c.
            st.recorded_submodel
                .clone()
                .expect("recorded flag implies a stored sub-model")
        } else {
            // Line 9: weighted random selection from M_c.
            st.score_map.weighted_select(&self.spec, self.fdr, rng)
        };
        st.current = Some(sm.clone());
        st.participated = true;
        sm
    }

    fn report_loss(&mut self, _round: usize, client: usize, loss: f64) {
        let st = &mut self.clients[client];
        let sm = st
            .current
            .take()
            .expect("report_loss without a preceding select");
        // Lines 16-23. `last_loss` starts at 0, so the first round can
        // never record (0 < 0 is false) — matching the paper.
        if st.last_loss > 0.0 && loss < st.last_loss {
            let delta = (st.last_loss - loss) / st.last_loss;
            st.score_map.credit(&sm, delta);
            st.recorded_submodel = Some(sm);
            st.recorded = true;
        } else {
            st.recorded = false;
        }
        st.last_loss = loss;
    }

    fn end_round(&mut self, _round: usize) {}

    fn name(&self) -> &'static str {
        "afd_multi"
    }

    fn fdr(&self) -> f64 {
        self.fdr
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        use crate::dropout::statebytes as sb;
        sb::push_u64(out, self.clients.len() as u64);
        for st in &self.clients {
            sb::push_f64(out, st.last_loss);
            sb::push_bool(out, st.recorded);
            sb::push_bool(out, st.participated);
            sb::push_score_map(out, &st.score_map);
            sb::push_opt_submodel(out, st.recorded_submodel.as_ref());
            // `current` can be Some across a round boundary: a client
            // lost in transit never reports its loss, so the taken
            // sub-model stays pending. Serialize it or a restored run
            // diverges on that client's next selection.
            sb::push_opt_submodel(out, st.current.as_ref());
        }
    }

    fn load_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        use crate::dropout::statebytes as sb;
        let mut r = sb::Reader::new(bytes);
        let n = r.u64()? as usize;
        if n != self.clients.len() {
            anyhow::bail!(
                "afd_multi state: {n} clients in blob, strategy has {}",
                self.clients.len()
            );
        }
        for st in self.clients.iter_mut() {
            st.last_loss = r.f64()?;
            st.recorded = r.boolean()?;
            st.participated = r.boolean()?;
            r.score_map_into(&mut st.score_map)?;
            st.recorded_submodel = r.opt_submodel()?;
            st.current = r.opt_submodel()?;
        }
        r.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::tiny_spec;

    #[test]
    fn first_round_never_records() {
        let spec = tiny_spec();
        let mut s = MultiModelAfd::new(&spec, 2, 0.25);
        let mut rng = Pcg64::new(0);
        let _ = s.select(1, 0, &mut rng);
        s.report_loss(1, 0, 1.0);
        assert!(!s.recorded(0));
        assert_eq!(s.score_map(0).total(), 0.0);
    }

    #[test]
    fn improvement_records_and_credits() {
        let spec = tiny_spec();
        let mut s = MultiModelAfd::new(&spec, 1, 0.25);
        let mut rng = Pcg64::new(1);
        let _ = s.select(1, 0, &mut rng);
        s.report_loss(1, 0, 2.0);
        let sm2 = s.select(2, 0, &mut rng);
        s.report_loss(2, 0, 1.0); // improved by 50%
        assert!(s.recorded(0));
        // Exactly the kept activations carry score 0.5.
        let m = s.score_map(0);
        for (g, keep) in sm2.keep.iter().enumerate() {
            for (u, &k) in keep.iter().enumerate() {
                let want = if k { 0.5 } else { 0.0 };
                assert_eq!(m.scores[g][u], want);
            }
        }
        // Next round reuses the same sub-model (recorded).
        let sm3 = s.select(3, 0, &mut rng);
        assert_eq!(sm3, sm2);
    }

    #[test]
    fn regression_switches_to_weighted_random() {
        let spec = tiny_spec();
        let mut s = MultiModelAfd::new(&spec, 1, 0.5);
        let mut rng = Pcg64::new(2);
        let _ = s.select(1, 0, &mut rng);
        s.report_loss(1, 0, 1.0);
        let _ = s.select(2, 0, &mut rng);
        s.report_loss(2, 0, 0.5); // improve → record
        assert!(s.recorded(0));
        let _ = s.select(3, 0, &mut rng);
        s.report_loss(3, 0, 0.9); // regress → stop reusing
        assert!(!s.recorded(0));
        // Selection still produces valid sub-models of the right size.
        let sm = s.select(4, 0, &mut rng);
        assert_eq!(sm.kept_counts(), vec![2]);
    }

    #[test]
    fn clients_are_independent() {
        let spec = tiny_spec();
        let mut s = MultiModelAfd::new(&spec, 3, 0.25);
        let mut rng = Pcg64::new(3);
        for c in 0..3 {
            let _ = s.select(1, c, &mut rng);
            s.report_loss(1, c, 1.0);
        }
        let _ = s.select(2, 1, &mut rng);
        s.report_loss(2, 1, 0.4); // only client 1 improves
        assert!(!s.recorded(0));
        assert!(s.recorded(1));
        assert!(!s.recorded(2));
        assert_eq!(s.score_map(0).total(), 0.0);
        assert!(s.score_map(1).total() > 0.0);
    }

    #[test]
    fn state_roundtrips_through_save_load() {
        let spec = tiny_spec();
        let mut s = MultiModelAfd::new(&spec, 2, 0.25);
        let mut rng = Pcg64::new(7);
        for round in 1..4 {
            for c in 0..2 {
                let _ = s.select(round, c, &mut rng);
                // Client 1 is "lost" in the last round: no loss report,
                // so its taken sub-model stays pending in `current`.
                if !(round == 3 && c == 1) {
                    s.report_loss(round, c, 1.0 / round as f64);
                }
            }
            s.end_round(round);
        }
        let mut blob = Vec::new();
        s.save_state(&mut blob);
        let mut t = MultiModelAfd::new(&spec, 2, 0.25);
        t.load_state(&blob).unwrap();
        // Identical future behaviour from identical RNG cursors.
        let mut ra = Pcg64::new(99);
        let mut rb = Pcg64::new(99);
        for c in 0..2 {
            assert_eq!(s.select(4, c, &mut ra), t.select(4, c, &mut rb));
        }
        // Truncated and shape-mismatched blobs diagnose, not panic.
        assert!(t.load_state(&blob[..blob.len() - 1]).is_err());
        assert!(MultiModelAfd::new(&spec, 3, 0.25).load_state(&blob).is_err());
    }

    #[test]
    fn scores_accumulate_over_improvements() {
        let spec = tiny_spec();
        let mut s = MultiModelAfd::new(&spec, 1, 0.25);
        let mut rng = Pcg64::new(4);
        let mut loss = 8.0;
        let _ = s.select(1, 0, &mut rng);
        s.report_loss(1, 0, loss);
        for round in 2..8 {
            let _ = s.select(round, 0, &mut rng);
            loss *= 0.5;
            s.report_loss(round, 0, loss);
        }
        // Each improving round credits 0.5 to the 3 kept units.
        let total = s.score_map(0).total();
        assert!((total - 6.0 * 0.5 * 3.0).abs() < 1e-9, "total={total}");
    }
}
