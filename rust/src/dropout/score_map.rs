//! Activation score maps — the paper's central data structure.
//!
//! A score map assigns every droppable activation a real value measuring
//! its importance: whenever a sub-model improves the (client or round)
//! loss, each of its activations is credited with the relative
//! improvement `(l_prev − l_now) / l_prev` (Alg. 1 line 18 / Alg. 2
//! line 19). Weighted random selection then biases future sub-models
//! toward high-scoring activations.

use crate::model::manifest::VariantSpec;
use crate::model::submodel::SubModel;
use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct ScoreMap {
    /// scores[g][u], indexed like `spec.mask_groups` — initialised to 0.
    pub scores: Vec<Vec<f64>>,
}

impl ScoreMap {
    pub fn zeros(spec: &VariantSpec) -> ScoreMap {
        ScoreMap {
            scores: spec.mask_groups.iter().map(|g| vec![0.0; g.size]).collect(),
        }
    }

    /// Credit every activation of `sm` with `delta` (the relative loss
    /// improvement). Paper: "signing a positive value equal to
    /// (l_c − l_t^c)/l_c to their corresponding entries".
    pub fn credit(&mut self, sm: &SubModel, delta: f64) {
        debug_assert!(delta >= 0.0);
        for (g, keep) in sm.keep.iter().enumerate() {
            for (u, &k) in keep.iter().enumerate() {
                if k {
                    self.scores[g][u] += delta;
                }
            }
        }
    }

    /// Weighted random selection of a sub-model keeping `1 − fdr` of each
    /// group's units (Alg. 1 line 9: "weighted random selection of the
    /// activations using weights from M").
    pub fn weighted_select(
        &self,
        spec: &VariantSpec,
        fdr: f64,
        rng: &mut Pcg64,
    ) -> SubModel {
        let kept: Vec<Vec<usize>> = self
            .scores
            .iter()
            .enumerate()
            .map(|(g, ws)| {
                let keep = kept_count(spec.mask_groups[g].size, fdr);
                rng.weighted_sample_distinct(ws, keep)
            })
            .collect();
        SubModel::from_kept_indices(spec, &kept)
    }

    /// Uniform random selection (round 1 / plain Federated Dropout).
    pub fn uniform_select(spec: &VariantSpec, fdr: f64, rng: &mut Pcg64) -> SubModel {
        let kept: Vec<Vec<usize>> = spec
            .mask_groups
            .iter()
            .map(|g| {
                let keep = kept_count(g.size, fdr);
                rng.sample_indices(g.size, keep)
            })
            .collect();
        SubModel::from_kept_indices(spec, &kept)
    }

    /// Total score mass (diagnostics / tests).
    pub fn total(&self) -> f64 {
        self.scores.iter().flatten().sum()
    }

    /// Top-scoring unit per group (diagnostics).
    pub fn argmax(&self) -> Vec<usize> {
        self.scores
            .iter()
            .map(|g| {
                g.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }
}

/// Units kept per group under a federated dropout rate. At least one
/// unit is always kept (a fully-dropped layer would sever the network).
pub fn kept_count(group_size: usize, fdr: f64) -> usize {
    let keep = ((group_size as f64) * (1.0 - fdr)).round() as usize;
    keep.clamp(1, group_size)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::tiny_spec;

    #[test]
    fn kept_count_bounds() {
        assert_eq!(kept_count(100, 0.25), 75);
        assert_eq!(kept_count(4, 0.25), 3);
        assert_eq!(kept_count(10, 0.999), 1); // never zero
        assert_eq!(kept_count(10, 0.0), 10);
    }

    #[test]
    fn credit_only_touches_kept_units() {
        let spec = tiny_spec();
        let mut m = ScoreMap::zeros(&spec);
        let sm = SubModel::from_kept_indices(&spec, &[vec![1, 2]]);
        m.credit(&sm, 0.5);
        assert_eq!(m.scores[0], vec![0.0, 0.5, 0.5, 0.0]);
        m.credit(&sm, 0.25);
        assert_eq!(m.scores[0], vec![0.0, 0.75, 0.75, 0.0]);
        assert_eq!(m.total(), 1.5);
        assert!(m.argmax()[0] == 1 || m.argmax()[0] == 2);
    }

    #[test]
    fn weighted_select_prefers_credited_units() {
        let spec = tiny_spec();
        let mut m = ScoreMap::zeros(&spec);
        let good = SubModel::from_kept_indices(&spec, &[vec![0, 3]]);
        for _ in 0..20 {
            m.credit(&good, 1.0);
        }
        let mut rng = Pcg64::new(1);
        let mut hits = 0;
        let trials = 300;
        for _ in 0..trials {
            let sm = m.weighted_select(&spec, 0.5, &mut rng); // keep 2 of 4
            let kept = sm.kept_indices();
            if kept[0] == vec![0, 3] {
                hits += 1;
            }
        }
        // With 20:1e-9 weight ratio, {0,3} should dominate overwhelmingly.
        assert!(hits > trials * 8 / 10, "hits={hits}/{trials}");
    }

    #[test]
    fn uniform_select_respects_fdr() {
        let spec = tiny_spec();
        let mut rng = Pcg64::new(2);
        let sm = ScoreMap::uniform_select(&spec, 0.25, &mut rng);
        assert_eq!(sm.kept_counts(), vec![3]);
        let sm = ScoreMap::uniform_select(&spec, 0.0, &mut rng);
        assert!(sm.is_full());
    }
}
