//! Activation score maps — the paper's central data structure.
//!
//! A score map assigns every droppable activation a real value measuring
//! its importance: whenever a sub-model improves the (client or round)
//! loss, each of its activations is credited with the relative
//! improvement `(l_prev − l_now) / l_prev` (Alg. 1 line 18 / Alg. 2
//! line 19). Weighted random selection then biases future sub-models
//! toward high-scoring activations.

use crate::model::manifest::VariantSpec;
use crate::model::submodel::SubModel;
use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct ScoreMap {
    /// scores[g][u], indexed like `spec.mask_groups` — initialised to 0.
    pub scores: Vec<Vec<f64>>,
}

impl ScoreMap {
    pub fn zeros(spec: &VariantSpec) -> ScoreMap {
        ScoreMap {
            scores: spec.mask_groups.iter().map(|g| vec![0.0; g.size]).collect(),
        }
    }

    /// Credit every activation of `sm` with `delta` (the relative loss
    /// improvement). Paper: "signing a positive value equal to
    /// (l_c − l_t^c)/l_c to their corresponding entries".
    pub fn credit(&mut self, sm: &SubModel, delta: f64) {
        debug_assert!(delta >= 0.0);
        for (g, keep) in sm.keep.iter().enumerate() {
            for (u, &k) in keep.iter().enumerate() {
                if k {
                    self.scores[g][u] += delta;
                }
            }
        }
    }

    /// Weighted random selection of a sub-model keeping `1 − fdr` of each
    /// group's units (Alg. 1 line 9: "weighted random selection of the
    /// activations using weights from M"). One prefix-sum (Fenwick)
    /// structure is built per group per selection round; each of the
    /// `keep` draws is then a single O(log n) prefix-sum descent with
    /// removal, replacing the per-draw linear rescans.
    pub fn weighted_select(
        &self,
        spec: &VariantSpec,
        fdr: f64,
        rng: &mut Pcg64,
    ) -> SubModel {
        let kept: Vec<Vec<usize>> = self
            .scores
            .iter()
            .enumerate()
            .map(|(g, ws)| {
                let keep = kept_count(spec.mask_groups[g].size, fdr);
                prefix_sum_sample_distinct(ws, keep, rng)
            })
            .collect();
        SubModel::from_kept_indices(spec, &kept)
    }

    /// Uniform random selection (round 1 / plain Federated Dropout).
    pub fn uniform_select(spec: &VariantSpec, fdr: f64, rng: &mut Pcg64) -> SubModel {
        let kept: Vec<Vec<usize>> = spec
            .mask_groups
            .iter()
            .map(|g| {
                let keep = kept_count(g.size, fdr);
                rng.sample_indices(g.size, keep)
            })
            .collect();
        SubModel::from_kept_indices(spec, &kept)
    }

    /// Total score mass (diagnostics / tests).
    pub fn total(&self) -> f64 {
        self.scores.iter().flatten().sum()
    }

    /// Top-scoring unit per group (diagnostics).
    pub fn argmax(&self) -> Vec<usize> {
        self.scores
            .iter()
            .map(|g| {
                g.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }
}

/// Units kept per group under a federated dropout rate. At least one
/// unit is always kept (a fully-dropped layer would sever the network).
pub fn kept_count(group_size: usize, fdr: f64) -> usize {
    let keep = ((group_size as f64) * (1.0 - fdr)).round() as usize;
    keep.clamp(1, group_size)
}

/// Draw `k` distinct indices ∝ `weights` via a Fenwick prefix-sum tree:
/// O(n) build, then one O(log n) cumulative-sum descent + weight
/// removal per draw. Zero/negative weights get a tiny epsilon floor so
/// unscored units stay explorable (weighted *random* selection, Alg. 1
/// line 9) — the same floor the previous sampler used.
pub fn prefix_sum_sample_distinct(
    weights: &[f64],
    k: usize,
    rng: &mut Pcg64,
) -> Vec<usize> {
    let n = weights.len();
    assert!(k <= n, "cannot draw {k} distinct of {n}");
    let mut eff: Vec<f64> = weights
        .iter()
        .map(|&w| if w > 0.0 { w } else { 1e-9 })
        .collect();
    // Fenwick build: tree[i] covers (i − lowbit(i), i], 1-based.
    let mut tree = vec![0.0f64; n + 1];
    for i in 1..=n {
        tree[i] += eff[i - 1];
        let j = i + (i & i.wrapping_neg());
        if j <= n {
            let t = tree[i];
            tree[j] += t;
        }
    }
    let prefix = |tree: &[f64], mut i: usize| -> f64 {
        let mut s = 0.0;
        while i > 0 {
            s += tree[i];
            i &= i - 1;
        }
        s
    };
    let mut top = 1usize;
    while top * 2 <= n {
        top *= 2;
    }
    let mut selected = vec![false; n];
    let mut out = Vec::with_capacity(k);
    for _ in 0..k {
        // Remaining mass read off the tree each draw (no FP drift).
        let total = prefix(&tree, n);
        let u = rng.next_f64() * total;
        // Descend: largest pos with cumsum(pos) <= u; the draw lands
        // in element pos (0-based).
        let mut pos = 0usize;
        let mut rem = u;
        let mut bit = top;
        while bit > 0 {
            let next = pos + bit;
            if next <= n && tree[next] <= rem {
                rem -= tree[next];
                pos = next;
            }
            bit >>= 1;
        }
        let mut idx = pos.min(n - 1);
        if selected[idx] {
            // FP boundary case (u rounded onto a removed coordinate's
            // edge): fall back to the first live index.
            idx = (0..n).find(|&i| !selected[i]).expect("k <= n");
        }
        selected[idx] = true;
        out.push(idx);
        // Remove the drawn weight from the tree.
        let w = eff[idx];
        eff[idx] = 0.0;
        let mut i = idx + 1;
        while i <= n {
            tree[i] -= w;
            i += i & i.wrapping_neg();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::tiny_spec;

    #[test]
    fn kept_count_bounds() {
        assert_eq!(kept_count(100, 0.25), 75);
        assert_eq!(kept_count(4, 0.25), 3);
        assert_eq!(kept_count(10, 0.999), 1); // never zero
        assert_eq!(kept_count(10, 0.0), 10);
    }

    #[test]
    fn credit_only_touches_kept_units() {
        let spec = tiny_spec();
        let mut m = ScoreMap::zeros(&spec);
        let sm = SubModel::from_kept_indices(&spec, &[vec![1, 2]]);
        m.credit(&sm, 0.5);
        assert_eq!(m.scores[0], vec![0.0, 0.5, 0.5, 0.0]);
        m.credit(&sm, 0.25);
        assert_eq!(m.scores[0], vec![0.0, 0.75, 0.75, 0.0]);
        assert_eq!(m.total(), 1.5);
        assert!(m.argmax()[0] == 1 || m.argmax()[0] == 2);
    }

    #[test]
    fn weighted_select_prefers_credited_units() {
        let spec = tiny_spec();
        let mut m = ScoreMap::zeros(&spec);
        let good = SubModel::from_kept_indices(&spec, &[vec![0, 3]]);
        for _ in 0..20 {
            m.credit(&good, 1.0);
        }
        let mut rng = Pcg64::new(1);
        let mut hits = 0;
        let trials = 300;
        for _ in 0..trials {
            let sm = m.weighted_select(&spec, 0.5, &mut rng); // keep 2 of 4
            let kept = sm.kept_indices();
            if kept[0] == vec![0, 3] {
                hits += 1;
            }
        }
        // With 20:1e-9 weight ratio, {0,3} should dominate overwhelmingly.
        assert!(hits > trials * 8 / 10, "hits={hits}/{trials}");
    }

    #[test]
    fn prefix_sum_draws_are_distinct_and_in_range() {
        let mut rng = Pcg64::new(11);
        for k in [1usize, 3, 7, 10] {
            let weights: Vec<f64> = (0..10).map(|i| i as f64).collect(); // includes 0
            let s = prefix_sum_sample_distinct(&weights, k, &mut rng);
            assert_eq!(s.len(), k);
            let mut t = s.clone();
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), k, "duplicates in {s:?}");
            assert!(s.iter().all(|&i| i < 10));
        }
    }

    #[test]
    fn prefix_sum_selection_frequencies_track_scores() {
        // Property: with weights 1:2:4:8 and single-unit draws, the
        // selection frequencies reproduce the weight proportions.
        let spec = tiny_spec();
        let mut m = ScoreMap::zeros(&spec);
        m.scores[0] = vec![1.0, 2.0, 4.0, 8.0];
        let mut rng = Pcg64::new(9);
        let trials = 6000;
        let mut counts = [0usize; 4];
        for _ in 0..trials {
            let sm = m.weighted_select(&spec, 0.75, &mut rng); // keep 1 of 4
            counts[sm.kept_indices()[0][0]] += 1;
        }
        // Expected proportions i/15; allow generous sampling noise.
        for (i, &c) in counts.iter().enumerate() {
            let expect = trials as f64 * m.scores[0][i] / 15.0;
            let err = (c as f64 - expect).abs();
            assert!(
                err < 0.15 * trials as f64 / 4.0 + 5.0 * expect.sqrt(),
                "unit {i}: {c} vs expected {expect:.0} ({counts:?})"
            );
        }
        assert!(
            counts[0] < counts[1] && counts[1] < counts[2] && counts[2] < counts[3],
            "{counts:?}"
        );
    }

    #[test]
    fn uniform_select_respects_fdr() {
        let spec = tiny_spec();
        let mut rng = Pcg64::new(2);
        let sm = ScoreMap::uniform_select(&spec, 0.25, &mut rng);
        assert_eq!(sm.kept_counts(), vec![3]);
        let sm = ScoreMap::uniform_select(&spec, 0.0, &mut rng);
        assert!(sm.is_full());
    }
}
