//! Federated Dropout baseline (Caldas et al. 2018): uniform random
//! sub-models each round, no importance signal.

use crate::dropout::score_map::ScoreMap;
use crate::dropout::SubmodelStrategy;
use crate::model::manifest::VariantSpec;
use crate::model::submodel::SubModel;
use crate::util::rng::Pcg64;

pub struct RandomFd {
    spec: VariantSpec,
    fdr: f64,
}

impl RandomFd {
    pub fn new(spec: &VariantSpec, fdr: f64) -> Self {
        assert!((0.0..1.0).contains(&fdr), "FDR must be in [0,1), got {fdr}");
        RandomFd {
            spec: spec.clone(),
            fdr,
        }
    }
}

impl SubmodelStrategy for RandomFd {
    fn select(&mut self, _round: usize, _client: usize, rng: &mut Pcg64) -> SubModel {
        ScoreMap::uniform_select(&self.spec, self.fdr, rng)
    }

    fn report_loss(&mut self, _round: usize, _client: usize, _loss: f64) {}

    fn end_round(&mut self, _round: usize) {}

    fn name(&self) -> &'static str {
        "fd"
    }

    fn fdr(&self) -> f64 {
        self.fdr
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::tiny_spec;

    #[test]
    fn drops_requested_fraction_every_round() {
        let spec = tiny_spec();
        let mut s = RandomFd::new(&spec, 0.25);
        let mut rng = Pcg64::new(3);
        for round in 1..20 {
            let sm = s.select(round, round % 3, &mut rng);
            assert_eq!(sm.kept_counts(), vec![3]); // 4 units, keep 75%
        }
    }

    #[test]
    fn selections_vary_between_calls() {
        let spec = tiny_spec();
        let mut s = RandomFd::new(&spec, 0.5);
        let mut rng = Pcg64::new(4);
        let picks: Vec<_> = (0..30).map(|r| s.select(r, 0, &mut rng).kept_indices()).collect();
        let first = &picks[0];
        assert!(picks.iter().any(|p| p != first), "FD must randomize");
    }

    #[test]
    #[should_panic]
    fn rejects_fdr_one() {
        let spec = tiny_spec();
        RandomFd::new(&spec, 1.0);
    }
}
