//! Sub-model selection strategies — the paper's contribution.
//!
//! * [`afd_multi::MultiModelAfd`] — Algorithm 1: one activation score map
//!   **per client**, driven by per-client local losses.
//! * [`afd_single::SingleModelAfd`] — Algorithm 2: one **global** score
//!   map, one shared sub-model per round, driven by the round-average
//!   loss.
//! * [`random_fd::RandomFd`] — the Federated Dropout baseline (Caldas et
//!   al. '18): uniform random sub-models each round.
//! * [`NoDropout`] — full model every round (the No-Compression and
//!   DGC-only baselines).
//!
//! The coordinator drives every strategy through [`SubmodelStrategy`]:
//! `select` before the round's local training, `report_loss` after each
//! client trains, `end_round` once the cohort finishes.

pub mod afd_multi;
pub mod afd_single;
pub mod random_fd;
pub mod score_map;

use crate::model::manifest::VariantSpec;
use crate::model::submodel::SubModel;
use crate::util::rng::Pcg64;

pub use afd_multi::MultiModelAfd;
pub use afd_single::SingleModelAfd;
pub use random_fd::RandomFd;
pub use score_map::{kept_count, ScoreMap};

/// Strategy interface the coordinator drives each round.
pub trait SubmodelStrategy: Send {
    /// Sub-model for `client` in `round` (1-based, as in the paper).
    fn select(&mut self, round: usize, client: usize, rng: &mut Pcg64) -> SubModel;

    /// Client `client`'s local training loss for this round.
    fn report_loss(&mut self, round: usize, client: usize, loss: f64);

    /// All of the round's cohort finished; update round-level state.
    fn end_round(&mut self, round: usize);

    fn name(&self) -> &'static str;

    /// Fraction of activations dropped (0 for NoDropout).
    fn fdr(&self) -> f64;

    /// Serialize round-boundary strategy state for a coordinator
    /// checkpoint ([`crate::coordinator::checkpoint`]). Stateless
    /// strategies (NoDropout, RandomFd — whose only state is the
    /// caller's RNG) write nothing.
    fn save_state(&self, _out: &mut Vec<u8>) {}

    /// Restore state written by [`SubmodelStrategy::save_state`].
    fn load_state(&mut self, _bytes: &[u8]) -> anyhow::Result<()> {
        Ok(())
    }
}

/// Little-endian byte (de)serialization shared by the AFD strategies'
/// checkpoint state. Kept deliberately dumb: fixed-width fields,
/// length prefixes, no varints — byte-stable across platforms.
pub(crate) mod statebytes {
    use crate::dropout::score_map::ScoreMap;
    use crate::model::submodel::SubModel;

    pub fn push_u64(out: &mut Vec<u8>, v: u64) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    pub fn push_f64(out: &mut Vec<u8>, v: f64) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    pub fn push_bool(out: &mut Vec<u8>, v: bool) {
        out.push(v as u8);
    }

    pub fn push_score_map(out: &mut Vec<u8>, m: &ScoreMap) {
        push_u64(out, m.scores.len() as u64);
        for g in &m.scores {
            push_u64(out, g.len() as u64);
            for &s in g {
                push_f64(out, s);
            }
        }
    }

    /// Sub-models serialize as their keep bitmaps (one byte per unit);
    /// the derived f32 masks are rebuilt by `SubModel::from_keep`.
    pub fn push_opt_submodel(out: &mut Vec<u8>, sm: Option<&SubModel>) {
        match sm {
            None => push_bool(out, false),
            Some(sm) => {
                push_bool(out, true);
                push_u64(out, sm.keep.len() as u64);
                for g in &sm.keep {
                    push_u64(out, g.len() as u64);
                    for &k in g {
                        push_bool(out, k);
                    }
                }
            }
        }
    }

    /// Cursor over a state blob; every read is bounds-checked so a
    /// corrupt checkpoint diagnoses instead of panicking.
    pub struct Reader<'a> {
        bytes: &'a [u8],
        off: usize,
    }

    impl<'a> Reader<'a> {
        pub fn new(bytes: &'a [u8]) -> Reader<'a> {
            Reader { bytes, off: 0 }
        }

        fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
            if self.off + n > self.bytes.len() {
                anyhow::bail!("strategy state: truncated blob");
            }
            let s = &self.bytes[self.off..self.off + n];
            self.off += n;
            Ok(s)
        }

        pub fn u64(&mut self) -> anyhow::Result<u64> {
            Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
        }

        pub fn f64(&mut self) -> anyhow::Result<f64> {
            Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
        }

        pub fn boolean(&mut self) -> anyhow::Result<bool> {
            Ok(self.take(1)?[0] != 0)
        }

        /// Read a score map into `m`, which must already have the
        /// spec's group shape (shape mismatch ⇒ wrong config/spec).
        pub fn score_map_into(&mut self, m: &mut ScoreMap) -> anyhow::Result<()> {
            let groups = self.u64()? as usize;
            if groups != m.scores.len() {
                anyhow::bail!("strategy state: score map group count mismatch");
            }
            for g in m.scores.iter_mut() {
                let len = self.u64()? as usize;
                if len != g.len() {
                    anyhow::bail!("strategy state: score map group size mismatch");
                }
                for s in g.iter_mut() {
                    *s = self.f64()?;
                }
            }
            Ok(())
        }

        pub fn opt_submodel(&mut self) -> anyhow::Result<Option<SubModel>> {
            if !self.boolean()? {
                return Ok(None);
            }
            let groups = self.u64()? as usize;
            let mut keep = Vec::with_capacity(groups);
            for _ in 0..groups {
                let len = self.u64()? as usize;
                let mut g = Vec::with_capacity(len);
                for _ in 0..len {
                    g.push(self.boolean()?);
                }
                keep.push(g);
            }
            Ok(Some(SubModel::from_keep(keep)))
        }

        pub fn finish(&self) -> anyhow::Result<()> {
            if self.off != self.bytes.len() {
                anyhow::bail!("strategy state: trailing bytes");
            }
            Ok(())
        }
    }
}

/// Baseline: every client trains the full model.
pub struct NoDropout {
    spec: VariantSpec,
}

impl NoDropout {
    pub fn new(spec: &VariantSpec) -> Self {
        NoDropout { spec: spec.clone() }
    }
}

impl SubmodelStrategy for NoDropout {
    fn select(&mut self, _round: usize, _client: usize, _rng: &mut Pcg64) -> SubModel {
        SubModel::full(&self.spec)
    }

    fn report_loss(&mut self, _round: usize, _client: usize, _loss: f64) {}

    fn end_round(&mut self, _round: usize) {}

    fn name(&self) -> &'static str {
        "none"
    }

    fn fdr(&self) -> f64 {
        0.0
    }
}

/// Construct a strategy by name (CLI / config layer).
pub fn make_strategy(
    kind: &str,
    spec: &VariantSpec,
    num_clients: usize,
    fdr: f64,
) -> anyhow::Result<Box<dyn SubmodelStrategy>> {
    Ok(match kind {
        "none" => Box::new(NoDropout::new(spec)),
        "fd" => Box::new(RandomFd::new(spec, fdr)),
        "afd_multi" => Box::new(MultiModelAfd::new(spec, num_clients, fdr)),
        "afd_single" => Box::new(SingleModelAfd::new(spec, fdr)),
        other => anyhow::bail!(
            "unknown dropout strategy {other:?} (expected none|fd|afd_multi|afd_single)"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::tiny_spec;

    #[test]
    fn no_dropout_always_full() {
        let spec = tiny_spec();
        let mut s = NoDropout::new(&spec);
        let mut rng = Pcg64::new(0);
        for round in 1..5 {
            assert!(s.select(round, 0, &mut rng).is_full());
        }
        assert_eq!(s.fdr(), 0.0);
    }

    #[test]
    fn factory_builds_all_kinds() {
        let spec = tiny_spec();
        for kind in ["none", "fd", "afd_multi", "afd_single"] {
            let s = make_strategy(kind, &spec, 10, 0.25).unwrap();
            assert!(!s.name().is_empty());
        }
        assert!(make_strategy("bogus", &spec, 10, 0.25).is_err());
    }
}
