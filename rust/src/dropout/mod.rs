//! Sub-model selection strategies — the paper's contribution.
//!
//! * [`afd_multi::MultiModelAfd`] — Algorithm 1: one activation score map
//!   **per client**, driven by per-client local losses.
//! * [`afd_single::SingleModelAfd`] — Algorithm 2: one **global** score
//!   map, one shared sub-model per round, driven by the round-average
//!   loss.
//! * [`random_fd::RandomFd`] — the Federated Dropout baseline (Caldas et
//!   al. '18): uniform random sub-models each round.
//! * [`NoDropout`] — full model every round (the No-Compression and
//!   DGC-only baselines).
//!
//! The coordinator drives every strategy through [`SubmodelStrategy`]:
//! `select` before the round's local training, `report_loss` after each
//! client trains, `end_round` once the cohort finishes.

pub mod afd_multi;
pub mod afd_single;
pub mod random_fd;
pub mod score_map;

use crate::model::manifest::VariantSpec;
use crate::model::submodel::SubModel;
use crate::util::rng::Pcg64;

pub use afd_multi::MultiModelAfd;
pub use afd_single::SingleModelAfd;
pub use random_fd::RandomFd;
pub use score_map::{kept_count, ScoreMap};

/// Strategy interface the coordinator drives each round.
pub trait SubmodelStrategy: Send {
    /// Sub-model for `client` in `round` (1-based, as in the paper).
    fn select(&mut self, round: usize, client: usize, rng: &mut Pcg64) -> SubModel;

    /// Client `client`'s local training loss for this round.
    fn report_loss(&mut self, round: usize, client: usize, loss: f64);

    /// All of the round's cohort finished; update round-level state.
    fn end_round(&mut self, round: usize);

    fn name(&self) -> &'static str;

    /// Fraction of activations dropped (0 for NoDropout).
    fn fdr(&self) -> f64;
}

/// Baseline: every client trains the full model.
pub struct NoDropout {
    spec: VariantSpec,
}

impl NoDropout {
    pub fn new(spec: &VariantSpec) -> Self {
        NoDropout { spec: spec.clone() }
    }
}

impl SubmodelStrategy for NoDropout {
    fn select(&mut self, _round: usize, _client: usize, _rng: &mut Pcg64) -> SubModel {
        SubModel::full(&self.spec)
    }

    fn report_loss(&mut self, _round: usize, _client: usize, _loss: f64) {}

    fn end_round(&mut self, _round: usize) {}

    fn name(&self) -> &'static str {
        "none"
    }

    fn fdr(&self) -> f64 {
        0.0
    }
}

/// Construct a strategy by name (CLI / config layer).
pub fn make_strategy(
    kind: &str,
    spec: &VariantSpec,
    num_clients: usize,
    fdr: f64,
) -> anyhow::Result<Box<dyn SubmodelStrategy>> {
    Ok(match kind {
        "none" => Box::new(NoDropout::new(spec)),
        "fd" => Box::new(RandomFd::new(spec, fdr)),
        "afd_multi" => Box::new(MultiModelAfd::new(spec, num_clients, fdr)),
        "afd_single" => Box::new(SingleModelAfd::new(spec, fdr)),
        other => anyhow::bail!(
            "unknown dropout strategy {other:?} (expected none|fd|afd_multi|afd_single)"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::tiny_spec;

    #[test]
    fn no_dropout_always_full() {
        let spec = tiny_spec();
        let mut s = NoDropout::new(&spec);
        let mut rng = Pcg64::new(0);
        for round in 1..5 {
            assert!(s.select(round, 0, &mut rng).is_full());
        }
        assert_eq!(s.fdr(), 0.0);
    }

    #[test]
    fn factory_builds_all_kinds() {
        let spec = tiny_spec();
        for kind in ["none", "fd", "afd_multi", "afd_single"] {
            let s = make_strategy(kind, &spec, 10, 0.25).unwrap();
            assert!(!s.name().is_empty());
        }
        assert!(make_strategy("bogus", &spec, 10, 0.25).is_err());
    }
}
