//! Literal conversion helpers: flat Rust buffers ⇄ `xla::Literal`.
//!
//! This is the PJRT boundary of the hot path — building input literals
//! and reading back outputs for every client-round. Kept separate so the
//! §Perf pass can measure and optimize it in isolation.

use anyhow::{anyhow, Result};
use xla::{ElementType, Literal};

/// f32 tensor literal with the given dims.
pub fn f32_literal(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let numel: usize = dims.iter().product();
    anyhow::ensure!(
        numel == data.len(),
        "f32_literal: dims {:?} need {} values, got {}",
        dims,
        numel,
        data.len()
    );
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Literal::create_from_shape_and_untyped_data(ElementType::F32, dims, bytes)
        .map_err(|e| anyhow!("creating f32 literal: {e:?}"))
}

/// i32 tensor literal with the given dims.
pub fn i32_literal(data: &[i32], dims: &[usize]) -> Result<Literal> {
    let numel: usize = dims.iter().product();
    anyhow::ensure!(
        numel == data.len(),
        "i32_literal: dims {:?} need {} values, got {}",
        dims,
        numel,
        data.len()
    );
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Literal::create_from_shape_and_untyped_data(ElementType::S32, dims, bytes)
        .map_err(|e| anyhow!("creating i32 literal: {e:?}"))
}

pub fn f32_scalar(v: f32) -> Result<Literal> {
    f32_literal(&[v], &[])
}

/// Read a literal back as Vec<f32>.
pub fn to_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow!("literal to_vec<f32>: {e:?}"))
}

pub fn to_f32_scalar(lit: &Literal) -> Result<f32> {
    let v = to_f32_vec(lit)?;
    anyhow::ensure!(v.len() == 1, "expected scalar, got {} values", v.len());
    Ok(v[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let data = vec![1.0f32, -2.5, 3.25, 0.0, 7.0, 8.0];
        let lit = f32_literal(&data, &[2, 3]).unwrap();
        assert_eq!(to_f32_vec(&lit).unwrap(), data);
    }

    #[test]
    fn i32_roundtrip() {
        let data = vec![1i32, -2, 3];
        let lit = i32_literal(&data, &[3]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data);
    }

    #[test]
    fn scalar_roundtrip() {
        let lit = f32_scalar(4.5).unwrap();
        assert_eq!(to_f32_scalar(&lit).unwrap(), 4.5);
    }

    #[test]
    fn dim_mismatch_is_error() {
        assert!(f32_literal(&[1.0, 2.0], &[3]).is_err());
        assert!(i32_literal(&[1], &[2, 2]).is_err());
    }
}
