//! PJRT-backed model runtime: load HLO text, compile once, execute many.
//!
//! One `PjrtRuntime` per model variant holds the compiled train and eval
//! executables. The train artifact runs a full local epoch per call
//! (`lax.scan` over the round's batches happens *inside* XLA), so the
//! per-client PJRT boundary cost is one literal build + one execute.

use std::path::Path;

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::model::manifest::{DType, Manifest, VariantSpec};
use crate::runtime::literal::{f32_literal, f32_scalar, i32_literal, to_f32_vec};
use crate::runtime::{
    check_epoch_data, check_eval_batch, BatchInput, EpochData, EvalBatch, EvalOutput,
    ModelRuntime, TrainOutput,
};

pub struct PjrtRuntime {
    spec: VariantSpec,
    train_exe: PjRtLoadedExecutable,
    eval_exe: PjRtLoadedExecutable,
}

fn compile_hlo(client: &PjRtClient, path: &Path) -> Result<PjRtLoadedExecutable> {
    let proto = HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
    let comp = XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))
}

impl PjrtRuntime {
    /// Load + compile a variant's artifacts on the given client.
    pub fn load(client: &PjRtClient, manifest: &Manifest, variant: &str) -> Result<PjrtRuntime> {
        let spec = manifest.variant(variant)?.clone();
        let train_exe = compile_hlo(client, &manifest.dir.join(&spec.train_hlo))
            .with_context(|| format!("train artifact for {variant}"))?;
        let eval_exe = compile_hlo(client, &manifest.dir.join(&spec.eval_hlo))
            .with_context(|| format!("eval artifact for {variant}"))?;
        Ok(PjrtRuntime {
            spec,
            train_exe,
            eval_exe,
        })
    }

    fn param_literals(&self, params: &[f32]) -> Result<Vec<Literal>> {
        anyhow::ensure!(
            params.len() == self.spec.num_params,
            "params: expected {}, got {}",
            self.spec.num_params,
            params.len()
        );
        self.spec
            .params
            .iter()
            .map(|seg| f32_literal(&params[seg.range()], &seg.shape))
            .collect()
    }

    fn input_literal(&self, xs: &BatchInput, lead: &[usize]) -> Result<Literal> {
        let mut dims = lead.to_vec();
        dims.extend_from_slice(&self.spec.input_shape);
        match (xs, self.spec.input_dtype) {
            (BatchInput::F32(v), DType::F32) => f32_literal(v, &dims),
            (BatchInput::I32(v), DType::I32) => i32_literal(v, &dims),
            _ => anyhow::bail!("input dtype mismatch for {}", self.spec.name),
        }
    }
}

impl ModelRuntime for PjrtRuntime {
    fn spec(&self) -> &VariantSpec {
        &self.spec
    }

    fn train_epoch(
        &self,
        params: &[f32],
        masks: &[Vec<f32>],
        data: &EpochData,
        lr: f32,
    ) -> Result<TrainOutput> {
        check_epoch_data(&self.spec, data)?;
        anyhow::ensure!(
            masks.len() == self.spec.mask_groups.len(),
            "expected {} masks, got {}",
            self.spec.mask_groups.len(),
            masks.len()
        );
        let mut inputs = self.param_literals(params)?;
        for (g, m) in self.spec.mask_groups.iter().zip(masks) {
            anyhow::ensure!(
                m.len() == g.size,
                "mask {} expected {} units, got {}",
                g.name,
                g.size,
                m.len()
            );
            inputs.push(f32_literal(m, &[g.size])?);
        }
        inputs.push(self.input_literal(
            &data.xs,
            &[self.spec.num_batches, self.spec.batch_size],
        )?);
        inputs.push(i32_literal(
            &data.ys,
            &[self.spec.num_batches, self.spec.batch_size],
        )?);
        inputs.push(f32_scalar(lr)?);

        let result = self
            .train_exe
            .execute::<Literal>(&inputs)
            .map_err(|e| anyhow!("train execute: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("train to_literal: {e:?}"))?
            .to_tuple()
            .map_err(|e| anyhow!("train to_tuple: {e:?}"))?;
        anyhow::ensure!(
            tuple.len() == self.spec.params.len() + 1,
            "train artifact returned {} outputs, expected {}",
            tuple.len(),
            self.spec.params.len() + 1
        );
        let mut out = vec![0.0f32; self.spec.num_params];
        for (seg, lit) in self.spec.params.iter().zip(&tuple) {
            let vals = to_f32_vec(lit)?;
            anyhow::ensure!(vals.len() == seg.size, "output {} size mismatch", seg.name);
            out[seg.range()].copy_from_slice(&vals);
        }
        let mean_loss = crate::runtime::literal::to_f32_scalar(&tuple[tuple.len() - 1])?;
        Ok(TrainOutput {
            params: out,
            mean_loss,
        })
    }

    fn evaluate(&self, params: &[f32], batch: &EvalBatch) -> Result<EvalOutput> {
        check_eval_batch(&self.spec, batch)?;
        let mut inputs = self.param_literals(params)?;
        inputs.push(self.input_literal(&batch.xs, &[self.spec.batch_size])?);
        inputs.push(i32_literal(&batch.ys, &[self.spec.batch_size])?);
        let result = self
            .eval_exe
            .execute::<Literal>(&inputs)
            .map_err(|e| anyhow!("eval execute: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("eval to_literal: {e:?}"))?
            .to_tuple()
            .map_err(|e| anyhow!("eval to_tuple: {e:?}"))?;
        anyhow::ensure!(tuple.len() == 2, "eval artifact must return 2 outputs");
        Ok(EvalOutput {
            loss_sum: crate::runtime::literal::to_f32_scalar(&tuple[0])? as f64,
            correct: crate::runtime::literal::to_f32_scalar(&tuple[1])? as f64,
            count: self.spec.batch_size,
        })
    }
}

/// Load + compile a standalone L1 kernel artifact (tests/benches).
pub fn compile_kernel_artifact(
    client: &PjRtClient,
    manifest: &Manifest,
    hlo_file: &str,
) -> Result<PjRtLoadedExecutable> {
    compile_hlo(client, &manifest.dir.join(hlo_file))
}
