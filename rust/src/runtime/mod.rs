//! Execution layer: run AOT-compiled model artifacts from the Rust
//! coordinator (Python is never on this path).
//!
//! * [`ModelRuntime`] — the interface the coordinator trains through:
//!   one *local epoch* per call (the artifact scans SGD over the round's
//!   batches) plus one-batch evaluation.
//! * [`pjrt::PjrtRuntime`] — the real backend: `xla` crate / PJRT CPU,
//!   loading `artifacts/*.hlo.txt` (HLO text → compile → execute).
//! * [`native::NativeMlp`] — a pure-Rust reference model (1-hidden-layer
//!   masked MLP with handwritten fwd/bwd). Used by artifact-free tests,
//!   property suites and as a CPU baseline in benches.
//!
//! PJRT wrapper types are not `Send`; executions are issued from the
//! coordinator thread (XLA CPU parallelizes internally), while the
//! `util::pool` workers handle compression/data work.

pub mod literal;
pub mod native;
pub mod pjrt;

use anyhow::Result;

use crate::model::manifest::VariantSpec;

/// Input tensor data for one call (train: all batches; eval: one batch).
#[derive(Clone, Debug)]
pub enum BatchInput {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl BatchInput {
    pub fn len(&self) -> usize {
        match self {
            BatchInput::F32(v) => v.len(),
            BatchInput::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One local-epoch's worth of training data, already batched:
/// `xs` is `[num_batches, batch_size, *input_shape]` flattened,
/// `ys` is `[num_batches * batch_size]`.
#[derive(Clone, Debug)]
pub struct EpochData {
    pub xs: BatchInput,
    pub ys: Vec<i32>,
}

/// One evaluation batch: `xs` is `[batch_size, *input_shape]` flattened.
#[derive(Clone, Debug)]
pub struct EvalBatch {
    pub xs: BatchInput,
    pub ys: Vec<i32>,
}

#[derive(Clone, Debug)]
pub struct TrainOutput {
    pub params: Vec<f32>,
    pub mean_loss: f32,
}

#[derive(Clone, Debug, Default)]
pub struct EvalOutput {
    pub loss_sum: f64,
    pub correct: f64,
    pub count: usize,
}

impl EvalOutput {
    pub fn merge(&mut self, other: &EvalOutput) {
        self.loss_sum += other.loss_sum;
        self.correct += other.correct;
        self.count += other.count;
    }

    pub fn accuracy(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.correct / self.count as f64
        }
    }

    pub fn mean_loss(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.loss_sum / self.count as f64
        }
    }
}

/// The coordinator's view of a compiled model.
pub trait ModelRuntime {
    fn spec(&self) -> &VariantSpec;

    /// Run one local epoch of SGD on `data` starting from `params`
    /// (flat, manifest layout) under the given unit `masks` (one f32
    /// 0/1 vector per mask group). Returns updated params + mean loss.
    fn train_epoch(
        &self,
        params: &[f32],
        masks: &[Vec<f32>],
        data: &EpochData,
        lr: f32,
    ) -> Result<TrainOutput>;

    /// In-place epoch: trains `params` directly, drawing scratch from
    /// `ws` so a warmed workspace makes the epoch allocation-free.
    /// Returns the mean loss. The default forwards to [`train_epoch`]
    /// (backends without a workspace path, e.g. PJRT, stay correct);
    /// the native backend overrides it with the kernel implementation.
    fn train_epoch_in(
        &self,
        ws: &mut crate::tensor::kernels::Workspace,
        params: &mut [f32],
        masks: &[Vec<f32>],
        data: &EpochData,
        lr: f32,
    ) -> Result<f32> {
        let _ = ws;
        let out = self.train_epoch(params, masks, data, lr)?;
        params.copy_from_slice(&out.params);
        Ok(out.mean_loss)
    }

    /// Evaluate the *full* model on one batch.
    fn evaluate(&self, params: &[f32], batch: &EvalBatch) -> Result<EvalOutput>;
}

/// How the coordinator holds a runtime, which decides whether the
/// scheduler may fan client rounds out across `util::pool` workers.
pub enum RuntimeHost {
    /// Thread-safe runtime (native backend): in-flight clients train in
    /// parallel, sharing the runtime behind an `Arc`.
    Parallel(std::sync::Arc<dyn ModelRuntime + Send + Sync>),
    /// Not thread-safe (PJRT wrapper types are not `Send`): clients
    /// execute serially on the coordinator thread; XLA parallelizes
    /// internally.
    Serial(Box<dyn ModelRuntime>),
}

impl RuntimeHost {
    pub fn get(&self) -> &dyn ModelRuntime {
        match self {
            RuntimeHost::Parallel(rt) => rt.as_ref(),
            RuntimeHost::Serial(rt) => rt.as_ref(),
        }
    }
}

/// Validate data sizes against the spec (shared by both backends).
pub fn check_epoch_data(spec: &VariantSpec, data: &EpochData) -> Result<()> {
    let per_sample: usize = spec.input_shape.iter().product();
    let want_x = spec.num_batches * spec.batch_size * per_sample;
    let want_y = spec.num_batches * spec.batch_size;
    anyhow::ensure!(
        data.xs.len() == want_x,
        "xs: expected {want_x} elements, got {}",
        data.xs.len()
    );
    anyhow::ensure!(
        data.ys.len() == want_y,
        "ys: expected {want_y} labels, got {}",
        data.ys.len()
    );
    Ok(())
}

pub fn check_eval_batch(spec: &VariantSpec, batch: &EvalBatch) -> Result<()> {
    let per_sample: usize = spec.input_shape.iter().product();
    anyhow::ensure!(
        batch.xs.len() == spec.batch_size * per_sample,
        "eval xs: expected {}, got {}",
        spec.batch_size * per_sample,
        batch.xs.len()
    );
    anyhow::ensure!(
        batch.ys.len() == spec.batch_size,
        "eval ys: expected {}, got {}",
        spec.batch_size,
        batch.ys.len()
    );
    Ok(())
}
