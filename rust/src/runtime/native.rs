//! Pure-Rust reference model: a 1-hidden-layer masked MLP with
//! handwritten forward/backward.
//!
//! Purpose:
//! 1. Artifact-free end-to-end tests of the whole coordinator stack
//!    (round loop, AFD, compression, aggregation) with *real* learning —
//!    no PJRT, no Python.
//! 2. A native baseline the benches can race against the XLA path.
//!
//! The MLP honours exactly the same masking semantics as the L2 models:
//! the hidden mask zeroes activations, so dropped units' weights receive
//! zero gradient and stay bit-identical through SGD.
//!
//! ## Two implementations
//!
//! The hot path runs on the blocked [`crate::tensor::kernels`] layer
//! through [`NativeMlp::train_epoch_in`] /
//! [`NativeMlp::train_epoch_with_block`]: scratch comes from a
//! [`Workspace`], so a warmed epoch performs zero heap allocations
//! (`rust/tests/zero_alloc.rs`), and the SGD update fuses batch rows
//! in blocks of [`kernels::DEFAULT_BATCH_BLOCK`]. The original
//! unblocked scalar implementation is retained verbatim as
//! [`NativeMlp::train_epoch_scalar`] — it is the numerical reference
//! (`rust/tests/kernel_equivalence.rs` proves the kernel path is
//! bit-identical at block size 1 and within 1e-5 blocked) and the
//! "before" side of `bench_micro_hotpath`.

use anyhow::Result;

use crate::model::manifest::{AxisPack, DType, MaskGroup, ParamSeg, VariantSpec};
use crate::runtime::{
    check_epoch_data, check_eval_batch, BatchInput, EpochData, EvalBatch, EvalOutput,
    ModelRuntime, TrainOutput,
};
use crate::tensor::kernels::{self, Workspace};

/// Build a synthetic `VariantSpec` for a d→h(masked)→c MLP so every
/// coordinator component (packing, compression accounting, score maps)
/// works on it unchanged.
pub fn mlp_spec(
    name: &str,
    d: usize,
    h: usize,
    c: usize,
    batch_size: usize,
    num_batches: usize,
    lr: f32,
) -> VariantSpec {
    let pack_h = AxisPack {
        group: "hidden".to_string(),
        count: h,
        repeat: 1,
        fixed: 0,
    };
    let params = vec![
        ParamSeg {
            name: "w1".into(),
            shape: vec![d, h],
            size: d * h,
            offset: 0,
            trainable: true,
            transmit: true,
            rows: None,
            cols: Some(pack_h.clone()),
            flops_per_sample: 2.0 * d as f64 * h as f64,
        },
        ParamSeg {
            name: "b1".into(),
            shape: vec![h],
            size: h,
            offset: d * h,
            trainable: true,
            transmit: true,
            rows: None,
            cols: Some(pack_h.clone()),
            flops_per_sample: 0.0,
        },
        ParamSeg {
            name: "w2".into(),
            shape: vec![h, c],
            size: h * c,
            offset: d * h + h,
            trainable: true,
            transmit: true,
            rows: Some(pack_h),
            cols: None,
            flops_per_sample: 2.0 * h as f64 * c as f64,
        },
        ParamSeg {
            name: "b2".into(),
            shape: vec![c],
            size: c,
            offset: d * h + h + h * c,
            trainable: true,
            transmit: true,
            rows: None,
            cols: None,
            flops_per_sample: 0.0,
        },
    ];
    let num_params = d * h + h + h * c + c;
    VariantSpec {
        name: name.to_string(),
        kind: "mlp".to_string(),
        dataset: "synthetic".to_string(),
        lr,
        batch_size,
        num_batches,
        classes: c,
        vocab: 0,
        input_shape: vec![d],
        input_dtype: DType::F32,
        num_params,
        params,
        mask_groups: vec![MaskGroup {
            name: "hidden".to_string(),
            size: h,
            kind: "dense_units".to_string(),
        }],
        train_hlo: String::new(),
        eval_hlo: String::new(),
        init_params: String::new(),
        train_args: vec![],
        train_outputs: vec![],
        eval_args: vec![],
        eval_outputs: vec![],
    }
}

/// Build the native runtime + spec exactly as `Experiment::build`
/// does. Single construction point shared with the remote transport
/// client (`afd client` rebuilds its environment from the shipped
/// config), so the coordinator and a remote process can never drift on
/// model geometry.
pub fn mlp_from_config(cfg: &crate::config::ExperimentConfig) -> (NativeMlp, VariantSpec) {
    let (d, h, c) = cfg.native_dims;
    let spec = mlp_spec(&cfg.variant, d, h, c, 10, 5, 0.1);
    (NativeMlp::new(spec.clone()), spec)
}

pub struct NativeMlp {
    spec: VariantSpec,
    d: usize,
    h: usize,
    c: usize,
}

impl NativeMlp {
    pub fn new(spec: VariantSpec) -> NativeMlp {
        let d = spec.input_shape[0];
        let h = spec.mask_groups[0].size;
        let c = spec.classes;
        NativeMlp { spec, d, h, c }
    }

    /// Glorot-uniform initial parameters (deterministic per seed).
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Pcg64::new(seed);
        let mut out = vec![0.0f32; self.spec.num_params];
        let (d, h, c) = (self.d, self.h, self.c);
        let lim1 = (6.0 / (d + h) as f64).sqrt();
        for v in &mut out[..d * h] {
            *v = rng.uniform(-lim1, lim1) as f32;
        }
        let w2_off = d * h + h;
        let lim2 = (6.0 / (h + c) as f64).sqrt();
        for v in &mut out[w2_off..w2_off + h * c] {
            *v = rng.uniform(-lim2, lim2) as f32;
        }
        out
    }

    // ---- kernel path (the hot path) ---------------------------------

    /// One SGD step on one batch through the kernel layer; scratch
    /// slices are caller-provided (sized `bsz*h`, `bsz*h`, `bsz*c`,
    /// `bsz*h`). Returns the batch's mean loss.
    #[allow(clippy::too_many_arguments)]
    fn sgd_step_kernels(
        &self,
        params: &mut [f32],
        mask: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
        pre: &mut [f32],
        hid: &mut [f32],
        dlog: &mut [f32],
        dh: &mut [f32],
        bb: usize,
    ) -> f32 {
        let (d, h, c) = (self.d, self.h, self.c);
        let bsz = y.len();
        let w2_off = d * h + h;
        let b2_off = w2_off + h * c;

        // Forward: pre = b1 + x·W1 ; hid = mask ⊙ relu(pre) ;
        // dlog (as logits) = b2 + hid·W2.
        kernels::gemm_bias(x, &params[..d * h], &params[d * h..w2_off], pre, bsz, d, h, bb);
        kernels::relu_mask(pre, mask, hid, bsz, h);
        kernels::gemm_bias(hid, &params[w2_off..b2_off], &params[b2_off..], dlog, bsz, h, c, bb);

        // Loss + gradient, fused in place on the logits buffer.
        let loss = kernels::softmax_xent_grad(dlog, y, bsz, c);

        // dh from the *pre-update* W2 (the reference computes dh first).
        kernels::backprop_hidden(dlog, &params[w2_off..b2_off], mask, pre, dh, bsz, h, c);

        // W2/b2 then W1/b1 — the reference's update order.
        {
            let (w2, b2) = params[w2_off..].split_at_mut(h * c);
            kernels::sgd_rank_update(w2, b2, hid, dlog, lr, bsz, h, c, bb);
        }
        {
            let (w1, rest) = params.split_at_mut(d * h);
            kernels::sgd_rank_update(w1, &mut rest[..h], x, dh, lr, bsz, d, h, bb);
        }
        loss
    }

    /// In-place epoch with an explicit batch-row block size (`bb == 1`
    /// reproduces [`NativeMlp::train_epoch_scalar`] bit-for-bit; the
    /// default block is [`kernels::DEFAULT_BATCH_BLOCK`]). Zero heap
    /// allocations once `ws` is warm.
    pub fn train_epoch_with_block(
        &self,
        ws: &mut Workspace,
        params: &mut [f32],
        masks: &[Vec<f32>],
        data: &EpochData,
        lr: f32,
        bb: usize,
    ) -> Result<f32> {
        check_epoch_data(&self.spec, data)?;
        anyhow::ensure!(masks.len() == 1, "NativeMlp expects one mask group");
        anyhow::ensure!(params.len() == self.spec.num_params, "params length mismatch");
        let xs = match &data.xs {
            BatchInput::F32(v) => v,
            _ => anyhow::bail!("NativeMlp expects f32 inputs"),
        };
        let (bs, d, h, c) = (self.spec.batch_size, self.d, self.h, self.c);
        // Every kernel writes every element of its output buffer, so
        // stale scratch is fine (no per-epoch memset).
        let mut pre = ws.take_uncleared(bs * h);
        let mut hid = ws.take_uncleared(bs * h);
        let mut dlog = ws.take_uncleared(bs * c);
        let mut dh = ws.take_uncleared(bs * h);
        let mask = &masks[0];
        let mut loss_sum = 0.0f32;
        for nb in 0..self.spec.num_batches {
            let x = &xs[nb * bs * d..(nb + 1) * bs * d];
            let y = &data.ys[nb * bs..(nb + 1) * bs];
            loss_sum += self.sgd_step_kernels(
                params, mask, x, y, lr, &mut pre, &mut hid, &mut dlog, &mut dh, bb,
            );
        }
        ws.give(pre);
        ws.give(hid);
        ws.give(dlog);
        ws.give(dh);
        Ok(loss_sum / self.spec.num_batches as f32)
    }

    // ---- scalar reference (retained verbatim) -----------------------

    /// Forward pass for one batch; returns (probs [B,c], hidden [B,h],
    /// pre-activations [B,h]). The original unblocked implementation,
    /// kept as the numerical reference for the kernel path.
    fn forward_scalar(
        &self,
        params: &[f32],
        mask: &[f32],
        x: &[f32],
        bsz: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let (d, h, c) = (self.d, self.h, self.c);
        let w1 = &params[..d * h];
        let b1 = &params[d * h..d * h + h];
        let w2 = &params[d * h + h..d * h + h + h * c];
        let b2 = &params[d * h + h + h * c..];

        let mut pre = vec![0.0f32; bsz * h];
        for b in 0..bsz {
            let xr = &x[b * d..(b + 1) * d];
            let row = &mut pre[b * h..(b + 1) * h];
            row.copy_from_slice(b1);
            for (i, &xi) in xr.iter().enumerate() {
                if xi != 0.0 {
                    let wrow = &w1[i * h..(i + 1) * h];
                    for j in 0..h {
                        row[j] += xi * wrow[j];
                    }
                }
            }
        }
        let mut hid = vec![0.0f32; bsz * h];
        for b in 0..bsz {
            for j in 0..h {
                let v = pre[b * h + j];
                hid[b * h + j] = if v > 0.0 { v * mask[j] } else { 0.0 };
            }
        }
        let mut logits = vec![0.0f32; bsz * c];
        for b in 0..bsz {
            let row = &mut logits[b * c..(b + 1) * c];
            row.copy_from_slice(b2);
            for j in 0..h {
                let hv = hid[b * h + j];
                if hv != 0.0 {
                    let wrow = &w2[j * c..(j + 1) * c];
                    for k in 0..c {
                        row[k] += hv * wrow[k];
                    }
                }
            }
        }
        // softmax in place
        for b in 0..bsz {
            let row = &mut logits[b * c..(b + 1) * c];
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
            let mut z = 0.0f32;
            for v in row.iter_mut() {
                *v = (*v - m).exp();
                z += *v;
            }
            for v in row.iter_mut() {
                *v /= z;
            }
        }
        (logits, hid, pre)
    }

    /// One SGD step on one batch (scalar reference); returns the
    /// batch's mean loss.
    fn sgd_step_scalar(
        &self,
        params: &mut [f32],
        mask: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> f32 {
        let (d, h, c) = (self.d, self.h, self.c);
        let bsz = y.len();
        let (probs, hid, pre) = self.forward_scalar(params, mask, x, bsz);

        let mut loss = 0.0f32;
        // dlogits = (probs - onehot) / B
        let mut dlog = probs;
        for b in 0..bsz {
            let yi = y[b] as usize;
            loss += -dlog[b * c + yi].max(1e-12).ln();
            dlog[b * c + yi] -= 1.0;
        }
        let inv_b = 1.0 / bsz as f32;
        for v in dlog.iter_mut() {
            *v *= inv_b;
        }
        loss *= inv_b;

        let w2_off = d * h + h;
        let b2_off = w2_off + h * c;
        // dh = dlog @ w2^T, masked + relu'
        let mut dh = vec![0.0f32; bsz * h];
        {
            let w2 = &params[w2_off..b2_off];
            for b in 0..bsz {
                let dl = &dlog[b * c..(b + 1) * c];
                let dhrow = &mut dh[b * h..(b + 1) * h];
                for j in 0..h {
                    if mask[j] == 0.0 || pre[b * h + j] <= 0.0 {
                        continue;
                    }
                    let wrow = &w2[j * c..(j + 1) * c];
                    let mut acc = 0.0f32;
                    for k in 0..c {
                        acc += dl[k] * wrow[k];
                    }
                    dhrow[j] = acc * mask[j];
                }
            }
        }
        // w2 -= lr * hid^T dlog ; b2 -= lr * sum dlog
        for b in 0..bsz {
            let dl = &dlog[b * c..(b + 1) * c];
            for j in 0..h {
                let hv = hid[b * h + j];
                if hv != 0.0 {
                    let wrow = &mut params[w2_off + j * c..w2_off + (j + 1) * c];
                    for k in 0..c {
                        wrow[k] -= lr * hv * dl[k];
                    }
                }
            }
            for k in 0..c {
                params[b2_off + k] -= lr * dl[k];
            }
        }
        // w1 -= lr * x^T dh ; b1 -= lr * sum dh
        let b1_off = d * h;
        for b in 0..bsz {
            let xr = &x[b * d..(b + 1) * d];
            let dhrow = &dh[b * h..(b + 1) * h];
            for i in 0..d {
                let xi = xr[i];
                if xi != 0.0 {
                    let wrow = &mut params[i * h..(i + 1) * h];
                    for j in 0..h {
                        wrow[j] -= lr * xi * dhrow[j];
                    }
                }
            }
            for j in 0..h {
                params[b1_off + j] -= lr * dhrow[j];
            }
        }
        loss
    }

    /// The original allocating scalar epoch, retained as the "before"
    /// baseline of `bench_micro_hotpath` and the bit-exactness
    /// reference of `rust/tests/kernel_equivalence.rs`.
    pub fn train_epoch_scalar(
        &self,
        params: &[f32],
        masks: &[Vec<f32>],
        data: &EpochData,
        lr: f32,
    ) -> Result<TrainOutput> {
        check_epoch_data(&self.spec, data)?;
        anyhow::ensure!(masks.len() == 1, "NativeMlp expects one mask group");
        let xs = match &data.xs {
            BatchInput::F32(v) => v,
            _ => anyhow::bail!("NativeMlp expects f32 inputs"),
        };
        let mut p = params.to_vec();
        let (bs, d) = (self.spec.batch_size, self.d);
        let mut loss_sum = 0.0f32;
        for nb in 0..self.spec.num_batches {
            let x = &xs[nb * bs * d..(nb + 1) * bs * d];
            let y = &data.ys[nb * bs..(nb + 1) * bs];
            loss_sum += self.sgd_step_scalar(&mut p, &masks[0], x, y, lr);
        }
        Ok(TrainOutput {
            params: p,
            mean_loss: loss_sum / self.spec.num_batches as f32,
        })
    }
}

impl ModelRuntime for NativeMlp {
    fn spec(&self) -> &VariantSpec {
        &self.spec
    }

    fn train_epoch(
        &self,
        params: &[f32],
        masks: &[Vec<f32>],
        data: &EpochData,
        lr: f32,
    ) -> Result<TrainOutput> {
        let mut p = params.to_vec();
        let mut ws = Workspace::new();
        let mean_loss = self.train_epoch_with_block(
            &mut ws,
            &mut p,
            masks,
            data,
            lr,
            kernels::DEFAULT_BATCH_BLOCK,
        )?;
        Ok(TrainOutput {
            params: p,
            mean_loss,
        })
    }

    fn train_epoch_in(
        &self,
        ws: &mut Workspace,
        params: &mut [f32],
        masks: &[Vec<f32>],
        data: &EpochData,
        lr: f32,
    ) -> Result<f32> {
        let _sp = crate::obs::span_ab(
            crate::obs::Stage::Train,
            params.len() as u64,
            self.spec.num_batches as u64,
        );
        self.train_epoch_with_block(ws, params, masks, data, lr, kernels::DEFAULT_BATCH_BLOCK)
    }

    fn evaluate(&self, params: &[f32], batch: &EvalBatch) -> Result<EvalOutput> {
        check_eval_batch(&self.spec, batch)?;
        let xs = match &batch.xs {
            BatchInput::F32(v) => v,
            _ => anyhow::bail!("NativeMlp expects f32 inputs"),
        };
        let (bsz, d, h, c) = (self.spec.batch_size, self.d, self.h, self.c);
        let w2_off = d * h + h;
        let b2_off = w2_off + h * c;
        let ones = vec![1.0f32; h];
        let mut pre = vec![0.0f32; bsz * h];
        let mut hid = vec![0.0f32; bsz * h];
        let mut probs = vec![0.0f32; bsz * c];
        let bb = kernels::DEFAULT_BATCH_BLOCK;
        kernels::gemm_bias(xs, &params[..d * h], &params[d * h..w2_off], &mut pre, bsz, d, h, bb);
        kernels::relu_mask(&pre, &ones, &mut hid, bsz, h);
        kernels::gemm_bias(
            &hid,
            &params[w2_off..b2_off],
            &params[b2_off..],
            &mut probs,
            bsz,
            h,
            c,
            bb,
        );
        kernels::softmax_rows(&mut probs, bsz, c);
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        for b in 0..bsz {
            let row = &probs[b * c..(b + 1) * c];
            let yi = batch.ys[b] as usize;
            loss_sum += -(row[yi].max(1e-12) as f64).ln();
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0;
            if pred == yi {
                correct += 1.0;
            }
        }
        Ok(EvalOutput {
            loss_sum,
            correct,
            count: bsz,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn toy_data(
        spec: &VariantSpec,
        seed: u64,
        n_batches: usize,
    ) -> (Vec<f32>, Vec<i32>) {
        // Linearly-separable-ish blobs: class k centred at unit vector e_k.
        let mut rng = Pcg64::new(seed);
        let d = spec.input_shape[0];
        let n = n_batches * spec.batch_size;
        let mut xs = vec![0.0f32; n * d];
        let mut ys = vec![0i32; n];
        for i in 0..n {
            let k = (rng.below(spec.classes as u64)) as usize;
            ys[i] = k as i32;
            for j in 0..d {
                let centre = if j % spec.classes == k { 2.0 } else { 0.0 };
                xs[i * d + j] = centre + rng.normal_f32(0.0, 0.5);
            }
        }
        (xs, ys)
    }

    #[test]
    fn learns_separable_blobs() {
        let spec = mlp_spec("t", 12, 16, 3, 10, 4, 0.2);
        let mlp = NativeMlp::new(spec);
        let mut params = mlp.init_params(0);
        let (xs, ys) = toy_data(mlp.spec(), 1, 4);
        let data = EpochData {
            xs: BatchInput::F32(xs.clone()),
            ys: ys.clone(),
        };
        let masks = vec![vec![1.0f32; 16]];
        let mut losses = vec![];
        for _ in 0..15 {
            let out = mlp.train_epoch(&params, &masks, &data, 0.2).unwrap();
            losses.push(out.mean_loss);
            params = out.params;
        }
        assert!(
            losses[14] < 0.5 * losses[0],
            "losses: {:?}",
            &losses
        );
        // Eval accuracy on the training batch should be high now.
        let batch = EvalBatch {
            xs: BatchInput::F32(xs[..10 * 12].to_vec()),
            ys: ys[..10].to_vec(),
        };
        let ev = mlp.evaluate(&params, &batch).unwrap();
        assert!(ev.accuracy() >= 0.8, "acc={}", ev.accuracy());
    }

    #[test]
    fn dropped_units_stay_bit_identical() {
        let spec = mlp_spec("t", 8, 10, 3, 5, 2, 0.1);
        let mlp = NativeMlp::new(spec);
        let params = mlp.init_params(3);
        let (xs, ys) = toy_data(mlp.spec(), 2, 2);
        let data = EpochData {
            xs: BatchInput::F32(xs),
            ys,
        };
        let mut mask = vec![1.0f32; 10];
        for j in [1usize, 4, 7] {
            mask[j] = 0.0;
        }
        let out = mlp.train_epoch(&params, &[mask.clone()], &data, 0.1).unwrap();
        let spec = mlp.spec();
        let d = spec.input_shape[0];
        let h = 10;
        let c = spec.classes;
        for j in [1usize, 4, 7] {
            // w1 col j
            for i in 0..d {
                assert_eq!(out.params[i * h + j], params[i * h + j]);
            }
            // b1[j]
            assert_eq!(out.params[d * h + j], params[d * h + j]);
            // w2 row j
            for k in 0..c {
                let off = d * h + h + j * c + k;
                assert_eq!(out.params[off], params[off]);
            }
        }
        // but kept units moved
        assert!(out.params[..d * h]
            .iter()
            .zip(&params[..d * h])
            .any(|(a, b)| a != b));
    }

    #[test]
    fn masked_vs_reduced_equivalence_through_packing() {
        // pack(train(masked)) must equal what an (emulated) reduced model
        // would produce: we verify the packed sub-model round-trips and
        // dropped coordinates are exactly untouched.
        use crate::model::packing;
        use crate::model::submodel::SubModel;
        let spec = mlp_spec("t", 6, 8, 3, 4, 2, 0.1);
        let mlp = NativeMlp::new(spec.clone());
        let params = mlp.init_params(7);
        let sm = SubModel::from_kept_indices(&spec, &[vec![0, 2, 3, 6]]);
        let (xs, ys) = toy_data(&spec, 5, 2);
        let data = EpochData {
            xs: BatchInput::F32(xs),
            ys,
        };
        let out = mlp
            .train_epoch(&params, &sm.masks_f32(), &data, 0.1)
            .unwrap();
        let packed = packing::pack_values(&spec, &out.params, &sm);
        let mut recovered = params.clone();
        packing::unpack_values(&spec, &packed, &sm, &mut recovered);
        // Recovered == trained: sub-model coords updated, rest == params.
        assert_eq!(recovered, out.params);
    }

    #[test]
    fn scalar_reference_still_learns() {
        // The retained reference must stay a working implementation —
        // the equivalence suite and the bench baseline depend on it.
        let spec = mlp_spec("t", 12, 16, 3, 10, 4, 0.2);
        let mlp = NativeMlp::new(spec);
        let mut params = mlp.init_params(0);
        let (xs, ys) = toy_data(mlp.spec(), 1, 4);
        let data = EpochData {
            xs: BatchInput::F32(xs),
            ys,
        };
        let masks = vec![vec![1.0f32; 16]];
        let mut first = 0.0;
        let mut last = 0.0;
        for e in 0..15 {
            let out = mlp.train_epoch_scalar(&params, &masks, &data, 0.2).unwrap();
            if e == 0 {
                first = out.mean_loss;
            }
            last = out.mean_loss;
            params = out.params;
        }
        assert!(last < 0.5 * first, "first {first} last {last}");
    }

    #[test]
    fn spec_is_structurally_valid() {
        let spec = mlp_spec("t", 5, 7, 4, 3, 2, 0.1);
        assert_eq!(
            spec.num_params,
            5 * 7 + 7 + 7 * 4 + 4
        );
        let mut off = 0;
        for p in &spec.params {
            assert_eq!(p.offset, off);
            off += p.size;
        }
        assert_eq!(off, spec.num_params);
    }
}
