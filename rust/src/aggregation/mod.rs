//! FedAvg aggregation with sub-model recovery.
//!
//! Paper Eq. (2): `W_{t+1} = (1/n_t) Σ_c n_c W_t^c`, weighted by each
//! client's sample count. Under AFD, client c only holds (and returns)
//! the coordinates of its sub-model, so the average is **per
//! coordinate** over the clients that hold it (Fig. 1 step 7 "recovered
//! in its original shape ... aggregated"); coordinates no selected
//! client held keep their previous global value.
//!
//! Three aggregators coexist (see `README.md` in this directory):
//!
//! * [`FedAvg`] — the original single-threaded pass over the flat
//!   parameter vector, retained as the bit-exactness **reference** (it
//!   also still serves `Experiment::step_serial_reference`);
//! * [`ShardedFedAvg`] — the flat production path: the vector
//!   partitioned into contiguous shards, adds and finalize fanned out
//!   across the worker pool, output bit-identical to [`FedAvg`] for
//!   every shard count (enforced by `rust/tests/agg_sharding.rs`);
//! * [`HierarchicalFedAvg`] — the tree production path for
//!   population-scale rounds: edge aggregators merging partial
//!   `(accum, weight)` sums upward, bit-identical to both of the above
//!   at every tree shape (enforced by `rust/tests/agg_hierarchy.rs`).
//!
//! The engine holds whichever production path the config selects
//! behind the [`Aggregator`] enum.

pub mod hierarchy;
pub mod sharded;

pub use hierarchy::HierarchicalFedAvg;
pub use sharded::{AddOp, ShardedFedAvg, ShardingConfig};

use std::sync::Arc;

use crate::util::pool::LazyPool;

/// The engine's aggregation path: flat sharded (the default) or a
/// hierarchical tree (`tree_levels ≥ 2` in [`ShardingConfig`]). Both
/// expose the same batched round entry point and are bit-identical to
/// each other and to the [`FedAvg`] reference, so the choice is purely
/// a throughput/topology knob.
pub enum Aggregator {
    Flat(ShardedFedAvg),
    Tree(HierarchicalFedAvg),
}

impl Aggregator {
    /// Build the path [`ShardingConfig`] selects: a flat aggregator
    /// with the resolved shard count, or a tree when `tree_levels ≥ 2`.
    pub fn from_config(
        cfg: &ShardingConfig,
        num_params: usize,
        pool: Arc<LazyPool>,
    ) -> Aggregator {
        if cfg.tree_levels >= 2 {
            Aggregator::Tree(HierarchicalFedAvg::new(
                num_params,
                cfg.tree_levels,
                cfg.tree_fanout,
                pool,
            ))
        } else {
            let shards = cfg.resolve(num_params, pool.size());
            Aggregator::Flat(ShardedFedAvg::new(num_params, shards, pool))
        }
    }

    pub fn num_params(&self) -> usize {
        match self {
            Aggregator::Flat(a) => a.num_params(),
            Aggregator::Tree(a) => a.num_params(),
        }
    }

    /// One round in one dispatch: reset, every add in `ops` order,
    /// finalize into `out`. See the variants' own docs.
    pub fn aggregate_batch(&mut self, ops: &[AddOp], base: &[f32], out: &mut Vec<f32>) {
        match self {
            Aggregator::Flat(a) => a.aggregate_batch(ops, base, out),
            Aggregator::Tree(a) => a.aggregate_batch(ops, base, out),
        }
    }

    /// Fraction of coordinates updated in the last batch.
    pub fn coverage(&self) -> f64 {
        match self {
            Aggregator::Flat(a) => a.coverage(),
            Aggregator::Tree(a) => a.coverage(),
        }
    }
}

/// Accumulates one round of client updates.
pub struct FedAvg {
    accum: Vec<f64>,
    weight: Vec<f64>,
}

impl FedAvg {
    pub fn new(num_params: usize) -> FedAvg {
        FedAvg {
            accum: vec![0.0; num_params],
            weight: vec![0.0; num_params],
        }
    }

    pub fn reset(&mut self) {
        self.accum.fill(0.0);
        self.weight.fill(0.0);
    }

    /// Add a client's model restricted to its sub-model coordinates.
    /// `n_c` is the client's sample count (the FedAvg weight).
    pub fn add_masked(&mut self, values: &[f32], coord_mask: &[bool], n_c: f64) {
        assert_eq!(
            values.len(),
            self.accum.len(),
            "add_masked: values buffer length != accum length"
        );
        assert_eq!(
            coord_mask.len(),
            self.accum.len(),
            "add_masked: coord_mask buffer length != accum length"
        );
        for i in 0..values.len() {
            if coord_mask[i] {
                self.accum[i] += n_c * values[i] as f64;
                self.weight[i] += n_c;
            }
        }
    }

    /// Add a full-model client update (the no-dropout baselines).
    pub fn add_full(&mut self, values: &[f32], n_c: f64) {
        assert_eq!(
            values.len(),
            self.accum.len(),
            "add_full: values buffer length != accum length"
        );
        for i in 0..values.len() {
            self.accum[i] += n_c * values[i] as f64;
            self.weight[i] += n_c;
        }
    }

    /// Finalize: coordinates nobody updated keep `base`'s value.
    pub fn finalize(&self, base: &[f32]) -> Vec<f32> {
        assert_eq!(
            base.len(),
            self.accum.len(),
            "finalize: base buffer length != accum length"
        );
        (0..base.len())
            .map(|i| {
                if self.weight[i] > 0.0 {
                    (self.accum[i] / self.weight[i]) as f32
                } else {
                    base[i]
                }
            })
            .collect()
    }

    /// Fraction of coordinates that received at least one update.
    pub fn coverage(&self) -> f64 {
        let covered = self.weight.iter().filter(|&&w| w > 0.0).count();
        covered as f64 / self.weight.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_average_matches_paper_formula() {
        let mut agg = FedAvg::new(3);
        agg.add_full(&[1.0, 2.0, 3.0], 10.0); // n_c = 10
        agg.add_full(&[3.0, 0.0, 6.0], 30.0); // n_c = 30
        let out = agg.finalize(&[9.0, 9.0, 9.0]);
        // (10*1 + 30*3)/40 = 2.5 ; (10*2)/40 = 0.5 ; (10*3+30*6)/40 = 5.25
        assert_eq!(out, vec![2.5, 0.5, 5.25]);
        assert_eq!(agg.coverage(), 1.0);
    }

    #[test]
    fn uncovered_coordinates_keep_base() {
        let mut agg = FedAvg::new(4);
        agg.add_masked(&[1.0, 2.0, 3.0, 4.0], &[true, false, true, false], 5.0);
        agg.add_masked(&[10.0, 20.0, 30.0, 40.0], &[true, false, false, false], 5.0);
        let out = agg.finalize(&[-1.0, -2.0, -3.0, -4.0]);
        assert_eq!(out, vec![5.5, -2.0, 3.0, -4.0]);
        assert_eq!(agg.coverage(), 0.5);
    }

    #[test]
    fn reset_clears_state() {
        let mut agg = FedAvg::new(2);
        agg.add_full(&[1.0, 1.0], 1.0);
        agg.reset();
        let out = agg.finalize(&[7.0, 8.0]);
        assert_eq!(out, vec![7.0, 8.0]);
        assert_eq!(agg.coverage(), 0.0);
    }

    #[test]
    fn weighting_respects_sample_counts() {
        // A client with 9× the data dominates the average 9:1.
        let mut agg = FedAvg::new(1);
        agg.add_full(&[0.0], 90.0);
        agg.add_full(&[10.0], 10.0);
        assert_eq!(agg.finalize(&[0.0]), vec![1.0]);
    }
}
