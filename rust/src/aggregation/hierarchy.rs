//! Hierarchical (edge-aggregated) FedAvg: a tree of aggregators
//! merging partial `(accum, weight)` sums upward, bit-identical to the
//! flat [`ShardedFedAvg`](super::ShardedFedAvg) at **every** tree
//! shape.
//!
//! ## Why the tree partitions coordinates, not clients
//!
//! The obvious hierarchy — each edge aggregator sums *its* clients,
//! parents add children's partial sums — is **not** bit-identical to
//! flat aggregation: f64 addition is non-associative, so
//! `(a + b) + (c + d)` can differ in the last ulp from
//! `((a + b) + c) + d`, and the result would depend on the tree shape.
//! That would break the repo's load-bearing conformance ladder
//! (serial ≡ Sync ≡ sharded ≡ traced).
//!
//! Instead, every level of this tree partitions the **coordinate
//! space**. Edge aggregators (the leaves) are exactly the flat
//! aggregator's shards: each owns a contiguous coordinate range and
//! replays *all* client ops over it in caller order. An internal node
//! owns the union of its children's (disjoint, adjacent) ranges, so
//! the upward merge is a pure copy of the children's `(accum, weight)`
//! buffers into the parent's — **zero floating-point arithmetic on the
//! way up**. Per coordinate, the op sequence is identical to flat
//! aggregation, hence bit-identical output regardless of depth or
//! fanout.
//!
//! This models the communication pattern of a real edge hierarchy
//! (bounded-degree merges, partial-sum records flowing upward, the
//! root finalizing) while keeping determinism. What it deliberately
//! does *not* model is client-axis partial summation — see the
//! "Hierarchical merge" section of `aggregation/README.md` for the
//! full honesty note.

use std::sync::Arc;

use crate::util::pool::LazyPool;

use super::sharded::{stage_ops, AddOp, OpView, Shard, SliceView, SliceViewMut};

/// Hard cap on edge aggregators: `fanout^(levels-1)` grows fast and
/// leaves below ~16k coordinates are pure overhead (cf.
/// `ShardingConfig::min_shard_params`).
const MAX_LEAVES: usize = 1024;

/// A coordinate-partitioned aggregation tree. `levels ≥ 2`: level 0 is
/// the edge (leaf) level, the last level is the single root. Node `i`
/// at level `l + 1` absorbs children `[i·fanout, (i+1)·fanout)` of
/// level `l`.
pub struct HierarchicalFedAvg {
    num_params: usize,
    fanout: usize,
    /// `tiers[0]` = leaves … `tiers.last()` = `[root]`. Every tier
    /// partitions `[0, num_params)` into contiguous ranges.
    tiers: Vec<Vec<Shard>>,
    op_scratch: Vec<OpView>,
    pool: Arc<LazyPool>,
}

impl HierarchicalFedAvg {
    /// Build a tree of `levels` tiers with the given fanout. The leaf
    /// count is `fanout^(levels-1)`, clamped to `MAX_LEAVES` and to the
    /// parameter count; each upper tier has `ceil(below / fanout)`
    /// nodes, ending in a single root.
    pub fn new(
        num_params: usize,
        levels: usize,
        fanout: usize,
        pool: Arc<LazyPool>,
    ) -> HierarchicalFedAvg {
        let levels = levels.max(2);
        let fanout = fanout.max(2);
        let mut leaves: usize = 1;
        for _ in 0..levels - 1 {
            leaves = leaves.saturating_mul(fanout).min(MAX_LEAVES);
        }
        let leaves = leaves.min(num_params.max(1));
        // Leaf tier: the flat aggregator's balanced contiguous split.
        let mut tiers = vec![(0..leaves)
            .map(|i| {
                let start = i * num_params / leaves;
                let end = (i + 1) * num_params / leaves;
                Shard::new(start, end - start)
            })
            .collect::<Vec<_>>()];
        // Upper tiers: each node spans its children's union. Built
        // until a single root remains (clamping can make the tree
        // shallower than `levels`, never deeper).
        while tiers.last().unwrap().len() > 1 {
            let below = tiers.last().unwrap();
            let tier: Vec<Shard> = below
                .chunks(fanout)
                .map(|kids| {
                    let start = kids[0].start;
                    let len: usize = kids.iter().map(Shard::len).sum();
                    Shard::new(start, len)
                })
                .collect();
            tiers.push(tier);
        }
        HierarchicalFedAvg {
            num_params,
            fanout,
            tiers,
            op_scratch: Vec::new(),
            pool,
        }
    }

    pub fn num_params(&self) -> usize {
        self.num_params
    }

    /// Tiers in the tree (≥ 1; 1 only for degenerate single-leaf
    /// trees, where the leaf is the root).
    pub fn depth(&self) -> usize {
        self.tiers.len()
    }

    pub fn leaf_count(&self) -> usize {
        self.tiers[0].len()
    }

    fn root(&self) -> &Shard {
        &self.tiers.last().unwrap()[0]
    }

    /// One round in a single edge-parallel fan-out plus the upward
    /// merge: leaves reset and replay `ops` in caller order over their
    /// own coordinates; each upper tier copies its children's
    /// `(accum, weight)` partial sums into place; the root finalizes
    /// into `out` (resized to `num_params`; capacity reused).
    /// Bit-identical to the flat path — enforced by
    /// `rust/tests/agg_hierarchy.rs`.
    pub fn aggregate_batch(&mut self, ops: &[AddOp], base: &[f32], out: &mut Vec<f32>) {
        let _sp = crate::obs::span_ab(
            crate::obs::Stage::ShardAggregate,
            ops.len() as u64,
            self.tiers[0].len() as u64,
        );
        assert_eq!(
            base.len(),
            self.num_params,
            "aggregate_batch: base buffer length != aggregator num_params"
        );
        let mut staged = std::mem::take(&mut self.op_scratch);
        stage_ops(ops, self.num_params, &mut staged);
        let ops_v = SliceView::new(&staged);
        // Edge tier: the only tier that sees client updates. Same
        // pinned-worker fan-out as the flat aggregator.
        if self.tiers[0].len() == 1 {
            let leaf = &mut self.tiers[0][0];
            leaf.reset();
            // SAFETY: staged views are dereferenced only inside this
            // call, and `staged` outlives it.
            unsafe { leaf.replay(ops_v.get()) };
        } else {
            let leaves = std::mem::take(&mut self.tiers[0]);
            // SAFETY: `Pool::map` joins every job before returning, so
            // the `staged`/caller borrows behind the views outlive
            // every dereference (the SliceView contract).
            let leaves = self.pool.get().map(leaves, move |mut s: Shard| {
                s.reset();
                unsafe { s.replay(ops_v.get()) };
                s
            });
            self.tiers[0] = leaves;
        }
        // Upward merge: tier l+1 absorbs tier l. Pure copies of
        // disjoint ranges — no FP arithmetic, so tree shape cannot
        // perturb any sum.
        for l in 0..self.tiers.len() - 1 {
            let (below, above) = self.tiers.split_at_mut(l + 1);
            let below = &below[l];
            for (i, node) in above[0].iter_mut().enumerate() {
                for child in below
                    .iter()
                    .skip(i * self.fanout)
                    .take(self.fanout)
                {
                    node.merge_child(child);
                }
            }
        }
        // Root finalize: one pass over the merged accumulators.
        out.clear();
        out.resize(self.num_params, 0.0);
        if self.tiers.len() == 1 {
            // Degenerate single-leaf tree: the leaf is the root.
            self.tiers[0][0].finalize_into(base, out);
        } else if self.tiers[0].len() == 1 {
            self.root().finalize_into(base, out);
        } else {
            // Finalize is per-coordinate too, so it can fan out over
            // the *leaf* partition of the root's buffers without
            // changing any arithmetic.
            let root_v = SliceView::new(std::slice::from_ref(self.root()));
            let base_v = SliceView::new(base);
            let out_v = SliceViewMut::new(out);
            let spans: Vec<(usize, usize)> = self.tiers[0]
                .iter()
                .map(|s| (s.start, s.len()))
                .collect();
            // SAFETY: views dereferenced only inside this fan-out;
            // output/finalize ranges are the leaf partition — pairwise
            // disjoint; the root shard is only read.
            self.pool.get().map(spans, move |(start, len)| {
                let root = unsafe { &root_v.get()[0] };
                let b = unsafe { base_v.get() };
                let o = unsafe { out_v.range_mut(start, len) };
                for (j, oj) in o.iter_mut().enumerate() {
                    let i = start + j; // absolute coordinate
                    oj_write(root, b, i, oj);
                }
            });
        }
        self.op_scratch = staged;
    }

    /// Fraction of coordinates updated in the last batch, computed at
    /// the root (valid after [`HierarchicalFedAvg::aggregate_batch`]).
    /// Same count and division as the flat aggregator's coverage.
    pub fn coverage(&self) -> f64 {
        self.root().covered() as f64 / self.num_params.max(1) as f64
    }
}

/// One coordinate of the root finalize — factored out so the
/// fanned-out finalize is textually the same arithmetic as
/// `Shard::finalize_into` (divide when covered, else keep base).
#[inline]
fn oj_write(root: &Shard, base: &[f32], i: usize, out: &mut f32) {
    let k = i - root.start; // root.start is 0, kept for symmetry
    *out = if root.weight[k] > 0.0 {
        (root.accum[k] / root.weight[k]) as f32
    } else {
        base[i]
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::ShardedFedAvg;

    fn pool() -> Arc<LazyPool> {
        Arc::new(LazyPool::new(3))
    }

    fn ops_for<'a>(
        vals_a: &'a [f32],
        vals_b: &'a [f32],
        mask: &'a [bool],
    ) -> Vec<AddOp<'a>> {
        vec![
            AddOp::Masked {
                values: vals_a,
                coord_mask: mask,
                n_c: 10.0,
            },
            AddOp::Full {
                values: vals_b,
                n_c: 3.0,
            },
            AddOp::Masked {
                values: vals_b,
                coord_mask: mask,
                n_c: 0.5,
            },
        ]
    }

    #[test]
    fn tree_shape_tiles_and_terminates_at_a_root() {
        for (n, levels, fanout) in
            [(1000usize, 2usize, 4usize), (1000, 3, 3), (7, 4, 2), (0, 2, 2), (1, 5, 8)]
        {
            let t = HierarchicalFedAvg::new(n, levels, fanout, pool());
            assert_eq!(t.tiers.last().unwrap().len(), 1, "single root");
            for tier in &t.tiers {
                let mut next = 0usize;
                for s in tier {
                    assert_eq!(s.start, next, "tiers tile contiguously");
                    next += s.len();
                }
                assert_eq!(next, n, "every tier covers the vector");
            }
            assert!(t.leaf_count() <= n.max(1));
        }
    }

    #[test]
    fn every_tree_shape_matches_flat_bitwise() {
        let n = 777;
        let vals_a: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
        let vals_b: Vec<f32> = (0..n).map(|i| (i as f32) * 0.013 - 2.0).collect();
        let mask: Vec<bool> = (0..n).map(|i| i % 3 != 1).collect();
        let base: Vec<f32> = (0..n).map(|i| (i as f32).cos()).collect();
        let ops = ops_for(&vals_a, &vals_b, &mask);

        let mut flat = ShardedFedAvg::new(n, 4, pool());
        let mut want = Vec::new();
        flat.aggregate_batch(&ops, &base, &mut want);

        for (levels, fanout) in [(2usize, 2usize), (2, 8), (3, 2), (3, 4), (4, 3), (6, 2)] {
            let mut tree = HierarchicalFedAvg::new(n, levels, fanout, pool());
            let mut out = Vec::new();
            tree.aggregate_batch(&ops, &base, &mut out);
            assert_eq!(out.len(), want.len());
            for (i, (a, b)) in out.iter().zip(&want).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "levels={levels} fanout={fanout} coord {i}"
                );
            }
            assert_eq!(
                tree.coverage().to_bits(),
                flat.coverage().to_bits(),
                "levels={levels} fanout={fanout}"
            );
            // Replay on the same tree (reused buffers) stays identical.
            tree.aggregate_batch(&ops, &base, &mut out);
            for (a, b) in out.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn empty_batch_returns_base() {
        let base: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let mut tree = HierarchicalFedAvg::new(100, 3, 2, pool());
        let mut out = Vec::new();
        tree.aggregate_batch(&[], &base, &mut out);
        assert_eq!(out, base);
        assert_eq!(tree.coverage(), 0.0);
    }

    #[test]
    fn leaf_count_is_capped() {
        let t = HierarchicalFedAvg::new(2_000_000, 12, 8, pool());
        assert!(t.leaf_count() <= MAX_LEAVES);
        assert_eq!(t.tiers.last().unwrap().len(), 1);
    }
}
