//! Sharded parallel FedAvg: the flat parameter vector partitioned into
//! contiguous shards, each owned by one `(accum, weight)` slice pair and
//! processed on the worker pool.
//!
//! ## Shard layout
//!
//! Shard `i` of `k` owns the contiguous coordinate range
//! `[i·n/k, (i+1)·n/k)` (balanced split: shard lengths differ by at most
//! one; shards beyond `n` are empty). Shards are disjoint and cover the
//! whole vector, so every coordinate has exactly one owner.
//!
//! ## Bit-identity contract
//!
//! [`ShardedFedAvg`] must produce output **bit-identical** to the
//! retained single-threaded [`FedAvg`](crate::aggregation::FedAvg)
//! reference for *every* shard count (including 1 and counts larger
//! than the parameter count). This holds because each coordinate's
//! accumulator is independent: `accum[i]`/`weight[i]` depend only on
//! the sequence of client adds touching coordinate `i`, which every
//! shard replays in the caller's add order. No cross-coordinate
//! arithmetic happens anywhere, so the partition cannot reorder any
//! floating-point sum. The contract is enforced property-style by
//! `rust/tests/agg_sharding.rs` and end-to-end by the Sync-vs-serial
//! bit-identity test in `rust/tests/sched_policies.rs`.
//!
//! ## Disjoint-slice ownership rule
//!
//! During a fan-out, a worker may touch (a) its own shard's `accum` /
//! `weight` slices mutably, (b) the caller's input buffers read-only,
//! and (c) for `finalize`, the output range matching its own shard.
//! Input/output borrows are smuggled into the pool's `'static` jobs
//! through lifetime-erased views ([`SliceView`] / [`SliceViewMut`]);
//! this is sound because [`Pool::map`](crate::util::pool::Pool::map)
//! joins every job before returning (the manual scoped-threads
//! argument — see the SAFETY notes below).

use std::sync::Arc;

use crate::model::packing::PackPlan;
use crate::util::pool::LazyPool;

/// Aggregation-sharding configuration (experiment-config subtree).
#[derive(Clone, Debug)]
pub struct ShardingConfig {
    /// Shard count: `0` = auto — one shard per pool worker, capped so
    /// every shard keeps at least `min_shard_params` coordinates;
    /// `k ≥ 1` = exactly `k` shards (clamped to the parameter count by
    /// [`ShardingConfig::resolve`]).
    pub shard_count: usize,
    /// Auto mode: lower bound on coordinates per shard (below this the
    /// fan-out overhead dominates the per-coordinate work).
    pub min_shard_params: usize,
    /// Aggregation-tree depth: `1` = flat [`ShardedFedAvg`]; `L ≥ 2` =
    /// a hierarchical tree with `L − 1` merge levels above the edge
    /// aggregators (see [`super::hierarchy`]).
    pub tree_levels: usize,
    /// Children per internal tree node (≥ 2; only meaningful when
    /// `tree_levels ≥ 2`).
    pub tree_fanout: usize,
}

impl Default for ShardingConfig {
    fn default() -> Self {
        ShardingConfig {
            shard_count: 0,
            min_shard_params: 16_384,
            tree_levels: 1,
            tree_fanout: 4,
        }
    }
}

impl ShardingConfig {
    /// Resolve the effective shard count for a model of `num_params`
    /// aggregated on a pool of `pool_width` workers. Explicit counts
    /// are clamped to `num_params` (surplus shards would be empty —
    /// semantics-preserving, and it keeps a typo'd `--shards 1e8` from
    /// allocating and dispatching millions of no-op shard jobs).
    pub fn resolve(&self, num_params: usize, pool_width: usize) -> usize {
        if self.shard_count > 0 {
            return self.shard_count.min(num_params.max(1));
        }
        let cap = num_params.div_ceil(self.min_shard_params.max(1)).max(1);
        pool_width.clamp(1, cap)
    }
}

/// Lifetime-erased read-only view of a caller-borrowed slice, used to
/// hand borrowed inputs to the pool's `'static` jobs.
///
/// Soundness contract: a view may only be dereferenced inside the
/// `Pool::map` call it was built for. `Pool::map` returns only after
/// every job has finished (each job reports completion even when it
/// panics), so the borrow the view was created from strictly outlives
/// every dereference — the classic scoped-threads argument, done by
/// hand because the offline `Pool` requires `'static` jobs.
pub(crate) struct SliceView<T> {
    ptr: *const T,
    len: usize,
}

impl<T> Clone for SliceView<T> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<T> Copy for SliceView<T> {}

// SAFETY: the view only permits shared (&[T]) access, and the
// soundness contract above guarantees the underlying borrow is live
// for every dereference.
unsafe impl<T: Sync> Send for SliceView<T> {}
unsafe impl<T: Sync> Sync for SliceView<T> {}

impl<T> SliceView<T> {
    pub(crate) fn new(s: &[T]) -> SliceView<T> {
        SliceView {
            ptr: s.as_ptr(),
            len: s.len(),
        }
    }

    /// SAFETY: callers must uphold the view's soundness contract (only
    /// dereference inside the fan-out the view was built for).
    pub(crate) unsafe fn get<'a>(self) -> &'a [T] {
        std::slice::from_raw_parts(self.ptr, self.len)
    }
}

/// Lifetime-erased mutable view; each shard materializes only its own
/// disjoint sub-range, so no two `&mut` slices ever overlap.
pub(crate) struct SliceViewMut<T> {
    ptr: *mut T,
    len: usize,
}

impl<T> Clone for SliceViewMut<T> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<T> Copy for SliceViewMut<T> {}

// SAFETY: see SliceView; additionally, callers must only materialize
// pairwise-disjoint sub-ranges (the shard partition guarantees this).
unsafe impl<T: Send> Send for SliceViewMut<T> {}
unsafe impl<T: Send> Sync for SliceViewMut<T> {}

impl<T> SliceViewMut<T> {
    pub(crate) fn new(s: &mut [T]) -> SliceViewMut<T> {
        SliceViewMut {
            ptr: s.as_mut_ptr(),
            len: s.len(),
        }
    }

    /// SAFETY: callers must uphold the view's soundness contract and
    /// must never materialize overlapping ranges across live jobs.
    pub(crate) unsafe fn range_mut<'a>(self, start: usize, len: usize) -> &'a mut [T] {
        debug_assert!(start + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

/// One shard: a contiguous coordinate range and its private
/// accumulator/weight slices. All methods read full-length input
/// buffers and index them by absolute coordinate, writing only the
/// shard's own state.
///
/// `pub(crate)` so [`super::hierarchy`] can reuse it as the edge
/// aggregator / tree-node state: a hierarchy node is exactly a shard
/// whose `(accum, weight)` pair covers the union of its children's
/// coordinate ranges.
pub(crate) struct Shard {
    /// First flat coordinate this shard owns.
    pub(crate) start: usize,
    pub(crate) accum: Vec<f64>,
    pub(crate) weight: Vec<f64>,
}

impl Shard {
    pub(crate) fn new(start: usize, len: usize) -> Shard {
        Shard {
            start,
            accum: vec![0.0; len],
            weight: vec![0.0; len],
        }
    }

    pub(crate) fn len(&self) -> usize {
        self.accum.len()
    }

    pub(crate) fn reset(&mut self) {
        self.accum.fill(0.0);
        self.weight.fill(0.0);
    }

    fn add_masked(&mut self, values: &[f32], coord_mask: &[bool], n_c: f64) {
        let s = self.start;
        for i in 0..self.len() {
            if coord_mask[s + i] {
                self.accum[i] += n_c * values[s + i] as f64;
                self.weight[i] += n_c;
            }
        }
    }

    fn add_full(&mut self, values: &[f32], n_c: f64) {
        let s = self.start;
        for i in 0..self.len() {
            self.accum[i] += n_c * values[s + i] as f64;
            self.weight[i] += n_c;
        }
    }

    /// Accumulate the kept coordinates of a pack plan: scan the plan's
    /// contiguous runs clipped to this shard's range instead of
    /// testing a full-length `coord_mask` per coordinate.
    fn add_runs(&mut self, values: &[f32], runs: &[(u32, u32)], n_c: f64) {
        let lo = self.start;
        let hi = self.start + self.len();
        for &(rs, rl) in runs {
            let rs = rs as usize;
            let re = rs + rl as usize;
            if re <= lo || rs >= hi {
                continue;
            }
            for i in rs.max(lo)..re.min(hi) {
                self.accum[i - lo] += n_c * values[i] as f64;
                self.weight[i - lo] += n_c;
            }
        }
    }

    /// Replay a staged op list over this shard's coordinates in caller
    /// order — the shared inner loop of the flat and hierarchical
    /// batched rounds.
    ///
    /// SAFETY: every view in `ops` must satisfy the [`SliceView`]
    /// contract — this is only called from inside the fan-out the
    /// views were staged for.
    pub(crate) unsafe fn replay(&mut self, ops: &[OpView]) {
        for op in ops {
            match *op {
                OpView::Masked(values, mask, n_c) => {
                    let (v, m) = (values.get(), mask.get());
                    self.add_masked(v, m, n_c);
                }
                OpView::Planned(values, runs, n_c) => {
                    let (v, r) = (values.get(), runs.get());
                    self.add_runs(v, r, n_c);
                }
                OpView::Full(values, n_c) => {
                    let v = values.get();
                    self.add_full(v, n_c);
                }
            }
        }
    }

    /// Absorb a child node's partial sums: a pure copy of the child's
    /// `(accum, weight)` into this shard's matching sub-range. The
    /// child's coordinate range must lie inside this shard's. No
    /// floating-point arithmetic happens here — coordinate ranges in
    /// the hierarchy are disjoint, so the upward "merge" is
    /// concatenation, which is what keeps the tree bit-identical to
    /// flat aggregation (see `aggregation/README.md`).
    pub(crate) fn merge_child(&mut self, child: &Shard) {
        let off = child.start - self.start;
        self.accum[off..off + child.len()].copy_from_slice(&child.accum);
        self.weight[off..off + child.len()].copy_from_slice(&child.weight);
    }

    /// Write this shard's averaged coordinates into `out` (the shard's
    /// own range of the full output, `out.len() == self.len()`).
    pub(crate) fn finalize_into(&self, base: &[f32], out: &mut [f32]) {
        let s = self.start;
        for i in 0..self.len() {
            out[i] = if self.weight[i] > 0.0 {
                (self.accum[i] / self.weight[i]) as f32
            } else {
                base[s + i]
            };
        }
    }

    pub(crate) fn covered(&self) -> usize {
        self.weight.iter().filter(|&&w| w > 0.0).count()
    }
}

/// One queued client add for a batched round, borrowing the caller's
/// buffers. [`ShardedFedAvg::aggregate_batch`] replays a round's worth
/// of these in one pool dispatch (persistent fan-out: shard workers
/// stay pinned across the adds instead of re-dispatching per client).
pub enum AddOp<'a> {
    /// A sub-model update restricted to `coord_mask` (DGC uplink).
    Masked {
        values: &'a [f32],
        coord_mask: &'a [bool],
        n_c: f64,
    },
    /// A raw-uplink update scanned through its pack plan's runs.
    Planned {
        values: &'a [f32],
        plan: &'a PackPlan,
        n_c: f64,
    },
    /// A full-model update (no-dropout baselines).
    Full { values: &'a [f32], n_c: f64 },
}

/// Lifetime-erased twin of [`AddOp`], safe to move into the pool's
/// `'static` jobs under the [`SliceView`] soundness contract.
#[derive(Clone, Copy)]
pub(crate) enum OpView {
    Masked(SliceView<f32>, SliceView<bool>, f64),
    Planned(SliceView<f32>, SliceView<(u32, u32)>, f64),
    Full(SliceView<f32>, f64),
}

/// Validate a batch's ops against `num_params` and stage their
/// lifetime-erased twins into `staged` (cleared first; capacity
/// reused). Shared by the flat and hierarchical batched rounds so both
/// enforce identical input contracts.
pub(crate) fn stage_ops(ops: &[AddOp], num_params: usize, staged: &mut Vec<OpView>) {
    for op in ops {
        match op {
            AddOp::Masked { values, coord_mask, .. } => {
                assert_eq!(
                    values.len(),
                    num_params,
                    "aggregate_batch: values buffer length != aggregator num_params"
                );
                assert_eq!(
                    coord_mask.len(),
                    num_params,
                    "aggregate_batch: coord_mask buffer length != aggregator num_params"
                );
            }
            AddOp::Planned { values, plan, .. } => {
                assert_eq!(
                    values.len(),
                    num_params,
                    "aggregate_batch: values buffer length != aggregator num_params"
                );
                assert_eq!(
                    plan.num_params(),
                    num_params,
                    "aggregate_batch: plan num_params != aggregator num_params"
                );
            }
            AddOp::Full { values, .. } => {
                assert_eq!(
                    values.len(),
                    num_params,
                    "aggregate_batch: values buffer length != aggregator num_params"
                );
            }
        }
    }
    staged.clear();
    staged.extend(ops.iter().map(|op| match op {
        AddOp::Masked { values, coord_mask, n_c } => {
            OpView::Masked(SliceView::new(values), SliceView::new(coord_mask), *n_c)
        }
        AddOp::Planned { values, plan, n_c } => {
            OpView::Planned(SliceView::new(values), SliceView::new(plan.runs()), *n_c)
        }
        AddOp::Full { values, n_c } => OpView::Full(SliceView::new(values), *n_c),
    }));
}

/// Sharded parallel FedAvg accumulator: the drop-in replacement for
/// the retained [`FedAvg`](crate::aggregation::FedAvg) reference on
/// the coordinator's aggregation path. Same per-coordinate semantics
/// (paper Eq. 2 / Fig. 1 step 7), bit-identical output for every
/// shard count, with `add_masked` / `add_full` / `add_planned` /
/// `finalize` fanned out across the worker pool — one disjoint
/// `(accum, weight)` slice pair per shard. The engine drives whole
/// rounds through [`ShardedFedAvg::aggregate_batch`]: one dispatch
/// replays reset, every add and the finalize on pinned shard workers.
pub struct ShardedFedAvg {
    num_params: usize,
    shards: Vec<Shard>,
    /// Reused staging for a batch's lifetime-erased op list.
    op_scratch: Vec<OpView>,
    /// Lazily-spawned shared pool: a single-shard aggregator never
    /// forces the worker threads into existence.
    pool: Arc<LazyPool>,
}

impl ShardedFedAvg {
    /// `shard_count` is clamped to at least 1; counts larger than
    /// `num_params` simply leave the surplus shards empty.
    pub fn new(num_params: usize, shard_count: usize, pool: Arc<LazyPool>) -> ShardedFedAvg {
        let k = shard_count.max(1);
        let shards = (0..k)
            .map(|i| {
                let start = i * num_params / k;
                let end = (i + 1) * num_params / k;
                Shard::new(start, end - start)
            })
            .collect();
        ShardedFedAvg {
            num_params,
            shards,
            op_scratch: Vec::new(),
            pool,
        }
    }

    pub fn num_params(&self) -> usize {
        self.num_params
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn reset(&mut self) {
        // Plain memsets: not worth a fan-out.
        for s in &mut self.shards {
            s.reset();
        }
    }

    /// Apply `op` to every shard — inline for a single shard, on the
    /// worker pool otherwise. Shards are moved through `Pool::map`
    /// (input order preserved) so each job owns its shard outright;
    /// only the caller's input buffers cross threads by reference.
    fn for_each_shard(&mut self, op: impl Fn(&mut Shard) + Send + Sync + 'static) {
        if self.shards.len() == 1 {
            op(&mut self.shards[0]);
            return;
        }
        let shards = std::mem::take(&mut self.shards);
        let shards = self.pool.get().map(shards, move |mut s: Shard| {
            op(&mut s);
            s
        });
        self.shards = shards;
    }

    /// Add a client's model restricted to its sub-model coordinates.
    /// `n_c` is the client's sample count (the FedAvg weight).
    pub fn add_masked(&mut self, values: &[f32], coord_mask: &[bool], n_c: f64) {
        assert_eq!(
            values.len(),
            self.num_params,
            "add_masked: values buffer length != aggregator num_params"
        );
        assert_eq!(
            coord_mask.len(),
            self.num_params,
            "add_masked: coord_mask buffer length != aggregator num_params"
        );
        let values = SliceView::new(values);
        let mask = SliceView::new(coord_mask);
        // SAFETY: the views are dereferenced only inside this fan-out;
        // `for_each_shard` joins every pool job before returning, so
        // the borrows outlive every dereference.
        self.for_each_shard(move |s| {
            let (v, m) = unsafe { (values.get(), mask.get()) };
            s.add_masked(v, m, n_c);
        });
    }

    /// Add a full-model client update (the no-dropout baselines).
    pub fn add_full(&mut self, values: &[f32], n_c: f64) {
        assert_eq!(
            values.len(),
            self.num_params,
            "add_full: values buffer length != aggregator num_params"
        );
        let values = SliceView::new(values);
        // SAFETY: see `add_masked`.
        self.for_each_shard(move |s| {
            let v = unsafe { values.get() };
            s.add_full(v, n_c);
        });
    }

    /// Add a raw-uplink client update through its pack plan: each
    /// shard scans the plan's contiguous kept runs clipped to its own
    /// range instead of testing `coord_mask[i]` per coordinate.
    /// Bit-identical to [`ShardedFedAvg::add_masked`] with the plan's
    /// coordinate mask — same per-coordinate operation, and every
    /// packed coordinate appears in exactly one run.
    pub fn add_planned(&mut self, values: &[f32], plan: &PackPlan, n_c: f64) {
        assert_eq!(
            values.len(),
            self.num_params,
            "add_planned: values buffer length != aggregator num_params"
        );
        assert_eq!(
            plan.num_params(),
            self.num_params,
            "add_planned: plan num_params != aggregator num_params"
        );
        let values = SliceView::new(values);
        let runs = SliceView::new(plan.runs());
        // SAFETY: see `add_masked`; the plan is borrowed by the caller
        // for the duration of this call, so the runs view is live too.
        self.for_each_shard(move |s| {
            let (v, r) = unsafe { (values.get(), runs.get()) };
            s.add_runs(v, r, n_c);
        });
    }

    /// Finalize into `out` (length `num_params`): coordinates nobody
    /// updated keep `base`'s value. Each shard writes only its own
    /// disjoint range of the output.
    pub fn finalize_into(&mut self, base: &[f32], out: &mut [f32]) {
        assert_eq!(
            base.len(),
            self.num_params,
            "finalize: base buffer length != aggregator num_params"
        );
        assert_eq!(
            out.len(),
            self.num_params,
            "finalize: output buffer length != aggregator num_params"
        );
        let base_v = SliceView::new(base);
        let out_v = SliceViewMut::new(out);
        // SAFETY: see `add_masked`; each shard materializes only its
        // own `[start, start+len)` output range, and the shard
        // partition makes those ranges pairwise disjoint.
        self.for_each_shard(move |s| {
            let b = unsafe { base_v.get() };
            let o = unsafe { out_v.range_mut(s.start, s.len()) };
            s.finalize_into(b, o);
        });
    }

    /// Allocating wrapper around [`ShardedFedAvg::finalize_into`].
    pub fn finalize(&mut self, base: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.num_params];
        self.finalize_into(base, &mut out);
        out
    }

    /// Execute one round's aggregation — reset, every add in `ops`
    /// order, finalize into `out` (resized to `num_params`; capacity
    /// reused) — in a **single** pool dispatch: shard workers stay
    /// pinned across the round's adds instead of being re-dispatched
    /// per client. Bit-identical to calling [`ShardedFedAvg::reset`],
    /// the matching `add_*` sequence and
    /// [`ShardedFedAvg::finalize_into`]: each shard replays the ops in
    /// caller order over its own coordinates, so no per-coordinate
    /// operation sequence changes (enforced by
    /// `rust/tests/agg_sharding.rs`).
    pub fn aggregate_batch(&mut self, ops: &[AddOp], base: &[f32], out: &mut Vec<f32>) {
        let _sp = crate::obs::span_ab(
            crate::obs::Stage::ShardAggregate,
            ops.len() as u64,
            self.shards.len() as u64,
        );
        assert_eq!(
            base.len(),
            self.num_params,
            "aggregate_batch: base buffer length != aggregator num_params"
        );
        // Stage the lifetime-erased op list in a local (its heap
        // buffer is recycled through `op_scratch` across rounds, but
        // the Vec itself is moved out so the fan-out's view never
        // aliases the `&mut self` borrow `for_each_shard` takes).
        let mut staged = std::mem::take(&mut self.op_scratch);
        stage_ops(ops, self.num_params, &mut staged);
        out.clear();
        out.resize(self.num_params, 0.0);
        let ops_v = SliceView::new(&staged);
        let base_v = SliceView::new(base);
        let out_v = SliceViewMut::new(out);
        // SAFETY: see `add_masked`/`finalize_into` — every view
        // (including the staged op list, a local the fan-out cannot
        // touch) is dereferenced only inside this fan-out, and output
        // ranges are pairwise disjoint.
        self.for_each_shard(move |s| {
            s.reset();
            unsafe { s.replay(ops_v.get()) };
            let b = unsafe { base_v.get() };
            let o = unsafe { out_v.range_mut(s.start, s.len()) };
            s.finalize_into(b, o);
        });
        self.op_scratch = staged;
    }

    /// Fraction of coordinates that received at least one update.
    /// Same covered-count and same final division as the reference
    /// [`FedAvg::coverage`](crate::aggregation::FedAvg::coverage), so
    /// the two agree exactly.
    pub fn coverage(&self) -> f64 {
        let covered: usize = self.shards.iter().map(Shard::covered).sum();
        covered as f64 / self.num_params.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::FedAvg;

    fn pool() -> Arc<LazyPool> {
        Arc::new(LazyPool::new(3))
    }

    #[test]
    fn matches_reference_on_the_paper_example() {
        for shards in [1usize, 2, 3, 7] {
            let mut agg = ShardedFedAvg::new(3, shards, pool());
            agg.add_full(&[1.0, 2.0, 3.0], 10.0);
            agg.add_full(&[3.0, 0.0, 6.0], 30.0);
            let out = agg.finalize(&[9.0, 9.0, 9.0]);
            assert_eq!(out, vec![2.5, 0.5, 5.25], "shards={shards}");
            assert_eq!(agg.coverage(), 1.0);
        }
    }

    #[test]
    fn shard_partition_is_disjoint_balanced_and_covering() {
        for (n, k) in [(13usize, 5usize), (4, 7), (0, 3), (942, 4), (16, 16)] {
            let agg = ShardedFedAvg::new(n, k, pool());
            assert_eq!(agg.shard_count(), k.max(1));
            let mut next = 0usize;
            let (mut min_len, mut max_len) = (usize::MAX, 0usize);
            for s in &agg.shards {
                assert_eq!(s.start, next, "n={n} k={k}: shards must tile contiguously");
                next += s.len();
                min_len = min_len.min(s.len());
                max_len = max_len.max(s.len());
            }
            assert_eq!(next, n, "n={n} k={k}: shards must cover the vector");
            assert!(max_len - min_len <= 1, "n={n} k={k}: balanced split");
        }
    }

    #[test]
    fn coverage_agrees_exactly_with_reference() {
        let n = 29;
        for shards in [1usize, 2, 7, 40] {
            let mut sharded = ShardedFedAvg::new(n, shards, pool());
            let mut reference = FedAvg::new(n);
            let values: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let mask: Vec<bool> = (0..n).map(|i| i % 3 == 0).collect();
            sharded.add_masked(&values, &mask, 5.0);
            reference.add_masked(&values, &mask, 5.0);
            assert_eq!(
                sharded.coverage().to_bits(),
                reference.coverage().to_bits(),
                "shards={shards}"
            );
            // Zero-weight adds cover nothing extra in either.
            sharded.add_full(&values, 0.0);
            reference.add_full(&values, 0.0);
            assert_eq!(sharded.coverage().to_bits(), reference.coverage().to_bits());
        }
        // Degenerate: empty aggregator.
        let empty = ShardedFedAvg::new(0, 4, pool());
        assert_eq!(empty.coverage(), FedAvg::new(0).coverage());
    }

    #[test]
    fn aggregate_batch_matches_per_add_dispatch_bitwise() {
        use crate::model::submodel::SubModel;
        use crate::runtime::native::mlp_spec;
        let spec = mlp_spec("batch", 7, 12, 4, 2, 1, 0.1);
        let n = spec.num_params;
        let sm = SubModel::from_kept_indices(&spec, &[vec![0, 3, 4, 9, 11]]);
        let plan = PackPlan::build(&spec, &sm);
        let vals_a: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
        let vals_b: Vec<f32> = (0..n).map(|i| (i as f32) * 0.01 - 1.0).collect();
        let mask: Vec<bool> = (0..n).map(|i| i % 3 != 1).collect();
        let base: Vec<f32> = (0..n).map(|i| i as f32).collect();
        for shards in [1usize, 2, 7, 40] {
            let mut per_add = ShardedFedAvg::new(n, shards, pool());
            per_add.reset();
            per_add.add_masked(&vals_a, &mask, 10.0);
            per_add.add_planned(&vals_b, &plan, 3.0);
            per_add.add_full(&vals_a, 0.5);
            let want = per_add.finalize(&base);

            let mut batched = ShardedFedAvg::new(n, shards, pool());
            let ops = vec![
                AddOp::Masked {
                    values: &vals_a,
                    coord_mask: &mask,
                    n_c: 10.0,
                },
                AddOp::Planned {
                    values: &vals_b,
                    plan: &plan,
                    n_c: 3.0,
                },
                AddOp::Full {
                    values: &vals_a,
                    n_c: 0.5,
                },
            ];
            let mut out = Vec::new();
            batched.aggregate_batch(&ops, &base, &mut out);
            for (i, (a, b)) in out.iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "shards={shards} coord {i}");
            }
            // The batch resets internally: replay on the same
            // aggregator (reused output buffer) must be identical.
            batched.aggregate_batch(&ops, &base, &mut out);
            for (a, b) in out.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            // Empty batch: pure reset + finalize.
            batched.aggregate_batch(&[], &base, &mut out);
            assert_eq!(out, base);
        }
    }

    #[test]
    fn finalize_into_matches_finalize() {
        let mut a = ShardedFedAvg::new(10, 3, pool());
        let mut b = ShardedFedAvg::new(10, 3, pool());
        let vals = [0.5f32; 10];
        a.add_full(&vals, 2.0);
        b.add_full(&vals, 2.0);
        let base = [9.0f32; 10];
        let want = a.finalize(&base);
        let mut out = vec![0.0f32; 10];
        b.finalize_into(&base, &mut out);
        assert_eq!(out, want);
    }

    #[test]
    fn reset_clears_every_shard() {
        let mut agg = ShardedFedAvg::new(10, 4, pool());
        agg.add_full(&[1.0; 10], 2.0);
        agg.reset();
        let out = agg.finalize(&[7.0; 10]);
        assert_eq!(out, vec![7.0; 10]);
        assert_eq!(agg.coverage(), 0.0);
    }

    #[test]
    fn sharding_config_resolves_auto_and_explicit() {
        let mut cfg = ShardingConfig::default();
        assert_eq!(cfg.shard_count, 0, "default is auto");
        // Auto: small models stay single-shard, big ones use the pool.
        assert_eq!(cfg.resolve(942, 8), 1);
        assert_eq!(cfg.resolve(1_000_000, 8), 8);
        assert_eq!(cfg.resolve(40_000, 8), 3); // ceil(40000/16384)=3 caps it
        assert_eq!(cfg.resolve(0, 8), 1);
        // Explicit wins regardless of size, but clamps to num_params
        // (surplus shards would be empty no-op jobs).
        cfg.shard_count = 5;
        assert_eq!(cfg.resolve(10, 8), 5);
        cfg.shard_count = 100_000_000;
        assert_eq!(cfg.resolve(10, 8), 10);
        assert_eq!(cfg.resolve(0, 8), 1);
    }
}
