//! Static metrics registry: atomic counters/gauges and fixed-size
//! log-bucketed histograms.
//!
//! Everything here is a `static` with const-initialized atomics — no
//! allocation ever, safe to hammer from any thread. Instrument sites
//! gate on [`crate::obs::enabled`] *once per site* (cheaper than
//! per-counter checks when a site updates several metrics together);
//! the primitives themselves are ungated so unit tests can exercise
//! local instances without touching the global flag.

use std::sync::atomic::{AtomicU64, Ordering};

use super::span::{Stage, STAGE_COUNT};

// ---------------------------------------------------------------------
// Primitives
// ---------------------------------------------------------------------

/// Monotonic atomic counter.
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub const fn new() -> Counter {
        Counter {
            v: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn incr(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.v.store(0, Ordering::Relaxed);
    }
}

impl Default for Counter {
    fn default() -> Counter {
        Counter::new()
    }
}

/// Last-write-wins gauge (queue depths, pool width).
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge {
            v: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn set(&self, n: u64) {
        self.v.store(n, Ordering::Relaxed);
    }

    /// Track the high-water mark too (`set` forgets peaks).
    #[inline]
    pub fn set_max(&self, n: u64) {
        self.v.fetch_max(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.v.store(0, Ordering::Relaxed);
    }
}

impl Default for Gauge {
    fn default() -> Gauge {
        Gauge::new()
    }
}

/// Number of log₂ buckets: bucket 0 holds value 0, bucket `i ≥ 1`
/// holds values in `[2^(i-1), 2^i)`, with the top bucket absorbing
/// everything ≥ 2^62.
pub const HIST_BUCKETS: usize = 64;

/// Fixed-size log-bucketed histogram (durations in ns, sizes in
/// bytes). `sum`/`count` ride along so means are exact even though
/// quantiles are bucket-resolution.
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    pub const fn new() -> Histogram {
        // Repeat-initializer for the atomic array; never borrowed as
        // a const, so the interior-mutability footgun doesn't apply.
        #[allow(clippy::declare_interior_mutable_const)]
        const Z: AtomicU64 = AtomicU64::new(0);
        Histogram {
            buckets: [Z; HIST_BUCKETS],
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    #[inline]
    fn bucket_of(v: u64) -> usize {
        (64 - v.leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }

    #[inline]
    pub fn observe(&self, v: u64) {
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum() as f64 / c as f64
        }
    }

    /// Upper bound of the bucket holding the `q`-quantile observation
    /// (0 for the zero bucket). Bucket-resolution: within a factor of
    /// 2 of the true value, which is what a log histogram promises.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return if i == 0 { 0 } else { 1u64 << i };
            }
        }
        u64::MAX
    }

    /// Raw occupancy of bucket `i` — the telemetry shipper reads every
    /// bucket to compute per-round deltas.
    pub fn bucket_count(&self, i: usize) -> u64 {
        self.buckets[i].load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.sum.store(0, Ordering::Relaxed);
        self.count.store(0, Ordering::Relaxed);
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

// ---------------------------------------------------------------------
// The registry
// ---------------------------------------------------------------------

/// Wire bytes framed for the downlink (offer + model + ack/cut).
pub static BYTES_DOWN_WIRE: Counter = Counter::new();
/// Wire bytes received on the uplink (update frames).
pub static BYTES_UP_WIRE: Counter = Counter::new();
/// Codec payload bytes inside downlink model frames.
pub static BYTES_DOWN_PAYLOAD: Counter = Counter::new();
/// Update payload bytes inside uplink frames.
pub static BYTES_UP_PAYLOAD: Counter = Counter::new();
/// Frames that failed CRC validation (see `transport/README.md`).
pub static CRC_FAILURES: Counter = Counter::new();
/// Clients cut by a round deadline (straggler policy).
pub static STRAGGLERS_CUT: Counter = Counter::new();
/// Clients whose finished work was dropped by churn.
pub static CLIENTS_DROPPED: Counter = Counter::new();
/// Clients lost in transit by the transport (dead/timed-out
/// connection); the scheduler converts these into cuts.
pub static CLIENTS_LOST: Counter = Counter::new();
/// Connections that timed out waiting on socket I/O.
pub static TRANSPORT_TIMEOUTS: Counter = Counter::new();
/// Client sessions re-accepted after a disconnect (session resume).
pub static CONN_RECONNECTS: Counter = Counter::new();
/// `StateSync` wire bytes sent to resuming clients (excluded from the
/// round records so TCP and loopback accounting compare equal).
pub static RESYNC_BYTES: Counter = Counter::new();
/// Rounds the engine completed.
pub static ROUNDS_COMPLETED: Counter = Counter::new();
/// Full-model evaluations run by the coordinator.
pub static EVALS_RUN: Counter = Counter::new();
/// Residual-store lookups served from saved state (resident or spill).
pub static RESIDUAL_STORE_HITS: Counter = Counter::new();
/// Residual-store lookups that materialized a fresh client.
pub static RESIDUAL_STORE_MISSES: Counter = Counter::new();
/// Clients evicted from the resident set by the byte budget.
pub static RESIDUAL_STORE_EVICTIONS: Counter = Counter::new();
/// Bytes written to the residual-store spill file.
pub static RESIDUAL_STORE_SPILLED_BYTES: Counter = Counter::new();
/// Coordinator checkpoints written (`afd serve --checkpoint`).
pub static CHECKPOINTS_WRITTEN: Counter = Counter::new();
/// Total checkpoint bytes written (post-rename file sizes).
pub static CHECKPOINT_BYTES: Counter = Counter::new();
/// Coordinator restores performed (`afd serve --restore`).
pub static RESTORES: Counter = Counter::new();
/// Clients quarantined after repeated faults (see `fault/README.md`).
pub static CLIENTS_QUARANTINED: Counter = Counter::new();
/// `Telemetry` frame wire bytes received by the coordinator. Like
/// [`RESYNC_BYTES`], a side channel excluded from `RoundRecord`
/// accounting so telemetry-on runs stay byte-identical.
pub static TELEMETRY_BYTES: Counter = Counter::new();
/// `Telemetry` frames merged by the coordinator.
pub static TELEMETRY_FRAMES: Counter = Counter::new();
/// Remote spans discarded because a remote process hit its merge-side
/// storage cap (`obs::remote::REMOTE_SPAN_CAP`).
pub static TELEMETRY_SPANS_DROPPED: Counter = Counter::new();

/// Injected faults by `fault::Site` discriminant. Incremented by
/// `fault::should` itself (unconditionally — fault accounting is part
/// of the run's output, not the optional trace).
#[allow(clippy::declare_interior_mutable_const)]
const FAULT_SLOT: Counter = Counter::new();
pub static FAULTS_INJECTED: [Counter; crate::fault::SITE_COUNT] =
    [FAULT_SLOT; crate::fault::SITE_COUNT];

/// Async engine: in-flight heap depth (high-water mark).
pub static QUEUE_DEPTH: Gauge = Gauge::new();
/// Worker pool width the experiment was built with.
pub static POOL_WIDTH: Gauge = Gauge::new();
/// Residual store: resident client-state bytes (high-water mark).
pub static RESIDENT_BYTES_PEAK: Gauge = Gauge::new();
/// TCP coordinator: pipelined offers in flight on one connection
/// (high-water mark across all connections).
pub static PIPELINE_DEPTH: Gauge = Gauge::new();
/// Round the coordinator is currently driving (live stats endpoint).
pub static CURRENT_ROUND: Gauge = Gauge::new();

/// Stable wire ids for the counters a `Telemetry` frame ships: the
/// array index is the id byte on the wire, the name is the stats key.
/// Append-only — reordering entries would silently misattribute
/// remote totals between binaries of different ages.
pub static WIRE_COUNTERS: [(&str, &Counter); 31] = [
    ("bytes_down_wire", &BYTES_DOWN_WIRE),
    ("bytes_up_wire", &BYTES_UP_WIRE),
    ("bytes_down_payload", &BYTES_DOWN_PAYLOAD),
    ("bytes_up_payload", &BYTES_UP_PAYLOAD),
    ("crc_failures", &CRC_FAILURES),
    ("stragglers_cut", &STRAGGLERS_CUT),
    ("clients_dropped", &CLIENTS_DROPPED),
    ("clients_lost", &CLIENTS_LOST),
    ("transport_timeouts", &TRANSPORT_TIMEOUTS),
    ("conn_reconnects", &CONN_RECONNECTS),
    ("resync_bytes", &RESYNC_BYTES),
    ("rounds_completed", &ROUNDS_COMPLETED),
    ("evals_run", &EVALS_RUN),
    ("residual_store_hits", &RESIDUAL_STORE_HITS),
    ("residual_store_misses", &RESIDUAL_STORE_MISSES),
    ("residual_store_evictions", &RESIDUAL_STORE_EVICTIONS),
    ("residual_store_spilled_bytes", &RESIDUAL_STORE_SPILLED_BYTES),
    ("checkpoints_written", &CHECKPOINTS_WRITTEN),
    ("checkpoint_bytes", &CHECKPOINT_BYTES),
    ("restores", &RESTORES),
    ("clients_quarantined", &CLIENTS_QUARANTINED),
    ("faults_sock_write", &FAULTS_INJECTED[0]),
    ("faults_sock_read", &FAULTS_INJECTED[1]),
    ("faults_partial_write", &FAULTS_INJECTED[2]),
    ("faults_frame_corrupt", &FAULTS_INJECTED[3]),
    ("faults_frame_delay", &FAULTS_INJECTED[4]),
    ("faults_frame_dup", &FAULTS_INJECTED[5]),
    ("faults_spill_truncate", &FAULTS_INJECTED[6]),
    ("faults_spill_corrupt", &FAULTS_INJECTED[7]),
    ("faults_worker_panic", &FAULTS_INJECTED[8]),
    ("faults_clock_stall", &FAULTS_INJECTED[9]),
];

/// Stable wire ids for gauges, mirroring [`WIRE_COUNTERS`].
pub static WIRE_GAUGES: [(&str, &Gauge); 4] = [
    ("queue_depth_peak", &QUEUE_DEPTH),
    ("pool_width", &POOL_WIDTH),
    ("resident_bytes_peak", &RESIDENT_BYTES_PEAK),
    ("pipeline_depth_peak", &PIPELINE_DEPTH),
];

/// Frame counts by `FrameKind as u8` (slot 0 unused; kinds are 1-11).
pub const FRAME_KIND_SLOTS: usize = 16;

// Repeat-initializers for the static arrays below; only ever used in
// `[X; N]` position, never borrowed as consts.
#[allow(clippy::declare_interior_mutable_const)]
const FRAME_SLOT: Counter = Counter::new();
/// Frames sealed by `end_frame`, per kind.
pub static FRAMES_SENT: [Counter; FRAME_KIND_SLOTS] = [FRAME_SLOT; FRAME_KIND_SLOTS];
/// Frames accepted by `parse_frame`, per kind.
pub static FRAMES_PARSED: [Counter; FRAME_KIND_SLOTS] = [FRAME_SLOT; FRAME_KIND_SLOTS];

/// Per-TCP-connection round-trip counts (connection `c` lands in slot
/// `c % CONN_SLOTS`; the federation multiplexes clients over a small
/// connection pool so slots are effectively exact).
pub const CONN_SLOTS: usize = 64;
#[allow(clippy::declare_interior_mutable_const)]
const CONN_SLOT: Counter = Counter::new();
pub static CONN_ROUND_TRIPS: [Counter; CONN_SLOTS] = [CONN_SLOT; CONN_SLOTS];

/// Per-stage wall-clock duration histograms (ns), fed by span guards.
#[allow(clippy::declare_interior_mutable_const)]
const STAGE_HIST: Histogram = Histogram::new();
pub static STAGE_NS: [Histogram; STAGE_COUNT] = [STAGE_HIST; STAGE_COUNT];

/// Sizes of every sealed frame (bytes).
pub static FRAME_BYTES: Histogram = Histogram::new();

/// Span-guard hook: one closed span of `stage` lasting `ns`.
#[inline]
pub fn stage_observe(stage: Stage, ns: u64) {
    STAGE_NS[stage as usize].observe(ns);
}

/// Zero every counter, gauge and histogram (rings are reset
/// separately by [`crate::obs::reset`]).
pub fn reset_all() {
    for c in [
        &BYTES_DOWN_WIRE,
        &BYTES_UP_WIRE,
        &BYTES_DOWN_PAYLOAD,
        &BYTES_UP_PAYLOAD,
        &CRC_FAILURES,
        &STRAGGLERS_CUT,
        &CLIENTS_DROPPED,
        &CLIENTS_LOST,
        &TRANSPORT_TIMEOUTS,
        &CONN_RECONNECTS,
        &RESYNC_BYTES,
        &ROUNDS_COMPLETED,
        &EVALS_RUN,
        &RESIDUAL_STORE_HITS,
        &RESIDUAL_STORE_MISSES,
        &RESIDUAL_STORE_EVICTIONS,
        &RESIDUAL_STORE_SPILLED_BYTES,
        &CHECKPOINTS_WRITTEN,
        &CHECKPOINT_BYTES,
        &RESTORES,
        &CLIENTS_QUARANTINED,
        &TELEMETRY_BYTES,
        &TELEMETRY_FRAMES,
        &TELEMETRY_SPANS_DROPPED,
    ] {
        c.reset();
    }
    for c in &FAULTS_INJECTED {
        c.reset();
    }
    QUEUE_DEPTH.reset();
    POOL_WIDTH.reset();
    RESIDENT_BYTES_PEAK.reset();
    PIPELINE_DEPTH.reset();
    CURRENT_ROUND.reset();
    for c in FRAMES_SENT.iter().chain(FRAMES_PARSED.iter()) {
        c.reset();
    }
    for c in &CONN_ROUND_TRIPS {
        c.reset();
    }
    for h in &STAGE_NS {
        h.reset();
    }
    FRAME_BYTES.reset();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.add(3);
        c.incr();
        assert_eq!(c.get(), 4);
        c.reset();
        assert_eq!(c.get(), 0);
        let g = Gauge::new();
        g.set(9);
        g.set(2);
        assert_eq!(g.get(), 2);
        g.set_max(7);
        g.set_max(4);
        assert_eq!(g.get(), 7);
    }

    #[test]
    fn histogram_buckets_by_log2() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 1);
        assert_eq!(Histogram::bucket_of(2), 2);
        assert_eq!(Histogram::bucket_of(3), 2);
        assert_eq!(Histogram::bucket_of(4), 3);
        assert_eq!(Histogram::bucket_of(1023), 10);
        assert_eq!(Histogram::bucket_of(1024), 11);
        assert_eq!(Histogram::bucket_of(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn histogram_mean_and_quantiles() {
        let h = Histogram::new();
        for v in [0u64, 1, 1, 6, 6, 6, 6, 6, 100, 1000] {
            h.observe(v);
        }
        assert_eq!(h.count(), 10);
        assert_eq!(h.sum(), 1132);
        assert!((h.mean() - 113.2).abs() < 1e-9);
        // p50 falls in the [4,8) bucket → upper bound 8.
        assert_eq!(h.quantile(0.5), 8);
        // p100 falls in the [512,1024) bucket → upper bound 1024.
        assert_eq!(h.quantile(1.0), 1024);
        // Empty histogram.
        let e = Histogram::new();
        assert_eq!(e.quantile(0.99), 0);
        assert_eq!(e.mean(), 0.0);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum(), 0);
    }
}
