//! Observability substrate: spans, counters, histograms and trace
//! export for the whole federation pipeline.
//!
//! The repo's per-round [`RoundRecord`](crate::metrics::RoundRecord)
//! says *what* a round produced; this layer says *where* the time and
//! bytes went inside it — per stage, per client, per worker thread,
//! per TCP connection. The upcoming adaptive bandwidth controller
//! (ROADMAP) reads its signals from here.
//!
//! Four pieces:
//!
//! * [`span`] — an RAII span recorder writing fixed-size records into
//!   **preallocated per-thread ring buffers** (no locks, no heap on
//!   the warm path). Spans carry monotonic wall-clock timestamps;
//!   round markers additionally carry the scheduler's *virtual* clock
//!   so simulated time can be lined up with real time. Fault
//!   injections, quarantines, checkpoints, restores and session
//!   resumes are instant-only stages on the same rings.
//! * [`metrics`] — atomic counters/gauges and fixed-size log-bucketed
//!   histograms in a static registry (bytes per direction, frames by
//!   kind, CRC failures, stragglers cut, queue depth, per-connection
//!   round-trips, per-stage latency).
//! * [`export`] — Chrome trace-event JSON (`afd … --trace-out
//!   trace.json`, loadable in Perfetto / `chrome://tracing`; one track
//!   per worker thread plus one per TCP connection, one process group
//!   per remote client process) and a stats JSON dump (`--stats-out`),
//!   plus the per-stage breakdown table printed next to the experiment
//!   summary.
//! * [`remote`] — the distributed telemetry plane: a client-side
//!   [`remote::Shipper`] that delta-encodes local rings/counters into
//!   `Telemetry` wire frames, a coordinator-side merge registry that
//!   aligns remote monotonic clocks onto the coordinator's, and a live
//!   HTTP stats endpoint (`--metrics-addr`, Prometheus text +
//!   machine-readable JSON snapshot).
//!
//! ## The two load-bearing contracts
//!
//! 1. **Bit-identity**: instrumentation only *reads and times* — it
//!    never draws randomness, reorders work, or touches a byte stream
//!    — so a traced fixed-seed run produces bit-identical
//!    `RoundRecord`s and final model hash to an untraced one
//!    (`rust/tests/obs_conformance.rs` pins this for all three
//!    scheduler policies).
//! 2. **Zero-alloc**: ring buffers, counters and histogram buckets are
//!    preallocated, so a warm client round allocates nothing with
//!    tracing enabled (`rust/tests/zero_alloc.rs`).
//!
//! ## Gating
//!
//! Recording is compiled in only with the `trace` cargo feature (on by
//! default; `--no-default-features` compiles every probe down to a
//! constant-false branch) and must *also* be enabled at runtime via
//! [`set_enabled`] (the `--trace-out`/`--stats-out` flags or
//! `AFD_TRACE=1`). Disabled probes cost one relaxed atomic load.
//!
//! See `rust/src/obs/README.md` for the span taxonomy and how to open
//! a trace in Perfetto.

pub mod export;
pub mod metrics;
pub mod remote;
pub mod span;

pub use span::{
    mark, register_thread, span, span_ab, span_on_track, SpanGuard, Stage, CONN_TRACK_BASE,
    STAGE_COUNT,
};

use std::sync::atomic::{AtomicBool, Ordering};

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is recording active? Compile-time false without the `trace` feature;
/// otherwise one relaxed atomic load (the whole cost of a disabled
/// probe site).
#[inline(always)]
pub fn enabled() -> bool {
    cfg!(feature = "trace") && ENABLED.load(Ordering::Relaxed)
}

/// Turn runtime recording on or off (the `trace` feature must be
/// compiled in for `on = true` to have any effect).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Honor `AFD_TRACE=1|true|on` (remote `afd client` processes have no
/// `--trace-out` flag of their own) and pin the wall-clock epoch so
/// early spans don't race its initialization.
pub fn init_from_env() {
    if matches!(
        std::env::var("AFD_TRACE").as_deref(),
        Ok("1") | Ok("true") | Ok("on")
    ) {
        set_enabled(true);
    }
    span::pin_epoch();
}

/// Clear every ring, counter and histogram (tests and back-to-back
/// runs in one process). Rings stay allocated.
pub fn reset() {
    span::reset_rings();
    metrics::reset_all();
    remote::reset();
}

/// Unit tests that toggle the global enable flag serialize on this
/// (the lib test binary runs tests in parallel).
#[cfg(test)]
pub(crate) static TEST_FLAG_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[cfg(test)]
mod tests {
    #[test]
    fn flag_toggles_only_with_the_feature() {
        let _l = super::TEST_FLAG_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        super::set_enabled(false);
        assert!(!super::enabled());
        super::set_enabled(true);
        assert_eq!(super::enabled(), cfg!(feature = "trace"));
        super::set_enabled(false);
    }
}
