//! Exporters: Chrome trace-event JSON and the end-of-run stats dump.
//!
//! The trace file is the [trace-event format] consumed by Perfetto and
//! `chrome://tracing`: one complete (`"ph":"X"`) event per span with
//! microsecond timestamps, one track (`tid`) per registered thread
//! plus one synthetic track per TCP connection, and `"ph":"M"`
//! metadata events naming every track. Round markers become global
//! instant events carrying the scheduler's virtual clock in `args`.
//! `scripts/check_trace.py` validates the shape in CI (`obs-smoke`).
//!
//! [trace-event format]: https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU

use std::io;
use std::path::Path;

use super::metrics;
use super::remote;
use super::span::{self, SpanRec, Stage, CONN_TRACK_BASE, STAGE_COUNT};
use crate::util::json::Json;

fn frame_kind_name(tag: usize) -> Option<&'static str> {
    Some(match tag {
        1 => "hello",
        2 => "config",
        3 => "ready",
        4 => "round_offer",
        5 => "model_down",
        6 => "update_up",
        7 => "ack",
        8 => "cut",
        9 => "bye",
        10 => "state_sync",
        11 => "telemetry",
        _ => return None,
    })
}

fn us(ns: u64) -> Json {
    Json::Num(ns as f64 / 1000.0)
}

fn span_event(pid: u32, tid: u32, s: &SpanRec) -> Json {
    let mut ev = Json::obj();
    ev.set("pid", Json::Num(pid as f64));
    ev.set("tid", Json::Num(tid as f64));
    ev.set("ts", us(s.start_ns));
    if s.stage.is_instant() {
        ev.set("ph", Json::Str("i".into()));
        ev.set("s", Json::Str("g".into()));
        ev.set("name", Json::Str(s.stage.name().into()));
        let mut args = Json::obj();
        if s.stage == Stage::RoundMark {
            args.set("round", Json::Num(s.a as f64));
            args.set("virtual_s", Json::Num(s.b as f64 / 1e9));
        } else {
            args.set("a", Json::Num(s.a as f64));
            args.set("b", Json::Num(s.b as f64));
        }
        ev.set("args", args);
    } else {
        ev.set("ph", Json::Str("X".into()));
        ev.set("cat", Json::Str("afd".into()));
        ev.set("name", Json::Str(s.stage.name().into()));
        ev.set("dur", us(s.dur_ns));
        let mut args = Json::obj();
        args.set("a", Json::Num(s.a as f64));
        args.set("b", Json::Num(s.b as f64));
        ev.set("args", args);
    }
    ev
}

fn thread_name_event(pid: u32, tid: u32, name: &str) -> Json {
    let mut ev = Json::obj();
    ev.set("ph", Json::Str("M".into()));
    ev.set("name", Json::Str("thread_name".into()));
    ev.set("pid", Json::Num(pid as f64));
    ev.set("tid", Json::Num(tid as f64));
    let mut args = Json::obj();
    args.set("name", Json::Str(name.into()));
    ev.set("args", args);
    ev
}

fn process_name_event(pid: u32, name: &str) -> Json {
    let mut ev = Json::obj();
    ev.set("ph", Json::Str("M".into()));
    ev.set("name", Json::Str("process_name".into()));
    ev.set("pid", Json::Num(pid as f64));
    let mut args = Json::obj();
    args.set("name", Json::Str(name.into()));
    ev.set("args", args);
    ev
}

/// Build the whole Chrome trace document: the coordinator's own rings
/// (pid [`remote::COORDINATOR_PID`]) merged with every remote
/// process's shipped spans, each on its own named `pid` track group
/// with timestamps realigned onto the coordinator clock.
pub fn chrome_trace_json() -> Json {
    let threads = span::snapshot();
    let mut events: Vec<Json> = Vec::new();
    events.push(process_name_event(remote::COORDINATOR_PID, "afd"));

    // One named track per registered thread, plus one per TCP
    // connection actually seen in the spans.
    let mut conn_tracks: Vec<u32> = Vec::new();
    for t in &threads {
        events.push(thread_name_event(remote::COORDINATOR_PID, t.tid, &t.name));
        for s in &t.spans {
            if s.track >= CONN_TRACK_BASE && !conn_tracks.contains(&s.track) {
                conn_tracks.push(s.track);
            }
        }
    }
    conn_tracks.sort_unstable();
    for track in &conn_tracks {
        events.push(thread_name_event(
            remote::COORDINATOR_PID,
            *track,
            &format!("tcp-conn-{}", track - CONN_TRACK_BASE),
        ));
    }

    for t in &threads {
        for s in &t.spans {
            let tid = if s.track >= CONN_TRACK_BASE {
                s.track
            } else {
                t.tid
            };
            events.push(span_event(remote::COORDINATOR_PID, tid, s));
        }
    }

    // Remote processes: one pid per process, threads and synthetic
    // tracks named inside it, span timestamps shifted by the
    // process's clock offset.
    remote::with_remotes(|procs| {
        for (idx, p) in procs.iter().enumerate() {
            let pid = remote::RemoteProc::pid_for(idx);
            events.push(process_name_event(pid, &p.name));
            for (tid, name, _) in &p.threads {
                events.push(thread_name_event(pid, *tid, name));
            }
            let mut rtracks: Vec<u32> = Vec::new();
            for s in &p.spans {
                if s.track >= CONN_TRACK_BASE && !rtracks.contains(&s.track) {
                    rtracks.push(s.track);
                }
            }
            rtracks.sort_unstable();
            for track in &rtracks {
                events.push(thread_name_event(
                    pid,
                    *track,
                    &format!("tcp-conn-{}", track - CONN_TRACK_BASE),
                ));
            }
            for s in &p.spans {
                let rec = SpanRec {
                    stage: s.stage,
                    track: s.track,
                    start_ns: p.aligned_ns(s.start_ns),
                    dur_ns: s.dur_ns,
                    a: s.a,
                    b: s.b,
                };
                let tid = if s.track >= CONN_TRACK_BASE {
                    s.track
                } else {
                    s.tid
                };
                events.push(span_event(pid, tid, &rec));
            }
        }
    });

    let mut doc = Json::obj();
    doc.set("traceEvents", Json::Arr(events));
    doc.set("displayTimeUnit", Json::Str("ms".into()));
    doc.set("afd_stats", stats_json());
    doc
}

/// Write the Chrome trace file (`--trace-out`).
pub fn write_chrome_trace(path: &Path) -> io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, chrome_trace_json().to_string_compact())
}

/// Per-stage latency summary rows: `(stage name, count, total ns,
/// mean ns, p50 ns, p99 ns)`. Feeds the breakdown table next to
/// [`crate::metrics::render_table`] and the stats dump.
pub fn stage_rows() -> Vec<(&'static str, u64, u64, f64, u64, u64)> {
    let mut rows = Vec::with_capacity(STAGE_COUNT);
    for stage in Stage::ALL {
        if stage.is_instant() {
            continue; // instants, not durations
        }
        let h = &metrics::STAGE_NS[stage as usize];
        rows.push((
            stage.name(),
            h.count(),
            h.sum(),
            h.mean(),
            h.quantile(0.5),
            h.quantile(0.99),
        ));
    }
    rows
}

/// The end-of-run stats dump: every counter, gauge and histogram in
/// the registry, the span-ring accounting, and the logging layer's
/// dropped-line count.
pub fn stats_json() -> Json {
    let mut counters = Json::obj();
    counters.set(
        "bytes_down_wire",
        Json::Num(metrics::BYTES_DOWN_WIRE.get() as f64),
    );
    counters.set(
        "bytes_up_wire",
        Json::Num(metrics::BYTES_UP_WIRE.get() as f64),
    );
    counters.set(
        "bytes_down_payload",
        Json::Num(metrics::BYTES_DOWN_PAYLOAD.get() as f64),
    );
    counters.set(
        "bytes_up_payload",
        Json::Num(metrics::BYTES_UP_PAYLOAD.get() as f64),
    );
    counters.set(
        "crc_failures",
        Json::Num(metrics::CRC_FAILURES.get() as f64),
    );
    counters.set(
        "stragglers_cut",
        Json::Num(metrics::STRAGGLERS_CUT.get() as f64),
    );
    counters.set(
        "clients_dropped",
        Json::Num(metrics::CLIENTS_DROPPED.get() as f64),
    );
    counters.set(
        "clients_lost",
        Json::Num(metrics::CLIENTS_LOST.get() as f64),
    );
    counters.set(
        "transport_timeouts",
        Json::Num(metrics::TRANSPORT_TIMEOUTS.get() as f64),
    );
    counters.set(
        "conn_reconnects",
        Json::Num(metrics::CONN_RECONNECTS.get() as f64),
    );
    counters.set(
        "resync_bytes",
        Json::Num(metrics::RESYNC_BYTES.get() as f64),
    );
    counters.set(
        "rounds_completed",
        Json::Num(metrics::ROUNDS_COMPLETED.get() as f64),
    );
    counters.set("evals_run", Json::Num(metrics::EVALS_RUN.get() as f64));
    counters.set(
        "residual_store_hits",
        Json::Num(metrics::RESIDUAL_STORE_HITS.get() as f64),
    );
    counters.set(
        "residual_store_misses",
        Json::Num(metrics::RESIDUAL_STORE_MISSES.get() as f64),
    );
    counters.set(
        "residual_store_evictions",
        Json::Num(metrics::RESIDUAL_STORE_EVICTIONS.get() as f64),
    );
    counters.set(
        "residual_store_spilled_bytes",
        Json::Num(metrics::RESIDUAL_STORE_SPILLED_BYTES.get() as f64),
    );
    counters.set(
        "checkpoints_written",
        Json::Num(metrics::CHECKPOINTS_WRITTEN.get() as f64),
    );
    counters.set(
        "checkpoint_bytes",
        Json::Num(metrics::CHECKPOINT_BYTES.get() as f64),
    );
    counters.set("restores", Json::Num(metrics::RESTORES.get() as f64));
    counters.set(
        "clients_quarantined",
        Json::Num(metrics::CLIENTS_QUARANTINED.get() as f64),
    );
    counters.set(
        "telemetry_bytes",
        Json::Num(metrics::TELEMETRY_BYTES.get() as f64),
    );
    counters.set(
        "telemetry_frames",
        Json::Num(metrics::TELEMETRY_FRAMES.get() as f64),
    );
    counters.set(
        "telemetry_spans_dropped",
        Json::Num(metrics::TELEMETRY_SPANS_DROPPED.get() as f64),
    );
    let mut faults_total = 0u64;
    for site in crate::fault::ALL_SITES {
        let n = metrics::FAULTS_INJECTED[site as usize].get();
        faults_total += n;
        counters.set(&format!("faults_{}", site.name()), Json::Num(n as f64));
    }
    counters.set("faults_injected_total", Json::Num(faults_total as f64));

    let mut gauges = Json::obj();
    gauges.set(
        "queue_depth_peak",
        Json::Num(metrics::QUEUE_DEPTH.get() as f64),
    );
    gauges.set("pool_width", Json::Num(metrics::POOL_WIDTH.get() as f64));
    gauges.set(
        "resident_bytes_peak",
        Json::Num(metrics::RESIDENT_BYTES_PEAK.get() as f64),
    );
    gauges.set(
        "pipeline_depth_peak",
        Json::Num(metrics::PIPELINE_DEPTH.get() as f64),
    );
    gauges.set("round", Json::Num(metrics::CURRENT_ROUND.get() as f64));

    let mut sent = Json::obj();
    let mut parsed = Json::obj();
    for tag in 0..metrics::FRAME_KIND_SLOTS {
        let Some(name) = frame_kind_name(tag) else {
            continue;
        };
        sent.set(name, Json::Num(metrics::FRAMES_SENT[tag].get() as f64));
        parsed.set(name, Json::Num(metrics::FRAMES_PARSED[tag].get() as f64));
    }
    let mut frames = Json::obj();
    frames.set("sent", sent);
    frames.set("parsed", parsed);
    let fb = &metrics::FRAME_BYTES;
    let mut frame_bytes = Json::obj();
    frame_bytes.set("count", Json::Num(fb.count() as f64));
    frame_bytes.set("sum", Json::Num(fb.sum() as f64));
    frame_bytes.set("p50", Json::Num(fb.quantile(0.5) as f64));
    frame_bytes.set("p99", Json::Num(fb.quantile(0.99) as f64));
    frames.set("bytes", frame_bytes);

    let mut conns = Json::obj();
    for (i, c) in metrics::CONN_ROUND_TRIPS.iter().enumerate() {
        let n = c.get();
        if n > 0 {
            conns.set(&format!("conn_{i}"), Json::Num(n as f64));
        }
    }

    let mut stages = Json::obj();
    for (name, count, total_ns, mean_ns, p50, p99) in stage_rows() {
        let mut s = Json::obj();
        s.set("count", Json::Num(count as f64));
        s.set("total_ns", Json::Num(total_ns as f64));
        s.set("mean_ns", Json::Num(mean_ns));
        s.set("p50_ns", Json::Num(p50 as f64));
        s.set("p99_ns", Json::Num(p99 as f64));
        stages.set(name, s);
    }

    let threads = span::snapshot();
    let mut spans = Json::obj();
    spans.set("threads", Json::Num(threads.len() as f64));
    spans.set(
        "recorded",
        Json::Num(threads.iter().map(|t| t.spans.len() as u64).sum::<u64>() as f64),
    );
    spans.set(
        "dropped",
        Json::Num(threads.iter().map(|t| t.dropped).sum::<u64>() as f64),
    );
    let (ring_recorded, ring_dropped) = span::ring_totals();
    spans.set("ring_recorded", Json::Num(ring_recorded as f64));
    spans.set("ring_dropped", Json::Num(ring_dropped as f64));

    // Remote telemetry: one object per registered remote process with
    // its shipped counter totals, span accounting and clock offset.
    let mut remotes = Json::obj();
    remote::with_remotes(|procs| {
        for (idx, p) in procs.iter().enumerate() {
            let mut r = Json::obj();
            r.set(
                "pid",
                Json::Num(remote::RemoteProc::pid_for(idx) as f64),
            );
            r.set("frames", Json::Num(p.frames as f64));
            r.set("spans", Json::Num(p.spans.len() as f64));
            r.set("spans_dropped", Json::Num(p.spans_dropped as f64));
            r.set(
                "ring_dropped",
                Json::Num(p.threads.iter().map(|(_, _, d)| *d).sum::<u64>() as f64),
            );
            r.set("offset_ns", Json::Num(p.offset_ns as f64));
            let mut rc = Json::obj();
            for (id, (name, _)) in metrics::WIRE_COUNTERS.iter().enumerate() {
                let v = p.counters.get(id).copied().unwrap_or(0);
                if v > 0 {
                    rc.set(name, Json::Num(v as f64));
                }
            }
            r.set("counters", rc);
            let mut rg = Json::obj();
            for (id, (name, _)) in metrics::WIRE_GAUGES.iter().enumerate() {
                let v = p.gauges.get(id).copied().unwrap_or(0);
                if v > 0 {
                    rg.set(name, Json::Num(v as f64));
                }
            }
            r.set("gauges", rg);
            remotes.set(&p.name, r);
        }
    });

    let mut log = Json::obj();
    log.set(
        "jsonl_lines_dropped",
        Json::Num(crate::util::logging::dropped_lines() as f64),
    );

    let mut doc = Json::obj();
    doc.set("counters", counters);
    doc.set("gauges", gauges);
    doc.set("frames", frames);
    doc.set("conn_round_trips", conns);
    doc.set("stages", stages);
    doc.set("spans", spans);
    doc.set("remote", remotes);
    doc.set("log", log);
    doc
}

/// Write the stats dump (`--stats-out`) as pretty JSON.
pub fn write_stats(path: &Path) -> io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut text = stats_json().to_string_pretty();
    text.push('\n');
    std::fs::write(path, text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_json_is_parseable_and_complete() {
        let doc = stats_json();
        let text = doc.to_string_pretty();
        let back = crate::util::json::parse(&text).unwrap();
        for key in [
            "counters", "gauges", "frames", "stages", "spans", "remote", "log",
        ] {
            assert!(back.get(key).is_some(), "missing {key}");
        }
        // Every duration stage has a row (instant markers excluded).
        let stages = back.get("stages").unwrap();
        for stage in Stage::ALL {
            if stage.is_instant() {
                assert!(stages.get(stage.name()).is_none(), "{}", stage.name());
            } else {
                assert!(stages.get(stage.name()).is_some(), "{}", stage.name());
            }
        }
        assert!(stages.get("round").is_none());
        let counters = back.get("counters").unwrap();
        assert!(counters.get("telemetry_bytes").is_some());
        assert!(counters.get("telemetry_frames").is_some());
    }

    #[test]
    fn merged_trace_gives_each_remote_process_its_own_pid() {
        let name = format!("export-test-proc-{}", line!());
        let id = remote::register(&name);
        remote::anchor_at(id, 1_000, 2_000);
        let mut payload = Vec::new();
        {
            use crate::transport::frame::TelemetryEncoder;
            let mut enc = TelemetryEncoder::begin(&mut payload, 1, 1_500);
            enc.begin_threads();
            enc.begin_thread(0, "worker", 0);
            enc.span(Stage::Train as u8, 0, 1_100, 50, 7, 8);
            enc.end_threads();
            enc.begin_counters();
            enc.end_counters();
            enc.begin_gauges();
            enc.end_gauges();
            enc.begin_hists();
            enc.end_hists();
            enc.finish();
        }
        let view = crate::transport::frame::parse_frame(&payload).unwrap().0;
        let msg = crate::transport::frame::parse_telemetry(&view).unwrap();
        remote::ingest_at(id, &msg, 2_500);

        let doc = chrome_trace_json();
        let back = crate::util::json::parse(&doc.to_string_compact()).unwrap();
        let events = back.get("traceEvents").unwrap().as_arr().unwrap();
        // The remote process got a process_name metadata event with a
        // pid other than the coordinator's.
        let named = events.iter().any(|e| {
            e.get("name").and_then(|n| n.as_str()) == Some("process_name")
                && e.get("args")
                    .and_then(|a| a.get("name"))
                    .and_then(|n| n.as_str())
                    == Some(name.as_str())
                && e.get("pid").and_then(|p| p.as_f64())
                    != Some(remote::COORDINATOR_PID as f64)
        });
        assert!(named, "remote process_name event missing");
        // Its train span landed on the same pid, clock-aligned
        // (offset 1000ns => start 2100ns => ts 2.1us).
        let span_ok = events.iter().any(|e| {
            e.get("name").and_then(|n| n.as_str()) == Some("train")
                && e.get("pid").and_then(|p| p.as_f64())
                    != Some(remote::COORDINATOR_PID as f64)
                && e.get("ts").and_then(|t| t.as_f64()) == Some(2.1)
        });
        assert!(span_ok, "aligned remote span missing");
        // And the stats dump carries its counter totals.
        let stats = back.get("afd_stats").unwrap();
        let rem = stats.get("remote").unwrap().get(&name).unwrap();
        assert_eq!(rem.get("spans").and_then(|s| s.as_f64()), Some(1.0));
    }

    #[test]
    #[cfg_attr(not(feature = "trace"), ignore = "needs the trace feature")]
    fn chrome_trace_contains_recorded_spans_and_tracks() {
        let _l = crate::obs::TEST_FLAG_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        crate::obs::set_enabled(true);
        {
            let _g = span::span_ab(Stage::CodecEncode, 1, 2);
        }
        {
            let _g = span::span_on_track(Stage::RoundTrip, CONN_TRACK_BASE + 3, 1, 2);
        }
        span::mark(Stage::RoundMark, 4, 2_000_000_000);
        crate::obs::set_enabled(false);

        let doc = chrome_trace_json();
        let text = doc.to_string_compact();
        let back = crate::util::json::parse(&text).unwrap();
        let events = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!events.is_empty());
        let names: Vec<&str> = events
            .iter()
            .filter_map(|e| e.get("name").and_then(|n| n.as_str()))
            .collect();
        assert!(names.contains(&"codec_encode"));
        assert!(names.contains(&"round_trip"));
        assert!(names.contains(&"round"));
        assert!(names.contains(&"thread_name"));
        // The synthetic connection track got named.
        let conn_named = events.iter().any(|e| {
            e.get("name").and_then(|n| n.as_str()) == Some("thread_name")
                && e.get("args")
                    .and_then(|a| a.get("name"))
                    .and_then(|n| n.as_str())
                    == Some("tcp-conn-3")
        });
        assert!(conn_named);
        // Every X event carries the required fields.
        for e in events {
            match e.get("ph").and_then(|p| p.as_str()) {
                Some("X") => {
                    for k in ["name", "cat", "ts", "dur", "pid", "tid"] {
                        assert!(e.get(k).is_some(), "X event missing {k}");
                    }
                }
                Some("M") | Some("i") => {}
                other => panic!("unexpected ph {other:?}"),
            }
        }
        assert!(back.get("afd_stats").is_some());
    }
}
