//! Span recorder: RAII guards writing fixed-size records into
//! preallocated per-thread ring buffers.
//!
//! ## Warm-path cost model
//!
//! Recording a span is: one relaxed load (the enable check), one
//! `Instant::now()` at open and one at close, six relaxed atomic
//! stores into the thread's ring slot, and a histogram bucket update.
//! No locks, no allocation — the ring (a fixed
//! [`RING_CAPACITY`]-slot array of atomics) is allocated once per
//! thread, on registration ([`register_thread`], called by the worker
//! pool at spawn) or lazily on the thread's first span. When a ring
//! wraps, the oldest records are overwritten and counted in
//! `dropped` — the trace keeps the most recent window, the
//! [`metrics`](super::metrics) totals keep the full run.
//!
//! ## Slot layout
//!
//! Each slot is five `AtomicU64`s (`meta` packs the stage tag and the
//! track override): single-writer (the owning thread), read by the
//! exporter after the run quiesces. Relaxed atomics keep the slots
//! safely shareable without a lock; torn *logical* records across the
//! wrap boundary are impossible for the exporter's post-run snapshot
//! because `head` is published with `Release` after the slot stores.
//!
//! ## Tracks
//!
//! A span normally lands on its recording thread's track (one Chrome
//! trace `tid` per registered thread). A nonzero `track` override
//! (≥ [`CONN_TRACK_BASE`]) pins it to a synthetic track instead — the
//! TCP transport uses one per connection, so per-connection
//! round-trips render as their own rows in Perfetto.

use std::cell::OnceCell;
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Slots per thread ring (~640 KiB of atomics per thread). Power of
/// two so the wrap modulo is a mask.
pub const RING_CAPACITY: usize = 16384;

/// Track ids at or above this are synthetic per-connection tracks
/// (`CONN_TRACK_BASE + conn_index`), not thread tracks.
pub const CONN_TRACK_BASE: u32 = 1_000_000;

/// Every instrumented pipeline stage. The wire-stable `u8` tag is the
/// ring-slot encoding; [`Stage::name`] is the Chrome trace event name.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Stage {
    /// Coordinator slices a client's epoch from the dataset.
    EpochAssembly = 0,
    /// Sub-model gather (`PackPlan::pack_into`) or raw uplink pack.
    Pack = 1,
    /// Sub-model scatter back onto a full vector (both directions).
    Unpack = 2,
    /// Dense downlink codec encode (`raw_f32` / `quant8`).
    CodecEncode = 3,
    /// Dense downlink codec decode.
    CodecDecode = 4,
    /// One local training epoch on a client.
    Train = 5,
    /// DGC momentum scan + top-k + sparse encode (uplink).
    DgcCompress = 6,
    /// One round's sharded FedAvg batch (reset + adds + finalize).
    ShardAggregate = 7,
    /// Framing a protocol message (header + payload + CRC).
    FrameEncode = 8,
    /// Parsing + validating a received frame.
    FrameParse = 9,
    /// One client's offer→update exchange through a `Transport`.
    RoundTrip = 10,
    /// Instant marker closing a round; `a` = round index, `b` = the
    /// scheduler's *virtual* clock in ns (simulated seconds × 1e9).
    RoundMark = 11,
    /// Instant: a fault fired; `a` = `fault::Site` discriminant, `b` =
    /// the site's first key (typically the round or client).
    FaultMark = 12,
    /// Instant: a client was quarantined; `a` = client, `b` = the
    /// fault count that tripped the threshold.
    QuarantineMark = 13,
    /// Instant: a coordinator checkpoint was written; `a` = round,
    /// `b` = checkpoint bytes.
    CheckpointMark = 14,
    /// Instant: the coordinator restored from a checkpoint; `a` = the
    /// restored round, `b` = 0.
    RestoreMark = 15,
    /// Instant: a client session resumed after a reconnect; `a` =
    /// connection slot, `b` = session token.
    ResumeMark = 16,
}

pub const STAGE_COUNT: usize = 17;

impl Stage {
    pub const ALL: [Stage; STAGE_COUNT] = [
        Stage::EpochAssembly,
        Stage::Pack,
        Stage::Unpack,
        Stage::CodecEncode,
        Stage::CodecDecode,
        Stage::Train,
        Stage::DgcCompress,
        Stage::ShardAggregate,
        Stage::FrameEncode,
        Stage::FrameParse,
        Stage::RoundTrip,
        Stage::RoundMark,
        Stage::FaultMark,
        Stage::QuarantineMark,
        Stage::CheckpointMark,
        Stage::RestoreMark,
        Stage::ResumeMark,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Stage::EpochAssembly => "epoch_assembly",
            Stage::Pack => "pack",
            Stage::Unpack => "unpack",
            Stage::CodecEncode => "codec_encode",
            Stage::CodecDecode => "codec_decode",
            Stage::Train => "train",
            Stage::DgcCompress => "dgc_compress",
            Stage::ShardAggregate => "shard_aggregate",
            Stage::FrameEncode => "frame_encode",
            Stage::FrameParse => "frame_parse",
            Stage::RoundTrip => "round_trip",
            Stage::RoundMark => "round",
            Stage::FaultMark => "fault",
            Stage::QuarantineMark => "quarantine",
            Stage::CheckpointMark => "checkpoint",
            Stage::RestoreMark => "restore",
            Stage::ResumeMark => "resume",
        }
    }

    pub fn from_u8(v: u8) -> Option<Stage> {
        Stage::ALL.get(v as usize).copied()
    }

    /// Instant-only stages: recorded via [`mark`] with zero duration,
    /// rendered as Chrome `"i"` events, and excluded from the
    /// duration-histogram stage table (they time nothing).
    pub fn is_instant(self) -> bool {
        matches!(
            self,
            Stage::RoundMark
                | Stage::FaultMark
                | Stage::QuarantineMark
                | Stage::CheckpointMark
                | Stage::RestoreMark
                | Stage::ResumeMark
        )
    }
}

// ---------------------------------------------------------------------
// Wall clock
// ---------------------------------------------------------------------

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Pin the trace epoch now (idempotent). `main` calls this early so
/// timestamps start near zero; otherwise the first span pins it.
pub fn pin_epoch() {
    let _ = EPOCH.get_or_init(Instant::now);
}

#[inline]
fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// The process-local monotonic trace clock (ns since the pinned
/// epoch). Public for the distributed-telemetry plane: a client ships
/// this reading in `Ready` and in every `Telemetry` frame, and the
/// coordinator subtracts it from its own reading to align the two
/// timelines (see `obs/remote.rs`).
#[inline]
pub fn monotonic_ns() -> u64 {
    now_ns()
}

// ---------------------------------------------------------------------
// Per-thread rings
// ---------------------------------------------------------------------

struct SpanSlot {
    /// `(track as u64) << 8 | stage as u64`.
    meta: AtomicU64,
    start_ns: AtomicU64,
    dur_ns: AtomicU64,
    a: AtomicU64,
    b: AtomicU64,
}

impl SpanSlot {
    const fn new() -> SpanSlot {
        SpanSlot {
            meta: AtomicU64::new(0),
            start_ns: AtomicU64::new(0),
            dur_ns: AtomicU64::new(0),
            a: AtomicU64::new(0),
            b: AtomicU64::new(0),
        }
    }
}

/// One thread's preallocated span ring. Single writer (the owning
/// thread); the exporter reads it through the shared registry after
/// the run quiesces.
pub struct ThreadRing {
    name: String,
    tid: u32,
    slots: Vec<SpanSlot>,
    /// Total records ever written (wraps the ring at `RING_CAPACITY`).
    head: AtomicUsize,
}

impl ThreadRing {
    pub fn tid(&self) -> u32 {
        self.tid
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Total records ever written (monotonic; wraps index the ring
    /// modulo [`RING_CAPACITY`]). `Acquire` pairs with the writer's
    /// `Release` publish.
    pub fn head(&self) -> usize {
        self.head.load(Ordering::Acquire)
    }

    /// Read one logical record (`i` counts from 0, monotonically, like
    /// [`ThreadRing::head`]): `(meta, start_ns, dur_ns, a, b)` where
    /// `meta = (track << 8) | stage`. Relaxed reads — a concurrently
    /// written slot can read torn, never unsafely (same contract as
    /// [`snapshot`]).
    pub fn read_raw(&self, i: usize) -> (u64, u64, u64, u64, u64) {
        let slot = &self.slots[i & (RING_CAPACITY - 1)];
        (
            slot.meta.load(Ordering::Relaxed),
            slot.start_ns.load(Ordering::Relaxed),
            slot.dur_ns.load(Ordering::Relaxed),
            slot.a.load(Ordering::Relaxed),
            slot.b.load(Ordering::Relaxed),
        )
    }

    #[inline]
    fn record(&self, stage: Stage, track: u32, start_ns: u64, dur_ns: u64, a: u64, b: u64) {
        let h = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[h & (RING_CAPACITY - 1)];
        slot.meta
            .store(((track as u64) << 8) | stage as u64, Ordering::Relaxed);
        slot.start_ns.store(start_ns, Ordering::Relaxed);
        slot.dur_ns.store(dur_ns, Ordering::Relaxed);
        slot.a.store(a, Ordering::Relaxed);
        slot.b.store(b, Ordering::Relaxed);
        self.head.store(h + 1, Ordering::Release);
    }
}

static REGISTRY: Mutex<Vec<Arc<ThreadRing>>> = Mutex::new(Vec::new());
static NEXT_TID: AtomicU32 = AtomicU32::new(0);

thread_local! {
    static LOCAL: OnceCell<Arc<ThreadRing>> = const { OnceCell::new() };
}

fn new_ring() -> Arc<ThreadRing> {
    let name = std::thread::current()
        .name()
        .unwrap_or("unnamed")
        .to_string();
    let mut slots = Vec::with_capacity(RING_CAPACITY);
    for _ in 0..RING_CAPACITY {
        slots.push(SpanSlot::new());
    }
    let ring = Arc::new(ThreadRing {
        name,
        tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
        slots,
        head: AtomicUsize::new(0),
    });
    REGISTRY
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .push(ring.clone());
    ring
}

/// Preallocate and register the calling thread's ring. The worker pool
/// calls this at spawn so even a worker's *first* span is
/// allocation-free; any unregistered thread self-registers on its
/// first span instead.
pub fn register_thread() {
    LOCAL.with(|c| {
        let _ = c.get_or_init(new_ring);
    });
}

#[inline]
fn record(stage: Stage, track: u32, start_ns: u64, dur_ns: u64, a: u64, b: u64) {
    LOCAL.with(|c| {
        c.get_or_init(new_ring)
            .record(stage, track, start_ns, dur_ns, a, b)
    });
}

// ---------------------------------------------------------------------
// RAII guards
// ---------------------------------------------------------------------

/// An open span; records on drop. Unarmed (free) when tracing is off.
pub struct SpanGuard {
    stage: Stage,
    track: u32,
    a: u64,
    b: u64,
    start_ns: u64,
    armed: bool,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let dur = now_ns().saturating_sub(self.start_ns);
        record(self.stage, self.track, self.start_ns, dur, self.a, self.b);
        super::metrics::stage_observe(self.stage, dur);
    }
}

#[inline]
fn open(stage: Stage, track: u32, a: u64, b: u64) -> SpanGuard {
    if !super::enabled() {
        return SpanGuard {
            stage,
            track: 0,
            a: 0,
            b: 0,
            start_ns: 0,
            armed: false,
        };
    }
    SpanGuard {
        stage,
        track,
        a,
        b,
        start_ns: now_ns(),
        armed: true,
    }
}

/// Open a span on the calling thread's track.
#[inline]
pub fn span(stage: Stage) -> SpanGuard {
    open(stage, 0, 0, 0)
}

/// Open a span carrying two stage-specific arguments (by convention
/// `a` = round, `b` = client, unless the stage says otherwise).
#[inline]
pub fn span_ab(stage: Stage, a: u64, b: u64) -> SpanGuard {
    open(stage, 0, a, b)
}

/// Open a span pinned to a synthetic track (per-TCP-connection rows;
/// pass `CONN_TRACK_BASE + conn_index`).
#[inline]
pub fn span_on_track(stage: Stage, track: u32, a: u64, b: u64) -> SpanGuard {
    open(stage, track, a, b)
}

/// Record an instant event (zero-duration span), e.g. a round marker.
#[inline]
pub fn mark(stage: Stage, a: u64, b: u64) {
    if !super::enabled() {
        return;
    }
    record(stage, 0, now_ns(), 0, a, b);
}

// ---------------------------------------------------------------------
// Snapshot (exporter side)
// ---------------------------------------------------------------------

/// One decoded span record.
#[derive(Clone, Debug)]
pub struct SpanRec {
    pub stage: Stage,
    /// 0 = the recording thread's track; ≥ [`CONN_TRACK_BASE`] = a
    /// synthetic per-connection track.
    pub track: u32,
    pub start_ns: u64,
    pub dur_ns: u64,
    pub a: u64,
    pub b: u64,
}

/// One thread's snapshot: its spans in chronological order (oldest
/// surviving record first) plus how many older records the ring
/// overwrote.
pub struct ThreadSpans {
    pub tid: u32,
    pub name: String,
    pub dropped: u64,
    pub spans: Vec<SpanRec>,
}

/// Copy every registered ring out. Meant for after the run quiesces
/// (the engine joins all fan-outs before the exporter runs); a record
/// being written concurrently could at worst read torn, never unsafe.
pub fn snapshot() -> Vec<ThreadSpans> {
    let rings: Vec<Arc<ThreadRing>> = REGISTRY
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .iter()
        .cloned()
        .collect();
    let mut out = Vec::with_capacity(rings.len());
    for ring in rings {
        let head = ring.head.load(Ordering::Acquire);
        let kept = head.min(RING_CAPACITY);
        let first = head - kept; // oldest surviving record index
        let mut spans = Vec::with_capacity(kept);
        for i in first..head {
            let slot = &ring.slots[i & (RING_CAPACITY - 1)];
            let meta = slot.meta.load(Ordering::Relaxed);
            let Some(stage) = Stage::from_u8((meta & 0xff) as u8) else {
                continue;
            };
            spans.push(SpanRec {
                stage,
                track: (meta >> 8) as u32,
                start_ns: slot.start_ns.load(Ordering::Relaxed),
                dur_ns: slot.dur_ns.load(Ordering::Relaxed),
                a: slot.a.load(Ordering::Relaxed),
                b: slot.b.load(Ordering::Relaxed),
            });
        }
        out.push(ThreadSpans {
            tid: ring.tid,
            name: ring.name.clone(),
            dropped: (head - kept) as u64,
            spans,
        });
    }
    out.sort_by_key(|t| t.tid);
    out
}

/// Visit every registered ring without copying it out — the telemetry
/// shipper walks rings in place so a warm snapshot encode allocates
/// nothing. The registry lock is held for the duration of the walk.
pub fn for_each_ring(mut f: impl FnMut(&ThreadRing)) {
    for ring in REGISTRY.lock().unwrap_or_else(|e| e.into_inner()).iter() {
        f(ring);
    }
}

/// Totals across every ring: `(recorded, dropped)` where `recorded`
/// counts the records currently held and `dropped` the older ones
/// each ring overwrote (`RING_CAPACITY` wraps). Allocation-free.
pub fn ring_totals() -> (u64, u64) {
    let (mut recorded, mut dropped) = (0u64, 0u64);
    for ring in REGISTRY.lock().unwrap_or_else(|e| e.into_inner()).iter() {
        let head = ring.head.load(Ordering::Acquire);
        let kept = head.min(RING_CAPACITY);
        recorded += kept as u64;
        dropped += (head - kept) as u64;
    }
    (recorded, dropped)
}

/// Rewind every ring (slots stay allocated; old records become
/// unreachable). Tests and back-to-back runs.
pub fn reset_rings() {
    for ring in REGISTRY.lock().unwrap_or_else(|e| e.into_inner()).iter() {
        ring.head.store(0, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_tags_roundtrip_and_names_are_unique() {
        for (i, s) in Stage::ALL.iter().enumerate() {
            assert_eq!(*s as usize, i);
            assert_eq!(Stage::from_u8(*s as u8), Some(*s));
        }
        assert_eq!(Stage::from_u8(STAGE_COUNT as u8), None);
        let mut names: Vec<&str> = Stage::ALL.iter().map(|s| s.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), STAGE_COUNT);
    }

    #[test]
    #[cfg_attr(not(feature = "trace"), ignore = "needs the trace feature")]
    fn guard_records_into_this_threads_ring() {
        let _l = crate::obs::TEST_FLAG_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        crate::obs::set_enabled(true);
        register_thread();
        let my_tid = LOCAL.with(|c| c.get_or_init(new_ring).tid);
        let before = snapshot()
            .into_iter()
            .find(|t| t.tid == my_tid)
            .map(|t| t.spans.len())
            .unwrap_or(0);
        {
            let _g = span_ab(Stage::Train, 3, 9);
        }
        mark(Stage::RoundMark, 7, 1_500_000_000);
        let mine = snapshot().into_iter().find(|t| t.tid == my_tid).unwrap();
        crate::obs::set_enabled(false);
        assert_eq!(mine.spans.len(), before + 2);
        let tr = &mine.spans[before];
        assert_eq!(tr.stage, Stage::Train);
        assert_eq!((tr.a, tr.b), (3, 9));
        let rm = &mine.spans[before + 1];
        assert_eq!(rm.stage, Stage::RoundMark);
        assert_eq!(rm.dur_ns, 0);
        assert!(rm.start_ns >= tr.start_ns);
    }

    #[test]
    #[cfg_attr(not(feature = "trace"), ignore = "needs the trace feature")]
    fn ring_wraps_and_counts_dropped() {
        let _l = crate::obs::TEST_FLAG_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        crate::obs::set_enabled(true);
        register_thread();
        let my_tid = LOCAL.with(|c| c.get_or_init(new_ring).tid);
        // This test owns its thread's ring outright, so rewinding it
        // here cannot race another test.
        LOCAL.with(|c| c.get_or_init(new_ring).head.store(0, Ordering::Release));
        for i in 0..(RING_CAPACITY + 10) {
            mark(Stage::Pack, i as u64, 0);
        }
        crate::obs::set_enabled(false);
        let mine = snapshot().into_iter().find(|t| t.tid == my_tid).unwrap();
        assert_eq!(mine.spans.len(), RING_CAPACITY);
        assert_eq!(mine.dropped, 10);
        // Oldest surviving record is the 11th ever written.
        assert_eq!(mine.spans[0].a, 10);
        assert_eq!(mine.spans.last().unwrap().a, (RING_CAPACITY + 9) as u64);
    }

    #[test]
    fn disabled_guard_is_inert() {
        let _l = crate::obs::TEST_FLAG_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        crate::obs::set_enabled(false);
        let g = span(Stage::Train);
        assert!(!g.armed);
    }
}
