//! The distributed telemetry plane: client-side snapshot shipping and
//! coordinator-side merging, plus the live stats endpoint.
//!
//! Since the federation became multi-process, every remote `afd
//! client` recorded spans, counters and histograms that died inside
//! its own process. This module closes the loop:
//!
//! * **[`Shipper`]** (client side) encodes incremental snapshots —
//!   new span-ring records, counter/gauge deltas, stage-histogram
//!   deltas — into `Telemetry` frames, piggybacked after `UpdateUp`
//!   at round boundaries. Every buffer is preallocated, so a warm
//!   snapshot encode makes zero heap allocations (the telemetry-armed
//!   row of `tests/zero_alloc.rs`).
//! * **The merge registry** (coordinator side) assigns each remote
//!   process its own Chrome-trace `pid`, accumulates its counter
//!   totals, and realigns its span timestamps onto the coordinator's
//!   monotonic clock so one trace covers the whole federation.
//! * **[`spawn_metrics_server`]** serves a Prometheus-style text
//!   exposition (`GET /metrics`) and a machine-readable JSON snapshot
//!   (`GET /snapshot`) from a background thread, so a running
//!   federation can be watched mid-flight (`afd serve
//!   --metrics-addr`).
//!
//! ## Clock alignment
//!
//! Each process timestamps spans against its own pinned monotonic
//! epoch, so remote readings are meaningless on the coordinator's
//! axis until shifted by a per-process offset. Two sources feed the
//! estimate, both of the form `offset = coordinator_now − remote_now`
//! sampled when a frame carrying `remote_now` arrives:
//!
//! 1. **Handshake**: `Ready` carries the client's clock; the first
//!    sample seeds the offset.
//! 2. **Round anchors**: every `Telemetry` frame carries a fresh
//!    reading; since network latency only ever *inflates* a sample
//!    (the coordinator reads its clock strictly after the remote
//!    read), the running **minimum** over samples converges onto the
//!    true offset from above. Alignment error is bounded by the best
//!    one-way latency ever observed.
//!
//! Offsets can be negative (a client that pinned its epoch before the
//! coordinator); aligned timestamps clamp at zero.
//!
//! ## Byte accounting
//!
//! Telemetry is a pure side channel: its wire bytes land in
//! `TELEMETRY_BYTES` (like `RESYNC_BYTES`), never in
//! `RoundRecord::{down,up}_bytes` — a telemetry-armed fixed-seed run
//! is byte-identical (JSONL + model hash) to a telemetry-off run
//! (`tests/obs_distributed.rs`).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Mutex;
use std::time::Duration;

use super::metrics::{self, HIST_BUCKETS};
use super::span::{self, Stage, RING_CAPACITY, STAGE_COUNT};
use crate::transport::frame;

// ---------------------------------------------------------------------
// Client side: the shipper
// ---------------------------------------------------------------------

/// Incremental telemetry snapshot encoder for one process. Owns the
/// "what did I already ship" cursors: per-ring heads, per-counter and
/// per-gauge last values, per-stage histogram bucket occupancies. All
/// state is preallocated at construction; [`Shipper::encode_into`] on
/// a warm sink allocates nothing.
pub struct Shipper {
    ring_heads: Vec<(u32, usize)>,
    last_counters: Vec<u64>,
    last_gauges: Vec<u64>,
    last_hist_count: Vec<u64>,
    last_hist_sum: Vec<u64>,
    last_hist_buckets: Vec<u64>,
}

impl Default for Shipper {
    fn default() -> Shipper {
        Shipper::new()
    }
}

impl Shipper {
    pub fn new() -> Shipper {
        Shipper {
            ring_heads: Vec::with_capacity(64),
            last_counters: vec![0; metrics::WIRE_COUNTERS.len()],
            last_gauges: vec![0; metrics::WIRE_GAUGES.len()],
            last_hist_count: vec![0; STAGE_COUNT],
            last_hist_sum: vec![0; STAGE_COUNT],
            last_hist_buckets: vec![0; STAGE_COUNT * HIST_BUCKETS],
        }
    }

    /// Encode one incremental snapshot as a complete `Telemetry` frame
    /// appended to `out` (not cleared). Ships only what is new since
    /// the previous call; a quiet process encodes four zero counts
    /// (40 bytes on the wire).
    pub fn encode_into(&mut self, out: &mut Vec<u8>, round: u32) {
        let now = span::monotonic_ns();
        let mut enc = frame::TelemetryEncoder::begin(out, round, now);

        enc.begin_threads();
        let mut threads = 0usize;
        span::for_each_ring(|ring| {
            if threads >= frame::MAX_TELEMETRY_THREADS {
                return;
            }
            let tid = ring.tid();
            let head = ring.head();
            let last = match self.ring_heads.iter_mut().find(|(t, _)| *t == tid) {
                Some(s) => s,
                None => {
                    self.ring_heads.push((tid, 0));
                    self.ring_heads.last_mut().unwrap()
                }
            };
            if head <= last.1 {
                // Nothing new (a rewound ring after obs::reset starts
                // a fresh cursor).
                if head < last.1 {
                    last.1 = head;
                }
                return;
            }
            // Oldest record still in the ring, and the cap on how many
            // we put in one frame; everything older ships as drops.
            let surviving = head.saturating_sub(RING_CAPACITY).max(last.1);
            let from = head - (head - surviving).min(frame::MAX_TELEMETRY_SPANS);
            let dropped = (from - last.1) as u64;
            enc.begin_thread(tid, ring.name(), dropped);
            threads += 1;
            for i in from..head {
                let (meta, start_ns, dur_ns, a, b) = ring.read_raw(i);
                let stage = (meta & 0xff) as u8;
                if stage as usize >= STAGE_COUNT {
                    continue;
                }
                enc.span(stage, (meta >> 8) as u32, start_ns, dur_ns, a, b);
            }
            last.1 = head;
        });
        enc.end_threads();

        enc.begin_counters();
        for (i, (_, c)) in metrics::WIRE_COUNTERS.iter().enumerate() {
            let v = c.get();
            let d = v.saturating_sub(self.last_counters[i]);
            if d != 0 || v < self.last_counters[i] {
                enc.counter(i as u8, d);
            }
            self.last_counters[i] = v;
        }
        enc.end_counters();

        enc.begin_gauges();
        for (i, (_, g)) in metrics::WIRE_GAUGES.iter().enumerate() {
            let v = g.get();
            if v != self.last_gauges[i] {
                enc.gauge(i as u8, v);
                self.last_gauges[i] = v;
            }
        }
        enc.end_gauges();

        enc.begin_hists();
        for s in 0..STAGE_COUNT {
            let h = &metrics::STAGE_NS[s];
            let count = h.count();
            let d_count = count.saturating_sub(self.last_hist_count[s]);
            if d_count == 0 {
                self.last_hist_count[s] = count;
                continue;
            }
            let sum = h.sum();
            enc.begin_hist(
                s as u8,
                d_count,
                sum.saturating_sub(self.last_hist_sum[s]),
            );
            self.last_hist_count[s] = count;
            self.last_hist_sum[s] = sum;
            for bkt in 0..HIST_BUCKETS {
                let v = h.bucket_count(bkt);
                let at = s * HIST_BUCKETS + bkt;
                let d = v.saturating_sub(self.last_hist_buckets[at]);
                if d != 0 {
                    enc.bucket(bkt as u8, d);
                }
                self.last_hist_buckets[at] = v;
            }
        }
        enc.end_hists();
        enc.finish();
    }
}

// ---------------------------------------------------------------------
// Coordinator side: the merge registry
// ---------------------------------------------------------------------

/// Spans stored per remote process before the exporter runs; beyond
/// this the oldest stay and later arrivals count as
/// `TELEMETRY_SPANS_DROPPED`.
pub const REMOTE_SPAN_CAP: usize = 65536;

/// Chrome-trace `pid` of the coordinator process itself; remote
/// processes get `FIRST_REMOTE_PID + index`.
pub const COORDINATOR_PID: u32 = 1;
pub const FIRST_REMOTE_PID: u32 = 2;

/// One span shipped by a remote process, timestamps still on the
/// *remote* clock (aligned at export via the process offset).
#[derive(Clone, Debug)]
pub struct RemoteSpan {
    pub tid: u32,
    pub stage: Stage,
    pub track: u32,
    pub start_ns: u64,
    pub dur_ns: u64,
    pub a: u64,
    pub b: u64,
}

/// One remote process's merged telemetry.
pub struct RemoteProc {
    pub name: String,
    /// `coordinator_ns ≈ remote_ns + offset_ns` (see module docs).
    pub offset_ns: i64,
    anchored: bool,
    /// `(tid, thread name, ring drops reported by the remote)`.
    pub threads: Vec<(u32, String, u64)>,
    pub spans: Vec<RemoteSpan>,
    /// Spans discarded at [`REMOTE_SPAN_CAP`].
    pub spans_dropped: u64,
    /// Totals per [`metrics::WIRE_COUNTERS`] id.
    pub counters: Vec<u64>,
    /// Latest per [`metrics::WIRE_GAUGES`] id (peaks ship as peaks).
    pub gauges: Vec<u64>,
    /// Per-stage histogram totals (count, sum ns).
    pub hist_count: Vec<u64>,
    pub hist_sum: Vec<u64>,
    /// Telemetry frames merged from this process.
    pub frames: u64,
}

impl RemoteProc {
    fn new(name: String) -> RemoteProc {
        RemoteProc {
            name,
            offset_ns: 0,
            anchored: false,
            threads: Vec::new(),
            spans: Vec::new(),
            spans_dropped: 0,
            counters: vec![0; metrics::WIRE_COUNTERS.len()],
            gauges: vec![0; metrics::WIRE_GAUGES.len()],
            hist_count: vec![0; STAGE_COUNT],
            hist_sum: vec![0; STAGE_COUNT],
            frames: 0,
        }
    }

    /// Shift a remote clock reading onto the coordinator timeline.
    pub fn aligned_ns(&self, remote_ns: u64) -> u64 {
        (remote_ns as i64).saturating_add(self.offset_ns).max(0) as u64
    }

    /// Chrome-trace pid for remote process index `idx`.
    pub fn pid_for(idx: usize) -> u32 {
        FIRST_REMOTE_PID + idx as u32
    }

    fn anchor(&mut self, remote_now_ns: u64, coord_now_ns: u64) {
        let sample = (coord_now_ns as i64).saturating_sub(remote_now_ns as i64);
        if !self.anchored {
            self.offset_ns = sample;
            self.anchored = true;
        } else {
            // Latency only inflates samples; the minimum is tightest.
            self.offset_ns = self.offset_ns.min(sample);
        }
    }
}

static REMOTES: Mutex<Vec<RemoteProc>> = Mutex::new(Vec::new());

fn remotes() -> std::sync::MutexGuard<'static, Vec<RemoteProc>> {
    REMOTES.lock().unwrap_or_else(|e| e.into_inner())
}

/// Register (or look up) a remote process by name and return its
/// index. A reconnecting process re-registers under the same name and
/// keeps its track and totals.
pub fn register(name: &str) -> usize {
    let mut r = remotes();
    if let Some(i) = r.iter().position(|p| p.name == name) {
        return i;
    }
    r.push(RemoteProc::new(name.to_string()));
    r.len() - 1
}

/// Feed one clock-offset sample for process `id` (handshake-time
/// exchange): `remote_now_ns` is the reading the remote sent,
/// sampled against the coordinator clock now.
pub fn anchor(id: usize, remote_now_ns: u64) {
    anchor_at(id, remote_now_ns, span::monotonic_ns());
}

/// Deterministic core of [`anchor`], split out for tests.
pub fn anchor_at(id: usize, remote_now_ns: u64, coord_now_ns: u64) {
    let mut r = remotes();
    if let Some(p) = r.get_mut(id) {
        p.anchor(remote_now_ns, coord_now_ns);
    }
}

/// Merge one parsed `Telemetry` frame into process `id`: refine the
/// clock offset with the frame's anchor, accumulate counter/gauge and
/// histogram deltas, and append new spans (bounded by
/// [`REMOTE_SPAN_CAP`]).
pub fn ingest(id: usize, msg: &frame::TelemetryMsg) {
    ingest_at(id, msg, span::monotonic_ns());
}

/// Deterministic core of [`ingest`], split out for tests.
pub fn ingest_at(id: usize, msg: &frame::TelemetryMsg, coord_now_ns: u64) {
    let mut r = remotes();
    let Some(p) = r.get_mut(id) else {
        return;
    };
    p.anchor(msg.sender_now_ns, coord_now_ns);
    p.frames += 1;
    metrics::TELEMETRY_FRAMES.incr();
    for t in &msg.threads {
        match p.threads.iter_mut().find(|(tid, _, _)| *tid == t.tid) {
            Some(entry) => {
                entry.2 += t.dropped;
                if entry.1 != t.name {
                    entry.1 = t.name.clone();
                }
            }
            None => p.threads.push((t.tid, t.name.clone(), t.dropped)),
        }
        for s in &t.spans {
            if p.spans.len() >= REMOTE_SPAN_CAP {
                p.spans_dropped += 1;
                metrics::TELEMETRY_SPANS_DROPPED.incr();
                continue;
            }
            let Some(stage) = Stage::from_u8(s.stage) else {
                continue;
            };
            p.spans.push(RemoteSpan {
                tid: t.tid,
                stage,
                track: s.track,
                start_ns: s.start_ns,
                dur_ns: s.dur_ns,
                a: s.a,
                b: s.b,
            });
        }
    }
    for &(cid, delta) in &msg.counters {
        if let Some(slot) = p.counters.get_mut(cid as usize) {
            *slot = slot.saturating_add(delta);
        }
    }
    for &(gid, value) in &msg.gauges {
        if let Some(slot) = p.gauges.get_mut(gid as usize) {
            *slot = (*slot).max(value);
        }
    }
    for h in &msg.hists {
        let s = h.stage as usize;
        if s < STAGE_COUNT {
            p.hist_count[s] = p.hist_count[s].saturating_add(h.d_count);
            p.hist_sum[s] = p.hist_sum[s].saturating_add(h.d_sum);
        }
    }
}

/// Run `f` over the merged remote processes (export side).
pub fn with_remotes<R>(f: impl FnOnce(&[RemoteProc]) -> R) -> R {
    f(&remotes())
}

/// Number of registered remote processes.
pub fn remote_count() -> usize {
    remotes().len()
}

/// Forget every remote process (tests and back-to-back runs; called
/// by [`crate::obs::reset`]).
pub fn reset() {
    remotes().clear();
}

// ---------------------------------------------------------------------
// Live stats endpoint
// ---------------------------------------------------------------------

/// Bind `addr` and serve the live stats endpoint from a background
/// thread: `GET /metrics` returns a Prometheus-style text exposition,
/// `GET /snapshot` (or any other path) the full machine-readable JSON
/// stats dump (the same document `--stats-out` writes, plus the
/// current round). Returns the bound address (pass port 0 for an
/// ephemeral one). The thread serves until the process exits.
pub fn spawn_metrics_server(addr: &str) -> std::io::Result<SocketAddr> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    std::thread::Builder::new()
        .name("afd-metrics".into())
        .spawn(move || {
            for stream in listener.incoming() {
                let Ok(mut s) = stream else { continue };
                let _ = serve_one(&mut s);
            }
        })?;
    Ok(local)
}

fn serve_one(s: &mut TcpStream) -> std::io::Result<()> {
    s.set_read_timeout(Some(Duration::from_millis(500)))?;
    s.set_write_timeout(Some(Duration::from_millis(2000)))?;
    let mut buf = [0u8; 2048];
    let n = s.read(&mut buf).unwrap_or(0);
    let head = String::from_utf8_lossy(&buf[..n]);
    let path = head
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .unwrap_or("/metrics")
        .to_string();
    let (ctype, body) = if path.starts_with("/metrics") {
        ("text/plain; version=0.0.4", prometheus_text())
    } else {
        ("application/json", super::export::stats_json().to_string_compact())
    };
    write!(
        s,
        "HTTP/1.1 200 OK\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    s.write_all(body.as_bytes())
}

/// Render the Prometheus text exposition: every wire counter and
/// gauge, the live round, telemetry side-channel totals, per-stage
/// p50/p99/count/sum from `STAGE_NS`, and the remote process count.
pub fn prometheus_text() -> String {
    use std::fmt::Write as _;
    let mut out = String::with_capacity(4096);
    for (name, c) in metrics::WIRE_COUNTERS.iter() {
        let _ = writeln!(out, "# TYPE afd_{name} counter\nafd_{name} {}", c.get());
    }
    for (name, g) in metrics::WIRE_GAUGES.iter() {
        let _ = writeln!(out, "# TYPE afd_{name} gauge\nafd_{name} {}", g.get());
    }
    let _ = writeln!(
        out,
        "# TYPE afd_round gauge\nafd_round {}",
        metrics::CURRENT_ROUND.get()
    );
    for (name, v) in [
        ("telemetry_bytes", metrics::TELEMETRY_BYTES.get()),
        ("telemetry_frames", metrics::TELEMETRY_FRAMES.get()),
        (
            "telemetry_spans_dropped",
            metrics::TELEMETRY_SPANS_DROPPED.get(),
        ),
    ] {
        let _ = writeln!(out, "# TYPE afd_{name} counter\nafd_{name} {v}");
    }
    let _ = writeln!(out, "# TYPE afd_stage_ns summary");
    for stage in Stage::ALL.iter().filter(|s| !s.is_instant()) {
        let h = &metrics::STAGE_NS[*stage as usize];
        if h.count() == 0 {
            continue;
        }
        let name = stage.name();
        let _ = writeln!(
            out,
            "afd_stage_ns{{stage=\"{name}\",quantile=\"0.5\"}} {}",
            h.quantile(0.5)
        );
        let _ = writeln!(
            out,
            "afd_stage_ns{{stage=\"{name}\",quantile=\"0.99\"}} {}",
            h.quantile(0.99)
        );
        let _ = writeln!(out, "afd_stage_ns_sum{{stage=\"{name}\"}} {}", h.sum());
        let _ = writeln!(out, "afd_stage_ns_count{{stage=\"{name}\"}} {}", h.count());
    }
    let _ = writeln!(
        out,
        "# TYPE afd_remote_processes gauge\nafd_remote_processes {}",
        remote_count()
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unique_name(tag: &str) -> String {
        // Names key the registry; keep tests independent of each other
        // even though they share the process-global state.
        use std::sync::atomic::{AtomicU32, Ordering};
        static N: AtomicU32 = AtomicU32::new(0);
        format!("test-proc-{tag}-{}", N.fetch_add(1, Ordering::Relaxed))
    }

    #[test]
    fn offset_estimate_is_min_over_samples() {
        let id = register(&unique_name("offset"));
        anchor_at(id, 1_000, 1_500); // +500 (handshake, latency-inflated)
        anchor_at(id, 2_000, 2_120); // +120 (tighter round anchor)
        anchor_at(id, 3_000, 3_400); // +400 (slow sample; ignored)
        with_remotes(|procs| {
            let p = &procs[id];
            assert_eq!(p.offset_ns, 120);
            assert_eq!(p.aligned_ns(2_000), 2_120);
        });
    }

    #[test]
    fn negative_offsets_align_and_clamp() {
        let id = register(&unique_name("negative"));
        anchor_at(id, 10_000, 4_000); // remote epoch pinned first
        with_remotes(|procs| {
            let p = &procs[id];
            assert_eq!(p.offset_ns, -6_000);
            assert_eq!(p.aligned_ns(10_500), 4_500);
            assert_eq!(p.aligned_ns(1_000), 0); // clamped
        });
    }

    #[test]
    fn register_is_idempotent_by_name() {
        let name = unique_name("idem");
        let a = register(&name);
        let b = register(&name);
        assert_eq!(a, b);
    }

    #[test]
    fn ingest_merges_counters_spans_and_hists() {
        let id = register(&unique_name("ingest"));
        let mut out = Vec::new();
        let mut enc = frame::TelemetryEncoder::begin(&mut out, 3, 500);
        enc.begin_threads();
        enc.begin_thread(0, "main", 2);
        enc.span(Stage::Train as u8, 0, 100, 50, 3, 9);
        enc.span(Stage::FaultMark as u8, 0, 160, 0, 1, 7);
        enc.end_threads();
        enc.begin_counters();
        enc.counter(11, 5); // rounds_completed
        enc.end_counters();
        enc.begin_gauges();
        enc.gauge(0, 4);
        enc.end_gauges();
        enc.begin_hists();
        enc.begin_hist(Stage::Train as u8, 1, 50);
        enc.bucket(6, 1);
        enc.end_hists();
        enc.finish();
        let (view, _) = frame::parse_frame(&out).unwrap();
        let msg = frame::parse_telemetry(&view).unwrap();

        ingest_at(id, &msg, 800); // offset = 300
        ingest_at(id, &msg, 700); // offset min → 200; totals double
        with_remotes(|procs| {
            let p = &procs[id];
            assert_eq!(p.offset_ns, 200);
            assert_eq!(p.frames, 2);
            assert_eq!(p.threads, vec![(0, "main".to_string(), 4)]);
            assert_eq!(p.spans.len(), 4);
            assert_eq!(p.spans[0].stage, Stage::Train);
            assert_eq!(p.aligned_ns(p.spans[0].start_ns), 300);
            assert_eq!(p.spans[1].stage, Stage::FaultMark);
            assert_eq!(p.counters[11], 10);
            assert_eq!(p.gauges[0], 4);
            assert_eq!(p.hist_count[Stage::Train as usize], 2);
            assert_eq!(p.hist_sum[Stage::Train as usize], 100);
        });
    }

    #[test]
    fn shipper_ships_deltas_not_totals() {
        let mut sh = Shipper::new();
        let mut out = Vec::new();
        sh.encode_into(&mut out, 1);
        let (view, used) = frame::parse_frame(&out).unwrap();
        assert_eq!(used, out.len());
        let first = frame::parse_telemetry(&view).unwrap();
        assert_eq!(first.round, 1);
        // Immediately shipping again: ring cursors and counter
        // baselines advanced, so the second frame carries no spans for
        // already-shipped records.
        let mark = out.len();
        sh.encode_into(&mut out, 2);
        let (view, _) = frame::parse_frame(&out[mark..]).unwrap();
        let second = frame::parse_telemetry(&view).unwrap();
        assert_eq!(second.round, 2);
        for t in &second.threads {
            assert!(
                t.spans.is_empty() || t.spans.len() < RING_CAPACITY,
                "re-ship must not resend full rings"
            );
        }
    }

    #[test]
    fn metrics_endpoint_serves_prometheus_and_json() {
        let addr = spawn_metrics_server("127.0.0.1:0").expect("bind");
        for (path, needle) in [
            ("/metrics", "# TYPE afd_rounds_completed counter"),
            ("/snapshot", "\"counters\""),
        ] {
            let mut s = TcpStream::connect(addr).expect("connect");
            write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
            let mut body = String::new();
            s.read_to_string(&mut body).expect("read");
            assert!(body.starts_with("HTTP/1.1 200 OK\r\n"), "{body}");
            assert!(body.contains(needle), "{path} missing {needle}: {body}");
        }
    }

    #[test]
    fn prometheus_text_is_line_shaped() {
        metrics::ROUNDS_COMPLETED.add(0);
        let text = prometheus_text();
        assert!(text.contains("# TYPE afd_round gauge"));
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let mut parts = line.split_whitespace();
            let (name, value) = (parts.next().unwrap(), parts.next().unwrap());
            assert!(name.starts_with("afd_"), "{line}");
            assert!(value.parse::<f64>().is_ok(), "{line}");
            assert!(parts.next().is_none(), "{line}");
        }
    }
}
