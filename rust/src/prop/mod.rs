//! Minimal property-testing substrate (no `proptest` offline).
//!
//! `check` runs a property over `cases` generated inputs; on failure it
//! re-runs a bounded shrink loop (halving sizes via the generator's own
//! `shrink`) and reports the smallest failing seed + case so failures
//! are reproducible (`AFD_PROP_SEED=<n>` re-runs a specific seed).

use crate::util::rng::Pcg64;

/// A generator of random test cases with optional shrinking.
pub trait Gen {
    type Output;
    fn generate(&self, rng: &mut Pcg64) -> Self::Output;
    /// Candidate smaller versions of a failing case (default: none).
    fn shrink(&self, _case: &Self::Output) -> Vec<Self::Output> {
        Vec::new()
    }
}

/// Run `prop` on `cases` random inputs. Panics with the seed and the
/// (possibly shrunk) counterexample on failure.
pub fn check<G, F>(name: &str, gen: &G, cases: usize, prop: F)
where
    G: Gen,
    G::Output: std::fmt::Debug,
    F: Fn(&G::Output) -> Result<(), String>,
{
    let base_seed = std::env::var("AFD_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok());
    let seeds: Vec<u64> = match base_seed {
        Some(s) => vec![s],
        None => (0..cases as u64).collect(),
    };
    for seed in seeds {
        let mut rng = Pcg64::with_stream(seed, 0x9409);
        let case = gen.generate(&mut rng);
        if let Err(msg) = prop(&case) {
            // Shrink loop: greedily accept any smaller failing case.
            let mut best = case;
            let mut best_msg = msg;
            let mut budget = 200;
            loop {
                let mut advanced = false;
                for cand in gen.shrink(&best) {
                    budget -= 1;
                    if budget == 0 {
                        break;
                    }
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        advanced = true;
                        break;
                    }
                }
                if !advanced || budget == 0 {
                    break;
                }
            }
            panic!(
                "property {name:?} failed (seed {seed}, rerun with \
                 AFD_PROP_SEED={seed}):\n  case: {best:?}\n  error: {best_msg}"
            );
        }
    }
}

/// Generator combinators ------------------------------------------------

/// Uniform usize in [lo, hi].
pub struct UsizeIn(pub usize, pub usize);

impl Gen for UsizeIn {
    type Output = usize;

    fn generate(&self, rng: &mut Pcg64) -> usize {
        self.0 + rng.below((self.1 - self.0 + 1) as u64) as usize
    }

    fn shrink(&self, case: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *case > self.0 {
            out.push(self.0);
            out.push(self.0 + (case - self.0) / 2);
        }
        out.dedup();
        out
    }
}

/// Vec<f32> of random length with N(0, sigma) entries.
pub struct F32Vec {
    pub min_len: usize,
    pub max_len: usize,
    pub sigma: f32,
}

impl Gen for F32Vec {
    type Output = Vec<f32>;

    fn generate(&self, rng: &mut Pcg64) -> Vec<f32> {
        let n = self.min_len + rng.below((self.max_len - self.min_len + 1) as u64) as usize;
        (0..n).map(|_| rng.normal_f32(0.0, self.sigma)).collect()
    }

    fn shrink(&self, case: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if case.len() > self.min_len {
            let half = self.min_len.max(case.len() / 2);
            out.push(case[..half].to_vec());
        }
        // Also try zeroing the tail (often isolates the failing value).
        if case.iter().any(|&v| v != 0.0) {
            let mut z = case.clone();
            let n = z.len();
            for v in &mut z[n / 2..] {
                *v = 0.0;
            }
            out.push(z);
        }
        out
    }
}

/// Pair generator.
pub struct Pair<A, B>(pub A, pub B);

impl<A, B> Gen for Pair<A, B>
where
    A: Gen,
    B: Gen,
    A::Output: Clone,
    B::Output: Clone,
{
    type Output = (A::Output, B::Output);

    fn generate(&self, rng: &mut Pcg64) -> Self::Output {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, case: &Self::Output) -> Vec<Self::Output> {
        let mut out: Vec<Self::Output> = self
            .0
            .shrink(&case.0)
            .into_iter()
            .map(|a| (a, case.1.clone()))
            .collect();
        out.extend(
            self.1
                .shrink(&case.1)
                .into_iter()
                .map(|b| (case.0.clone(), b)),
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_quiet() {
        check("len in range", &UsizeIn(3, 9), 50, |&n| {
            if (3..=9).contains(&n) {
                Ok(())
            } else {
                Err(format!("{n} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        check("always fails", &UsizeIn(0, 10), 5, |_| Err("nope".into()));
    }

    #[test]
    fn f32vec_respects_bounds() {
        let gen = F32Vec {
            min_len: 2,
            max_len: 40,
            sigma: 1.0,
        };
        check("vec len", &gen, 40, |v| {
            if (2..=40).contains(&v.len()) {
                Ok(())
            } else {
                Err(format!("len {}", v.len()))
            }
        });
    }

    #[test]
    #[should_panic]
    fn shrinking_finds_smaller_case() {
        // Fails for any vec of length ≥ 4; the shrinker should reach a
        // small one (we can't capture the panic message easily here, so
        // just verify it panics — shrink exercised on the way).
        let gen = F32Vec {
            min_len: 1,
            max_len: 64,
            sigma: 1.0,
        };
        check("short only", &gen, 30, |v| {
            if v.len() < 4 {
                Ok(())
            } else {
                Err("too long".into())
            }
        });
    }

    #[test]
    fn pair_generates_both() {
        let gen = Pair(UsizeIn(1, 5), UsizeIn(10, 20));
        check("pair ranges", &gen, 30, |&(a, b)| {
            if (1..=5).contains(&a) && (10..=20).contains(&b) {
                Ok(())
            } else {
                Err(format!("({a},{b})"))
            }
        });
    }
}
