//! `afd` — launcher CLI for the Adaptive Federated Dropout system.
//!
//! Subcommands:
//!   train     run one federated experiment (preset + overrides)
//!   compare   run the paper's 4-method grid on one preset
//!   serve     run the coordinator over a real transport (TCP or
//!             in-process loopback) and print the final model hash
//!   client    join a coordinator as a remote client process
//!   inspect   print the artifacts manifest summary
//!   selftest  artifact-free native end-to-end smoke
//!
//! Examples:
//!   afd train --preset femnist_noniid --rounds 120 --seeds 3
//!   afd train --preset native --dropout afd_single
//!   afd compare --preset femnist_noniid --rounds 80 --target 0.70
//!   afd serve --preset native --rounds 10 --conns 2 --addr 127.0.0.1:4777
//!   afd client --connect 127.0.0.1:4777        # run one (or more) of these
//!   afd serve --preset native --rounds 10 --conns 0   # same run, loopback
//!   afd inspect

use std::sync::Arc;

use anyhow::Result;

use afd::config::{Backend, ExperimentConfig};
use afd::coordinator::experiment::{artifacts_dir, run_experiment, Experiment};
use afd::metrics::{render_table, summarize, ExperimentReport};
use afd::transport::tcp::{run_client_loop, ClientEnd, ClientOptions, TcpServer, TcpTransport};
use afd::transport::{Loopback, Transport};
use afd::util::cli::ArgSpec;
use afd::util::json::Json;
use afd::util::logging;

fn main() {
    logging::init_from_env();
    // Honors AFD_TRACE=1 (remote client processes) and pins the span
    // clock epoch before any thread can race it.
    afd::obs::init_from_env();
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = if argv.is_empty() {
        "help".to_string()
    } else {
        argv.remove(0)
    };
    let result = match cmd.as_str() {
        "train" => cmd_train(argv),
        "compare" => cmd_compare(argv),
        "serve" => cmd_serve(argv),
        "client" => cmd_client(argv),
        "inspect" => cmd_inspect(),
        "selftest" => cmd_selftest(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "afd — Adaptive Federated Dropout (paper reproduction)\n\n\
         Usage: afd <command> [flags]\n\n\
         Commands:\n\
           train     run one federated experiment\n\
           compare   run the paper's No-Compression/DGC/FD+DGC/AFD+DGC grid\n\
           serve     coordinator over a real transport: accept --conns TCP\n\
                     client processes (0 = in-process loopback) and print\n\
                     the final model hash for bit-identity checks\n\
           client    join an `afd serve` coordinator over TCP; the server\n\
                     ships the config, this process rebuilds the fleet and\n\
                     trains the rounds it is offered\n\
           inspect   summarize artifacts/manifest.json\n\
           selftest  artifact-free native end-to-end smoke\n\n\
         Run `afd <command> --help` for flags."
    );
}

fn experiment_spec() -> ArgSpec {
    ArgSpec::new("Run a federated AFD experiment")
        .opt("preset", "femnist_noniid",
             "femnist_noniid|shakespeare_noniid|sent140_noniid|femnist_iid|shakespeare_iid|sent140_iid|native|native_population")
        .opt_maybe("rounds", "total federated rounds")
        .opt_maybe("clients", "client population size")
        .opt_maybe("fraction", "fraction of clients per round")
        .opt_maybe("dropout", "none|fd|afd_multi|afd_single")
        .opt_maybe("fdr", "federated dropout rate (0..1)")
        .opt_maybe("downlink", "raw|quant8")
        .opt_maybe("dgc", "true|false: DGC on the uplink")
        .opt_maybe("sched", "sync|overselect|async_buffered: round scheduler policy")
        .opt_maybe("churn", "client availability in (0,1]: enables on/off churn")
        .opt_maybe("shards", "aggregation shards (0 = auto: pool width, >=16k params/shard)")
        .opt_maybe("agg-tree-levels", "hierarchical aggregation depth (1 = flat, >=2 = tree)")
        .opt_maybe("agg-tree-fanout", "children per hierarchical aggregation node")
        .opt_maybe("population-lazy", "true|false: derive clients lazily from (seed, id)")
        .opt_maybe("store-budget-bytes", "residual-store byte budget (0 = unbounded)")
        .opt_maybe("spill-dir", "directory for the residual-store spill file")
        .opt_maybe("lr", "override the manifest learning rate")
        .opt_maybe("seed", "base RNG seed")
        .opt("seeds", "1", "number of seeds (mean ± std reporting)")
        .opt_maybe("target", "target accuracy for convergence time")
        .opt_maybe("fault-plan", "deterministic fault plan, e.g. frame_corrupt:0.1,clock_stall:0.05")
        .opt_maybe("fault-seed", "seed for the fault plan's hash (default 0)")
        .opt_maybe("fault-quarantine-after", "faulted rounds before a client is quarantined")
        .opt_maybe("out", "write per-round records to this JSONL file")
        .opt_maybe("trace-out", "write a Chrome trace-event JSON (open in Perfetto)")
        .opt_maybe("stats-out", "write the observability counters/histograms JSON")
        .opt_maybe(
            "metrics-addr",
            "serve live stats over HTTP (/metrics Prometheus text, /snapshot JSON)",
        )
}

/// Enable span/metric recording when an observability output was
/// requested (`AFD_TRACE=1` may have enabled it already), and start
/// the live stats endpoint if one was asked for.
fn init_obs(args: &afd::util::cli::Args) -> Result<()> {
    if args.get("trace-out").is_some()
        || args.get("stats-out").is_some()
        || args.get("metrics-addr").is_some()
    {
        afd::obs::set_enabled(true);
    }
    if let Some(addr) = args.get("metrics-addr") {
        let bound = afd::obs::remote::spawn_metrics_server(addr)?;
        println!("[afd] metrics endpoint on http://{bound}/metrics");
    }
    Ok(())
}

/// Write the requested trace/stats files and print the per-stage time
/// breakdown (the table renders only if something was recorded).
fn finish_obs(args: &afd::util::cli::Args) -> Result<()> {
    if let Some(path) = args.get("trace-out") {
        afd::obs::export::write_chrome_trace(std::path::Path::new(path))?;
        println!("  wrote trace to {path}");
    }
    if let Some(path) = args.get("stats-out") {
        afd::obs::export::write_stats(std::path::Path::new(path))?;
        println!("  wrote stats to {path}");
    }
    if let Some(table) = afd::metrics::render_stage_table() {
        println!("{table}");
    }
    Ok(())
}

fn parse_experiment(args: &afd::util::cli::Args) -> Result<ExperimentConfig> {
    let mut cfg =
        ExperimentConfig::preset_by_name(args.get("preset").unwrap_or("femnist_noniid"))
            .map_err(|e| anyhow::anyhow!("{e}"))?;
    if let Some(v) = args.get("rounds") {
        cfg.rounds = v.parse()?;
    }
    if let Some(v) = args.get("clients") {
        cfg.num_clients = v.parse()?;
    }
    if let Some(v) = args.get("fraction") {
        cfg.client_fraction = v.parse()?;
    }
    if let Some(v) = args.get("dropout") {
        cfg.dropout = v.to_string();
    }
    if let Some(v) = args.get("fdr") {
        cfg.fdr = v.parse()?;
    }
    if let Some(v) = args.get("downlink") {
        cfg.downlink = v.to_string();
    }
    if let Some(v) = args.get("dgc") {
        cfg.uplink_dgc = v == "true" || v == "1";
    }
    if let Some(v) = args.get("sched") {
        cfg.sched.policy = v.to_string();
    }
    if let Some(v) = args.get("churn") {
        cfg.sched.enable_churn(v.parse()?)?;
    }
    if let Some(v) = args.get("shards") {
        cfg.sharding.shard_count = v.parse()?;
    }
    if let Some(v) = args.get("agg-tree-levels") {
        cfg.sharding.tree_levels = v.parse()?;
    }
    if let Some(v) = args.get("agg-tree-fanout") {
        cfg.sharding.tree_fanout = v.parse()?;
    }
    if let Some(v) = args.get("population-lazy") {
        cfg.population.lazy = v == "true" || v == "1";
    }
    if let Some(v) = args.get("store-budget-bytes") {
        cfg.population.store_budget_bytes = v.parse()?;
    }
    if let Some(v) = args.get("spill-dir") {
        cfg.population.spill_dir = v.to_string();
    }
    if let Some(v) = args.get("lr") {
        cfg.lr_override = Some(v.parse()?);
    }
    if let Some(v) = args.get("seed") {
        cfg.seed = v.parse()?;
    }
    if let Some(v) = args.get("target") {
        cfg.target_accuracy = Some(v.parse()?);
    }
    if let Some(v) = args.get("fault-plan") {
        cfg.fault.plan = v.to_string();
    }
    if let Some(v) = args.get("fault-seed") {
        cfg.fault.seed = v.parse()?;
    }
    if let Some(v) = args.get("fault-quarantine-after") {
        cfg.fault.quarantine_after = v.parse()?;
    }
    Ok(cfg)
}

/// Arm the process-wide fault plan when the config carries one.
fn install_faults(cfg: &ExperimentConfig) -> Result<()> {
    if !cfg.fault.plan.is_empty() {
        afd::fault::install(&cfg.fault.plan, cfg.fault.seed, cfg.fault.quarantine_after)?;
        println!(
            "[afd] fault plan armed: {} (seed {})",
            cfg.fault.plan, cfg.fault.seed
        );
    }
    Ok(())
}

fn cmd_train(argv: Vec<String>) -> Result<()> {
    let spec = experiment_spec();
    let args = spec
        .parse("afd train", argv)
        .map_err(|e| anyhow::anyhow!(e))?;
    let base = parse_experiment(&args)?;
    install_faults(&base)?;
    let seeds: usize = args.usize("seeds").map_err(|e| anyhow::anyhow!(e))?;
    init_obs(&args)?;

    let mut reports = Vec::new();
    for s in 0..seeds as u64 {
        let mut cfg = base.clone();
        cfg.seed = base.seed + s;
        println!(
            "[afd] {} variant={} dropout={} rounds={} clients={} (seed {})",
            cfg.method_label(),
            cfg.variant,
            cfg.dropout,
            cfg.rounds,
            cfg.num_clients,
            cfg.seed
        );
        let report = run_experiment(&cfg)?;
        for r in &report.records {
            if let Some(acc) = r.eval_acc {
                println!(
                    "  round {:>4}  t={:>9}  loss {:.4}  acc {:.3}",
                    r.round,
                    afd::util::human_duration(r.cum_s),
                    r.train_loss,
                    acc
                );
            }
        }
        println!(
            "  final acc {:.3}  best {:.3}  sim time {}  down {}  up {}",
            report.final_accuracy(),
            report.best_accuracy(),
            afd::util::human_duration(report.total_sim_seconds()),
            afd::util::human_bytes(report.total_down_bytes()),
            afd::util::human_bytes(report.total_up_bytes()),
        );
        if let Some(path) = args.get("out") {
            let sink = afd::util::logging::JsonlSink::create(std::path::Path::new(path))?;
            for r in &report.records {
                let mut rec = r.to_json();
                rec.set("seed", Json::Num(cfg.seed as f64));
                rec.set("method", Json::Str(cfg.method_label()));
                sink.write(&rec);
            }
            println!("  wrote records to {path}");
        }
        reports.push(report);
    }
    if seeds > 1 {
        let summary = summarize(&base.method_label(), &reports, base.target_accuracy);
        println!(
            "\nmean best accuracy {:.2}% ± {:.2}% over {} seeds",
            summary.accuracy_mean * 100.0,
            summary.accuracy_std * 100.0,
            seeds
        );
    }
    finish_obs(&args)?;
    Ok(())
}

fn cmd_compare(argv: Vec<String>) -> Result<()> {
    let spec = experiment_spec();
    let args = spec
        .parse("afd compare", argv)
        .map_err(|e| anyhow::anyhow!(e))?;
    let base = parse_experiment(&args)?;
    let seeds: usize = args.usize("seeds").map_err(|e| anyhow::anyhow!(e))?;
    let afd_kind = if base.data.iid { "afd_single" } else { "afd_multi" };
    let target = base.target_accuracy;
    init_obs(&args)?;

    let grid = ExperimentConfig::paper_method_grid(&base, afd_kind);
    let mut rows = Vec::new();
    for (label, method_cfg) in &grid {
        let mut reports = Vec::new();
        for s in 0..seeds as u64 {
            let mut cfg = method_cfg.clone();
            cfg.seed = base.seed + s;
            println!("[afd] running {label} (seed {})...", cfg.seed);
            reports.push(run_experiment(&cfg)?);
        }
        rows.push(summarize(label, &reports, target));
    }
    println!(
        "{}",
        render_table(
            &format!(
                "{} ({}) — target {:?}",
                base.variant,
                if base.data.iid { "IID" } else { "non-IID" },
                target
            ),
            &rows
        )
    );
    finish_obs(&args)?;
    Ok(())
}

fn cmd_serve(argv: Vec<String>) -> Result<()> {
    let spec = experiment_spec()
        .opt("addr", "127.0.0.1:4777", "listen address for TCP clients")
        .opt(
            "conns",
            "0",
            "client connections to accept (0 = in-process loopback transport)",
        )
        .opt_maybe(
            "io-timeout-s",
            "seconds before an unanswered round fails its connection",
        )
        .opt_maybe(
            "resume",
            "true|false: replay open rounds to reconnecting clients",
        )
        .opt_maybe(
            "checkpoint",
            "write a coordinator checkpoint to this path at round boundaries",
        )
        .opt(
            "checkpoint-every",
            "1",
            "rounds between checkpoints (with --checkpoint)",
        )
        .opt_maybe(
            "restore",
            "resume a run from this checkpoint (bit-identical continuation)",
        )
        .opt_maybe(
            "crash-after",
            "exit(137) right after checkpointing round N (chaos-test hook)",
        );
    let args = spec
        .parse("afd serve", argv)
        .map_err(|e| anyhow::anyhow!(e))?;
    let mut cfg = parse_experiment(&args)?;
    install_faults(&cfg)?;
    // Before `to_json` below: the clients take their socket timeouts
    // from the shipped config.
    if let Some(v) = args.get("io-timeout-s") {
        cfg.transport.io_timeout_s = v.parse()?;
    }
    if let Some(v) = args.get("resume") {
        cfg.transport.resume = v == "true" || v == "1";
    }
    let conns: usize = args.usize("conns").map_err(|e| anyhow::anyhow!(e))?;
    init_obs(&args)?;
    let mut tcp_handle: Option<Arc<TcpTransport>> = None;
    let transport: Arc<dyn Transport> = if conns == 0 {
        Arc::new(Loopback::default())
    } else {
        anyhow::ensure!(
            cfg.backend == Backend::Native,
            "TCP clients rebuild the model from the shipped config and support \
             the native backend only; run PJRT in-process (--conns 0)"
        );
        let (_, model_spec) = afd::runtime::native::mlp_from_config(&cfg);
        let server = TcpServer::bind(args.get("addr").unwrap())?;
        println!(
            "[afd] serving on {} — waiting for {conns} client process(es)...",
            server.local_addr()?
        );
        let t = Arc::new(server.accept_clients(
            conns,
            &cfg.to_json().to_string_compact(),
            model_spec.layout_fingerprint(),
            &cfg.transport,
        )?);
        println!("[afd] {conns} client process(es) connected");
        tcp_handle = Some(Arc::clone(&t));
        t
    };
    println!(
        "[afd] {} over {} transport: rounds={} clients={} (seed {})",
        cfg.method_label(),
        transport.name(),
        cfg.rounds,
        cfg.num_clients,
        cfg.seed
    );
    let mut exp = Experiment::build_with_transport(&cfg, Arc::clone(&transport))?;
    let ckpt_path = args.get("checkpoint").map(std::path::PathBuf::from);
    let ckpt_every: usize = args.usize("checkpoint-every").map_err(|e| anyhow::anyhow!(e))?;
    anyhow::ensure!(ckpt_every >= 1, "--checkpoint-every must be >= 1");
    let crash_after: Option<usize> = match args.get("crash-after") {
        Some(v) => Some(v.parse()?),
        None => None,
    };
    anyhow::ensure!(
        crash_after.is_none() || ckpt_path.is_some(),
        "--crash-after without --checkpoint would lose the run"
    );
    let mut start = 1usize;
    if let Some(p) = args.get("restore") {
        let completed = exp.restore_from_checkpoint(std::path::Path::new(p))?;
        println!("[afd] restored {p}: {completed} round(s) already complete");
        // Re-attached clients carry fleet state from the previous
        // coordinator process; force a StateSync ahead of their first
        // dispatch so they rejoin the restored run bit-exactly.
        if let Some(t) = &tcp_handle {
            t.mark_recovered();
        }
        start = completed as usize + 1;
    }
    for round in start..=cfg.rounds {
        let rec = exp.step(round)?;
        if let Some(acc) = rec.eval_acc {
            println!(
                "  round {:>4}  t={:>9}  loss {:.4}  acc {:.3}",
                rec.round,
                afd::util::human_duration(rec.cum_s),
                rec.train_loss,
                acc
            );
        }
        if let Some(path) = &ckpt_path {
            if round % ckpt_every == 0 || round == cfg.rounds {
                exp.save_checkpoint(path, round as u64)?;
            }
        }
        if crash_after == Some(round) {
            // Simulated coordinator crash: no Bye, no shutdown, no
            // flushing — the checkpoint above is all a successor gets.
            println!("[afd] --crash-after {round}: exiting without shutdown");
            std::process::exit(137);
        }
    }
    let report = ExperimentReport {
        method: cfg.method_label(),
        variant: cfg.variant.clone(),
        seed: cfg.seed,
        records: exp.records().to_vec(),
        converged: None,
    };
    println!(
        "  final acc {:.3}  sim time {}  down {} wire / {} payload  \
         up {} wire / {} payload  framing {:.2}%",
        report.final_accuracy(),
        afd::util::human_duration(report.total_sim_seconds()),
        afd::util::human_bytes(report.total_down_bytes()),
        afd::util::human_bytes(report.total_down_payload_bytes()),
        afd::util::human_bytes(report.total_up_bytes()),
        afd::util::human_bytes(report.total_up_payload_bytes()),
        report.framing_overhead_fraction() * 100.0,
    );
    // The bit-identity handle: a TCP run and a loopback run of the
    // same seed must print the same hash (CI's socket smoke greps it).
    println!("model_hash={:016x}", afd::util::model_hash(&exp.global));
    if let Some(path) = args.get("out") {
        let sink = afd::util::logging::JsonlSink::create(std::path::Path::new(path))?;
        for r in &report.records {
            sink.write(&r.to_json());
        }
        println!("  wrote records to {path}");
    }
    transport.shutdown()?;
    finish_obs(&args)?;
    Ok(())
}

fn cmd_client(argv: Vec<String>) -> Result<()> {
    let spec = ArgSpec::new("Join an `afd serve` coordinator as a remote client process")
        .opt("connect", "127.0.0.1:4777", "coordinator address")
        .opt("retry-s", "30", "seconds to keep retrying the initial connect")
        .opt(
            "reconnect-s",
            "30",
            "seconds to keep redialing after a dropped connection (0 = give up)",
        )
        .opt_maybe(
            "exit-after",
            "exit abruptly after serving N rounds (churn-test crash hook)",
        );
    let args = spec
        .parse("afd client", argv)
        .map_err(|e| anyhow::anyhow!(e))?;
    let addr = args.get("connect").unwrap();
    let opts = ClientOptions {
        connect_retry_s: args.f64("retry-s").map_err(|e| anyhow::anyhow!(e))?,
        reconnect_s: args.f64("reconnect-s").map_err(|e| anyhow::anyhow!(e))?,
        exit_after: match args.get("exit-after") {
            Some(v) => Some(v.parse()?),
            None => None,
        },
    };
    println!("[afd] joining coordinator at {addr}");
    match run_client_loop(addr, &opts)? {
        ClientEnd::Bye => println!("[afd] coordinator said Bye — exiting"),
        ClientEnd::ExitAfter => println!("[afd] --exit-after reached — exiting"),
    }
    Ok(())
}

fn cmd_inspect() -> Result<()> {
    let dir = artifacts_dir();
    let manifest = afd::model::manifest::Manifest::load(&dir)?;
    println!("artifacts: {}", dir.display());
    for (name, spec) in &manifest.variants {
        println!(
            "\n{name}: kind={} dataset={} params={} ({} transmissible)",
            spec.kind,
            spec.dataset,
            spec.num_params,
            afd::util::human_bytes(spec.transmit_bytes_full()),
        );
        println!(
            "  lr={} batch={}x{} classes={} input={:?} ({:?})",
            spec.lr,
            spec.num_batches,
            spec.batch_size,
            spec.classes,
            spec.input_shape,
            spec.input_dtype
        );
        for g in &spec.mask_groups {
            println!("  mask group {:<10} {:>5} units ({})", g.name, g.size, g.kind);
        }
        for p in &spec.params {
            println!(
                "  param {:<12} shape {:?} {}{}",
                p.name,
                p.shape,
                if p.trainable { "" } else { "[frozen] " },
                if p.transmit { "" } else { "[not transmitted]" },
            );
        }
    }
    if let Some(k) = &manifest.kernels {
        println!(
            "\nkernel artifacts: masked_dense {:?}, hadamard block {}",
            k.masked_dense_dims, k.hadamard_block
        );
    }
    Ok(())
}

fn cmd_selftest() -> Result<()> {
    use afd::config::Preset;
    println!("[afd] native end-to-end selftest (no artifacts needed)");
    let mut cfg = ExperimentConfig::preset(Preset::NativeSmoke);
    cfg.rounds = 30;
    cfg.eval_every = 5;
    let report = run_experiment(&cfg)?;
    let best = report.best_accuracy();
    println!(
        "native MLP federated run: best acc {:.3}, sim time {}",
        best,
        afd::util::human_duration(report.total_sim_seconds())
    );
    anyhow::ensure!(best > 0.5, "selftest should learn (best={best})");
    println!("selftest OK");
    Ok(())
}
